"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81 layer slots: 13 groups of (5 Mamba2 + 1 shared transformer block) + 3
tail Mamba2 layers = 68 Mamba2 + 13 invocations of ONE shared attn+MLP block
(weights shared, per-site KV cache).  d_model=3584, attn 32H (kv=32),
d_ff=14336, ssm_state=64, expand=2 (d_inner=7168, 112 ssm heads of dim 64).
"""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2_7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(kind="mamba2", d_state=64, expand=2, head_dim=64, d_conv=4),
    hybrid=HybridConfig(n_groups=13, ssm_per_group=5, tail_ssm_layers=3),
    mlp_type="swiglu",
    tie_embeddings=False,
)
