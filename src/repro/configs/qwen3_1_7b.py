"""qwen3-1.7b [dense] — qk_norm + GQA [hf:Qwen/Qwen3-1.7B (family per
Qwen/Qwen3-8B card); hf].

28L, d_model=2048, 16H (kv=8), head_dim=128, d_ff=6144, vocab=151936.
Per-head RMSNorm on q and k before RoPE (qk_norm), rope_theta=1e6.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_1_7b",
    family="decoder",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    mlp_type="swiglu",
    tie_embeddings=True,
)
