"""pixtral-12b [vlm] — pixtral-ViT frontend + mistral-nemo-like decoder
[hf:mistralai/Pixtral-12B-2409; unverified].

Decoder backbone: 40L, d_model=5120, 32H (kv=8), head_dim=128, d_ff=14336,
vocab=131072, rope_theta=1e9 (nemo long-rope convention).  The ViT frontend
is a STUB per the assignment: ``input_specs()`` supplies precomputed patch
embeddings prepended to the text sequence.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral_12b",
    family="decoder",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e9,
    mlp_type="swiglu",
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=1024,
)
