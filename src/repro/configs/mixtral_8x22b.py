"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].

56L, d_model=6144, 48H (kv=8), head_dim=128, d_ff=16384 per expert,
vocab=32768, sliding window 4096 (per assignment) => runs long_500k with a
windowed KV cache.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    family="decoder",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    attn_type="swa",
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384, renormalize=True),
    mlp_type="swiglu",
    tie_embeddings=False,
)
