"""rwkv6-3b [ssm] — "Finch", attention-free with data-dependent decay
[arXiv:2404.05892; hf].

32L, d_model=2560, d_ff=8960, vocab=65536, head_dim=64 (40 wkv heads).
State is O(1) in sequence length => runs the long_500k cell.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6_3b",
    family="rwkv6",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64, lora_rank=64),
    tie_embeddings=False,
)
