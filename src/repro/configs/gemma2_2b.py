"""gemma2-2b [dense] — local+global alternating attention, logit softcap
[arXiv:2408.00118; hf].

26L, d_model=2304, 8H (kv=4), head_dim=256, d_ff=9216, vocab=256000.
GeGLU MLP, RMSNorm(1+w) with post-block norms, attn softcap 50, logit
softcap 30, sliding window 4096 on even (local) layers.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2_2b",
    family="decoder",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_type="local_global",
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    mlp_type="geglu",
    norm_plus_one=True,
    post_block_norm=True,
    embed_scale_sqrt_dim=True,
    tie_embeddings=True,
)
