"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + shared expert
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L, d_model=2048, 16H (kv=16), vocab=151936, moe_intermediate=1408,
shared_expert_intermediate=5632 (the "4 shared"), norm_topk_prob=False.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2_moe_a2_7b",
    family="decoder",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared_experts=4,
        d_ff_shared=5632,
        renormalize=False,
    ),
    mlp_type="swiglu",
    tie_embeddings=True,
)
