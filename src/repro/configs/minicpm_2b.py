"""minicpm-2b [dense] — llama-like, trained with WSD schedule
[arXiv:2404.06395; hf].

40L, d_model=2304, 36H (kv=36), d_ff=5760, vocab=122753.  The WSD
(warmup-stable-decay) schedule lives in repro.optim.schedule.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm_2b",
    family="decoder",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    mlp_type="swiglu",
    tie_embeddings=True,
)
