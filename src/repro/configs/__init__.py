"""Architecture registry: the 10 assigned configs + the paper's own examples.

Each ``<arch>.py`` exports ``CONFIG`` with the exact published dimensions
([source; verified-tier] in its docstring).  ``get_config(name)`` resolves
hyphen or underscore ids; ``get_config(name, reduced=True)`` returns the
CPU smoke-test reduction.
"""
from __future__ import annotations

import importlib

from .base import SHAPES, SHAPE_BY_NAME, ModelConfig, MoEConfig, ShapeConfig, SSMConfig, HybridConfig  # noqa: F401

ARCH_IDS = (
    "seamless_m4t_large_v2",
    "minicpm3_4b",
    "gemma2_2b",
    "minicpm_2b",
    "qwen3_1_7b",
    "rwkv6_3b",
    "zamba2_7b",
    "pixtral_12b",
    "qwen2_moe_a2_7b",
    "mixtral_8x22b",
)


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    cid = canon(name)
    if cid not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f".{cid}", __name__)
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs(reduced: bool = False):
    return {cid: get_config(cid, reduced=reduced) for cid in ARCH_IDS}
