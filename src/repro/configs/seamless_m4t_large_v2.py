"""seamless-m4t-large-v2 [audio] — enc-dec multimodal [arXiv:2308.11596; hf].

24L (24 enc + 24 dec), d_model=1024, 16H (kv=16), d_ff=8192, vocab=256206.
The speech frontend (w2v-BERT conformer) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, S_src, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_large_v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    mlp_type="gelu",
    attn_type="full",
    tie_embeddings=True,
    frontend="audio",
)
