"""minicpm3-4b [dense] — MLA attention [hf:openbmb/MiniCPM3-4B; hf].

62L, d_model=2560, 40H (kv=40), d_ff=6400, vocab=73448.  Multi-head Latent
Attention: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64 — the
compressed latent is the KV cache (int8-quantizable via the paper's scheme).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3_4b",
    family="decoder",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    mlp_type="swiglu",
    tie_embeddings=True,
)
