"""Model/run configuration dataclasses.

One :class:`ModelConfig` describes any of the 10 assigned architectures
(dense / MoE / SSM / hybrid / enc-dec / VLM-audio-stub); family-specific
sections are optional sub-configs.  ``reduced()`` derives the CPU smoke-test
configs; full configs are exercised via the dry-run only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001
    renormalize: bool = True  # mixtral renormalizes top-k probs; qwen2-moe not


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str  # "rwkv6" | "mamba2"
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4  # mamba2 conv width
    dt_rank: int = 0
    lora_rank: int = 64  # rwkv6 data-dependent-decay LoRA rank


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: groups of SSM layers with a shared transformer block
    interleaved (shared weights, per-site KV cache)."""

    n_groups: int
    ssm_per_group: int
    tail_ssm_layers: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "decoder" | "encdec" | "rwkv6" | "hybrid"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention flavor
    attn_type: str = "full"  # "full" | "swa" | "local_global" | "mla"
    window: Optional[int] = None
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # MLP
    mlp_type: str = "swiglu"  # "swiglu" | "geglu" | "gelu"
    norm_eps: float = 1e-6
    norm_plus_one: bool = False  # gemma-style (1 + w)
    post_block_norm: bool = False  # gemma2 post-norms
    embed_scale_sqrt_dim: bool = False
    tie_embeddings: bool = True
    # families
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # enc-dec
    n_encoder_layers: int = 0
    # modality frontend stub: "audio" | "vision" | None
    frontend: Optional[str] = None
    frontend_tokens: int = 0  # patches/frames prepended to the text sequence
    # scan/remat
    scan_layers: bool = True
    remat_policy: str = "nothing_saveable"  # "nothing_saveable"|"dots"|"none"
    # quantized serving (the paper's technique at scale)
    kv_cache_dtype: str = "bf16"  # "bf16" | "int8"
    w8a8_serving: bool = False

    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        cuts = dict(
            n_layers=min(self.n_layers, 2 if self.hybrid is None else self.n_layers),
            d_model=256,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads)) if self.n_kv_heads < self.n_heads else max(2, min(4, self.n_heads)),
            d_ff=512,
            vocab_size=512,
            head_dim=64 if self.head_dim else None,
            frontend_tokens=8 if self.frontend else 0,
            window=min(self.window, 64) if self.window else None,
            n_encoder_layers=min(self.n_encoder_layers, 2),
        )
        if self.q_lora_rank:
            cuts.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(moe, n_experts=min(8, moe.n_experts), top_k=min(2, moe.top_k),
                                      d_ff_expert=128, d_ff_shared=256 if moe.n_shared_experts else 0)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, d_state=16, head_dim=32, lora_rank=16)
        hybrid = self.hybrid
        if hybrid is not None:
            hybrid = dataclasses.replace(hybrid, n_groups=2, ssm_per_group=2, tail_ssm_layers=1)
            cuts["n_layers"] = 2 * 2 + 2 + 1  # groups*(ssm+shared) + tail
        return dataclasses.replace(self, moe=moe, ssm=ssm, hybrid=hybrid, **cuts)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    microbatches: int = 1  # gradient-accumulation steps (train only)


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256, microbatches=8),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}
