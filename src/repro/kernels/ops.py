"""Public jit'd wrappers around the Pallas kernels.

Handle here (so kernels stay tile-pure):
  * arbitrary leading batch dims (flattened into M),
  * shape padding to tile multiples (zero padding is exact for int matmul),
  * uint8 activations — folded to int8 by the compiler identity
        x_u8 @ W = (x_s8 + 128) @ W = x_s8 @ W + 128·colsum(W)
    i.e. a bias correction computed once at compile time, keeping the MXU on
    its signed-int8 fast path (a HW/SW co-design move the artifact's
    *expressiveness* makes possible: the compiler sees the true dtypes),
  * scalar vs per-channel rescale broadcasting,
  * backend dispatch: pallas (TPU) / pallas-interpret (CPU validation) /
    pure-jnp reference (dry-run lowering).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import pack as _pack
from . import qact_lut as _qact
from . import qmatmul as _qmm
from . import ref as _ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def fold_uint8_input(w_q: jax.Array, bias_q: Optional[jax.Array]):
    """Return the bias correction that converts a uint8-activation matmul into
    a signed-int8 one: bias' = bias + 128 * sum_k W[k, :]."""
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    corr = 128 * colsum
    return corr if bias_q is None else bias_q.astype(jnp.int32) + corr


# ---------------------------------------------------------------------------
# plan-time shape specialization (repro.backend lowering)
# ---------------------------------------------------------------------------


def template_qmatmul_params(
    w_q: np.ndarray,  # (K, N) int8 (unpacked; values in [-8, 7] when weight_bits=4)
    bias_q: Optional[np.ndarray],  # (N,) int32
    quant_scale: np.ndarray,  # scalar or (N,) f32
    quant_shift: np.ndarray,  # scalar or (N,) f32
    *,
    weight_bits: int = 8,
):
    """The batch-*independent* half of qmatmul shape specialization.

    Everything here is a property of the weights alone: the K/N tile choice,
    and the parameter pre-padding to tile multiples (kp = K and N rounded up
    to bk/bn).  None of it depends on the batch, so a batch-polymorphic plan
    template builds — and pays for — it exactly once, and every per-bucket
    specialization *shares* these padded arrays (binding a bucket copies no
    parameter data, see :func:`bind_qmatmul_batch`).

    Returns ``(consts, shape)``: ``consts = (w2, b2, qs2, qsh2)`` jnp arrays
    already shaped ``(kp, np)/(1, np)`` for the kernel, and ``shape`` the
    batch-open record ``{k, n, kp, np, bk, bn}`` (no ``m``/``bm`` yet).
    Zero padding is exact for integer matmul; scale/shift pad with 1.0 so the
    padded epilogue stays finite.

    ``weight_bits=4`` packs the padded weight 2-per-byte along K *here, once
    per template* (kp is always even — bk is a 128-multiple): ``w2`` becomes
    a uint8 ``(kp // 2, np)`` nibble array and the shape record carries
    ``bits: 4``; backends dispatch on it (the ref backend keeps the unpacked
    consts as the oracle — see ``repro.backend.fused``)."""
    if weight_bits not in (4, 8):
        raise ValueError(f"unsupported weight_bits: {weight_bits!r}")
    k, n = int(w_q.shape[0]), int(w_q.shape[1])
    _, bk, bn = _qmm.choose_tiles(None, k, n)
    kp, np_ = _round_up(k, bk), _round_up(n, bn)
    w2 = np.zeros((kp, np_), np.int8)
    w2[:k, :n] = np.asarray(w_q, np.int8)
    if weight_bits == 4:
        w2 = _pack.pack_int4(w2)  # (kp // 2, np) uint8, zero rows pack to 0x00
    b2 = np.zeros((1, np_), np.int32)
    if bias_q is not None:
        b2[0, :n] = np.asarray(bias_q, np.int32).reshape(-1)
    qs2 = np.ones((1, np_), np.float32)
    qs2[0, :n] = np.broadcast_to(np.asarray(quant_scale, np.float32).reshape(1, -1), (1, n))
    qsh2 = np.ones((1, np_), np.float32)
    qsh2[0, :n] = np.broadcast_to(np.asarray(quant_shift, np.float32).reshape(1, -1), (1, n))
    consts = (jnp.asarray(w2), jnp.asarray(b2), jnp.asarray(qs2), jnp.asarray(qsh2))
    shape = {"k": k, "n": n, "kp": kp, "np": np_, "bk": bk, "bn": bn}
    if weight_bits != 8:
        shape["bits"] = weight_bits  # omitted at 8: int8 records stay byte-identical
    return consts, shape


def bind_qmatmul_axes(shape: dict, bindings: Optional[dict], *, partial: bool = False) -> dict:
    """The scenario-*dependent* half: close a template shape record over
    concrete per-axis buckets.

    ``shape["lead"]`` is the activation's leading dims as inferred at
    template-build time: concrete ints, named symbolic axes (strings such as
    ``"N"``/``"S"``), ``None`` in the leading position for the legacy
    implicit batch, and the whole tuple ``None`` when inference knew nothing
    — M then stays unknown and the default bm stands.  The flat matmul M is
    the product of the lead dims with ``bindings`` substituted per axis name
    (an unnamed leading ``None`` binds to the batch axis ``"N"``, matching
    :func:`repro.passes.analysis.bind`).  Only ``m`` and the bm tile choice
    are computed here — the padded parameter arrays and K/N tiles come from
    the template unchanged, so a bucket specialization is O(1) (no
    re-lowering, no array copies).

    ``partial=True`` substitutes the given axes into ``lead`` but keeps the
    record *open* (no m/bm yet) — used when a template is specialized over a
    subset of its axes and must stay a template for the rest."""
    bindings = bindings or {}
    lead = shape.get("lead")
    if partial:
        if lead is None:
            return dict(shape)
        new_lead = []
        for i, d in enumerate(lead):
            if isinstance(d, str) and d in bindings:
                d = int(bindings[d])
            elif d is None and i == 0 and "N" in bindings:
                d = int(bindings["N"])
            new_lead.append(d)
        out = dict(shape)
        out["lead"] = tuple(new_lead)
        return out
    if lead is None:
        m: Optional[int] = None  # inference knew nothing: keep the default bm
    else:
        m = 1
        for i, d in enumerate(lead):
            if isinstance(d, str):
                d = bindings.get(d)
            elif d is None and i == 0:
                d = bindings.get("N")  # legacy implicit batch
            if not isinstance(d, int):
                m = None  # still-unknown dim: fall back to the default bm
                break
            m *= int(d)
    bound = {key: v for key, v in shape.items() if key != "lead"}
    bound["m"] = m
    bound["bm"] = _qmm.choose_bm(m)
    return bound


def with_tiles(
    shape: dict,
    *,
    bm: Optional[int] = None,
    bk: Optional[int] = None,
    bn: Optional[int] = None,
) -> dict:
    """A copy of a *bound* qmatmul shape record with tile overrides — the
    autotuner's way of re-tiling a cell without touching the template.

    Overrides are validated against the kernel's alignment constraints
    (:func:`repro.kernels.qmatmul.tile_aligned`), and ``bk``/``bn`` must
    additionally *divide* the template's padded ``kp``/``np`` — the padded
    parameter arrays were built once at template time and every tuned
    specialization shares them zero-copy, so a tile that would change the
    padding is not a legal candidate.  ``bm`` is free (any 32-multiple): the
    activation is padded per call, not baked into the template."""
    out = dict(shape)
    if bm is not None:
        if bm <= 0 or bm % _qmm.MIN_SUBLANE:
            raise ValueError(f"bm={bm} is not a positive {_qmm.MIN_SUBLANE}-multiple")
        out["bm"] = int(bm)
    if bk is not None:
        if bk <= 0 or bk % _qmm.MIN_LANE:
            raise ValueError(f"bk={bk} is not a positive {_qmm.MIN_LANE}-multiple")
        if shape["kp"] % bk:
            raise ValueError(
                f"bk={bk} does not divide the template's padded kp={shape['kp']} "
                "(tuned tiles must reuse the pre-padded parameter arrays)"
            )
        out["bk"] = int(bk)
    if bn is not None:
        if bn <= 0 or bn % _qmm.MIN_LANE:
            raise ValueError(f"bn={bn} is not a positive {_qmm.MIN_LANE}-multiple")
        if shape["np"] % bn:
            raise ValueError(
                f"bn={bn} does not divide the template's padded np={shape['np']} "
                "(tuned tiles must reuse the pre-padded parameter arrays)"
            )
        out["bn"] = int(bn)
    return out


def bind_qmatmul_batch(shape: dict, batch: Optional[int]) -> dict:
    """Single-axis sugar over :func:`bind_qmatmul_axes` (the PR 4 calling
    convention): bind the implicit batch axis only."""
    return bind_qmatmul_axes(shape, {} if batch is None else {"N": int(batch)})


def _bind_dim(d, bindings: dict):
    """One dim of an attention shape record: named axes substitute from
    ``bindings``, ints pass through, still-symbolic names stay as-is."""
    if isinstance(d, str) and d in bindings:
        return int(bindings[d])
    return d


def bind_qattention_axes(shape: dict, bindings: Optional[dict], *, partial: bool = False) -> dict:
    """Close a fused-attention template shape record over concrete buckets.

    The template record is ``{"b": lead-dims, "s": S, "t": T, "dh": int}``
    where ``b`` is the stacked batch×heads leading dims tuple and any entry
    (or ``s``/``t``) may be a named symbolic axis (``"N"``, ``"S"``).  A full
    bind substitutes the bindings, flattens ``b`` to its product, and picks
    the query row-tile ``bq`` via :func:`repro.kernels.qattention.choose_bq`
    (the autotuner may override it afterwards).  ``partial=True`` substitutes
    the given axes but keeps the record open — the step then stays a
    template for the remaining axes."""
    from . import qattention as _qatt

    bindings = bindings or {}
    out = dict(shape)
    lead = tuple(_bind_dim(d, bindings) for d in shape.get("b", ()))
    out["s"] = _bind_dim(shape.get("s"), bindings)
    out["t"] = _bind_dim(shape.get("t"), bindings)
    if partial:
        out["b"] = lead
        return out
    b = 1
    for d in lead:
        if not isinstance(d, int):
            raise ValueError(f"unbound attention batch dim {d!r} in {shape!r}")
        b *= int(d)
    if not isinstance(out["s"], int) or not isinstance(out["t"], int):
        raise ValueError(f"unbound attention seq dims in {out!r}")
    out["b"] = b
    out.setdefault("bq", _qatt.choose_bq(out["s"]))
    return out


def specialize_qmatmul_params(
    w_q: np.ndarray,  # (K, N) int8
    bias_q: Optional[np.ndarray],  # (N,) int32
    quant_scale: np.ndarray,  # scalar or (N,) f32
    quant_shift: np.ndarray,  # scalar or (N,) f32
    *,
    m: Optional[int] = None,  # static M if known, else None (dynamic batch)
    weight_bits: int = 8,
):
    """Fully-static specialization (the ``batch="static"`` compile path):
    template + immediate batch binding in one step.  Returns the same
    ``(consts, params)`` contract as before the template split — ``params``
    is the closed record ``{m, k, n, kp, np, bm, bk, bn}``."""
    consts, shape = template_qmatmul_params(
        w_q, bias_q, quant_scale, quant_shift, weight_bits=weight_bits
    )
    params = bind_qmatmul_batch({**shape, "lead": (m,)}, None)
    return consts, params


def quantized_matmul_planned(
    x_q: jax.Array,  # (..., K) int8 (uint8 already folded at plan time)
    w2: jax.Array,  # (kp, np) int8 — pre-padded
    b2: jax.Array,  # (1, np) int32 — pre-padded
    qs2: jax.Array,  # (1, np) f32 — pre-padded
    qsh2: jax.Array,  # (1, np) f32 — pre-padded
    shape: dict,  # the params record from specialize_qmatmul_params
    *,
    out_dtype=jnp.int8,
    relu: bool = False,
    two_mul: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Shape-specialized fused matmul: parameters arrive pre-padded, so the
    per-call work is at most an activation pad (skipped entirely when the
    traced shape is already a tile multiple).

    ``shape["bits"] == 4`` selects the packed-int4 kernel: ``w2`` is then the
    uint8 ``(kp // 2, np)`` nibble array the template packed once."""
    k, n, kp = shape["k"], shape["n"], shape["kp"]
    bm, bk, bn = shape["bm"], shape["bk"], shape["bn"]
    bits = shape.get("bits", 8)
    orig_shape = x_q.shape
    assert orig_shape[-1] == k, (orig_shape, k)
    x2 = x_q.reshape(-1, k)
    m = x2.shape[0]
    mp = _round_up(max(m, 1), bm)
    if mp != m or kp != k:
        x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    if bits == 4:
        assert w2.dtype == jnp.uint8 and w2.shape[0] * 2 == kp, (w2.dtype, w2.shape, kp)
        out = _qmm.qmatmul_packed(
            x2, w2, b2, qs2, qsh2,
            out_dtype=out_dtype, relu=relu, two_mul=two_mul,
            bm=bm, bk=bk, bn=bn, interpret=interpret,
        )
    else:
        out = _qmm.qmatmul(
            x2, w2, b2, qs2, qsh2,
            out_dtype=out_dtype, relu=relu, two_mul=two_mul,
            bm=bm, bk=bk, bn=bn, interpret=interpret,
        )
    return out[:m, :n].reshape(orig_shape[:-1] + (n,))


def quantized_matmul(
    x_q: jax.Array,  # (..., K) int8 or uint8
    w_q: jax.Array,  # (K, N) int8
    bias_q: Optional[jax.Array],  # (N,) int32
    quant_scale,  # python float/int, or (N,) array — integer values as FLOAT
    quant_shift,  # python float, or (N,) array — 2**-N
    *,
    out_dtype=jnp.int8,
    relu: bool = False,
    two_mul: bool = True,
    backend: str = "ref",  # "pallas" | "interpret" | "ref"
    bm: int = _qmm.BM,
    bk: int = _qmm.BK,
    bn: int = _qmm.BN,
) -> jax.Array:
    """Fused pre-quantized matmul over arbitrary leading dims."""
    orig_shape = x_q.shape
    k, n = w_q.shape
    assert orig_shape[-1] == k, (orig_shape, w_q.shape)

    if x_q.dtype == jnp.uint8:
        bias_q = fold_uint8_input(w_q, bias_q)
        x_q = (x_q.astype(jnp.int32) - 128).astype(jnp.int8)

    qs = jnp.asarray(quant_scale, jnp.float32)
    qsh = jnp.asarray(quant_shift, jnp.float32)

    if backend == "ref":
        return _ref.qmatmul_ref(
            x_q, w_q, bias_q, qs, qsh, out_dtype=out_dtype, relu=relu, two_mul=two_mul
        ).reshape(orig_shape[:-1] + (n,))

    x2 = x_q.reshape(-1, k)
    m = x2.shape[0]
    mp, kp, np_ = _round_up(max(m, 1), bm), _round_up(k, bk), _round_up(n, bn)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    w2 = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
    b2 = jnp.zeros((1, np_), jnp.int32) if bias_q is None else jnp.pad(
        bias_q.reshape(1, n).astype(jnp.int32), ((0, 0), (0, np_ - n))
    )
    qs2 = jnp.pad(jnp.broadcast_to(qs.reshape(1, -1), (1, n)), ((0, 0), (0, np_ - n)), constant_values=1.0)
    qsh2 = jnp.pad(jnp.broadcast_to(qsh.reshape(1, -1), (1, n)), ((0, 0), (0, np_ - n)), constant_values=1.0)
    out = _qmm.qmatmul(
        x2, w2, b2, qs2, qsh2,
        out_dtype=out_dtype, relu=relu, two_mul=two_mul,
        bm=bm, bk=bk, bn=bn, interpret=(backend == "interpret"),
    )
    return out[:m, :n].reshape(orig_shape[:-1] + (n,))


def quantized_activation(
    x_q: jax.Array,  # (...,) int8
    lut: jax.Array | np.ndarray,  # (256,) int8/uint8
    *,
    backend: str = "ref",
    one_hot: bool = False,
) -> jax.Array:
    """int8 LUT activation over arbitrary shape."""
    lut = jnp.asarray(lut)
    if backend == "ref":
        return _ref.qact_lut_ref(x_q, lut)
    orig_shape = x_q.shape
    n = orig_shape[-1]
    x2 = x_q.reshape(-1, n)
    m = x2.shape[0]
    bm = min(512, m) if m % min(512, m) == 0 else m
    out = _qact.qact_lut(x2, lut, block=bm, one_hot=one_hot, interpret=(backend == "interpret"))
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("out_dtype", "relu", "two_mul", "strides", "pads"))
def quantized_conv2d(
    x_q: jax.Array,  # (N, C, H, W) int8/uint8
    w_q: jax.Array,  # (M, C, kH, kW) int8
    bias_q: Optional[jax.Array],  # (M,) int32
    quant_scale,
    quant_shift,
    *,
    strides=(1, 1),
    pads=(0, 0, 0, 0),
    out_dtype=jnp.int8,
    relu: bool = False,
    two_mul: bool = True,
) -> jax.Array:
    """ConvInteger + epilogue.  Lowers to XLA's int8 conv (which maps onto the
    MXU via implicit im2col on TPU); the epilogue matches the artifact chain
    bit-for-bit.  Symmetric quantization ⇒ zero padding is exact."""
    if x_q.dtype == jnp.uint8:
        # Same signed-offset fold as matmul: correction = 128 * sum over C,kh,kw.
        corr = 128 * jnp.sum(w_q.astype(jnp.int32), axis=(1, 2, 3))
        bias_q = corr if bias_q is None else bias_q.astype(jnp.int32) + corr
        x_q = (x_q.astype(jnp.int32) - 128).astype(jnp.int8)
    acc = jax.lax.conv_general_dilated(
        x_q.astype(jnp.int8),
        w_q.astype(jnp.int8),
        window_strides=tuple(strides),
        padding=((pads[0], pads[2]), (pads[1], pads[3])),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.int32,
    )
    if bias_q is not None:
        acc = acc + bias_q.reshape(1, -1, 1, 1).astype(jnp.int32)
    f = acc.astype(jnp.float32)
    qs = jnp.asarray(quant_scale, jnp.float32)
    qsh = jnp.asarray(quant_shift, jnp.float32)
    f = f * (qs.reshape(1, -1, 1, 1) if qs.ndim else qs)
    if two_mul:
        f = f * (qsh.reshape(1, -1, 1, 1) if qsh.ndim else qsh)
    if relu:
        f = jnp.maximum(f, 0.0)
    r = jnp.rint(f)
    info = jnp.iinfo(out_dtype)
    return jnp.clip(r, info.min, info.max).astype(out_dtype)
