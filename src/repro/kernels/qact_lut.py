"""int8 activation as an exact 256-entry VMEM lookup table (Pallas TPU kernel).

TPU-native adaptation of the paper's §6 activation flows (DESIGN.md §3): the
artifact codifies ``QuantizeLinear → DequantizeLinear → [Cast f16] →
Tanh/Sigmoid → [Cast f32] → QuantizeLinear``.  Because the chain's input is
int8, it is a pure function of 256 possible values — the compiler evaluates
the chain once with *reference-runtime semantics* (including the fp16 casts of
Figs 5/6) into a 256-entry table, making the kernel bit-exact against the
reference interpreter by construction while eliminating all transcendental
work on-chip.

The table lives permanently in VMEM (256 B); the lookup is a VPU gather
(``jnp.take``).  On hardware generations where Mosaic lacks a fast dynamic
gather, set ``one_hot=True`` to lower the lookup as an int8 one-hot matmul on
the MXU (`one_hot(idx)·lut`), which is mathematically identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def build_lut(fn, in_scale: float, out_scale: float, out_dtype: str = "int8", compute_dtype: str = "float32") -> np.ndarray:
    """Evaluate DQL→[cast]→fn→[cast]→QL over all 256 int8 codes with numpy
    reference semantics.  ``fn`` maps a float array to a float array."""
    codes = np.arange(-128, 128, dtype=np.int32)
    x = codes.astype(np.float32) * np.float32(in_scale)
    if compute_dtype == "float16":
        y = fn(x.astype(np.float16)).astype(np.float16).astype(np.float32)
    else:
        y = fn(x.astype(np.float32)).astype(np.float32)
    q = np.rint(y / np.float32(out_scale))
    info = np.iinfo(out_dtype)
    return np.clip(q, info.min, info.max).astype(out_dtype)


def _lut_kernel(x_ref, lut_ref, o_ref, *, one_hot: bool):
    idx = x_ref[...].astype(jnp.int32) + 128
    if one_hot:
        # MXU path: one-hot int8 matmul against the 256-entry table.
        oh = (idx[..., None] == jax.lax.iota(jnp.int32, 256)).astype(jnp.int8)
        flat = oh.reshape(-1, 256)
        vals = jax.lax.dot_general(
            flat, lut_ref[...].astype(jnp.int8).reshape(256, 1),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32,
        )
        o_ref[...] = vals.reshape(idx.shape).astype(o_ref.dtype)
    else:
        o_ref[...] = jnp.take(lut_ref[...], idx).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "one_hot", "interpret"))
def qact_lut(
    x_q: jax.Array,  # (M, N) int8
    lut: jax.Array,  # (256,) int8/uint8
    *,
    block: int = 512,
    one_hot: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Apply an int8→int8/uint8 LUT activation.  Rows must be a multiple of
    ``block`` or smaller than it (wrapper in ops.py pads)."""
    m, n = x_q.shape
    bm = min(block, m)
    assert m % bm == 0, (m, bm)
    kernel = functools.partial(_lut_kernel, one_hot=one_hot)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), lut.dtype),
        interpret=interpret,
    )(x_q, lut)
