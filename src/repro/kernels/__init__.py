"""Pallas TPU kernels for the pre-quantized compute hot spots.

qmatmul   — fused MatMulInteger + bias + §3.1 integer rescale + requant
qact_lut  — int8 tanh/sigmoid as exact 256-entry VMEM LUT
ops       — jit'd public wrappers (padding, uint8 folding, backend dispatch)
ref       — pure-jnp oracles (bit-exact contract for every kernel)
"""
from . import ops, qact_lut, qmatmul, ref  # noqa: F401
from .ops import quantized_activation, quantized_conv2d, quantized_matmul  # noqa: F401
