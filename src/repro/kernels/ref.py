"""Pure-jnp oracles for the Pallas kernels (and the dry-run lowering path).

These implement the artifact's op chain exactly — int32 accumulation, f32
rescale in codified order, round-half-even, clip — so that
``kernel(interpret=True) == ref == reference_runtime`` bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qmatmul_ref(
    x_q: jax.Array,  # (..., M, K) int8/uint8
    w_q: jax.Array,  # (K, N) int8
    bias_q: jax.Array | None,  # (N,) or (1, N) int32
    quant_scale: jax.Array,  # scalar or (N,) f32
    quant_shift: jax.Array,  # scalar or (N,) f32
    *,
    out_dtype=jnp.int8,
    relu: bool = False,
    two_mul: bool = True,
) -> jax.Array:
    """MatMulInteger → Add → Cast → Mul(→Mul) → [Relu] → QuantizeLinear."""
    acc = jax.lax.dot_general(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    if bias_q is not None:
        acc = acc + bias_q.reshape((1,) * (acc.ndim - 1) + (-1,)).astype(jnp.int32)
    f = acc.astype(jnp.float32)
    f = f * quant_scale.reshape((1,) * (f.ndim - 1) + (-1,)) if quant_scale.ndim else f * quant_scale
    if two_mul:
        f = f * (quant_shift.reshape((1,) * (f.ndim - 1) + (-1,)) if quant_shift.ndim else quant_shift)
    if relu:
        f = jnp.maximum(f, 0.0)
    r = jnp.rint(f)
    info = jnp.iinfo(out_dtype)
    return jnp.clip(r, info.min, info.max).astype(out_dtype)


def qact_lut_ref(x_q: jax.Array, lut: jax.Array) -> jax.Array:
    """256-entry LUT gather oracle."""
    return jnp.take(lut, x_q.astype(jnp.int32) + 128)


def qattention_ref(
    q_q: jax.Array,  # (..., S, dh) int8
    k_q: jax.Array,  # (..., T, dh) int8
    v_q: jax.Array,  # (..., T, dh) int8
    mask: jax.Array,  # (..., S, T) f32 {0, 1} validity/causality mask
    qk_scale: jax.Array,  # scalar f32: s_q * s_k / sqrt(dh)
    big: jax.Array,  # scalar f32: the additive mask penalty
    lut_scale: jax.Array,  # scalar f32: score-delta quantization step
    lut: jax.Array,  # (256,) uint8 exp table (lut[0] must be 0)
    p_scale: jax.Array,  # scalar f32: probability quantization (127.0)
    rescale: jax.Array,  # scalar f32: s_v / (p_scale * s_out)
    *,
    out_dtype=jnp.int8,
) -> jax.Array:
    """Fused int8 attention oracle: the exact op chain the PQ-IR attention
    region codifies (see ``repro.core.patterns.emit_qattention``), so that
    ``reference runtime == ref == kernel(interpret=True)`` bit-for-bit.

    Every step is either integer arithmetic or an IEEE-exact f32 elementwise
    op, so the chain is deterministic across numpy / XLA / Pallas:

        MatMulInteger(Q, K^T) → ×qk_scale → additive {0,-big} mask →
        ReduceMax/Sub (running-max-free softmax shift) → QuantizeLinear(ls) →
        exp via 256-entry LUT gather → ReduceSum (int32) → Div →
        ×p_scale → QuantizeLinear → MatMulInteger(P, V) → ×rescale →
        QuantizeLinear(out_dtype)
    """
    acc = jnp.matmul(q_q.astype(jnp.int32), jnp.swapaxes(k_q.astype(jnp.int32), -1, -2))
    s_f = acc.astype(jnp.float32) * qk_scale
    masked = s_f * mask + (mask - 1.0) * big
    mx = jnp.max(masked, axis=-1, keepdims=True)
    d = masked - mx  # ≤ 0 everywhere
    d_q = jnp.clip(jnp.rint(d / lut_scale), -128, 127).astype(jnp.int32)
    w = jnp.take(lut, d_q + 128)  # uint8 weights; masked positions hit lut[0] == 0
    den = jnp.sum(w.astype(jnp.int32), axis=-1, keepdims=True)
    p = w.astype(jnp.float32) / den.astype(jnp.float32)
    p_q = jnp.clip(jnp.rint(p * p_scale), -128, 127).astype(jnp.int32)
    ctx = jnp.matmul(p_q, v_q.astype(jnp.int32))
    f = ctx.astype(jnp.float32) * rescale
    info = jnp.iinfo(out_dtype)
    return jnp.clip(jnp.rint(f), info.min, info.max).astype(out_dtype)
