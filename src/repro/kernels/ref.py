"""Pure-jnp oracles for the Pallas kernels (and the dry-run lowering path).

These implement the artifact's op chain exactly — int32 accumulation, f32
rescale in codified order, round-half-even, clip — so that
``kernel(interpret=True) == ref == reference_runtime`` bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qmatmul_ref(
    x_q: jax.Array,  # (..., M, K) int8/uint8
    w_q: jax.Array,  # (K, N) int8
    bias_q: jax.Array | None,  # (N,) or (1, N) int32
    quant_scale: jax.Array,  # scalar or (N,) f32
    quant_shift: jax.Array,  # scalar or (N,) f32
    *,
    out_dtype=jnp.int8,
    relu: bool = False,
    two_mul: bool = True,
) -> jax.Array:
    """MatMulInteger → Add → Cast → Mul(→Mul) → [Relu] → QuantizeLinear."""
    acc = jax.lax.dot_general(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    if bias_q is not None:
        acc = acc + bias_q.reshape((1,) * (acc.ndim - 1) + (-1,)).astype(jnp.int32)
    f = acc.astype(jnp.float32)
    f = f * quant_scale.reshape((1,) * (f.ndim - 1) + (-1,)) if quant_scale.ndim else f * quant_scale
    if two_mul:
        f = f * (quant_shift.reshape((1,) * (f.ndim - 1) + (-1,)) if quant_shift.ndim else quant_shift)
    if relu:
        f = jnp.maximum(f, 0.0)
    r = jnp.rint(f)
    info = jnp.iinfo(out_dtype)
    return jnp.clip(r, info.min, info.max).astype(out_dtype)


def qact_lut_ref(x_q: jax.Array, lut: jax.Array) -> jax.Array:
    """256-entry LUT gather oracle."""
    return jnp.take(lut, x_q.astype(jnp.int32) + 128)
