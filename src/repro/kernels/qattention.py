"""Fused int8 attention Pallas TPU kernel.

One kernel realizes the PQ-IR attention region the token path codifies
(see ``repro.core.patterns.emit_qattention``):

    MatMulInteger (int8 Q × int8 K^T → int32, on the MXU)
      → Cast f32 → Mul qk_scale                      (combined QK rescale)
      → masked shift: s·mask + (mask-1)·big          (additive {0,-big} mask)
      → ReduceMax / Sub                              (softmax max-shift)
      → QuantizeLinear(lut_scale)                    (int8 score deltas)
      → exp via 256-entry LUT gather                 (VPU, no transcendentals)
      → ReduceSum int32 / Div / Mul(p_scale) / QL    (int8 probabilities)
      → MatMulInteger (int8 P × int8 V → int32)      (MXU again)
      → Cast f32 → Mul rescale → QuantizeLinear      (int8 context)

TPU mapping: grid is ``(B, Sp/bq)`` — one query row-block per step with the
full-length K/V blocks resident in VMEM (their block specs index on the
batch dim only), the masked LUT-softmax runs on the VPU over the int32
score tile while it is live in VMEM, and both contractions drive the MXU at
its double-rate int8 throughput.  Nothing round-trips to HBM between the
two matmuls — that is the whole point of fusing the region.

Bit-exactness: every step is integer arithmetic or an IEEE-exact f32
elementwise op in the artifact's codified order, so
``reference runtime == qattention_ref == qattention(interpret=True)``
bit-for-bit.  Zero padding is exact end-to-end: padded keys carry a zero
mask, which drives their score to ``-big`` and their LUT weight to exactly
``lut[0] == 0`` (asserted by ``repro.core.patterns.build_exp_lut``), so they
contribute nothing to the denominator or the context; padded query rows are
sliced away.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qmatmul import MIN_LANE, MIN_SUBLANE

#: Default query row-block.
BQ = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def choose_bq(s, *, bq: int = BQ) -> int:
    """Per-bucket query tile: shrink the default toward the (sublane-aligned)
    query count so decode (S=1) runs a 32-row block instead of padding 1→128.
    ``s`` may be None/0 (unknown extent) — the default then stands."""
    return min(bq, _ceil_to(int(s), MIN_SUBLANE)) if s else bq


def bq_aligned(bq: int) -> bool:
    """The autotuner's validity predicate for a query-tile candidate."""
    return bq > 0 and bq % MIN_SUBLANE == 0


def _qattention_kernel(
    q_ref, k_ref, v_ref, m_ref, lut_ref, o_ref,
    *, qk_scale, big, lut_scale, p_scale, rescale, out_dtype,
):
    q = q_ref[0]  # (bq, dp) int8
    k = k_ref[0]  # (tp, dp) int8
    v = v_ref[0]  # (tp, dp) int8
    mask = m_ref[0]  # (bq, tp) f32

    # int8 Q × K^T → int32 scores on the MXU.
    acc = jax.lax.dot_general(
        q.astype(jnp.int32),
        k.astype(jnp.int32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    s_f = acc.astype(jnp.float32) * qk_scale
    masked = s_f * mask + (mask - 1.0) * big
    mx = jnp.max(masked, axis=-1, keepdims=True)
    d_q = jnp.clip(jnp.rint((masked - mx) / lut_scale), -128, 127).astype(jnp.int32)
    w = jnp.take(lut_ref[...], d_q + 128)  # uint8; masked keys hit lut[0] == 0
    den = jnp.sum(w.astype(jnp.int32), axis=-1, keepdims=True)
    p = w.astype(jnp.float32) / den.astype(jnp.float32)
    p_q = jnp.clip(jnp.rint(p * p_scale), -128, 127).astype(jnp.int32)
    # int8 P × V → int32 context on the MXU.
    ctx = jax.lax.dot_general(
        p_q,
        v.astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    f = ctx.astype(jnp.float32) * rescale
    info = jnp.iinfo(out_dtype)
    o_ref[0] = jnp.clip(jnp.rint(f), info.min, info.max).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "qk_scale", "big", "lut_scale", "p_scale", "rescale",
        "out_dtype", "bq", "interpret",
    ),
)
def qattention(
    q_q: jax.Array,  # (B, S, dh) int8
    k_q: jax.Array,  # (B, T, dh) int8
    v_q: jax.Array,  # (B, T, dh) int8
    mask: jax.Array,  # (B, S, T) f32 {0, 1}
    lut: jax.Array,  # (256,) uint8 exp table, lut[0] == 0
    *,
    qk_scale: float,
    big: float,
    lut_scale: float,
    p_scale: float,
    rescale: float,
    out_dtype=jnp.int8,
    bq: int = BQ,
    interpret: bool = False,
) -> jax.Array:
    """Fused int8 attention over a stacked batch of heads.

    Pads S to the ``bq`` row-block, and T/dh to lane multiples — all three
    paddings are exact (see module docstring) — runs the ``(B, Sp/bq)``
    grid, and slices back to the true extents."""
    b, s, dh = q_q.shape
    t = k_q.shape[1]
    bq = choose_bq(s, bq=bq)
    sp, tp, dp = _ceil_to(s, bq), _ceil_to(t, MIN_LANE), _ceil_to(dh, MIN_LANE)
    if (sp, dp) != (s, dh):
        q_q = jnp.pad(q_q, ((0, 0), (0, sp - s), (0, dp - dh)))
    if (tp, dp) != (t, dh):
        k_q = jnp.pad(k_q, ((0, 0), (0, tp - t), (0, dp - dh)))
        v_q = jnp.pad(v_q, ((0, 0), (0, tp - t), (0, dp - dh)))
    if (sp, tp) != (s, t):
        mask = jnp.pad(mask, ((0, 0), (0, sp - s), (0, tp - t)))  # 0 = masked
    kernel = functools.partial(
        _qattention_kernel,
        qk_scale=qk_scale, big=big, lut_scale=lut_scale,
        p_scale=p_scale, rescale=rescale, out_dtype=out_dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, sp // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tp, dp), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, tp, dp), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bq, tp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((256,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bq, dp), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, dp), out_dtype),
        interpret=interpret,
    )(q_q, k_q, v_q, mask, lut)
    return out[:, :s, :dh]
