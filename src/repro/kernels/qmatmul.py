"""Fused pre-quantized matmul Pallas TPU kernel.

One kernel realizes the paper's entire Fig.1/2 pattern:

    MatMulInteger (int8×int8 → int32, on the MXU)
      → Add int32 bias
      → Cast f32 → Mul quant_scale → Mul quant_shift   (§3.1 integer rescale)
      → optional ReLU
      → QuantizeLinear(scale=1, zp=0)                   (round-half-even + clip)

TPU mapping (DESIGN.md §3): the int8×int8→int32 product drives the MXU at its
double-rate int8 throughput; the rescale epilogue runs on the VPU over the
int32 accumulator while it is still resident in VMEM — the Cast/Mul/Mul/QL
chain of the artifact never round-trips to HBM.  Grid is (M/bm, N/bn, K/bk)
with a VMEM int32 accumulator scratch carried across the k dimension
(innermost, sequential on TPU).

Tile constraints: int8 operands want (32, 128)-aligned tiles, the int32
accumulator (8, 128); the default 128/256/128 blocks satisfy both and keep the
MXU busy (128×128 systolic array).  Shape padding is handled by
:mod:`repro.kernels.ops`, zero padding being exact for integer matmul.

Bit-exactness: the epilogue performs the *same f32 operations in the same
order* as the ONNX-dialect ops, so results match the reference runtime
bit-for-bit (asserted over shape/dtype sweeps in tests/test_kernels_qmatmul.py).

The packed-int4 variant (:func:`qmatmul_packed`) streams weights 2-per-byte
from HBM and unpacks per tile on the VPU before the same MXU product —
halving weight traffic for the bandwidth-bound decode path (see
docs/quantization.md and tests/test_int4.py for the bit-exactness pin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default MXU-aligned tile sizes.
BM, BK, BN = 128, 256, 128

# Minimum tile granularity: int8 operands want (32, 128)-aligned tiles and the
# int32 accumulator (8, 128) — 32-multiple sublanes × 128-lane last dims
# satisfy both.  Public: the autotuner's candidate lattice is built from these.
MIN_SUBLANE, MIN_LANE = 32, 128
_MIN_SUBLANE, _MIN_LANE = MIN_SUBLANE, MIN_LANE


def tile_aligned(bm: int, bk: int, bn: int) -> bool:
    """True iff (bm, bk, bn) satisfies the kernel's tile constraints: positive
    blocks, bm a 32-multiple (int8 sublane minimum, which also covers the
    int32 accumulator's 8), bk and bn 128-lane multiples."""
    return (
        min(bm, bk, bn) > 0
        and bm % MIN_SUBLANE == 0
        and bk % MIN_LANE == 0
        and bn % MIN_LANE == 0
    )


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def choose_bm(m, *, bm: int = BM) -> int:
    """Per-batch-bucket tile choice for the M dimension.

    The K/N tiles are a property of the *weights* (fixed at template-build
    time); ``bm`` is the one tile that depends on the batch, so it is the
    piece re-chosen per bucket by the batch-polymorphic specialization:
    a bucket of 1 runs with bm=32 (the int8 sublane minimum) instead of
    padding 1→128.  ``m`` may be None/0 (unknown batch) — the default
    ``bm`` then stands."""
    return min(bm, _ceil_to(int(m), _MIN_SUBLANE)) if m else bm


def choose_tiles(m, k: int, n: int, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """Pick (bm, bk, bn) for a *static* problem shape at plan time.

    Shrinks the default blocks toward the (hardware-minimum-aligned) problem
    size so small layers don't pad 33→256; ``m`` may be None when the batch
    dimension is dynamic, in which case the default ``bm`` stands (see
    :func:`choose_bm` for the per-bucket M choice)."""
    bk_ = min(bk, _ceil_to(int(k), _MIN_LANE))
    bn_ = min(bn, _ceil_to(int(n), _MIN_LANE))
    return choose_bm(m, bm=bm), bk_, bn_


def _epilogue(acc, bias, qscale, qshift, *, relu: bool, two_mul: bool, out_dtype):
    """The artifact's rescale chain, op-for-op (order matters for bit-exactness)."""
    acc = acc + bias  # int32 + int32
    f = acc.astype(jnp.float32)
    f = f * qscale
    if two_mul:
        f = f * qshift
    if relu:
        f = jnp.maximum(f, 0.0)
    r = jnp.rint(f)  # round half to even, as ONNX QuantizeLinear
    info = jnp.iinfo(out_dtype)
    return jnp.clip(r, info.min, info.max).astype(out_dtype)


def _qmatmul_kernel(x_ref, w_ref, b_ref, qs_ref, qsh_ref, o_ref, acc_ref, *, relu, two_mul, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 × int8 → int32 on the MXU.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = _epilogue(
            acc_ref[...], b_ref[...], qs_ref[...], qsh_ref[...],
            relu=relu, two_mul=two_mul, out_dtype=out_dtype,
        )


def _unpack_int4_rows(p):
    """(rows, bn) uint8 nibble-pairs → (2·rows, bn) int8, K-interleaved.

    Mirrors :func:`repro.kernels.pack.unpack_int4` with pure VPU shift
    arithmetic: the low nibble sign-extends via ``int8(p << 4) >> 4``, the
    high nibble via ``int8(p) >> 4`` (conversion wraps mod 2⁸, then the
    arithmetic right shift carries the sign).  The stack-reshape interleaves
    along the sublane axis only — the 128-lane layout is untouched."""
    lo = (p << 4).astype(jnp.int8) >> 4
    hi = p.astype(jnp.int8) >> 4
    return jnp.stack([lo, hi], axis=1).reshape(2 * p.shape[0], p.shape[1])


def _qmatmul_packed_kernel(x_ref, wp_ref, b_ref, qs_ref, qsh_ref, o_ref, acc_ref, *, relu, two_mul, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Unpack the (bk//2, bn) packed tile to (bk, bn) int8 in VMEM, then the
    # same int8 MXU product as the unpacked kernel — HBM only ever streamed
    # half the weight bytes.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        _unpack_int4_rows(wp_ref[...]),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = _epilogue(
            acc_ref[...], b_ref[...], qs_ref[...], qsh_ref[...],
            relu=relu, two_mul=two_mul, out_dtype=out_dtype,
        )


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "relu", "two_mul", "bm", "bk", "bn", "interpret"),
)
def qmatmul_packed(
    x_q: jax.Array,  # (M, K) int8
    w_p: jax.Array,  # (K // 2, N) uint8 — int4 nibble pairs along K
    bias_q: jax.Array,  # (1, N) int32
    quant_scale: jax.Array,  # (1, N) f32
    quant_shift: jax.Array,  # (1, N) f32
    *,
    out_dtype=jnp.int8,
    relu: bool = False,
    two_mul: bool = True,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """Packed-int4 variant of :func:`qmatmul`: weights arrive 2-per-byte
    (packed once at plan time by :func:`repro.kernels.pack.pack_int4`) and
    are unpacked per (bk, bn) tile inside the kernel.  Same grid, same
    epilogue, bit-exact with the unpacked kernel on int4-range weights."""
    m, k = x_q.shape
    kp2, n = w_p.shape
    assert k == 2 * kp2, (x_q.shape, w_p.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    assert bk % 2 == 0, bk

    kernel = functools.partial(_qmatmul_packed_kernel, relu=relu, two_mul=two_mul, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_p, bias_q, quant_scale, quant_shift)


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "relu", "two_mul", "bm", "bk", "bn", "interpret"),
)
def qmatmul(
    x_q: jax.Array,  # (M, K) int8
    w_q: jax.Array,  # (K, N) int8
    bias_q: jax.Array,  # (1, N) int32
    quant_scale: jax.Array,  # (1, N) f32 — integer values stored as FLOAT
    quant_shift: jax.Array,  # (1, N) f32 — 2**-N
    *,
    out_dtype=jnp.int8,
    relu: bool = False,
    two_mul: bool = True,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """Fused pre-quantized matmul.  All dims must already be tile-multiples
    (see :func:`repro.kernels.ops.quantized_matmul` for the padded wrapper)."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)

    kernel = functools.partial(_qmatmul_kernel, relu=relu, two_mul=two_mul, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, bias_q, quant_scale, quant_shift)
