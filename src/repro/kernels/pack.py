"""Int4 weight packing: 2 nibbles per byte along the contraction (K) axis.

Layout contract (shared by the numpy pre-packer here and the in-kernel
unpack in :mod:`repro.kernels.qmatmul`):

* input is an *unpacked* int4 weight — an int8 array with every value in
  [-8, 7] and an **even** K (rows).  Plan-time pre-padding guarantees even
  K for free: padded ``kp`` is always a multiple of the K tile ``bk``,
  itself a multiple of 128.
* ``packed[r, c]`` holds rows ``2r`` (low nibble) and ``2r + 1`` (high
  nibble) of column ``c``:  ``packed = (w[2r] & 0xF) | (w[2r+1] << 4)``,
  stored uint8 with shape ``(K // 2, N)``.
* unpacking is pure shift arithmetic (no table): the low nibble
  sign-extends via ``int8(p << 4) >> 4``, the high nibble via
  ``int8(p) >> 4`` — both lane-parallel on the VPU, which is why the
  packed Pallas kernel can unpack per tile at register speed.

Pairing along K (not N) keeps the packed tile ``(bk // 2, bn)`` an exact
sub-block of the packed array whenever ``bk`` divides ``kp``, so the tuned
tile lattice shares one packed const zero-copy, exactly like int8.
"""
from __future__ import annotations

import numpy as np

INT4_MIN, INT4_MAX = -8, 7


def pack_int4(w: np.ndarray) -> np.ndarray:
    """Pack an unpacked-int4 ``(K, N)`` int8 array to uint8 ``(K // 2, N)``.

    Raises on odd K or values outside [-8, 7] — packing silently wrapping
    an out-of-range weight would corrupt the model, not just lose accuracy.
    """
    w = np.asarray(w)
    if w.dtype != np.int8:
        raise ValueError(f"pack_int4 expects an int8 container, got {w.dtype}")
    if w.ndim != 2 or w.shape[0] % 2 != 0:
        raise ValueError(f"pack_int4 expects a 2-D even-K array, got shape {w.shape}")
    if w.size and (w.min() < INT4_MIN or w.max() > INT4_MAX):
        raise ValueError(
            f"pack_int4 values out of int4 range [{INT4_MIN}, {INT4_MAX}]: "
            f"[{w.min()}, {w.max()}]"
        )
    lo = w[0::2, :].astype(np.uint8) & 0xF
    hi = w[1::2, :].astype(np.uint8) & 0xF
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_int4(packed: np.ndarray, k: int = None) -> np.ndarray:
    """Inverse of :func:`pack_int4`: uint8 ``(K//2, N)`` → int8 ``(K, N)``.

    ``k`` optionally trims the result back to an original row count (the
    unpacked row count is always even; callers that padded before packing
    pass the pre-padding K).
    """
    p = np.asarray(packed)
    if p.dtype != np.uint8 or p.ndim != 2:
        raise ValueError(f"unpack_int4 expects a 2-D uint8 array, got {p.dtype} {p.shape}")
    lo = np.left_shift(p, 4).view(np.int8) >> 4  # sign-extend low nibble
    hi = p.view(np.int8) >> 4  # arithmetic shift sign-extends the high nibble
    out = np.empty((2 * p.shape[0], p.shape[1]), np.int8)
    out[0::2, :] = lo
    out[1::2, :] = hi
    if k is not None:
        if not 0 < k <= 2 * p.shape[0]:
            raise ValueError(f"k={k} inconsistent with packed rows {p.shape[0]}")
        out = out[:k]
    return out
