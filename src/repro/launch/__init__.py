from . import mesh, specs, steps  # noqa: F401
