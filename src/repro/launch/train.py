"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --steps 50 \
        --batch 8 --seq 256 [--qat] [--ckpt-dir /tmp/ckpt] [--schedule wsd]

On a single CPU host this runs reduced configs end-to-end (the quickstart /
examples path); on a TPU fleet the same script runs full configs under
``make_production_mesh()`` — the step function, sharding rules, checkpointing
and restart logic are identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..configs.base import ShapeConfig
from ..data.pipeline import DataConfig, Pipeline
from ..distributed.fault_tolerance import CheckpointManager, CheckpointManagerConfig, StragglerMonitor
from ..distributed.sharding import use_mesh
from ..models import model as M
from ..optim import adamw
from . import steps as steps_lib


def train(
    arch: str,
    *,
    steps: int = 20,
    batch: int = 8,
    seq: int = 128,
    microbatches: int = 1,
    reduced: bool = True,
    qat: bool = False,
    schedule: str = "warmup_cosine",
    ckpt_dir: Optional[str] = None,
    ckpt_interval: int = 50,
    mesh=None,
    compute_dtype=jnp.float32,
    seed: int = 0,
    log_every: int = 5,
    resume: bool = True,
):
    cfg = get_config(arch, reduced=reduced)
    sc = ShapeConfig("custom", "train", seq, batch, microbatches=microbatches)
    pipe = Pipeline(cfg, DataConfig(seed=seed))
    step_fn = steps_lib.make_train_step(
        cfg, sc, compute_dtype=compute_dtype, sched=schedule, qat=qat,
        sched_kwargs=dict(peak_lr=1e-3, warmup_steps=max(2, steps // 10), total_steps=steps),
        q_chunk=min(seq, 512), kv_chunk=min(seq, 512),
    )
    manager = None
    if ckpt_dir:
        manager = CheckpointManager(CheckpointManagerConfig(ckpt_dir, interval_steps=ckpt_interval))
    monitor = StragglerMonitor()

    with use_mesh(mesh):
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        opt = adamw.init(params)
        start = 0
        if manager and resume and manager.has_checkpoint():
            (params, opt), start, _ = manager.restore((params, opt))
            start += 1
            print(f"[train] resumed from step {start - 1}")
        history = []
        for step in range(start, steps):
            monitor.start_step()
            data = pipe.batch(step, batch, seq)
            params, opt, metrics = jitted(params, opt, {k: jnp.asarray(v) for k, v in data.items()})
            mm = monitor.end_step(step)
            loss = float(metrics["loss"])
            history.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"[train] {arch} step {step:4d} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e} "
                    f"dt {mm['step_time_s']:.2f}s",
                    flush=True,
                )
            if manager:
                manager.maybe_save(step, (params, opt))
                if manager.preempted:
                    break
    return params, opt, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--qat", action="store_true")
    ap.add_argument("--schedule", default="warmup_cosine", choices=["warmup_cosine", "wsd"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        microbatches=args.microbatches, reduced=not args.full, qat=args.qat,
        schedule=args.schedule, ckpt_dir=args.ckpt_dir, seed=args.seed,
    )


if __name__ == "__main__":
    main()
