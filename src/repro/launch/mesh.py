"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is pure
data parallelism whose gradient all-reduce crosses DCN (and is therefore the
int8-compression target, repro.optim.grad_compress).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (forced host devices)."""
    return jax.make_mesh(shape, axes)


def describe(mesh) -> str:
    return f"mesh{dict(mesh.shape)} over {mesh.devices.size} devices"
