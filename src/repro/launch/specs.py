"""ShapeDtypeStruct input specs + shardings for every (arch × shape) cell.

``input_specs(cfg, shape_cfg)`` returns weak-type-correct stand-ins for every
model input — batches for train/prefill, (tokens, pos, cache) for decode —
with NO device allocation; the dry-run lowers against these.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed import sharding as shlib
from ..models import model as M

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, sc: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = sc.global_batch, sc.seq_len
    out = {}
    if cfg.frontend == "vision":
        n_txt = s - cfg.frontend_tokens
        out["tokens"] = sds((b, n_txt), I32)
        out["labels"] = sds((b, n_txt), I32)
        out["patch_embeds"] = sds((b, cfg.frontend_tokens, cfg.d_model), BF16)
    else:
        out["tokens"] = sds((b, s), I32)
        out["labels"] = sds((b, s), I32)
    if cfg.family == "encdec":
        out["src_embeds"] = sds((b, s, cfg.d_model), BF16)
    return out


def batch_shardings(batch_specs, mesh) -> Dict:
    out = {}
    for k, v in batch_specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = shlib.sharding_for(v.shape, axes, mesh)
    return out


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def params_shardings(p_specs, mesh):
    axes = M.param_logical_axes(p_specs)
    return jax.tree.map(
        lambda leaf, ax: shlib.sharding_for(leaf.shape, ax, mesh), p_specs, axes
    )


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 0):
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, max_len, src_len=src_len))


def cache_shardings(c_specs, mesh):
    axes = M.cache_logical_axes(c_specs)
    return jax.tree.map(
        lambda leaf, ax: shlib.sharding_for(leaf.shape, ax, mesh), c_specs, axes
    )


def decode_input_specs(cfg: ModelConfig, sc: ShapeConfig):
    """(tokens, pos, cache) for one decode step with a cache of sc.seq_len."""
    b = sc.global_batch
    toks = sds((b, 1), I32)
    pos = sds((b,), I32)
    cache = cache_specs(cfg, b, sc.seq_len, src_len=min(sc.seq_len, 4096) if cfg.family == "encdec" else 0)
    return toks, pos, cache


def prefill_input_specs(cfg: ModelConfig, sc: ShapeConfig):
    batch = train_batch_specs(cfg, sc)
    batch.pop("labels")
    cache = cache_specs(cfg, sc.global_batch, sc.seq_len, src_len=sc.seq_len if cfg.family == "encdec" else 0)
    return batch, cache


def skip_reason(cfg: ModelConfig, sc: ShapeConfig) -> Optional[str]:
    """Assignment skip rules (documented in DESIGN.md §4)."""
    if sc.name == "long_500k":
        subquadratic = cfg.family in ("rwkv6", "hybrid") or cfg.attn_type in ("swa", "local_global")
        if not subquadratic:
            return "long_500k skipped: pure full-attention arch (per assignment)"
    return None
