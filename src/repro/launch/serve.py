"""Serving launcher: batched generation with the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
        --requests 8 --prompt-len 24 --new-tokens 8 [--int8-kv]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import model as M
from ..serving.engine import EngineConfig, Request, ServeEngine


def serve_demo(
    arch: str,
    *,
    requests: int = 8,
    prompt_len: int = 24,
    new_tokens: int = 8,
    slots: int = 4,
    int8_kv: bool = False,
    reduced: bool = True,
    seed: int = 0,
    greedy: bool = True,
    temperature: float = 1.0,
    top_k: int = 0,
):
    import jax

    cfg = get_config(arch, reduced=reduced)
    if int8_kv:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if cfg.family == "encdec" or cfg.frontend is not None:
        raise SystemExit(f"serve demo supports text decoder archs; {arch} needs frontend feeds")
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    ecfg = EngineConfig(
        slots=slots, max_len=prompt_len + new_tokens + 8,
        greedy=greedy, temperature=temperature, top_k=top_k, seed=seed,
    )
    eng = ServeEngine(params, cfg, ecfg)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(requests):
        r = Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, (prompt_len,)).astype(np.int32), max_new_tokens=new_tokens)
        reqs.append(r)
        eng.submit(r)
    t0 = time.monotonic()
    eng.run_until_drained()
    dt = time.monotonic() - t0
    toks = sum(len(r.generated) for r in reqs)
    ttfts = [r.t_first - r.t_submit for r in reqs]
    print(
        f"[serve] {arch} kv={cfg.kv_cache_dtype} requests={requests} tokens={toks} "
        f"wall={dt:.2f}s tput={toks / dt:.1f} tok/s "
        f"ttft p50={np.percentile(ttfts, 50):.3f}s metrics={eng.metrics}"
    )
    return reqs, eng


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--sample", action="store_true", help="temperature/top-k sampling instead of greedy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args(argv)
    serve_demo(
        args.arch, requests=args.requests, prompt_len=args.prompt_len,
        new_tokens=args.new_tokens, slots=args.slots, int8_kv=args.int8_kv,
        greedy=not args.sample, temperature=args.temperature, top_k=args.top_k,
    )


if __name__ == "__main__":
    main()
