import os

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape) cell
on the production meshes (16×16 single-pod, 2×16×16 multi-pod) with 512
placeholder host devices, then dump memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]

A cell PASSES when .lower().compile() succeeds; memory_analysis() proves it
fits; cost_analysis() + HLO collective byte counts feed EXPERIMENTS.md
§Roofline.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import ARCH_IDS, get_config  # noqa: E402
from ..configs.base import SHAPE_BY_NAME, SHAPES  # noqa: E402
from ..distributed.sharding import use_mesh  # noqa: E402
from . import specs as S  # noqa: E402
from . import steps  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "s64": 8, "u64": 8, "pred": 1, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    """Total bytes of all tensors in an HLO type string like
    'f32[128,256]' or '(bf16[4,8], s32[2])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes of every collective op in the (SPMD-partitioned) HLO.

    Byte counts are per-participant (the HLO is the per-device program after
    GSPMD partitioning), so `sum / chips` in the roofline denominator is NOT
    applied again — see benchmarks/roofline.py.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\s+(\w[\w\-]*)\(", ls)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _tensor_bytes(m.group(1))
            out["count"] += 1
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False, q_chunk: int = 1024, kv_chunk: int = 1024, w8a8: bool = False):
    """Lower + compile one cell.  Returns a result dict (see dryrun_cell)."""
    cfg = get_config(arch)
    sc = SHAPE_BY_NAME[shape_name]
    skip = S.skip_reason(cfg, sc)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with use_mesh(mesh):
        p_specs = S.params_specs(cfg)
        if w8a8 and sc.kind != "train":
            from ..core.convert import convert_params_w8a8

            p_specs = jax.eval_shape(convert_params_w8a8, p_specs)
        p_sh = S.params_shardings(p_specs, mesh)
        if sc.kind == "train":
            from ..optim import adamw

            o_specs = jax.eval_shape(adamw.init, p_specs)
            o_sh = S.params_shardings(o_specs["m"], mesh)
            o_sh = {"m": o_sh, "v": o_sh, "step": None}
            b_specs = S.train_batch_specs(cfg, sc)
            b_sh = S.batch_shardings(b_specs, mesh)
            fn = steps.make_train_step(cfg, sc, q_chunk=q_chunk, kv_chunk=kv_chunk)
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh), donate_argnums=(0, 1))
            lowered = jitted.lower(p_specs, o_specs, b_specs)
        elif sc.kind == "prefill":
            b_specs, c_specs = S.prefill_input_specs(cfg, sc)
            b_sh = S.batch_shardings(b_specs, mesh)
            c_sh = S.cache_shardings(c_specs, mesh)
            fn = steps.make_prefill_step(cfg, q_chunk=q_chunk, kv_chunk=kv_chunk)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh), donate_argnums=(2,))
            lowered = jitted.lower(p_specs, b_specs, c_specs)
        else:  # decode
            toks, pos, c_specs = S.decode_input_specs(cfg, sc)
            c_sh = S.cache_shardings(c_specs, mesh)
            t_sh = S.batch_shardings({"tokens": toks, "pos": pos}, mesh)
            fn = steps.make_decode_step(cfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, t_sh["tokens"], t_sh["pos"], c_sh), donate_argnums=(3,))
            lowered = jitted.lower(p_specs, toks, pos, c_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "multi_pod": multi_pod, "lowered": lowered, "compiled": compiled,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
    }


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False, w8a8: bool = False) -> Dict:
    """Full dry-run for one cell: compile + memory/cost/collective analysis."""
    try:
        res = lower_cell(arch, shape_name, multi_pod=multi_pod, w8a8=w8a8)
    except Exception as e:  # a failure here is a bug in our sharding config
        return {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "fail", "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
    if res["status"] != "ok":
        return res
    compiled = res.pop("compiled")
    lowered = res.pop("lowered")
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    res.update(
        {
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            "cost": {
                "flops": cost.get("flops"),
                "bytes_accessed": cost.get("bytes accessed"),
                "transcendentals": cost.get("transcendentals"),
            },
            "collectives": coll,
        }
    )
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--w8a8", action="store_true", help="pre-quantized W8A8 serving params")
    args = ap.parse_args(argv)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                r = dryrun_cell(a, s, multi_pod=mp, w8a8=args.w8a8)
                results.append(r)
                tag = "POD2" if mp else "POD1"
                status = r["status"].upper()
                extra = ""
                if r["status"] == "ok":
                    gb = (r["memory"]["temp_bytes"] or 0) / 2**30
                    extra = f" flops={r['cost']['flops']:.3e} temp={gb:.2f}GiB coll={r['collectives']['count']} t={r['t_compile_s']}s"
                elif r["status"] == "fail":
                    extra = " " + r["error"][:200]
                print(f"[{tag}] {a:24s} {s:12s} {status}{extra}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "fail"]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
