"""Jit-able train / prefill / decode step builders (shared by the real
launchers and the dry-run).

train_step: gradient accumulation via lax.scan over microbatches (bounds
activation memory), remat per config, AdamW + schedule, optional QAT
(fake-quant forward), optional int8 cross-pod gradient compression.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..core import qat as qatlib
from ..models import model as M
from ..optim import adamw, schedule as schedlib


def _qat_params(params: dict, enabled: bool):
    if not enabled:
        return params

    def maybe_fq(path, leaf):
        names = [str(getattr(k, "key", "")) for k in path]
        if leaf.ndim >= 2 and names[-1] not in ("router",) and leaf.dtype in (jnp.float32, jnp.bfloat16):
            return qatlib.fake_quant_weight_per_channel(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(maybe_fq, params)


def make_train_step(
    cfg: ModelConfig,
    sc: ShapeConfig,
    *,
    compute_dtype=jnp.bfloat16,
    adamw_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
    sched: str = "warmup_cosine",
    sched_kwargs: Optional[dict] = None,
    qat: bool = False,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    skw = sched_kwargs or dict(peak_lr=3e-4, warmup_steps=100, total_steps=10_000)
    if sched == "wsd" and "stable_steps" not in skw:
        skw = dict(peak_lr=skw.get("peak_lr", 3e-4), warmup_steps=100, stable_steps=8_000, decay_steps=1_900)
    sched_fn = functools.partial(schedlib.SCHEDULES[sched], **skw)
    n_micro = max(1, sc.microbatches)

    def loss(params, mb):
        p = _qat_params(params, qat)
        return M.loss_fn(p, mb, cfg, compute_dtype=compute_dtype, q_chunk=q_chunk, kv_chunk=kv_chunk)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch: Dict):
        def reshape_mb(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

        mbs = jax.tree.map(reshape_mb, batch)

        def micro(carry, mb):
            gsum, lsum = carry
            (l, aux), g = grad_fn(params, mb)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        lr = sched_fn(opt_state["step"])
        new_params, new_opt, om = adamw.update(grads, opt_state, params, lr, adamw_cfg)
        metrics = {"loss": lsum / n_micro, **om}
        return new_params, new_opt, metrics

    return train_step


def make_grad_step(
    cfg: ModelConfig,
    sc: ShapeConfig,
    *,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    """loss+grad only (no optimizer, no microbatch scan) — used by the
    roofline probes so per-layer costs can be separated cleanly."""

    def loss(params, mb):
        return M.loss_fn(params, mb, cfg, compute_dtype=compute_dtype, q_chunk=q_chunk, kv_chunk=kv_chunk)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def grad_step(params, batch):
        (l, aux), g = grad_fn(params, batch)
        return l, g

    return grad_step


def make_prefill_step(cfg: ModelConfig, *, compute_dtype=jnp.bfloat16, q_chunk: int = 1024, kv_chunk: int = 1024):
    def prefill_step(params, batch, cache):
        return M.prefill(params, batch, cfg, cache, compute_dtype=compute_dtype, q_chunk=q_chunk, kv_chunk=kv_chunk)

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, compute_dtype=jnp.bfloat16):
    def decode_step(params, tokens, pos, cache):
        return M.decode_step(params, tokens, pos, cache, cfg, compute_dtype=compute_dtype)

    return decode_step
