"""Sharded, atomic, elastic checkpoints (numpy container format).

Layout:  <dir>/step_<N>/
            manifest.json          — tree structure, shapes, dtypes, step
            leaf_<i>.npy           — one file per pytree leaf
         <dir>/LATEST              — atomic pointer (written last)

Fault-tolerance properties:
  * atomic: leaves + manifest land in a temp dir, then a single rename +
    LATEST pointer update — a crash mid-save never corrupts the previous
    checkpoint;
  * elastic restore: leaves are loaded host-side and ``jax.device_put`` with
    the *target* mesh's NamedSharding — the destination mesh/device-count can
    differ from the source (re-sharding is free at load);
  * self-describing: restore needs no model code, only the manifest.

(Scale note: at 1000+-node scale the leaf files would be written per-shard by
each data-parallel leader with a distributed barrier; the container format and
manifest stay identical — see DESIGN.md §5.)
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _paths_and_leaves(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Atomically save a pytree as step_<step>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    paths, leaves = _paths_and_leaves(tree)
    treedef = jax.tree_util.tree_structure(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), np.asarray(leaf))
        manifest = {
            "step": step,
            "paths": paths,
            "treedef": str(treedef),
            "num_leaves": len(leaves),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # LATEST pointer last — readers never see a partial checkpoint
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


def restore(
    ckpt_dir: str,
    target_tree: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``target_tree``; optionally place leaves
    with ``shardings`` (a matching pytree of NamedSharding — may describe a
    DIFFERENT mesh than the one that saved: elastic restart)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = [np.load(os.path.join(d, f"leaf_{i}.npy")) for i in range(manifest["num_leaves"])]
    treedef = jax.tree_util.tree_structure(target_tree)
    if treedef.num_leaves != len(leaves):
        raise ValueError(f"checkpoint has {len(leaves)} leaves; target expects {treedef.num_leaves}")
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        leaves = [
            jax.device_put(l, s) if s is not None else jax.numpy.asarray(l)
            for l, s in zip(leaves, flat_sh)
        ]
    else:
        leaves = [jax.numpy.asarray(l) for l in leaves]
    return treedef.unflatten(leaves), step, manifest.get("extra", {})
