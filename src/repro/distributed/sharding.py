"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Physical mesh axes:
  * ``pod``   — pure data parallelism across pods (gradient all-reduce over DCN)
  * ``data``  — FSDP: batch for activations, weight/optimizer sharding for params
  * ``model`` — tensor parallelism: heads / d_ff / vocab / expert-internal dims

Every tensor annotates *logical* axes; rules map them to physical axes with a
divisibility check — if a dim doesn't divide the physical axis size the rule
falls back to the next candidate (or replication).  This is what lets one
rule-set serve all 10 architectures (8-head gemma2 and 48-head mixtral alike)
without per-arch sharding code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered physical-axis candidates (first that divides wins).
# () means "replicate".  Tuples inside candidates mean "shard over both axes".
DEFAULT_RULES: dict = {
    # activations
    "batch": (("pod", "data"), ("data",)),
    "seq": (),
    "seq_shard": (("model",),),  # sequence parallelism (hillclimb option)
    "embed_act": (),  # activation d_model: replicated across model (TP gathers)
    "heads_act": (("model",),),
    "kv_heads_act": (("model",),),
    "mlp_act": (("model",),),
    "vocab_act": (("model",),),
    "expert_act": (("model",),),
    # params: FSDP over data on one dim, TP over model on another
    "embed": (("data",),),
    "embed_fsdp": (("data",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "mlp": (("model",),),
    "vocab": (("model",),),
    "expert": (("model",),),
    "expert_fsdp": (("data",),),
    # never sharded
    "layers": (),
    "norm": (),
    "state": (),
    "cap": (),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = DEFAULT_RULES


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + rules for logical sharding annotations.  Also enters
    the mesh context so collectives/pjit resolve axis names."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    _CTX.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _resolve_axis(logical: Optional[str], dim: int, mesh: Mesh, rules: dict, used: set):
    """First candidate whose axes all exist, are unused, and divide ``dim``."""
    if logical is None:
        return None
    for cand in rules.get(logical, ()):
        axes = cand if isinstance(cand, tuple) else (cand,)
        if not axes:
            continue
        if any(a not in mesh.shape or a in used for a in axes):
            continue
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % total == 0:
            used.update(axes)
            return axes if len(axes) > 1 else axes[0]
    return None


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None, rules: Optional[dict] = None) -> P:
    """Resolve logical axes to a PartitionSpec with divisibility fallback."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set = set()
    parts = [_resolve_axis(la, d, mesh, rules, used) for d, la in zip(shape, logical_axes)]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None, rules: Optional[dict] = None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(shape, logical_axes, mesh, rules))


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an intermediate with a logical sharding constraint.
    No-op outside a mesh context (keeps single-device smoke tests clean)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(tree, logical_fn, mesh: Optional[Mesh] = None):
    """Build a sharding pytree for ``tree`` where ``logical_fn(path, leaf)``
    returns the logical axes tuple for each leaf."""
    mesh = mesh or _CTX.mesh

    def per_leaf(path, leaf):
        axes = logical_fn(path, leaf)
        return sharding_for(leaf.shape, axes, mesh)

    return jax.tree_util.tree_map_with_path(per_leaf, tree)
