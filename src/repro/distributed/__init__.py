from . import sharding  # noqa: F401
from .sharding import shard, sharding_for, spec_for, use_mesh  # noqa: F401
