"""Fault-tolerance runtime: preemption-safe checkpointing, elastic restart,
straggler detection.

Host-side machinery around the pure train step:

* ``CheckpointManager`` — periodic + on-signal (SIGTERM/SIGINT preemption
  notice) saves via :mod:`repro.checkpoint.ckpt`, keep-last-k GC.
* ``StragglerMonitor`` — per-step wall-time EWMA; steps slower than
  ``threshold ×`` the EWMA are logged and counted.  At fleet scale the same
  signal drives hot-spare substitution; here it feeds metrics + tests.
  Because the data pipeline is step-indexed and stateless, a replacement
  worker reproduces the same batch — re-issue is deterministic.
* ``run_resilient`` — restart loop: on crash, reload latest checkpoint and
  continue (optionally on a different mesh: elastic re-shard is a
  device_put at restore, see checkpoint/ckpt.py).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

from ..checkpoint import ckpt as ckptlib


@dataclasses.dataclass
class CheckpointManagerConfig:
    directory: str
    interval_steps: int = 100
    keep_last: int = 3


class CheckpointManager:
    def __init__(self, cfg: CheckpointManagerConfig, install_signal_handlers: bool = False) -> None:
        self.cfg = cfg
        self._preempted = False
        self._saved_steps: List[int] = []
        if install_signal_handlers:
            for sig in (signal.SIGTERM, signal.SIGINT):
                signal.signal(sig, self._on_signal)

    def _on_signal(self, signum, frame) -> None:
        # Preemption notice: request a save at the next step boundary.
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    def maybe_save(self, step: int, tree: Any, extra: Optional[dict] = None, *, force: bool = False) -> Optional[str]:
        due = force or self._preempted or (step > 0 and step % self.cfg.interval_steps == 0)
        if not due:
            return None
        path = ckptlib.save(self.cfg.directory, step, tree, extra)
        self._saved_steps.append(step)
        self._gc()
        return path

    def _gc(self) -> None:
        import os
        import shutil

        while len(self._saved_steps) > self.cfg.keep_last:
            old = self._saved_steps.pop(0)
            shutil.rmtree(os.path.join(self.cfg.directory, f"step_{old}"), ignore_errors=True)

    def restore(self, target_tree: Any, shardings: Any = None):
        return ckptlib.restore(self.cfg.directory, target_tree, shardings=shardings)

    def has_checkpoint(self) -> bool:
        return ckptlib.latest_step(self.cfg.directory) is not None


class StragglerMonitor:
    """EWMA-based step-time anomaly detector."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1) -> None:
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.slow_steps: List[int] = []
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> Dict[str, float]:
        dt = time.monotonic() - self._t0
        is_slow = self.ewma is not None and dt > self.threshold * self.ewma
        if is_slow:
            self.slow_steps.append(step)
        # slow outliers do not poison the EWMA
        if self.ewma is None:
            self.ewma = dt
        elif not is_slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return {"step_time_s": dt, "step_time_ewma_s": self.ewma, "straggler": float(is_slow)}


def run_resilient(
    make_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    *,
    manager: CheckpointManager,
    total_steps: int,
    max_restarts: int = 3,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> Any:
    """Crash-tolerant training driver: resume-from-checkpoint restart loop.

    ``make_state()`` builds fresh (params, opt) state; ``step_fn(state, step)``
    returns the next state.  Any exception triggers restore-and-continue from
    the last checkpoint, up to ``max_restarts`` times.
    """
    restarts = 0
    state = make_state()
    start = 0
    if manager.has_checkpoint():
        state, start, _ = manager.restore(state)
        start += 1
    monitor = StragglerMonitor()
    step = start
    while step < total_steps:
        try:
            monitor.start_step()
            state = step_fn(state, step)
            metrics = monitor.end_step(step)
            if on_metrics:
                on_metrics(step, metrics)
            manager.maybe_save(step, state)
            if manager.preempted:
                manager.maybe_save(step, state, force=True)
                break
            step += 1
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            if manager.has_checkpoint():
                state, saved_step, _ = manager.restore(make_state())
                step = saved_step + 1
            else:
                state = make_state()
                step = 0
    return state
