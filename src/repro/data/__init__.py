from .pipeline import DataConfig, Pipeline  # noqa: F401
