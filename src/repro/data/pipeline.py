"""Deterministic, shardable token pipeline.

Two sources behind one interface:
  * SyntheticSource — seeded per (step, shard): reproducible anywhere, the
    default for smoke/dry-run/benchmarks.
  * FileSource — memory-mapped flat token file (one uint32 per token),
    strided into per-shard windows.

Determinism contract (fault tolerance): ``batch(step)`` is a pure function of
(seed, step, shard) — after a restart the pipeline *skips ahead* by resuming
at the checkpointed step; no iterator state needs saving.  Straggler
mitigation can re-issue any step's batch on a different host for the same
result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    path: Optional[str] = None  # None => synthetic
    shard_index: int = 0
    shard_count: int = 1


class SyntheticSource:
    def __init__(self, cfg: DataConfig, vocab: int) -> None:
        self.cfg = cfg
        self.vocab = vocab

    def tokens(self, step: int, batch: int, seq: int) -> np.ndarray:
        seed = (self.cfg.seed * 1_000_003 + step) * 65_537 + self.cfg.shard_index
        rng = np.random.default_rng(seed)
        # zipf-ish marginal so CE losses move like real text rather than
        # uniform noise
        z = rng.zipf(1.2, size=(batch, seq)).astype(np.int64)
        return np.minimum(z - 1, self.vocab - 1).astype(np.int32)


class FileSource:
    def __init__(self, cfg: DataConfig, vocab: int) -> None:
        self.cfg = cfg
        self.vocab = vocab
        self._data = np.memmap(cfg.path, dtype=np.uint32, mode="r")

    def tokens(self, step: int, batch: int, seq: int) -> np.ndarray:
        n = self._data.shape[0]
        need = batch * (seq + 1)
        start = (step * self.cfg.shard_count + self.cfg.shard_index) * need % max(n - need, 1)
        chunk = np.asarray(self._data[start : start + need]).astype(np.int64)
        return (chunk[: batch * seq].reshape(batch, seq) % self.vocab).astype(np.int32)


class Pipeline:
    """Builds model-ready batches for any of the 10 architectures."""

    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig = DataConfig()) -> None:
        self.mc = model_cfg
        self.dc = data_cfg
        src_cls = FileSource if data_cfg.path else SyntheticSource
        self.source = src_cls(data_cfg, model_cfg.vocab_size)

    def batch(self, step: int, batch_size: int, seq_len: int) -> Dict[str, np.ndarray]:
        mc = self.mc
        out: Dict[str, np.ndarray] = {}
        if mc.frontend == "vision":
            n_txt = seq_len - mc.frontend_tokens
            toks = self.source.tokens(step, batch_size, n_txt)
            rng = np.random.default_rng(self.dc.seed * 7 + step)
            out["patch_embeds"] = rng.normal(size=(batch_size, mc.frontend_tokens, mc.d_model)).astype(np.float32)
            out["tokens"] = toks
            out["labels"] = toks
        else:
            toks = self.source.tokens(step, batch_size, seq_len)
            out["tokens"] = toks
            out["labels"] = toks
        if mc.family == "encdec":
            rng = np.random.default_rng(self.dc.seed * 13 + step)
            out["src_embeds"] = rng.normal(size=(batch_size, seq_len, mc.d_model)).astype(np.float32)
        return out
