"""Plan provenance: the record of *how* an ExecutionPlan came to be.

The paper's artifact is something a hardware designer inspects to co-design
the backend.  ``print(plan)`` shows *what* will execute; the provenance
record attached to the plan explains *why it looks that way*:

* which optimization passes fired, in which fixpoint iteration, and what
  each rewrote (``const_fold folded=3`` ...),
* which fusion patterns matched, anchored where, consuming which nodes —
  the audit trail from graph ops to fused kernel ids,
* every scenario-cell specialization the template has served, with its
  axis bindings and the tiles chosen for them (appended lazily as buckets
  are visited; the record is *shared* between a template and all of its
  specializations, so reading it from either shows the full history),
* the obs trace id active at compile time, linking the plan to the span
  timeline that produced it.

Everything here is deterministic — no wall times, no ids that vary run to
run (the trace id is only attached when a tracer is installed) — so the
rendering can be golden-pinned like the plan itself.

Stdlib-only; imports nothing from the rest of :mod:`repro`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class PassEntry:
    """One pass application that changed the graph."""

    iteration: int
    name: str
    counters: Tuple[Tuple[str, int], ...]  # sorted, non-zero

    def describe(self) -> str:
        body = ";".join(f"{k}={v}" for k, v in self.counters)
        return f"it{self.iteration} {self.name}: {body}"


@dataclasses.dataclass(frozen=True)
class FusionRecord:
    """One fusion-pattern match: which nodes became which fused step."""

    pattern: str
    anchor: str  # anchor node name
    nodes: Tuple[str, ...]  # all consumed node names, chain order
    output: str  # the fused step's output tensor

    def describe(self) -> str:
        chain = "+".join(self.nodes)
        return f"{self.pattern} @ {self.anchor}: {chain} -> {self.output}"


@dataclasses.dataclass(frozen=True)
class SpecializationEvent:
    """One scenario-cell specialization of a plan template.

    Each tile record is the bound ``m=..,bm=..,bk=..,bn=..`` string; when the
    tiles came from the measured autotuner rather than the static heuristic
    the record carries a trailing source tag (``... [tuned]`` / ``[cache]``).
    Heuristic tiles render untagged — existing golden renderings are
    byte-identical."""

    bindings: Tuple[Tuple[str, int], ...]  # sorted (axis, bucket)
    tiles: Tuple[Tuple[str, str], ...]  # (fused step name, bound tile record)

    def describe(self) -> str:
        cell = ",".join(f"{a}={v}" for a, v in self.bindings)
        tiles = "; ".join(f"{name} {rec}" for name, rec in self.tiles) or "no fused steps"
        return f"({cell}): {tiles}"


@dataclasses.dataclass
class PlanProvenance:
    """The full how-this-plan-came-to-be record, attached to
    :class:`repro.backend.plan.ExecutionPlan` and rendered by
    ``plan.pretty(verbose=True)``."""

    nodes_before: int = 0
    nodes_after: int = 0
    pass_iterations: int = 0
    passes: List[PassEntry] = dataclasses.field(default_factory=list)
    fusions: List[FusionRecord] = dataclasses.field(default_factory=list)
    specializations: List[SpecializationEvent] = dataclasses.field(default_factory=list)
    trace_id: Optional[str] = None

    # -- construction helpers ------------------------------------------------
    def add_pass(self, iteration: int, name: str, counters: Dict[str, int]) -> None:
        nz = tuple(sorted((k, int(v)) for k, v in counters.items() if v))
        if nz:
            self.passes.append(PassEntry(iteration, name, nz))

    def add_fusion(self, pattern: str, anchor: str, nodes: Tuple[str, ...], output: str) -> None:
        self.fusions.append(FusionRecord(pattern, anchor, nodes, output))

    def add_specialization(
        self, bindings: Dict[str, int], tiles: Dict[str, str]
    ) -> SpecializationEvent:
        ev = SpecializationEvent(
            bindings=tuple(sorted((str(a), int(v)) for a, v in bindings.items())),
            tiles=tuple(sorted(tiles.items())),
        )
        self.specializations.append(ev)
        return ev

    @property
    def pass_totals(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for e in self.passes:
            for k, v in e.counters:
                agg[k] = agg.get(k, 0) + v
        return agg

    # -- rendering -----------------------------------------------------------
    def render(self, indent: str = "  ") -> str:
        """Deterministic human-readable provenance section."""
        pad = indent
        lines: List[str] = [f"{pad}provenance:"]
        totals = ";".join(f"{k}={v}" for k, v in sorted(self.pass_totals.items())) or "no-op"
        lines.append(
            f"{pad}  passes: nodes {self.nodes_before}->{self.nodes_after} "
            f"in {self.pass_iterations} iteration(s) ({totals})"
        )
        for e in self.passes:
            lines.append(f"{pad}    {e.describe()}")
        lines.append(f"{pad}  fusions: {len(self.fusions)} matched")
        for f in self.fusions:
            lines.append(f"{pad}    {f.describe()}")
        if self.specializations:
            lines.append(f"{pad}  specializations: {len(self.specializations)}")
            for s in self.specializations:
                lines.append(f"{pad}    {s.describe()}")
        if self.trace_id is not None:
            lines.append(f"{pad}  trace: {self.trace_id}")
        return "\n".join(lines)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlanProvenance":
        """Inverse of :meth:`to_dict` — reconstructs the record from its
        JSON-able form (AOT artifacts round-trip provenance through this).
        ``to_dict(from_dict(d)) == d`` for any ``to_dict`` output."""
        prov = cls(
            nodes_before=int(d.get("nodes_before", 0)),
            nodes_after=int(d.get("nodes_after", 0)),
            pass_iterations=int(d.get("pass_iterations", 0)),
            trace_id=d.get("trace_id"),
        )
        for e in d.get("passes", ()):
            prov.add_pass(int(e["iteration"]), str(e["name"]), dict(e["counters"]))
        for f in d.get("fusions", ()):
            prov.add_fusion(
                str(f["pattern"]), str(f["anchor"]),
                tuple(f["nodes"]), str(f["output"]),
            )
        for s in d.get("specializations", ()):
            prov.add_specialization(dict(s["bindings"]), dict(s["tiles"]))
        return prov

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able form (what ``benchmarks/run.py --trace`` embeds)."""
        return {
            "nodes_before": self.nodes_before,
            "nodes_after": self.nodes_after,
            "pass_iterations": self.pass_iterations,
            "passes": [
                {"iteration": e.iteration, "name": e.name, "counters": dict(e.counters)}
                for e in self.passes
            ],
            "fusions": [dataclasses.asdict(f) for f in self.fusions],
            "specializations": [
                {"bindings": dict(s.bindings), "tiles": dict(s.tiles)}
                for s in self.specializations
            ],
            "trace_id": self.trace_id,
        }
