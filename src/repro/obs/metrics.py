"""Unified metrics: one registry of counters / gauges / histograms for the
whole serving stack.

Before this module the system had three ad-hoc, differently-keyed dict
stores (the token engine's ``metrics``, the compiled-model server's
``metrics``/``summary()`` and the per-cache ``stats`` dicts).  They remain
as read-only *aliases*, but every number is now published through a
:class:`MetricsRegistry`:

* **Counter** — monotonically increasing int (``requests``, cache hits).
* **Gauge** — last-written value, or a *callback* gauge whose value is read
  live at snapshot time (cache sizes route through callbacks, so the
  registry never holds a stale copy).
* **Histogram** — log-bucketed distribution with exact count/sum/min/max
  and quantile estimates (p50/p95/p99 within the bucket growth factor).
  Memory is bounded by the number of occupied buckets (≈ ``log(max/min) /
  log(growth)``), never by the number of samples — a long-lived server
  records billions of latencies in a few hundred ints.

Canonical key scheme
====================

Dotted, lowercase: ``<subsystem>.<object>.<field>``.  The cache scheme the
three previously-divergent stores now share:

    cache.<scope>.size | capacity | hits | misses | evictions | hit_rate

with ``scope`` = ``plan`` (PlanCache specializations), ``prefill`` (token
engine's jitted-prefill cache), ...  Serving metrics live under
``serve.*`` (``serve.requests``, ``serve.latency_ms``), engine metrics
under ``engine.*``.

Exports: :meth:`MetricsRegistry.snapshot` (JSON-able dict, deterministic
key order) and :meth:`MetricsRegistry.to_prometheus` (text exposition
format, names sanitized to ``repro_``-prefixed underscores).

Stdlib-only; imports nothing from the rest of :mod:`repro`.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "set_default_registry", "CACHE_STAT_FIELDS",
    "cache_key",
]

#: The canonical per-cache stat fields (mirrors ``LruCache.stats``).
CACHE_STAT_FIELDS = ("size", "capacity", "hits", "misses", "evictions", "hit_rate")


def cache_key(scope: str, field: str) -> str:
    """The canonical registry key for one cache stat: ``cache.<scope>.<field>``."""
    return f"cache.{scope}.{field}"


class Counter:
    """Monotonic counter.  ``inc`` is thread-safe."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-written value; optionally backed by a live callback."""

    __slots__ = ("_lock", "_value", "_fn")

    def __init__(self, fn: Optional[Callable[[], float]] = None) -> None:
        self._lock = threading.Lock()
        self._value = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        if self._fn is not None:
            raise ValueError("cannot set() a callback gauge")
        with self._lock:
            self._value = v

    @property
    def value(self) -> float:
        if self._fn is not None:
            return self._fn()
        with self._lock:
            return self._value


class Histogram:
    """Log-bucketed distribution: bounded memory, quantiles within the
    bucket growth factor.

    Samples map to geometric buckets ``[lo * growth^i, lo * growth^(i+1))``;
    only *occupied* buckets are stored.  count/sum/min/max are exact;
    :meth:`quantile` returns the geometric midpoint of the bucket holding
    the requested rank, so its relative error is bounded by ``growth``
    (default 1.15 ⇒ ≤ ~7.5% either side — tighter than the bucket-to-bucket
    variance of any real latency measurement).  Values ≤ ``lo`` (including
    zero) land in a dedicated underflow bucket reported as ``lo``.
    """

    __slots__ = ("_lock", "growth", "lo", "_log_growth", "buckets",
                 "count", "total", "min", "max")

    def __init__(self, growth: float = 1.15, lo: float = 1e-6) -> None:
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if lo <= 0.0:
            raise ValueError(f"lo must be > 0, got {lo}")
        self._lock = threading.Lock()
        self.growth = growth
        self.lo = lo
        self._log_growth = math.log(growth)
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return -1  # underflow bucket
        return int(math.log(v / self.lo) / self._log_growth)

    def observe(self, v: float) -> None:
        v = float(v)
        i = self._index(v)
        with self._lock:
            self.buckets[i] = self.buckets.get(i, 0) + 1
            self.count += 1
            self.total += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def _bucket_value(self, i: int) -> float:
        if i < 0:
            return self.lo
        # geometric midpoint of [lo*g^i, lo*g^(i+1))
        return self.lo * self.growth ** (i + 0.5)

    def quantile(self, q: float) -> Optional[float]:
        """Value at quantile ``q`` ∈ [0, 1]; None when empty.  Exact at the
        extremes (min/max), bucket-midpoint in between."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self.count == 0:
                return None
            if q <= 0.0:
                return self.min
            if q >= 1.0:
                return self.max
            rank = q * (self.count - 1) + 1  # 1-based rank, linear in q
            seen = 0
            for i in sorted(self.buckets):
                seen += self.buckets[i]
                if seen >= rank:
                    return min(max(self._bucket_value(i), self.min), self.max)
            return self.max

    @property
    def avg(self) -> Optional[float]:
        return (self.total / self.count) if self.count else None

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            count, total = self.count, self.total
        if count == 0:
            return {"count": 0, "sum": 0.0, "avg": None, "min": None,
                    "max": None, "p50": None, "p95": None, "p99": None}
        return {
            "count": count,
            "sum": total,
            "avg": total / count,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.5),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Get-or-create registry of named metrics with deterministic exports.

    One registry per serving component tree: the compiled-model server, the
    token engine and standalone compiled models each own one (injectable
    for sharing/aggregation), and every cache they hold registers its
    canonical ``cache.<scope>.*`` callbacks into it.  ``snapshot()`` and
    ``to_prometheus()`` iterate names sorted, so exports are byte-stable
    for identical state regardless of registration/publish order."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get_or_create(self, name: str, cls, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}, "
                    f"requested {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, Gauge)

    def callback_gauge(self, name: str, fn: Callable[[], float]) -> Gauge:
        """(Re-)register a live-read gauge.  Re-registration replaces the
        callback — the registry reflects the *current* instance of whatever
        object backs the name (e.g. the newest attached cache)."""
        with self._lock:
            g = Gauge(fn)
            self._metrics[name] = g
            return g

    def histogram(self, name: str, **kwargs: Any) -> Histogram:
        return self._get_or_create(name, Histogram, lambda: Histogram(**kwargs))

    def attach_cache(self, scope: str, cache: Any) -> None:
        """Register the canonical ``cache.<scope>.*`` callback gauges for an
        :class:`repro.core.cache.LruCache`-shaped object (anything with a
        ``stats`` dict property)."""
        for field in CACHE_STAT_FIELDS:
            self.callback_gauge(
                cache_key(scope, field),
                lambda c=cache, f=field: float(c.stats[f]),
            )

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str) -> Optional[Any]:
        with self._lock:
            return self._metrics.get(name)

    # -- exports ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-able dict, keys sorted.  Counters → int, gauges → float,
        histograms → their stats dict."""
        out: Dict[str, Any] = {}
        for name in self.names():
            m = self.get(name)
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
            elif isinstance(m, Histogram):
                out[name] = m.stats()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4).  Dotted names become
        ``repro_``-prefixed underscore names; histograms render as
        summaries with p50/p95/p99 quantiles."""
        lines: List[str] = []
        for name in self.names():
            m = self.get(name)
            pname = "repro_" + "".join(
                ch if (ch.isalnum() or ch == "_") else "_" for ch in name
            )
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_num(m.value)}")
            elif isinstance(m, Histogram):
                s = m.stats()
                lines.append(f"# TYPE {pname} summary")
                for q, key in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
                    if s[key] is not None:
                        lines.append(f'{pname}{{quantile="{q}"}} {_prom_num(s[key])}')
                lines.append(f"{pname}_sum {_prom_num(s['sum'])}")
                lines.append(f"{pname}_count {s['count']}")
        return "\n".join(lines) + "\n"


def _prom_num(v: float) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-default registry: what components publish into when no
    explicit registry is injected, and what ``benchmarks/run.py --metrics``
    snapshots."""
    return _DEFAULT


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-default registry (tests use this for isolation).
    Returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, reg
    return prev
