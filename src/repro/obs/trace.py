"""Tracing: thread-safe nested spans over the whole lowering/serving stack.

One :class:`Tracer` records **spans** (named wall-time intervals with
structured attributes, nesting per thread), **instant events** (cache
hits/misses, evictions) and **async spans** (request lifecycles that begin
and end in different call stacks, linked by an id).  The recorded timeline
exports two ways:

* :meth:`Tracer.to_chrome_trace` — the Chrome ``chrome://tracing`` /
  Perfetto JSON object format (``{"traceEvents": [...]}``, ``ph`` = "X"
  complete spans, "i" instants, "b"/"e" async pairs, timestamps in
  microseconds on a single monotonic clock), loadable as-is.
* :meth:`Tracer.render_tree` — a human-readable nested tree with durations
  and attributes, for terminals and bug reports.

Install/uninstall discipline
============================

Nothing in the stack holds a tracer; instrumentation sites call the
module-level :func:`span` / :func:`event` helpers, which consult the one
installed tracer (:func:`install` / :func:`uninstall`).  With **no tracer
installed** the helpers return a shared no-op context manager — one global
read and no allocation — and the hottest sites additionally guard on the
module flag :data:`enabled`, so the uninstrumented hot path stays at parity
(the ``sys_plan_overhead`` benchmark row pins this).

This module is intentionally dependency-free (stdlib only) and imports
nothing from the rest of :mod:`repro`.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from typing import Any, Dict, List, Optional

#: True iff a tracer is installed.  Hot paths guard on this before building
#: span attribute dicts; everything else just calls :func:`span`.
enabled: bool = False

_TRACER: Optional["Tracer"] = None
_INSTALL_LOCK = threading.Lock()
_IDS = itertools.count(1)


@dataclasses.dataclass
class SpanRecord:
    """One finished timeline entry.

    kind   "span" (complete interval) | "instant" | "async_b" | "async_e"
    ts     start offset from the tracer epoch, seconds (monotonic clock)
    dur    duration in seconds (0.0 for instants and async endpoints)
    depth  nesting depth within its thread at record time (spans only)
    aid    async-link id ("async_b"/"async_e" only) — entries sharing an aid
           form one logical flow (e.g. one serving request)
    """

    name: str
    ts: float
    dur: float
    tid: int
    depth: int = 0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    kind: str = "span"
    aid: Optional[int] = None


class _ActiveSpan:
    """Context manager for one open span; finishing records it."""

    __slots__ = ("tracer", "name", "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0

    def set(self, **attrs: Any) -> "_ActiveSpan":
        """Attach attributes discovered mid-span (e.g. chosen tiles)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_ActiveSpan":
        self.t0 = time.perf_counter()
        self.tracer._enter(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.tracer._exit(self, time.perf_counter())
        return False


class _NullSpan:
    """Shared do-nothing span: what instrumentation sites get when no tracer
    is installed.  A singleton — entering it allocates nothing."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe span recorder with a single monotonic epoch.

    Per-thread nesting is tracked in a ``threading.local`` stack; finished
    records append to one list under a lock (recording is the only
    synchronized operation, and it is O(1))."""

    def __init__(self, trace_id: Optional[str] = None) -> None:
        self.trace_id = trace_id or f"trace-{next(_IDS)}-{int(time.time())}"
        self.epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._records: List[SpanRecord] = []
        self._local = threading.local()
        self._tids: Dict[int, int] = {}  # thread ident -> small stable tid

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **attrs: Any) -> _ActiveSpan:
        return _ActiveSpan(self, name, attrs)

    def event(self, name: str, **attrs: Any) -> None:
        """Record an instant event (cache hit/miss/evict, rejection, ...)."""
        now = time.perf_counter()
        with self._lock:
            self._records.append(
                SpanRecord(
                    name=name, ts=now - self.epoch, dur=0.0,
                    tid=self._tid(), depth=self._depth(), attrs=attrs,
                    kind="instant",
                )
            )

    def async_begin(self, name: str, aid: int, **attrs: Any) -> None:
        """Open an async span (ends in a different call stack / thread) —
        e.g. one serving request from submit to completion, ``aid`` = its
        request id."""
        now = time.perf_counter()
        with self._lock:
            self._records.append(
                SpanRecord(
                    name=name, ts=now - self.epoch, dur=0.0, tid=self._tid(),
                    attrs=attrs, kind="async_b", aid=aid,
                )
            )

    def async_end(self, name: str, aid: int, **attrs: Any) -> None:
        now = time.perf_counter()
        with self._lock:
            self._records.append(
                SpanRecord(
                    name=name, ts=now - self.epoch, dur=0.0, tid=self._tid(),
                    attrs=attrs, kind="async_e", aid=aid,
                )
            )

    def _stack(self) -> List[_ActiveSpan]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _depth(self) -> int:
        return len(self._stack())

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def _enter(self, span: _ActiveSpan) -> None:
        self._stack().append(span)

    def _exit(self, span: _ActiveSpan, t1: float) -> None:
        stack = self._stack()
        # tolerate exit-out-of-order (a leaked span) rather than corrupting
        # the whole stack: pop through the matching entry
        while stack:
            top = stack.pop()
            if top is span:
                break
        with self._lock:
            self._records.append(
                SpanRecord(
                    name=span.name, ts=span.t0 - self.epoch,
                    dur=t1 - span.t0, tid=self._tid(), depth=len(stack),
                    attrs=span.attrs, kind="span",
                )
            )

    # -- reading ------------------------------------------------------------
    @property
    def records(self) -> List[SpanRecord]:
        """Snapshot of everything recorded so far (copy, sorted by start)."""
        with self._lock:
            recs = list(self._records)
        return sorted(recs, key=lambda r: (r.ts, -r.depth))

    def spans(self, name: Optional[str] = None) -> List[SpanRecord]:
        """Finished complete spans, optionally filtered by exact name."""
        return [
            r for r in self.records
            if r.kind == "span" and (name is None or r.name == name)
        ]

    def events(self, name: Optional[str] = None) -> List[SpanRecord]:
        return [
            r for r in self.records
            if r.kind == "instant" and (name is None or r.name == name)
        ]

    # -- export -------------------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        """The Chrome-trace / Perfetto JSON object format.  Timestamps are
        microseconds from the tracer epoch on one monotonic clock, so the
        file loads with correct relative timing anywhere."""
        ph = {"span": "X", "instant": "i", "async_b": "b", "async_e": "e"}
        events: List[Dict[str, Any]] = [
            {
                "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                "args": {"name": f"repro.obs {self.trace_id}"},
            }
        ]
        for r in self.records:
            ev: Dict[str, Any] = {
                "name": r.name,
                "cat": r.name.split(".", 1)[0],
                "ph": ph[r.kind],
                "ts": round(r.ts * 1e6, 3),
                "pid": 0,
                "tid": r.tid,
                "args": _jsonable(r.attrs),
            }
            if r.kind == "span":
                ev["dur"] = round(r.dur * 1e6, 3)
            if r.aid is not None:
                ev["id"] = r.aid
                ev["s"] = "t"  # instant scope is ignored for b/e; harmless
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms", "otherData": {"trace_id": self.trace_id}}

    def dump(self, path: str) -> None:
        """Write the Chrome-trace JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
            f.write("\n")

    def render_tree(self) -> str:
        """Human-readable per-thread span tree with durations and attrs."""
        lines: List[str] = [f"trace {self.trace_id}"]
        recs = self.records
        tids = sorted({r.tid for r in recs})
        for tid in tids:
            if len(tids) > 1:
                lines.append(f"thread {tid}:")
            for r in recs:
                if r.tid != tid:
                    continue
                pad = "  " * (r.depth + 1)
                attrs = ", ".join(f"{k}={_fmt(v)}" for k, v in r.attrs.items())
                attrs = f"  [{attrs}]" if attrs else ""
                if r.kind == "span":
                    lines.append(f"{pad}{r.name}  {r.dur * 1e3:.3f} ms{attrs}")
                elif r.kind == "instant":
                    lines.append(f"{pad}* {r.name}{attrs}")
                else:
                    arrow = "=>" if r.kind == "async_b" else "<="
                    lines.append(f"{pad}{arrow} {r.name}#{r.aid}{attrs}")
        return "\n".join(lines)


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion of span attrs to JSON-clean values (numpy
    scalars/arrays stringify via their repr-ish forms)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return _jsonable(item())
        except (TypeError, ValueError):
            pass
    return str(obj)


# ---------------------------------------------------------------------------
# module-level install discipline + no-op-cheap helpers
# ---------------------------------------------------------------------------


def install(tracer: Optional[Tracer] = None) -> Tracer:
    """Install ``tracer`` (or a fresh one) as *the* process tracer and flip
    :data:`enabled`.  Returns the installed tracer."""
    global _TRACER, enabled
    with _INSTALL_LOCK:
        _TRACER = tracer if tracer is not None else Tracer()
        enabled = True
        return _TRACER


def uninstall() -> Optional[Tracer]:
    """Remove the installed tracer (returning it) and flip :data:`enabled`
    off — instrumentation sites go back to the shared no-op span."""
    global _TRACER, enabled
    with _INSTALL_LOCK:
        t, _TRACER, enabled = _TRACER, None, False
        return t


def current() -> Optional[Tracer]:
    return _TRACER


def span(name: str, **attrs: Any):
    """The instrumentation-site entry point: a real span when a tracer is
    installed, the shared :data:`NULL_SPAN` otherwise."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, **attrs)


def event(name: str, **attrs: Any) -> None:
    t = _TRACER
    if t is not None:
        t.event(name, **attrs)


def async_begin(name: str, aid: int, **attrs: Any) -> None:
    t = _TRACER
    if t is not None:
        t.async_begin(name, aid, **attrs)


def async_end(name: str, aid: int, **attrs: Any) -> None:
    t = _TRACER
    if t is not None:
        t.async_end(name, aid, **attrs)
