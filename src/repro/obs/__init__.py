"""repro.obs — the observability plane: tracing, unified metrics, plan
provenance.

Three zero-dependency pieces, threaded through every stage of the stack
(pass pipeline → fusion → lowering → specialization → plan cache → kernel
dispatch → serving):

* :mod:`repro.obs.trace` — a thread-safe :class:`Tracer` of nested spans
  with structured attributes, exportable as Chrome-trace/Perfetto JSON and
  a human-readable tree.  Install one (:func:`install`) and the whole
  stack lights up; with none installed every site costs one global read.
* :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` of counters,
  gauges and bounded log-bucketed histograms with the canonical
  ``cache.<scope>.<field>`` / ``serve.*`` / ``engine.*`` key scheme, JSON
  snapshots and Prometheus text export.
* :mod:`repro.obs.provenance` — the :class:`PlanProvenance` record an
  :class:`~repro.backend.plan.ExecutionPlan` carries so the co-design
  artifact explains itself (``plan.pretty(verbose=True)``).

The package imports nothing from the rest of :mod:`repro` (the rest of
:mod:`repro` imports *it*), so it can never create a dependency cycle and
is importable in any stripped-down context.
"""
from . import metrics, provenance, trace  # noqa: F401
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
)
from .provenance import (  # noqa: F401
    FusionRecord,
    PassEntry,
    PlanProvenance,
    SpecializationEvent,
)
from .trace import (  # noqa: F401
    NULL_SPAN,
    SpanRecord,
    Tracer,
    async_begin,
    async_end,
    current,
    event,
    install,
    span,
    uninstall,
)


def tracing_enabled() -> bool:
    """True iff a tracer is installed (live view of :data:`trace.enabled`)."""
    return trace.enabled
