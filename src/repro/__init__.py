"""repro — pre-quantized model codification (Hanebutte et al. 2021) as a
production JAX framework: quantizer toolchain, PQ-IR artifact, TPU compiler
with Pallas kernels, 10-arch model zoo, multi-pod pjit distribution."""
__version__ = "1.0.0"
