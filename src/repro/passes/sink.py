"""Reshape/Transpose/Flatten sinking: move pure data-movement ops *past*
elementwise ops so compute chains become contiguous and visible to the fusion
patterns.

    Transpose → Relu → …      ⇒      Relu → Transpose → …
    Reshape → Mul(c) → …      ⇒      Mul(c) → Reshape → …
    Flatten → Relu → …        ⇒      Relu → Flatten → …

Elementwise ops commute exactly with permutations/reshapes of their data
input, so the rewrite is bit-exact.  Binary ops only qualify when the other
operand is a **rank-0 scalar** initializer: broadcasting a true scalar is
layout invariant, while per-channel operands are not, and even a size-1
rank>0 constant can rank-expand its operand.  The pass iterates to a local
fixpoint, so a shape op sinks through an arbitrarily long elementwise chain
in one ``run`` — which also keeps the whole pipeline idempotent.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..core.pqir import Graph, Node
from .analysis import GraphAnalysis
from .canonicalize import Pass
from .rewrite import unique_name

_SHAPE_OPS = frozenset({"Reshape", "Transpose", "Flatten"})
_UNARY = frozenset({"Relu", "Tanh", "Sigmoid", "Erf", "Sqrt", "Cast"})
_BINARY = frozenset({"Mul", "Add", "Sub", "Div"})
_SCALAR_PARAM = frozenset({"QuantizeLinear", "DequantizeLinear", "Clip"})


def _sinkable_through(ga: GraphAnalysis, consumer: Node, tensor: str) -> bool:
    t = consumer.op_type
    if t in _UNARY:
        return consumer.inputs[0] == tensor
    if t in _SCALAR_PARAM:
        if consumer.inputs[0] != tensor:
            return False
        for extra in consumer.inputs[1:]:
            if not extra:
                continue
            c = ga.const(extra)
            if c is None or c.ndim != 0:
                return False
        return True
    if t in _BINARY:
        if len(consumer.inputs) != 2 or tensor not in consumer.inputs:
            return False
        other = consumer.inputs[1] if consumer.inputs[0] == tensor else consumer.inputs[0]
        if other == tensor:
            return False  # e.g. Mul(t, t): rewiring one side is not enough
        c = ga.const(other)
        return c is not None and c.ndim == 0
    return False


class SinkShapes(Pass):
    name = "sink_shapes"

    def run(self, graph: Graph) -> Dict[str, int]:
        sunk = 0
        while True:
            ga = GraphAnalysis(graph)
            move = self._find(ga, graph)
            if move is None:
                return {"sunk": sunk}
            shape_op, consumer = move
            t = shape_op.outputs[0]
            new_t = unique_name(graph, f"{consumer.outputs[0]}_pre{shape_op.op_type.lower()}")
            # consumer now reads the shape op's input and produces a fresh name
            consumer.inputs[:] = [shape_op.inputs[0] if i == t else i for i in consumer.inputs]
            e_out = consumer.outputs[0]
            consumer.outputs[0] = new_t
            # the shape op re-materializes afterwards, keeping the public name
            replayed = Node(
                shape_op.op_type,
                [new_t] + list(shape_op.inputs[1:]),
                [e_out],
                dict(shape_op.attrs),
                shape_op.name,
            )
            idx = next(i for i, n in enumerate(graph.nodes) if n is shape_op)
            graph.nodes[idx] = replayed
            sunk += 1

    @staticmethod
    def _find(ga: GraphAnalysis, graph: Graph):
        for node in graph.toposorted():
            if node.op_type not in _SHAPE_OPS:
                continue
            consumer = ga.single_consumer(node.outputs[0])
            if consumer is None or consumer.op_type in _SHAPE_OPS:
                continue
            if _sinkable_through(ga, consumer, node.outputs[0]):
                return node, consumer
        return None
