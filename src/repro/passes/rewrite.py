"""Declarative pattern-rewrite engine for PQ-IR graphs.

A fusion or canonicalization candidate is described as *data*, not code: a
:class:`Pattern` is a chain of :class:`OpSpec` entries matched along
single-consumer edges starting at an anchor node.  Each spec carries the
preconditions the old hand-written matchers used to check imperatively —
accepted op types, arity, required attribute values, which inputs must be
initializers (captured by name), and an optional escape-hatch predicate for
anything numeric (e.g. "scale must be exactly 1.0").

Matching walks the producer→consumer chain with the same safety contract the
original ``core.compile`` matchers enforced: every intermediate tensor must
have exactly one consumer and must not be a graph output, so consuming the
matched nodes can never orphan a value another part of the graph needs.

The module also hosts the small graph-surgery helpers every rewrite needs
(:func:`remove_nodes`, :func:`replace_uses`, :func:`bypass_tensor`,
:func:`unique_name`), so passes stay declarative + a few lines of wiring.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.pqir import Graph, Node
from .analysis import GraphAnalysis

Predicate = Callable[[GraphAnalysis, Node], bool]


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One link in a pattern chain.

    op            accepted op_type (or tuple of alternatives)
    capture       name under which the matched node is recorded
    optional      the chain may skip this link
    arity         required number of non-empty inputs (None = any)
    attrs         attribute values that must match exactly
    const_inputs  input-index → capture-name; that input must be an
                  initializer, whose value is recorded in ``Match.consts``
    const_operand for commutative binary ops: the operand that is *not* the
                  incoming chain tensor must be an initializer (captured)
    where         extra predicate on (analysis, node)
    """

    op: Union[str, Tuple[str, ...]]
    capture: str = ""
    optional: bool = False
    arity: Optional[int] = None
    attrs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    const_inputs: Mapping[int, str] = dataclasses.field(default_factory=dict)
    const_operand: str = ""
    where: Optional[Predicate] = None

    @property
    def ops(self) -> Tuple[str, ...]:
        return (self.op,) if isinstance(self.op, str) else tuple(self.op)


@dataclasses.dataclass(frozen=True)
class Pattern:
    """An op chain matched along single-consumer edges.  ``where`` (if set)
    validates the completed :class:`Match` — use it for cross-link
    constraints (e.g. "the fp16 down-cast and up-cast must appear together")."""

    name: str
    chain: Tuple[OpSpec, ...]
    where: Optional[Callable[["Match"], bool]] = None

    @property
    def anchor_ops(self) -> Tuple[str, ...]:
        return self.chain[0].ops


class Match:
    """A successful pattern application: matched nodes in chain order plus
    captured nodes/constants by name."""

    def __init__(self, pattern: Pattern) -> None:
        self.pattern = pattern
        self.nodes: List[Node] = []
        self._caps: Dict[str, Node] = {}
        self.consts: Dict[str, np.ndarray] = {}

    def node(self, capture: str) -> Optional[Node]:
        return self._caps.get(capture)

    def __contains__(self, capture: str) -> bool:
        return capture in self._caps

    @property
    def anchor(self) -> Node:
        return self.nodes[0]

    @property
    def last(self) -> Node:
        return self.nodes[-1]

    @property
    def out_tensor(self) -> str:
        return self.last.outputs[0]


def _try_spec(ga: GraphAnalysis, spec: OpSpec, node: Node, chain_in: Optional[str]) -> Optional[Dict[str, np.ndarray]]:
    """Check one spec against one node; returns captured constants or None."""
    if node.op_type not in spec.ops:
        return None
    if spec.arity is not None and len([i for i in node.inputs if i]) != spec.arity:
        return None
    for k, v in spec.attrs.items():
        if node.attrs.get(k) != v:
            return None
    consts: Dict[str, np.ndarray] = {}
    for idx, cap in spec.const_inputs.items():
        if idx >= len(node.inputs):
            return None
        val = ga.const(node.inputs[idx])
        if val is None:
            return None
        consts[cap] = val
    if spec.const_operand:
        if len(node.inputs) != 2:
            return None
        if chain_in is not None:
            if chain_in not in node.inputs:
                return None
            other = node.inputs[1] if node.inputs[0] == chain_in else node.inputs[0]
        else:
            # anchor position: exactly one operand must be the constant
            flags = [ga.is_const(i) for i in node.inputs]
            if sum(flags) != 1:
                return None
            other = node.inputs[flags.index(True)]
        val = ga.const(other)
        if val is None:
            return None
        consts[spec.const_operand] = val
    if spec.where is not None and not spec.where(ga, node):
        return None
    return consts


def match_chain(ga: GraphAnalysis, start: Node, pattern: Pattern) -> Optional[Match]:
    """Match ``pattern`` anchored at ``start``; None if any mandatory link
    fails.  Optional links are matched greedily."""
    specs = pattern.chain
    got = _try_spec(ga, specs[0], start, None)
    if got is None:
        return None
    m = Match(pattern)
    _record(m, specs[0], start, got)
    cur = start.outputs[0]
    for spec in specs[1:]:
        nxt = ga.single_consumer(cur)
        got = None
        if nxt is not None and (spec.const_operand or (nxt.inputs and nxt.inputs[0] == cur)):
            got = _try_spec(ga, spec, nxt, cur)
        if got is not None:
            _record(m, spec, nxt, got)
            cur = nxt.outputs[0]
        elif spec.optional:
            continue
        else:
            return None
    if pattern.where is not None and not pattern.where(m):
        return None
    return m


def _record(m: Match, spec: OpSpec, node: Node, consts: Dict[str, np.ndarray]) -> None:
    m.nodes.append(node)
    if spec.capture:
        m._caps[spec.capture] = node
    m.consts.update(consts)


def ql_params(ga: GraphAnalysis, node: Node):
    """(scale, zero_point) initializers of a QuantizeLinear/DequantizeLinear
    node; zero_point defaults to int8 0.  None scale means non-constant."""
    scale = ga.const(node.inputs[1]) if len(node.inputs) > 1 else None
    zp = ga.const(node.inputs[2]) if len(node.inputs) > 2 else np.zeros((), np.int8)
    return scale, zp


# ---------------------------------------------------------------------------
# graph surgery helpers
# ---------------------------------------------------------------------------


def all_tensor_names(graph: Graph) -> set:
    names = {t.name for t in graph.inputs} | {t.name for t in graph.outputs} | set(graph.initializers)
    for node in graph.nodes:
        names.update(node.inputs)
        names.update(node.outputs)
    return names


def unique_name(graph: Graph, base: str) -> str:
    taken = all_tensor_names(graph)
    if base not in taken:
        return base
    i = 1
    while f"{base}_{i}" in taken:
        i += 1
    return f"{base}_{i}"


def replace_uses(graph: Graph, old: str, new: str) -> None:
    """Rewrite every node input reading ``old`` to read ``new``."""
    for node in graph.nodes:
        node.inputs[:] = [new if i == old else i for i in node.inputs]


def remove_nodes(graph: Graph, nodes: Iterable[Node]) -> None:
    doomed = {id(n) for n in nodes}
    graph.nodes[:] = [n for n in graph.nodes if id(n) not in doomed]


def bypass_tensor(graph: Graph, src: str, dst: str) -> bool:
    """Make the graph read ``src`` wherever it read ``dst`` (the nodes that
    produced ``dst`` must already be removed).  If ``dst`` is a graph output,
    the surviving ``src`` tensor is renamed to ``dst`` so the artifact's
    external interface is unchanged; that rename is only possible when ``src``
    is node-produced and not itself part of the interface — returns False if
    the rewrite cannot be done safely (caller should skip the rewrite)."""
    out_names = {t.name for t in graph.outputs}
    if dst not in out_names:
        replace_uses(graph, dst, src)
        return True
    in_names = {t.name for t in graph.inputs}
    if src in out_names or src in in_names or src in graph.initializers:
        return False
    producer = None
    for node in graph.nodes:
        if src in node.outputs:
            producer = node
            break
    if producer is None:
        return False
    producer.outputs[producer.outputs.index(src)] = dst
    replace_uses(graph, src, dst)
    return True
