"""Graph-wide analyses for PQ-IR: dtype/shape inference and def-use maps.

This is the single home for the facts every optimization pass and the backend
compiler need about a :class:`repro.core.pqir.Graph`:

* :func:`infer_dtypes` — forward dtype propagation over the standard-op
  vocabulary (replaces the private ``infer_dtypes`` that used to live in
  ``repro.core.compile``).
* :func:`infer_shapes` — best-effort static shape propagation.  Unknown
  dimensions are ``None``; a wholly unknown shape is ``None``.  Passes must
  treat ``None`` as "don't know" and stay conservative.  A ``None`` *leading*
  dimension doubles as the symbolic batch: artifacts are exported with
  ``(None, …)`` inputs, the per-op rules (MatMul/Gemm/Conv/Reshape/Flatten/…)
  propagate that unknown through to the outputs, and the batch-polymorphic
  compile path (``compile_model(batch="dynamic")``) later *binds* it to a
  concrete bucket — either by re-running :func:`infer_shapes` with ``batch=``
  or per-value via :func:`bind_batch`.
* :class:`GraphAnalysis` — a cached bundle of dtypes, shapes, producer and
  consumer maps plus the constant/initializer view, rebuilt from scratch by
  each pass iteration so it can never go stale against a mutated graph.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.pqir import DTYPES, Graph, Model, Node

Shape = Optional[Tuple[Optional[int], ...]]

_UNARY_PASSTHROUGH = frozenset(
    {"Relu", "Tanh", "Sigmoid", "Erf", "Sqrt", "Softmax", "Clip", "Identity"}
)
_BINARY_PROMOTE = frozenset({"Mul", "Add", "Sub", "Div", "Pow"})


# ---------------------------------------------------------------------------
# dtype inference
# ---------------------------------------------------------------------------


def infer_dtypes(graph: Graph) -> Dict[str, str]:
    """Forward dtype propagation; returns tensor-name → dtype-name."""
    dt: Dict[str, str] = {t.name: t.dtype for t in graph.inputs}
    for name, arr in graph.initializers.items():
        dt[name] = str(arr.dtype)
    for node in graph.toposorted():
        o = node.outputs[0]
        t = node.op_type
        if t in ("MatMulInteger", "ConvInteger"):
            dt[o] = "int32"
        elif t == "Gemm":
            # integer Gemm accumulates in int32 (dialect rule, see
            # repro.core.runtime); float Gemm preserves its input dtype
            a = dt.get(node.inputs[0], "float32")
            dt[o] = "int32" if np.issubdtype(DTYPES.get(a, np.float32), np.integer) else a
        elif t == "QuantizeLinear":
            dt[o] = dt.get(node.inputs[2], "int8") if len(node.inputs) > 2 else "int8"
        elif t == "DequantizeLinear":
            dt[o] = "float32"
        elif t == "Cast":
            dt[o] = node.attrs["to"]
        elif t == "Shape":
            dt[o] = "int64"
        elif t in _BINARY_PROMOTE and len(node.inputs) >= 2:
            a, b = dt.get(node.inputs[0]), dt.get(node.inputs[1])
            if a is not None and b is not None:
                dt[o] = str(np.promote_types(a, b))
            else:
                dt[o] = a or b or "float32"
        else:
            dt[o] = dt.get(node.inputs[0], "float32") if node.inputs else "float32"
        for extra in node.outputs[1:]:
            dt[extra] = dt[o]
    return dt


# ---------------------------------------------------------------------------
# shape inference (best-effort; None = unknown)
# ---------------------------------------------------------------------------


def _broadcast(a: Shape, b: Shape) -> Shape:
    if a is None or b is None:
        return None
    n = max(len(a), len(b))
    out: List[Optional[int]] = []
    for i in range(n):
        da = a[len(a) - n + i] if i >= n - len(a) else 1
        db = b[len(b) - n + i] if i >= n - len(b) else 1
        if da is None and db is None:
            out.append(None)
        elif da is None:
            out.append(db if db != 1 else None)
        elif db is None:
            out.append(da if da != 1 else None)
        elif da == 1:
            out.append(db)
        elif db == 1:
            out.append(da)
        elif da == db:
            out.append(da)
        else:
            return None  # incompatible — treat as unknown
    return tuple(out)


def _prod(dims) -> Optional[int]:
    p = 1
    for d in dims:
        if d is None:
            return None
        p *= int(d)
    return p


def _conv_hw(d: Optional[int], k: int, pad0: int, pad1: int, stride: int, dil: int) -> Optional[int]:
    if d is None:
        return None
    return (d + pad0 + pad1 - (dil * (k - 1) + 1)) // stride + 1


def _node_shape(node: Node, sh, const) -> Shape:  # noqa: C901 (dispatch table)
    t = node.op_type
    s0: Shape = sh(node.inputs[0]) if node.inputs else None
    if t in _UNARY_PASSTHROUGH or t in ("Cast", "QuantizeLinear", "DequantizeLinear"):
        return s0
    if t in ("Mul", "Add", "Sub", "Div", "Pow"):
        return _broadcast(s0, sh(node.inputs[1]))
    if t in ("MatMul", "MatMulInteger"):
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None or len(s1) != 2 or len(s0) < 1:
            return None
        return tuple(s0[:-1]) + (s1[1],)
    if t == "Gemm":
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None or len(s0) != 2 or len(s1) != 2:
            return None
        m = s0[1] if node.attrs.get("transA", 0) else s0[0]
        n = s1[0] if node.attrs.get("transB", 0) else s1[1]
        return (m, n)
    if t in ("Conv", "ConvInteger"):
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None or len(s0) != 4 or len(s1) != 4:
            return None
        strides = tuple(node.attrs.get("strides", (1, 1)))
        pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
        dil = tuple(node.attrs.get("dilations", (1, 1)))
        kh, kw = s1[2], s1[3]
        return (
            s0[0],
            s1[0],
            _conv_hw(s0[2], int(kh), pads[0], pads[2], strides[0], dil[0]),
            _conv_hw(s0[3], int(kw), pads[1], pads[3], strides[1], dil[1]),
        )
    if t == "Reshape":
        target = const(node.inputs[1]) if len(node.inputs) > 1 else None
        if target is None:
            return None
        dims = [int(d) for d in np.asarray(target).reshape(-1)]
        if -1 not in dims:
            return tuple(dims)
        total = _prod(s0) if s0 is not None else None
        if total is None:
            return tuple(None if d == -1 else d for d in dims)
        rest = _prod([d for d in dims if d != -1])
        return tuple(total // rest if d == -1 else d for d in dims)
    if t == "Transpose":
        if s0 is None:
            return None
        perm = node.attrs.get("perm") or list(range(len(s0)))[::-1]
        return tuple(s0[int(p)] for p in perm)
    if t == "Flatten":
        if s0 is None:
            return None
        axis = int(node.attrs.get("axis", 1))
        return (_prod(s0[:axis]) if axis else 1, _prod(s0[axis:]))
    if t == "Concat":
        shapes = [sh(i) for i in node.inputs]
        if any(s is None for s in shapes):
            return None
        axis = int(node.attrs["axis"])
        dims = list(shapes[0])
        cat = 0
        for s in shapes:
            if s[axis] is None:
                cat = None
                break
            cat += s[axis]
        dims[axis] = cat
        return tuple(dims)
    if t == "Gather":
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None:
            return None
        axis = int(node.attrs.get("axis", 0))
        return tuple(s0[:axis]) + tuple(s1) + tuple(s0[axis + 1 :])
    if t == "Slice":
        starts = const(node.inputs[1]) if len(node.inputs) > 1 else None
        ends = const(node.inputs[2]) if len(node.inputs) > 2 else None
        if s0 is None or starts is None or ends is None:
            return None
        starts = [int(v) for v in np.asarray(starts).reshape(-1)]
        ends = [int(v) for v in np.asarray(ends).reshape(-1)]
        axes_c = const(node.inputs[3]) if len(node.inputs) > 3 and node.inputs[3] else None
        steps_c = const(node.inputs[4]) if len(node.inputs) > 4 and node.inputs[4] else None
        axes = [int(v) for v in np.asarray(axes_c).reshape(-1)] if axes_c is not None else list(range(len(starts)))
        steps = [int(v) for v in np.asarray(steps_c).reshape(-1)] if steps_c is not None else [1] * len(starts)
        dims = list(s0)
        for s, e, a, st in zip(starts, ends, axes, steps):
            if dims[a] is None:
                continue  # unknown stays unknown
            dims[a] = len(range(*slice(s, e, st).indices(int(dims[a]))))
        return tuple(dims)
    if t in ("Squeeze", "Unsqueeze"):
        axes = const(node.inputs[1]) if len(node.inputs) > 1 else None
        if s0 is None or axes is None:
            return None
        ax = [int(a) for a in np.asarray(axes).reshape(-1)]
        if t == "Squeeze":
            return tuple(d for i, d in enumerate(s0) if i not in ax and i - len(s0) not in ax)
        dims = list(s0)
        for a in sorted(ax):
            dims.insert(a if a >= 0 else a + len(dims) + 1, 1)
        return tuple(dims)
    if t in ("MaxPool", "AveragePool"):
        if s0 is None or len(s0) != 4:
            return None
        kh, kw = node.attrs["kernel_shape"]
        strides = tuple(node.attrs.get("strides", (kh, kw)))
        pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
        return (
            s0[0],
            s0[1],
            _conv_hw(s0[2], int(kh), pads[0], pads[2], strides[0], 1),
            _conv_hw(s0[3], int(kw), pads[1], pads[3], strides[1], 1),
        )
    if t == "GlobalAveragePool":
        return None if s0 is None else (s0[0], s0[1], 1, 1)
    if t == "ReduceMean":
        if s0 is None:
            return None
        axes = node.attrs.get("axes")
        ax = [int(a) % len(s0) for a in axes] if axes else list(range(len(s0)))
        keep = bool(node.attrs.get("keepdims", 1))
        if keep:
            return tuple(1 if i in ax else d for i, d in enumerate(s0))
        return tuple(d for i, d in enumerate(s0) if i not in ax)
    return None


# ---------------------------------------------------------------------------
# symbolic batch (leading-dim) helpers
# ---------------------------------------------------------------------------


def has_symbolic_batch(shape: Shape) -> bool:
    """True when the leading dimension is the symbolic (unknown) batch."""
    return shape is not None and len(shape) >= 1 and shape[0] is None


def bind_batch(shape: Shape, batch: Optional[int]) -> Shape:
    """Substitute the symbolic leading dim with a concrete ``batch``.

    ``None`` batch (or a shape without a symbolic leading dim) passes
    through unchanged — binding is always a no-op on static shapes."""
    if batch is None or not has_symbolic_batch(shape):
        return shape
    return (int(batch),) + tuple(shape[1:])


def batch_inputs(graph: Graph) -> List[str]:
    """Names of graph inputs carrying the symbolic batch (leading ``None``).

    These are the feeds a batch-polymorphic compiled model pads to the
    bucket size; a graph with none of them has no batch axis to
    specialize over."""
    return [t.name for t in graph.inputs if has_symbolic_batch(tuple(t.shape))]


#: Ops that are row-elementwise and shape-preserving along axis 0 whenever the
#: batch rides only the data operand (scales/zero-points are constants).
_ROWWISE_OPS = frozenset(
    {"Relu", "Tanh", "Sigmoid", "Erf", "Sqrt", "Clip", "Identity",
     "Cast", "QuantizeLinear", "DequantizeLinear"}
)
#: Contractions whose first operand carries independent rows / the N axis.
_LEAD0_OPS = frozenset({"MatMul", "MatMulInteger", "Gemm"})
_NCHW_OPS = frozenset(
    {"Conv", "ConvInteger", "MaxPool", "AveragePool", "GlobalAveragePool"}
)
_BCAST_OPS = frozenset({"Mul", "Add", "Sub", "Div", "Pow"})


def batch_mixing_nodes(ga: "GraphAnalysis") -> List[str]:
    """Nodes that cannot be *proved* batch-elementwise along axis 0.

    Batch-polymorphic execution pads feeds with zero rows and slices results
    back — exact only when no op mixes information across the leading dim.
    That holds for the artifact's quantized-inference vocabulary (rowwise
    elementwise chains, weight contractions, NCHW windows) but is false for
    e.g. a global ReduceMean, Softmax over axis 0, a batch-folding Reshape,
    or a Concat on axis 0 — those would silently compute over the zero
    padding.  ``compile_model(batch="dynamic")`` rejects graphs where this
    returns a non-empty list of human-readable reasons.  Conservative by
    construction: an op it cannot reason about (unknown shapes, unlisted op
    types touching a batch-carrying value) is reported, not assumed safe.
    """

    def carries(name: str) -> bool:
        if ga.is_const(name):
            return False
        s = ga.shape(name)
        if s is None:
            return True  # unknown: assume it may carry the batch
        return len(s) > 0 and s[0] is None

    def norm_axes(axes, rank):
        return {int(a) % rank for a in axes}

    problems: List[str] = []
    for node in ga.graph.toposorted():
        ins = [i for i in node.inputs if i]
        batch_ins = [i for i in ins if carries(i)]
        if not batch_ins:
            continue
        t = node.op_type
        s0 = ga.shape(node.inputs[0]) if node.inputs else None
        rank = len(s0) if s0 is not None else None
        only_data = set(batch_ins) <= {node.inputs[0]}
        reason = None

        if t in _ROWWISE_OPS:
            reason = None if only_data else "batch rides a non-data operand"
        elif t in _BCAST_OPS:
            out = ga.shape(node.outputs[0])
            if out is None or out[0] is not None:
                reason = "broadcast result does not keep the batch on axis 0"
            else:
                for i in ins:
                    s = ga.shape(i)
                    if s is None:
                        reason = f"operand {i!r} has unknown shape"
                        break
                    if len(s) == len(out) and s[0] is not None and s[0] != 1:
                        reason = f"operand {i!r} pins axis 0 to {s[0]}"
                        break
        elif t in _LEAD0_OPS:
            if not only_data:
                reason = "batch rides a non-row operand"
            elif t == "Gemm" and node.attrs.get("transA", 0):
                reason = "transA moves the batch off the row axis"
            elif t == "MatMul":
                s1 = ga.shape(node.inputs[1])
                if s1 is None or len(s1) != 2:
                    reason = "rhs is not a known 2-D operand (stacked matmul may broadcast over the batch)"
        elif t in _NCHW_OPS:
            reason = None if only_data else "batch rides a non-data operand"
        elif t == "Softmax":
            if not only_data or rank is None:
                reason = "cannot normalize the softmax axis"
            elif int(node.attrs.get("axis", -1)) % rank == 0:
                reason = "softmax normalizes over the batch axis"
        elif t == "ReduceMean":
            axes = node.attrs.get("axes")
            if axes is None or rank is None:
                reason = "reduces over all axes (including the batch)"
            elif 0 in norm_axes(axes, rank):
                reason = "reduces over the batch axis"
        elif t == "Flatten":
            if int(node.attrs.get("axis", 1)) != 1:
                reason = "flatten folds the batch into another axis"
        elif t == "Transpose":
            perm = node.attrs.get("perm")
            if not perm or int(perm[0]) != 0:
                reason = "permutation moves the batch off axis 0"
        elif t == "Concat":
            if rank is None or int(node.attrs["axis"]) % rank == 0:
                reason = "concatenates along the batch axis"
        elif t == "Gather":
            if not only_data:
                reason = "batch rides the indices"
            elif rank is None or int(node.attrs.get("axis", 0)) % rank == 0:
                reason = "gathers along the batch axis"
        elif t == "Slice":
            axes_c = ga.const(node.inputs[3]) if len(node.inputs) > 3 and node.inputs[3] else None
            if not only_data or axes_c is None or rank is None:
                reason = "slice axes unknown (may slice the batch axis)"
            elif 0 in norm_axes(np.asarray(axes_c).reshape(-1), rank):
                reason = "slices the batch axis"
        elif t in ("Squeeze", "Unsqueeze"):
            axes_c = ga.const(node.inputs[1]) if len(node.inputs) > 1 else None
            out_rank = rank + (1 if t == "Unsqueeze" else -1) * (
                np.asarray(axes_c).size if axes_c is not None else 0
            ) if rank is not None else None
            if not only_data or axes_c is None or rank is None:
                reason = "axes unknown"
            elif 0 in norm_axes(np.asarray(axes_c).reshape(-1), out_rank if t == "Unsqueeze" else rank):
                reason = "touches axis 0"
        elif t == "Reshape":
            target = ga.const(node.inputs[1]) if len(node.inputs) > 1 else None
            tail = s0[1:] if s0 is not None else None
            if target is None or tail is None or any(d is None for d in tail):
                reason = "target/operand shape unknown"
            else:
                dims = [int(d) for d in np.asarray(target).reshape(-1)]
                tail_total = int(np.prod([int(d) for d in tail])) if tail else 1
                rest = dims[1:]
                rest_total = int(np.prod(rest)) if rest else 1
                if not dims or dims[0] != -1 or any(d == -1 for d in rest):
                    reason = "target pins the batch dim (leading target must be -1)"
                elif rest_total != tail_total:
                    reason = "reshape folds batch rows into other axes"
        else:
            reason = "op not verified batch-elementwise under zero-row padding"

        if reason:
            problems.append(f"{node.name or t}[{t}]: {reason}")
    return problems


def infer_shapes(graph: Graph, *, batch: Optional[int] = None) -> Dict[str, Shape]:
    """Best-effort static shapes; tensors missing from the map are unknown.

    ``batch`` binds the symbolic leading dimension: every graph input whose
    first dim is ``None`` is seeded as ``(batch, …)`` before propagation, so
    the whole map comes out specialized for that batch bucket (used by the
    batch-polymorphic lowering to cross-check per-bucket plans)."""
    shapes: Dict[str, Shape] = {
        t.name: bind_batch(tuple(t.shape), batch) for t in graph.inputs
    }
    for name, arr in graph.initializers.items():
        shapes[name] = tuple(arr.shape)

    def sh(name: str) -> Shape:
        return shapes.get(name)

    def const(name: str):
        return graph.initializers.get(name)

    for node in graph.toposorted():
        try:
            s = _node_shape(node, sh, const)
        except Exception:
            s = None
        for o in node.outputs:
            shapes[o] = s
    return shapes


# ---------------------------------------------------------------------------
# cached bundle
# ---------------------------------------------------------------------------


class GraphAnalysis:
    """Immutable-use snapshot of everything a pass needs to reason about a
    graph.  Rebuild (cheap) after any mutation — never reuse across edits."""

    def __init__(self, graph: Graph) -> None:
        self.graph = graph
        self.dtypes = infer_dtypes(graph)
        self.shapes = infer_shapes(graph)
        self.consumers = graph.consumers()
        self.producers = graph.producers()
        self.out_names = {t.name for t in graph.outputs}
        self.in_names = {t.name for t in graph.inputs}

    # -- constants ----------------------------------------------------------
    def is_const(self, name: str) -> bool:
        return name in self.graph.initializers

    def const(self, name: str) -> Optional[np.ndarray]:
        return self.graph.initializers.get(name)

    # -- structure ----------------------------------------------------------
    def dtype(self, name: str) -> Optional[str]:
        return self.dtypes.get(name)

    def shape(self, name: str) -> Shape:
        return self.shapes.get(name)

    def single_consumer(self, tensor: str) -> Optional[Node]:
        """The unique consuming node, or None if the tensor is a graph output
        or has zero/multiple consumers (mirrors the fusion precondition)."""
        if tensor in self.out_names:
            return None
        cons = self.consumers.get(tensor, [])
        return cons[0] if len(cons) == 1 else None


# ---------------------------------------------------------------------------
# graph cloning (passes operate on a copy; the caller's artifact is untouched)
# ---------------------------------------------------------------------------


def clone_graph(graph: Graph) -> Graph:
    """Structural copy.  Initializer arrays are shared (passes replace dict
    entries, they never mutate arrays in place)."""
    return Graph(
        name=graph.name,
        inputs=[dataclasses.replace(t) for t in graph.inputs],
        outputs=[dataclasses.replace(t) for t in graph.outputs],
        nodes=[Node(n.op_type, list(n.inputs), list(n.outputs), dict(n.attrs), n.name) for n in graph.nodes],
        initializers=dict(graph.initializers),
    )


def clone_model(model: Model) -> Model:
    return Model(
        graph=clone_graph(model.graph),
        opset=model.opset,
        ir_version=model.ir_version,
        producer=model.producer,
        metadata=dict(model.metadata),
    )
