"""Graph-wide analyses for PQ-IR: dtype/shape inference and def-use maps.

This is the single home for the facts every optimization pass and the backend
compiler need about a :class:`repro.core.pqir.Graph`:

* :func:`infer_dtypes` — forward dtype propagation over the standard-op
  vocabulary (replaces the private ``infer_dtypes`` that used to live in
  ``repro.core.compile``).
* :func:`infer_shapes` — best-effort static shape propagation over
  :data:`SymDim` dimensions.  A dimension is a concrete ``int``, a *named
  symbolic axis* (a ``str`` such as ``"N"`` or ``"S"``), or ``None``
  (unknown); a wholly unknown shape is ``None``.  Passes must treat ``None``
  as "don't know" and stay conservative.  Named axes are declared in the
  artifact's input signatures (``("N", "S", 64)``) and the per-op rules
  (MatMul/Gemm/Conv/Reshape/Flatten/…) propagate each name through to the
  outputs, so every value knows *which* dynamic axes it carries and at what
  position.  The scenario-specialization compile path
  (``compile_model(dynamic_axes={...})``) later *binds* the names to
  concrete buckets — either by re-running :func:`infer_shapes` with
  ``bindings=`` or per-value via :func:`bind`.

  **Legacy batch convention:** artifacts that name no axis at all but export
  ``(None, …)`` inputs treat the leading ``None`` as the implicit batch axis
  :data:`BATCH_AXIS` (``"N"``) — exactly the PR 4 single-axis contract.
  :func:`graph_axes` detects this case and the per-axis machinery runs in
  *implicit* mode (the axis is pinned to position 0 by convention rather
  than tracked by name).
* :func:`axis_mixing_nodes` — the per-axis safety proof behind zero-padded
  dynamic execution: each dynamic axis is independently proven elementwise
  (no op mixes information across it) or the compile is rejected.
* :class:`GraphAnalysis` — a cached bundle of dtypes, shapes, producer and
  consumer maps plus the constant/initializer view, rebuilt from scratch by
  each pass iteration so it can never go stale against a mutated graph.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from ..core.pqir import DTYPES, Graph, Model, Node

#: One dimension: concrete int, named symbolic axis, or None (unknown).
SymDim = Optional[Union[int, str]]
Shape = Optional[Tuple[SymDim, ...]]

#: Canonical name of the implicit batch axis (the legacy leading-``None``
#: convention of ``compile_model(batch="dynamic")`` graphs).
BATCH_AXIS = "N"

_UNARY_PASSTHROUGH = frozenset(
    {"Relu", "Tanh", "Sigmoid", "Erf", "Sqrt", "Softmax", "Clip", "Identity"}
)
_BINARY_PROMOTE = frozenset({"Mul", "Add", "Sub", "Div", "Pow"})


# ---------------------------------------------------------------------------
# dtype inference
# ---------------------------------------------------------------------------


def infer_dtypes(graph: Graph) -> Dict[str, str]:
    """Forward dtype propagation; returns tensor-name → dtype-name."""
    dt: Dict[str, str] = {t.name: t.dtype for t in graph.inputs}
    for name, arr in graph.initializers.items():
        dt[name] = str(arr.dtype)
    for node in graph.toposorted():
        o = node.outputs[0]
        t = node.op_type
        if t in ("MatMulInteger", "ConvInteger"):
            dt[o] = "int32"
        elif t == "Gemm":
            # integer Gemm accumulates in int32 (dialect rule, see
            # repro.core.runtime); float Gemm preserves its input dtype
            a = dt.get(node.inputs[0], "float32")
            dt[o] = "int32" if np.issubdtype(DTYPES.get(a, np.float32), np.integer) else a
        elif t == "QuantizeLinear":
            dt[o] = dt.get(node.inputs[2], "int8") if len(node.inputs) > 2 else "int8"
        elif t == "DequantizeLinear":
            dt[o] = "float32"
        elif t == "Cast":
            dt[o] = node.attrs["to"]
        elif t == "Shape":
            dt[o] = "int64"
        elif t in _BINARY_PROMOTE and len(node.inputs) >= 2:
            a, b = dt.get(node.inputs[0]), dt.get(node.inputs[1])
            if a is not None and b is not None:
                dt[o] = str(np.promote_types(a, b))
            else:
                dt[o] = a or b or "float32"
        else:
            dt[o] = dt.get(node.inputs[0], "float32") if node.inputs else "float32"
        for extra in node.outputs[1:]:
            dt[extra] = dt[o]
    return dt


# ---------------------------------------------------------------------------
# shape inference (best-effort; None = unknown)
# ---------------------------------------------------------------------------


def _broadcast(a: Shape, b: Shape) -> Shape:
    if a is None or b is None:
        return None
    n = max(len(a), len(b))
    out: List[SymDim] = []
    for i in range(n):
        da = a[len(a) - n + i] if i >= n - len(a) else 1
        db = b[len(b) - n + i] if i >= n - len(b) else 1
        sa, sb = isinstance(da, str), isinstance(db, str)
        if sa or sb:
            # named symbolic axes: a name broadcasts against itself or 1;
            # anything else (another name, an unknown, a pinned extent) makes
            # the result untrackable — drop to wholly-unknown, never guess
            if sa and (db == 1 or da == db):
                out.append(da)
            elif sb and da == 1:
                out.append(db)
            else:
                return None
        elif da is None and db is None:
            out.append(None)
        elif da is None:
            out.append(db if db != 1 else None)
        elif db is None:
            out.append(da if da != 1 else None)
        elif da == 1:
            out.append(db)
        elif db == 1:
            out.append(da)
        elif da == db:
            out.append(da)
        else:
            return None  # incompatible — treat as unknown
    return tuple(out)


def _prod(dims) -> SymDim:
    """Product of dims: an int when fully concrete, the axis name when the
    product is one named symbolic axis times only 1s, else None (unknown)."""
    p, sym = 1, None
    for d in dims:
        if isinstance(d, str):
            if sym is not None:
                return None  # two symbolic factors: untrackable
            sym = d
        elif d is None:
            return None
        else:
            p *= int(d)
    if sym is not None:
        return sym if p == 1 else None
    return p


def _conv_hw(d: SymDim, k: int, pad0: int, pad1: int, stride: int, dil: int) -> Optional[int]:
    if not isinstance(d, int):
        return None
    return (d + pad0 + pad1 - (dil * (k - 1) + 1)) // stride + 1


def _node_shape(node: Node, sh, const) -> Shape:  # noqa: C901 (dispatch table)
    t = node.op_type
    s0: Shape = sh(node.inputs[0]) if node.inputs else None
    if t in _UNARY_PASSTHROUGH or t in ("Cast", "QuantizeLinear", "DequantizeLinear"):
        return s0
    if t in ("Mul", "Add", "Sub", "Div", "Pow"):
        return _broadcast(s0, sh(node.inputs[1]))
    if t in ("MatMul", "MatMulInteger"):
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None or len(s0) < 1:
            return None
        if len(s1) == 2:
            return tuple(s0[:-1]) + (s1[1],)
        # stacked matmul (both operands ≥ 2-D): leading dims broadcast, the
        # trailing two contract as (…, M, K) @ (…, K, N) -> (…, M, N)
        if len(s0) < 2 or len(s1) < 2:
            return None
        lead = _broadcast(tuple(s0[:-2]), tuple(s1[:-2]))
        if lead is None:
            return None
        return tuple(lead) + (s0[-2], s1[-1])
    if t == "Gemm":
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None or len(s0) != 2 or len(s1) != 2:
            return None
        m = s0[1] if node.attrs.get("transA", 0) else s0[0]
        n = s1[0] if node.attrs.get("transB", 0) else s1[1]
        return (m, n)
    if t in ("Conv", "ConvInteger"):
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None or len(s0) != 4 or len(s1) != 4:
            return None
        strides = tuple(node.attrs.get("strides", (1, 1)))
        pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
        dil = tuple(node.attrs.get("dilations", (1, 1)))
        kh, kw = s1[2], s1[3]
        return (
            s0[0],
            s1[0],
            _conv_hw(s0[2], int(kh), pads[0], pads[2], strides[0], dil[0]),
            _conv_hw(s0[3], int(kw), pads[1], pads[3], strides[1], dil[1]),
        )
    if t == "Reshape":
        target = const(node.inputs[1]) if len(node.inputs) > 1 else None
        if target is None:
            return None
        dims = [int(d) for d in np.asarray(target).reshape(-1)]
        if -1 not in dims:
            return tuple(dims)
        # a leading named axis survives a (-1, concrete...) reshape whose tail
        # product is preserved — the row-preserving form the per-axis safety
        # proof admits — so the name keeps flowing to downstream values
        if (
            s0 is not None and len(s0) >= 1 and isinstance(s0[0], str)
            and dims[0] == -1 and all(d != -1 for d in dims[1:])
            and _prod(s0[1:]) == _prod(dims[1:])
            and isinstance(_prod(dims[1:]), int)
        ):
            return (s0[0],) + tuple(dims[1:])
        total = _prod(s0) if s0 is not None else None
        if not isinstance(total, int):
            return tuple(None if d == -1 else d for d in dims)
        rest = _prod([d for d in dims if d != -1])
        return tuple(total // rest if d == -1 else d for d in dims)
    if t == "Transpose":
        if s0 is None:
            return None
        perm = node.attrs.get("perm") or list(range(len(s0)))[::-1]
        return tuple(s0[int(p)] for p in perm)
    if t == "Flatten":
        if s0 is None:
            return None
        axis = int(node.attrs.get("axis", 1))
        return (_prod(s0[:axis]) if axis else 1, _prod(s0[axis:]))
    if t == "Concat":
        shapes = [sh(i) for i in node.inputs]
        if any(s is None for s in shapes):
            return None
        axis = int(node.attrs["axis"])
        dims = list(shapes[0])
        cat = 0
        for s in shapes:
            if not isinstance(s[axis], int):
                cat = None
                break
            cat += s[axis]
        dims[axis] = cat
        return tuple(dims)
    if t == "Gather":
        s1 = sh(node.inputs[1])
        if s0 is None or s1 is None:
            return None
        axis = int(node.attrs.get("axis", 0))
        return tuple(s0[:axis]) + tuple(s1) + tuple(s0[axis + 1 :])
    if t == "Slice":
        starts = const(node.inputs[1]) if len(node.inputs) > 1 else None
        ends = const(node.inputs[2]) if len(node.inputs) > 2 else None
        if s0 is None or starts is None or ends is None:
            return None
        starts = [int(v) for v in np.asarray(starts).reshape(-1)]
        ends = [int(v) for v in np.asarray(ends).reshape(-1)]
        axes_c = const(node.inputs[3]) if len(node.inputs) > 3 and node.inputs[3] else None
        steps_c = const(node.inputs[4]) if len(node.inputs) > 4 and node.inputs[4] else None
        axes = [int(v) for v in np.asarray(axes_c).reshape(-1)] if axes_c is not None else list(range(len(starts)))
        steps = [int(v) for v in np.asarray(steps_c).reshape(-1)] if steps_c is not None else [1] * len(starts)
        dims = list(s0)
        for s, e, a, st in zip(starts, ends, axes, steps):
            if not isinstance(dims[a], int):
                # unknown stays unknown; a sliced *named* axis loses its name
                # (the slice extent is no longer the axis extent)
                dims[a] = None
                continue
            dims[a] = len(range(*slice(s, e, st).indices(int(dims[a]))))
        return tuple(dims)
    if t in ("Squeeze", "Unsqueeze"):
        axes = const(node.inputs[1]) if len(node.inputs) > 1 else None
        if s0 is None or axes is None:
            return None
        ax = [int(a) for a in np.asarray(axes).reshape(-1)]
        if t == "Squeeze":
            return tuple(d for i, d in enumerate(s0) if i not in ax and i - len(s0) not in ax)
        dims = list(s0)
        for a in sorted(ax):
            dims.insert(a if a >= 0 else a + len(dims) + 1, 1)
        return tuple(dims)
    if t in ("MaxPool", "AveragePool"):
        if s0 is None or len(s0) != 4:
            return None
        kh, kw = node.attrs["kernel_shape"]
        strides = tuple(node.attrs.get("strides", (kh, kw)))
        pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
        return (
            s0[0],
            s0[1],
            _conv_hw(s0[2], int(kh), pads[0], pads[2], strides[0], 1),
            _conv_hw(s0[3], int(kw), pads[1], pads[3], strides[1], 1),
        )
    if t == "GlobalAveragePool":
        return None if s0 is None else (s0[0], s0[1], 1, 1)
    if t in ("ReduceMean", "ReduceMax", "ReduceSum"):
        if s0 is None:
            return None
        axes = node.attrs.get("axes")
        ax = [int(a) % len(s0) for a in axes] if axes else list(range(len(s0)))
        keep = bool(node.attrs.get("keepdims", 1))
        if keep:
            return tuple(1 if i in ax else d for i, d in enumerate(s0))
        return tuple(d for i, d in enumerate(s0) if i not in ax)
    return None


# ---------------------------------------------------------------------------
# named symbolic axes
# ---------------------------------------------------------------------------


def is_sym(dim: SymDim) -> bool:
    """True for a named symbolic axis (a ``str`` dimension)."""
    return isinstance(dim, str)


def symbolic_axes(shape: Shape) -> Tuple[str, ...]:
    """The named symbolic axes a shape carries, in position order."""
    if shape is None:
        return ()
    return tuple(d for d in shape if isinstance(d, str))


def bind(shape: Shape, bindings: Optional[Dict[str, int]]) -> Shape:
    """Substitute named symbolic dims with concrete extents from ``bindings``.

    Axes absent from ``bindings`` stay symbolic (partial binding); an empty
    or ``None`` bindings map is always a no-op, and binding never touches a
    fully-static shape.  **Legacy convention:** an *unnamed* leading ``None``
    dim binds to :data:`BATCH_AXIS` when that axis is bound — this is what
    keeps PR 4 ``(None, …)`` single-axis artifacts working unchanged."""
    if not bindings or shape is None:
        return shape
    out: List[SymDim] = []
    for i, d in enumerate(shape):
        if isinstance(d, str) and d in bindings:
            out.append(int(bindings[d]))
        elif d is None and i == 0 and BATCH_AXIS in bindings:
            out.append(int(bindings[BATCH_AXIS]))
        else:
            out.append(d)
    return tuple(out)


def implicit_batch_graph(graph: Graph) -> bool:
    """True when the graph names no axis at all — its dynamic-axis contract
    (if any) is the legacy leading-``None`` batch convention."""
    return not any(isinstance(d, str) for t in graph.inputs for d in t.shape)


def graph_axes(graph: Graph) -> Tuple[str, ...]:
    """Named symbolic axes declared across the graph's input signatures, in
    first-appearance order.  A graph that names nothing but exports a
    ``(None, …)`` input contributes the implicit :data:`BATCH_AXIS`."""
    names: List[str] = []
    for t in graph.inputs:
        for d in t.shape:
            if isinstance(d, str) and d not in names:
                names.append(d)
    if names:
        return tuple(names)
    if any(len(t.shape) >= 1 and t.shape[0] is None for t in graph.inputs):
        return (BATCH_AXIS,)
    return ()


def axis_positions(shape: Shape, axis: str, *, implicit: bool = False) -> Optional[Tuple[int, ...]]:
    """Positions where ``axis`` occurs in ``shape`` (``None`` = shape unknown).

    ``implicit`` selects the legacy convention: the axis is the leading
    ``None`` dim (position 0) rather than a name match."""
    if shape is None:
        return None
    if implicit:
        return (0,) if (len(shape) >= 1 and shape[0] is None) else ()
    return tuple(i for i, d in enumerate(shape) if d == axis)


def axis_inputs(graph: Graph, axis: str) -> List[str]:
    """Names of graph inputs carrying the dynamic ``axis`` — the feeds a
    scenario-specialized compiled model pads to the axis bucket."""
    implicit = implicit_batch_graph(graph)
    out = []
    for t in graph.inputs:
        pos = axis_positions(tuple(t.shape), axis, implicit=implicit and axis == BATCH_AXIS)
        if pos:
            out.append(t.name)
    return out


#: Ops that are elementwise and shape-preserving along every axis whenever the
#: dynamic axis rides only the data operand (scales/zero-points are constants).
_ROWWISE_OPS = frozenset(
    {"Relu", "Tanh", "Sigmoid", "Erf", "Sqrt", "Clip", "Identity",
     "Cast", "QuantizeLinear", "DequantizeLinear"}
)
#: Contractions whose first operand carries independent rows / the N axis.
_LEAD0_OPS = frozenset({"MatMul", "MatMulInteger", "Gemm"})
_NCHW_OPS = frozenset(
    {"Conv", "ConvInteger", "MaxPool", "AveragePool", "GlobalAveragePool"}
)
_BCAST_OPS = frozenset({"Mul", "Add", "Sub", "Div", "Pow"})


def axis_mixing_nodes(
    ga: "GraphAnalysis",
    axis: str,
    *,
    implicit: Optional[bool] = None,
    exempt: frozenset = frozenset(),
) -> List[str]:
    """Nodes that cannot be *proved* elementwise along the dynamic ``axis``.

    Scenario-specialized execution pads feeds with zero slabs along each
    dynamic axis and slices results back — exact only when no op mixes
    information across that axis.  That holds for the artifact's
    quantized-inference vocabulary (elementwise chains, weight contractions
    over *other* dims, NCHW windows with the axis on the batch position) but
    is false for e.g. a global ReduceMean, Softmax over the axis, an
    axis-folding Reshape/Flatten, or a Concat along it — those would
    silently compute over the zero padding.
    ``compile_model(dynamic_axes=...)`` rejects graphs where this returns a
    non-empty list of human-readable reasons, once per requested axis.

    Two tracking modes:

    * **named** (graphs that declare axis names): the axis is followed *by
      name* through shape inference, so it may legally move position
      (Transpose, Unsqueeze) — the proof only requires that every op is
      elementwise along it and that the name survives to a unique position.
    * **implicit** (legacy ``(None, …)`` batch graphs): the axis is pinned
      to position 0 by convention, so any op that would move it off the
      leading dim is rejected — byte-for-byte the PR 4 behavior.

    Conservative by construction: an op the proof cannot reason about
    (unknown shapes, unlisted op types touching an axis-carrying value) is
    reported, not assumed safe.

    ``exempt`` lists node names the *caller* has already proven safe by a
    stronger, region-level argument — e.g. a fused-attention region whose
    masked softmax is exact under zero padding because a zero-padded mask
    forces the padded keys' weights to exactly 0 (see
    ``repro.core.compile.qattention_exempt_nodes``).  Exempted nodes are
    skipped, everything else is still proven node-by-node.
    """
    if implicit is None:
        implicit = implicit_batch_graph(ga.graph)

    def positions(name: str) -> Optional[Tuple[int, ...]]:
        if ga.is_const(name):
            return ()
        return axis_positions(ga.shape(name), axis, implicit=implicit)

    def carries(name: str) -> bool:
        p = positions(name)
        return p is None or len(p) > 0  # unknown shape: assume it may carry

    def pos_of(name: str) -> Optional[int]:
        """The unique tracked position, or None (unknown / ambiguous).
        Implicit mode pins the axis to position 0 by convention."""
        if implicit:
            return 0
        p = positions(name)
        return p[0] if p is not None and len(p) == 1 else None

    def norm_axes(axes, rank):
        return {int(a) % rank for a in axes}

    problems: List[str] = []
    for node in ga.graph.toposorted():
        if node.name and node.name in exempt:
            continue
        ins = [i for i in node.inputs if i]
        carrying = [i for i in ins if carries(i)]
        if not carrying:
            continue
        t = node.op_type
        s0 = ga.shape(node.inputs[0]) if node.inputs else None
        rank = len(s0) if s0 is not None else None
        only_data = set(carrying) <= {node.inputs[0]}
        p0 = pos_of(node.inputs[0]) if node.inputs else None
        reason = None

        if t in _ROWWISE_OPS:
            reason = None if only_data else "axis rides a non-data operand"
        elif t in _BCAST_OPS:
            out = ga.shape(node.outputs[0])
            out_pos = axis_positions(out, axis, implicit=implicit)
            if out_pos is None or len(out_pos) != 1:
                reason = "broadcast result does not keep the axis at a unique position"
            else:
                for i in ins:
                    s = ga.shape(i)
                    if s is None:
                        reason = f"operand {i!r} has unknown shape"
                        break
                    if implicit and len(s) == len(out) and s[0] is not None and s[0] != 1:
                        reason = f"operand {i!r} pins axis 0 to {s[0]}"
                        break
                    ip = axis_positions(s, axis, implicit=implicit)
                    if ip is not None and len(ip) > 1:
                        reason = f"operand {i!r} carries the axis more than once"
                        break
        elif t in _LEAD0_OPS:
            contraction = rank - 1 if rank is not None else None
            if not only_data:
                reason = "axis rides a non-row operand"
            elif p0 is None:
                reason = "cannot locate the axis on the data operand"
            elif t == "Gemm" and p0 != (1 if node.attrs.get("transA", 0) else 0):
                reason = "axis is not on the Gemm row axis"
            elif t != "Gemm" and contraction is not None and p0 == contraction:
                reason = "axis is the matmul contraction dim"
            elif t in ("MatMul", "MatMulInteger"):
                s1 = ga.shape(node.inputs[1])
                if s1 is None or len(s1) != 2:
                    reason = "rhs is not a known 2-D operand (stacked matmul may broadcast over the axis)"
        elif t in _NCHW_OPS:
            if not only_data:
                reason = "axis rides a non-data operand"
            elif p0 != 0:
                reason = "axis is not on the NCHW batch position (windows/channels mix it)"
        elif t == "Softmax":
            if not only_data or rank is None or p0 is None:
                reason = "cannot normalize the softmax axis"
            elif int(node.attrs.get("axis", -1)) % rank == p0:
                reason = "softmax normalizes over the axis"
        elif t in ("ReduceMean", "ReduceMax", "ReduceSum"):
            axes = node.attrs.get("axes")
            if axes is None or rank is None or p0 is None:
                reason = "reduces over all axes (including the dynamic axis)"
            elif p0 in norm_axes(axes, rank):
                reason = "reduces over the axis"
        elif t == "Flatten":
            a = int(node.attrs.get("axis", 1))
            if rank is None or p0 is None:
                reason = "operand shape unknown"
            else:
                side = list(enumerate(s0))[:a] if p0 < a else list(enumerate(s0))[a:]
                if any(d != 1 for i, d in side if i != p0):
                    reason = "flatten folds the axis together with other dims"
        elif t == "Transpose":
            if implicit:
                perm = node.attrs.get("perm")
                if not perm or int(perm[0]) != 0:
                    reason = "permutation moves the axis off position 0"
            else:
                out_pos = axis_positions(ga.shape(node.outputs[0]), axis)
                if out_pos is None or len(out_pos) != 1:
                    reason = "permutation loses track of the axis"
        elif t == "Concat":
            if rank is None or p0 is None or int(node.attrs["axis"]) % rank == p0:
                reason = "concatenates along the axis"
        elif t == "Gather":
            if not only_data:
                # a gather from a *constant* table is elementwise in the
                # indices (out[..., i, ...] = table[idx[..., i, ...]]), so a
                # dynamic axis riding the indices never mixes — this is the
                # embedding-lookup / LUT-gather case of the token path
                if not (ga.is_const(node.inputs[0]) and set(carrying) <= {node.inputs[1]}):
                    reason = "axis rides the indices"
            elif rank is None or p0 is None or int(node.attrs.get("axis", 0)) % rank == p0:
                reason = "gathers along the axis"
        elif t == "Slice":
            axes_c = ga.const(node.inputs[3]) if len(node.inputs) > 3 and node.inputs[3] else None
            if not only_data or axes_c is None or rank is None or p0 is None:
                reason = "slice axes unknown (may slice the dynamic axis)"
            elif p0 in norm_axes(np.asarray(axes_c).reshape(-1), rank):
                reason = "slices the axis"
        elif t in ("Squeeze", "Unsqueeze"):
            axes_c = ga.const(node.inputs[1]) if len(node.inputs) > 1 else None
            if not only_data or axes_c is None or rank is None or p0 is None:
                reason = "axes unknown"
            elif t == "Squeeze":
                if p0 in norm_axes(np.asarray(axes_c).reshape(-1), rank):
                    reason = "squeezes the axis"
            elif implicit:
                out_rank = rank + np.asarray(axes_c).size
                if 0 in norm_axes(np.asarray(axes_c).reshape(-1), out_rank):
                    reason = "moves the axis off position 0"
            # named Unsqueeze: inserting 1-dims never mixes, and shape
            # inference tracks the name to its new position
        elif t == "Reshape":
            target = ga.const(node.inputs[1]) if len(node.inputs) > 1 else None
            tail = s0[1:] if s0 is not None else None
            if p0 != 0:
                reason = "axis is not leading (only leading-axis reshapes are proven)"
            elif target is None or tail is None or any(not isinstance(d, int) for d in tail):
                reason = "target/operand shape unknown"
            else:
                dims = [int(d) for d in np.asarray(target).reshape(-1)]
                tail_total = int(np.prod([int(d) for d in tail])) if tail else 1
                rest = dims[1:]
                rest_total = int(np.prod(rest)) if rest else 1
                if not dims or dims[0] != -1 or any(d == -1 for d in rest):
                    reason = "target pins the axis dim (leading target must be -1)"
                elif rest_total != tail_total:
                    reason = "reshape folds the axis into other dims"
        else:
            reason = "op not verified elementwise along the axis under zero padding"

        if reason:
            problems.append(f"{node.name or t}[{t}]: {axis!r} {reason}")
    return problems


def infer_shapes(graph: Graph, *, bindings: Optional[Dict[str, int]] = None) -> Dict[str, Shape]:
    """Best-effort static shapes; tensors missing from the map are unknown.

    ``bindings`` substitutes named symbolic axes (and, per the legacy
    convention, an unnamed leading ``None`` when :data:`BATCH_AXIS` is
    bound) in every graph-input signature before propagation, so the whole
    map comes out specialized for that scenario bucket (used by the
    scenario-specializing lowering to cross-check per-bucket plans)."""
    shapes: Dict[str, Shape] = {
        t.name: bind(tuple(t.shape), bindings) for t in graph.inputs
    }
    for name, arr in graph.initializers.items():
        shapes[name] = tuple(arr.shape)

    def sh(name: str) -> Shape:
        return shapes.get(name)

    def const(name: str):
        return graph.initializers.get(name)

    for node in graph.toposorted():
        try:
            s = _node_shape(node, sh, const)
        except Exception:
            s = None
        for o in node.outputs:
            shapes[o] = s
    return shapes


# ---------------------------------------------------------------------------
# cached bundle
# ---------------------------------------------------------------------------


class GraphAnalysis:
    """Immutable-use snapshot of everything a pass needs to reason about a
    graph.  Rebuild (cheap) after any mutation — never reuse across edits."""

    def __init__(self, graph: Graph) -> None:
        self.graph = graph
        self.dtypes = infer_dtypes(graph)
        self.shapes = infer_shapes(graph)
        self.consumers = graph.consumers()
        self.producers = graph.producers()
        self.out_names = {t.name for t in graph.outputs}
        self.in_names = {t.name for t in graph.inputs}

    # -- constants ----------------------------------------------------------
    def is_const(self, name: str) -> bool:
        return name in self.graph.initializers

    def const(self, name: str) -> Optional[np.ndarray]:
        return self.graph.initializers.get(name)

    # -- structure ----------------------------------------------------------
    def dtype(self, name: str) -> Optional[str]:
        return self.dtypes.get(name)

    def shape(self, name: str) -> Shape:
        return self.shapes.get(name)

    def single_consumer(self, tensor: str) -> Optional[Node]:
        """The unique consuming node, or None if the tensor is a graph output
        or has zero/multiple consumers (mirrors the fusion precondition)."""
        if tensor in self.out_names:
            return None
        cons = self.consumers.get(tensor, [])
        return cons[0] if len(cons) == 1 else None


# ---------------------------------------------------------------------------
# graph cloning (passes operate on a copy; the caller's artifact is untouched)
# ---------------------------------------------------------------------------


def clone_graph(graph: Graph) -> Graph:
    """Structural copy.  Initializer arrays are shared (passes replace dict
    entries, they never mutate arrays in place)."""
    return Graph(
        name=graph.name,
        inputs=[dataclasses.replace(t) for t in graph.inputs],
        outputs=[dataclasses.replace(t) for t in graph.outputs],
        nodes=[Node(n.op_type, list(n.inputs), list(n.outputs), dict(n.attrs), n.name) for n in graph.nodes],
        initializers=dict(graph.initializers),
        states=[dataclasses.replace(s) for s in graph.states],
    )


def clone_model(model: Model) -> Model:
    return Model(
        graph=clone_graph(model.graph),
        opset=model.opset,
        ir_version=model.ir_version,
        producer=model.producer,
        metadata=dict(model.metadata),
    )
