"""PassManager: named, ordered, individually-toggleable optimization passes
with per-pass stats and an optional conformance hook.

The conformance hook is the subsystem's safety contract: after every pass
that changed the graph, the transformed model is re-executed by
:class:`repro.core.runtime.ReferenceRuntime` on deterministic probe inputs
and compared against the *original* artifact — bit-exact on integer outputs,
allclose on float outputs.  A pass that breaks semantics raises
:class:`ConformanceError` naming the pass, so a bad rewrite can never
silently reach the backend compiler.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.pqir import DTYPES, Graph, Model
from ..core.runtime import ReferenceRuntime
from ..obs import trace as _trace
from .analysis import clone_model
from .canonicalize import AddFold, ConstantFold, DeadCode, IdentityElim, MulFold, Pass, QdqCancel
from .sink import SinkShapes


class ConformanceError(RuntimeError):
    """A pass produced a graph that is not semantics-preserving."""


def default_passes() -> List[Pass]:
    """The canonicalization pipeline, in order: fold constants, drop
    identities, sink shape ops (exposing longer elementwise chains), fold the
    §3.1 two-Mul rescales and integer Add-bias pairs, cancel
    Dequantize→Quantize round trips, then sweep dead nodes/initializers."""
    return [ConstantFold(), IdentityElim(), SinkShapes(), MulFold(), AddFold(), QdqCancel(), DeadCode()]


@dataclasses.dataclass
class PassStat:
    iteration: int
    name: str
    counters: Dict[str, int]
    changed: bool


@dataclasses.dataclass
class PipelineReport:
    entries: List[PassStat] = dataclasses.field(default_factory=list)
    nodes_before: int = 0
    nodes_after: int = 0
    iterations: int = 0

    def total(self, key: str) -> int:
        return sum(e.counters.get(key, 0) for e in self.entries)

    @property
    def totals(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for e in self.entries:
            for k, v in e.counters.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    @property
    def changed(self) -> bool:
        return any(e.changed for e in self.entries)

    def summary(self) -> str:
        t = self.totals
        body = ";".join(f"{k}={v}" for k, v in sorted(t.items())) or "no-op"
        return f"nodes {self.nodes_before}->{self.nodes_after} ({body})"


def make_probe_feeds(graph: Graph, *, batch: int = 2, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic inputs matching the graph's declared signature (unknown
    dims become ``batch``) — what the conformance hook executes."""
    rng = np.random.default_rng(seed)
    feeds: Dict[str, np.ndarray] = {}
    for t in graph.inputs:
        shape = tuple(batch if d is None else int(d) for d in t.shape)
        np_dtype = DTYPES[t.dtype]
        if t.dtype == "bool":
            feeds[t.name] = rng.integers(0, 2, shape).astype(np_dtype)
        elif np.issubdtype(np_dtype, np.integer):
            info = np.iinfo(np_dtype)
            lo, hi = max(info.min, -128), min(int(info.max), 127) + 1
            if t.dtype in ("int32", "int64"):
                lo, hi = 0, 2  # likely indices — stay in range of any gather
            feeds[t.name] = rng.integers(lo, hi, shape).astype(np_dtype)
        else:
            feeds[t.name] = rng.standard_normal(shape).astype(np_dtype)
    return feeds


def _check_outputs(baseline: Dict[str, np.ndarray], got: Dict[str, np.ndarray], pass_name: str) -> None:
    for k, want in baseline.items():
        have = got[k]
        if want.dtype != have.dtype or want.shape != have.shape:
            raise ConformanceError(
                f"pass {pass_name!r} changed output {k!r} signature: "
                f"{want.dtype}{want.shape} -> {have.dtype}{have.shape}"
            )
        if np.issubdtype(want.dtype, np.integer) or want.dtype == np.bool_:
            if not np.array_equal(want, have):
                raise ConformanceError(f"pass {pass_name!r} is not bit-exact on integer output {k!r}")
        elif not np.allclose(want, have, rtol=1e-5, atol=1e-6):
            raise ConformanceError(f"pass {pass_name!r} diverged on float output {k!r}")


class PassManager:
    """Runs an ordered list of passes to a fixpoint (bounded by
    ``max_iterations`` sweeps over the list).

    passes    explicit pass list (default :func:`default_passes`)
    disable   names to skip (the toggle: ``PassManager(disable=("mul_fold",))``)
    verify    run the reference-runtime conformance hook after each changing
              pass (probe inputs are deterministic; see make_probe_feeds)
    """

    def __init__(
        self,
        passes: Optional[Sequence[Pass]] = None,
        *,
        disable: Iterable[str] = (),
        verify: bool = False,
        probe_batch: int = 2,
        probe_seed: int = 0,
        max_iterations: int = 4,
    ) -> None:
        disabled = set(disable)
        candidates = list(passes) if passes is not None else default_passes()
        unknown = disabled - {p.name for p in candidates}
        if unknown:
            raise ValueError(f"unknown pass name(s) in disable: {sorted(unknown)}")
        self.passes = [p for p in candidates if p.name not in disabled]
        self.verify = verify
        self.probe_batch = probe_batch
        self.probe_seed = probe_seed
        self.max_iterations = max_iterations

    def run(self, model: Model) -> Tuple[Model, PipelineReport]:
        """Optimize a *clone* of ``model`` (the input artifact is untouched)."""
        opt = clone_model(model)
        report = PipelineReport(nodes_before=len(opt.graph.nodes))
        baseline: Optional[Dict[str, np.ndarray]] = None
        feeds: Dict[str, np.ndarray] = {}
        with _trace.span(
            "passes.pipeline", nodes=report.nodes_before, verify=self.verify
        ) as pipe_span:
            if self.verify:
                feeds = make_probe_feeds(model.graph, batch=self.probe_batch, seed=self.probe_seed)
                baseline = ReferenceRuntime(model, validate=False).run(feeds)
            for it in range(self.max_iterations):
                sweep_changed = False
                for p in self.passes:
                    with _trace.span(f"pass.{p.name}", iteration=it) as pass_span:
                        counters = p.run(opt.graph)
                        changed = any(counters.values())
                        pass_span.set(
                            changed=changed, **{k: v for k, v in counters.items() if v}
                        )
                        report.entries.append(PassStat(it, p.name, counters, changed))
                        if changed and baseline is not None:
                            with _trace.span("pass.conformance_check"):
                                got = ReferenceRuntime(opt, validate=False).run(feeds)
                                _check_outputs(baseline, got, p.name)
                    sweep_changed |= changed
                report.iterations = it + 1
                if not sweep_changed:
                    break
            report.nodes_after = len(opt.graph.nodes)
            pipe_span.set(nodes_after=report.nodes_after, iterations=report.iterations)
            opt.validate(standard_ops_only=False)  # structural safety net
        return opt, report


def optimize(
    model: Model,
    *,
    passes: Optional[Sequence[Pass]] = None,
    disable: Iterable[str] = (),
    verify: bool = False,
) -> Tuple[Model, PipelineReport]:
    """One-shot convenience wrapper around :class:`PassManager`."""
    return PassManager(passes, disable=disable, verify=verify).run(model)
