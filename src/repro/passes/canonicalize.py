"""Canonicalization passes: semantics-preserving PQ-IR cleanups.

Every pass here is **bit-exact** on integer paths by construction — the
rewrite conditions are chosen so the transformed float arithmetic is
IEEE-identical, not merely close:

* ``const_fold``     — evaluate nodes whose inputs are all initializers using
                       the reference runtime's own op implementations (so the
                       folded value is the value the oracle would compute).
* ``qdq_cancel``     — ``DequantizeLinear → QuantizeLinear`` with identical
                       scale/zero-point and matching **8-bit** dtype is the
                       identity: ``rint((x−z)·s/s)+z == x`` for every
                       representable ``x`` (the f32 products round back
                       exactly because |x·s| error < 1/2 ULP of the integer —
                       true for |x| ≤ 255, not for wide dtypes like int32,
                       which are therefore excluded).
* ``mul_fold``       — consecutive constant ``Mul``s fold to one when either
                       constant is a power of two: scaling by 2**k is exact
                       and commutes with round-to-nearest, so
                       ``RN(RN(x·c)·2**k) == RN(x·(c·2**k))``.  This is
                       precisely the paper's §3.1 quant_scale × 2**−shift
                       rescale pair.  The argument is elementwise, so the
                       constants may be scalars, per-channel vectors, or any
                       broadcast-compatible mix — the per-channel 2**-N shift
                       vector is a power of two in every lane.
* ``add_fold``       — consecutive constant integer ``Add``s fold to one:
                       two's-complement addition is associative even under
                       wrap-around, so ``(x+c1)+c2 == x+(c1+c2)`` exactly for
                       any int dtype (float pairs are left alone — float
                       addition does not associate).
* ``identity_elim``  — same-dtype Cast, ×1.0 / ÷1.0, +0 / −0, identity
                       Transpose/Reshape.
* ``dead_code``      — drop nodes whose outputs are never consumed, and
                       initializers no remaining node reads.

``Reshape``/``Transpose`` sinking lives in :mod:`repro.passes.sink`.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from ..core import runtime as _rt
from ..core.pqir import DTYPES, Graph, Node
from .analysis import GraphAnalysis
from .rewrite import OpSpec, Pattern, bypass_tensor, match_chain, ql_params, remove_nodes, unique_name


class Pass:
    """A named graph transformation.  ``run`` mutates ``graph`` in place and
    returns its counters (all-zero ⇒ nothing changed)."""

    name = "pass"

    def run(self, graph: Graph) -> Dict[str, int]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# constant folding
# ---------------------------------------------------------------------------


class ConstantFold(Pass):
    name = "const_fold"

    def run(self, graph: Graph) -> Dict[str, int]:
        folded = 0
        while True:
            ga = GraphAnalysis(graph)
            victim = None
            for node in graph.nodes:
                if any(o in ga.out_names for o in node.outputs):
                    continue
                real_inputs = [i for i in node.inputs if i]
                if not real_inputs or not all(ga.is_const(i) for i in real_inputs):
                    continue
                if node.op_type not in _rt._OPS:
                    continue
                victim = node
                break
            if victim is None:
                return {"folded": folded}
            outs = _rt._OPS[victim.op_type](victim, [ga.const(i) if i else None for i in victim.inputs])
            for name, val in zip(victim.outputs, outs):
                graph.initializers[name] = np.asarray(val)
            remove_nodes(graph, [victim])
            folded += 1


# ---------------------------------------------------------------------------
# Dequantize → Quantize round-trip cancellation
# ---------------------------------------------------------------------------

_QDQ = Pattern(
    "qdq_cancel",
    (
        OpSpec("DequantizeLinear", capture="dql"),
        OpSpec("QuantizeLinear", capture="ql"),
    ),
)


class QdqCancel(Pass):
    name = "qdq_cancel"

    def run(self, graph: Graph) -> Dict[str, int]:
        eliminated = 0
        while True:
            ga = GraphAnalysis(graph)
            applied = False
            for node in graph.toposorted():
                if node.op_type != "DequantizeLinear":
                    continue
                m = match_chain(ga, node, _QDQ)
                if m is None:
                    continue
                dql, ql = m.node("dql"), m.node("ql")
                s1, z1 = ql_params(ga, dql)
                s2, z2 = ql_params(ga, ql)
                if s1 is None or s2 is None or z1 is None or z2 is None:
                    continue
                if not (np.array_equal(s1, s2) and np.array_equal(np.asarray(z1, np.int64), np.asarray(z2, np.int64))):
                    continue
                # per-channel scales cancel too (the round trip is exact
                # elementwise), but only if both ops quantize along the same
                # axis (ONNX default: 1) and the scale/zero-point constants
                # broadcast *into* the data — a rank- or dim-expanding
                # constant makes the chain reshape its input, so removing it
                # would change the output shape.  (s2/z2 have identical
                # shapes: np.array_equal above requires it.)
                if np.asarray(s1).ndim and dql.attrs.get("axis", 1) != ql.attrs.get("axis", 1):
                    continue
                if not (_broadcast_preserves(ga, dql.inputs[0], s1) and _broadcast_preserves(ga, dql.inputs[0], z1)):
                    continue
                # The round-trip only restores x if the output integer dtype
                # is the dtype x already has, and only for 8-bit data — wide
                # dtypes (int32) lose bits in the f32 round trip.
                if ga.dtype(dql.inputs[0]) not in ("int8", "uint8"):
                    continue
                if ga.dtype(dql.inputs[0]) != str(np.asarray(z2).dtype):
                    continue
                src = dql.inputs[0]
                remove_nodes(graph, [dql, ql])
                if not bypass_tensor(graph, src, ql.outputs[0]):
                    graph.nodes.extend([dql, ql])  # can't rewire safely; restore
                    continue
                eliminated += 2
                applied = True
                break
            if not applied:
                return {"eliminated": eliminated}


# ---------------------------------------------------------------------------
# consecutive-Mul rescale folding
# ---------------------------------------------------------------------------

_MULMUL = Pattern(
    "mul_mul",
    (
        OpSpec("Mul", capture="m1", const_operand="c1"),
        OpSpec("Mul", capture="m2", const_operand="c2"),
    ),
)


def _all_pow2(a: np.ndarray) -> bool:
    a = np.asarray(a)
    if not np.issubdtype(a.dtype, np.floating):
        return False
    flat = a.reshape(-1).astype(np.float64)
    if not np.all(np.isfinite(flat)) or np.any(flat <= 0.0):
        return False
    return all(math.frexp(float(v))[0] == 0.5 for v in flat)


def _broadcastable(c1: np.ndarray, c2: np.ndarray) -> bool:
    """Whether two constants may be folded into one.  Broadcast shapes
    compose associatively — broadcast(broadcast(x, c1), c2) ==
    broadcast(x, broadcast(c1, c2)) — so folding two broadcast-compatible
    constants (scalar, per-channel vector, or any mix) never changes the
    chain's output shape or which element pairs meet.  Orthogonal vectors
    (e.g. (1, K) × (K, 1)) are excluded: they broadcast, but the folded
    constant would materialize their O(K²) outer product in the artifact."""
    try:
        folded = np.broadcast_shapes(c1.shape, c2.shape)
    except ValueError:
        return False
    return int(np.prod(folded, dtype=np.int64)) <= max(c1.size, c2.size)


def _broadcast_preserves(ga: GraphAnalysis, tensor: str, c) -> bool:
    """True iff combining ``tensor`` with constant ``c`` cannot change the
    tensor's shape: ``c`` broadcasts *into* it (never expands rank or any
    size-1 dim).  Needs a known static shape for non-scalar ``c``."""
    c = np.asarray(c)
    if c.ndim == 0:
        return True
    sh = ga.shape(tensor)
    if sh is None or c.ndim > len(sh):
        return False
    for cd, xd in zip(c.shape[::-1], tuple(sh)[::-1]):
        if cd != 1 and (xd is None or cd != xd):
            return False
    return True


class MulFold(Pass):
    name = "mul_fold"

    def run(self, graph: Graph) -> Dict[str, int]:
        folded = 0
        eliminated = 0
        while True:
            ga = GraphAnalysis(graph)
            applied = False
            for node in graph.toposorted():
                if node.op_type != "Mul":
                    continue
                m = match_chain(ga, node, _MULMUL)
                if m is None:
                    continue
                c1, c2 = m.consts["c1"], m.consts["c2"]
                if c1.dtype != np.float32 or c2.dtype != np.float32:
                    continue
                # bit-exactness gate: power-of-two scaling commutes with
                # rounding, anything else would double-round differently.
                # Element-wise, so per-channel vectors qualify as long as
                # *every* entry of one constant is a power of two (the §3.1
                # per-channel decomposition makes the whole 2**-N vector so).
                if not (_all_pow2(c1) or _all_pow2(c2)):
                    continue
                if not _broadcastable(c1, c2):
                    continue
                m1, m2 = m.node("m1"), m.node("m2")
                x_in = m1.inputs[1] if ga.is_const(m1.inputs[0]) else m1.inputs[0]
                cname = unique_name(graph, f"{m2.outputs[0]}_folded_scale")
                graph.initializers[cname] = np.asarray(c1 * c2, np.float32)
                fused = Node("Mul", [x_in, cname], [m2.outputs[0]], name=m1.name or "mul_fold")
                idx = next(i for i, n in enumerate(graph.nodes) if n is m1)
                graph.nodes[idx] = fused
                remove_nodes(graph, [m2])
                folded += 1
                eliminated += 1
                applied = True
                break
            if not applied:
                return {"folded": folded, "eliminated": eliminated}


# ---------------------------------------------------------------------------
# consecutive-Add bias folding
# ---------------------------------------------------------------------------

_ADDADD = Pattern(
    "add_add",
    (
        OpSpec("Add", capture="a1", const_operand="c1"),
        OpSpec("Add", capture="a2", const_operand="c2"),
    ),
)


class AddFold(Pass):
    """Fold consecutive constant ``Add``s: ``(x + c1) + c2 → x + (c1 + c2)``.

    Bit-exactness gate: **integer** operands only.  Two's-complement addition
    is associative even under wrap-around, so the fold is exact for any int
    dtype; float addition is not associative, so float pairs are left alone
    (the ``+0`` identity case is already :class:`IdentityElim`'s job).  This
    is the bias-pair analogue of :class:`MulFold` — split int32 bias adds
    around a MatMulInteger collapse to the single Add the QLINEAR fusion
    pattern consumes."""

    name = "add_fold"

    def run(self, graph: Graph) -> Dict[str, int]:
        folded = 0
        eliminated = 0
        while True:
            ga = GraphAnalysis(graph)
            applied = False
            for node in graph.toposorted():
                if node.op_type != "Add":
                    continue
                m = match_chain(ga, node, _ADDADD)
                if m is None:
                    continue
                c1, c2 = m.consts["c1"], m.consts["c2"]
                if not (np.issubdtype(c1.dtype, np.integer) and np.issubdtype(c2.dtype, np.integer)):
                    continue
                a1 = m.node("a1")
                x_in = a1.inputs[1] if ga.is_const(a1.inputs[0]) else a1.inputs[0]
                xd = ga.dtype(x_in)
                if xd is None or not np.issubdtype(DTYPES.get(xd, np.float32), np.integer):
                    continue
                if not _broadcastable(c1, c2):
                    continue
                # Associativity only holds at one fixed width: the folded
                # constant must be summed in the sequential chain's compute
                # dtype d1 = promote(x, c1) (not promote(c1, c2) — narrow
                # consts would wrap too early), and if the second add widens
                # (promote(d1, c2) != d1) the first add's wraparound at d1 is
                # observable and the pair must be kept.
                d1 = np.promote_types(DTYPES[xd], c1.dtype)
                if np.promote_types(d1, c2.dtype) != d1:
                    continue
                a2 = m.node("a2")
                cname = unique_name(graph, f"{a2.outputs[0]}_folded_bias")
                with np.errstate(over="ignore"):
                    graph.initializers[cname] = c1.astype(d1) + c2.astype(d1)
                fused = Node("Add", [x_in, cname], [a2.outputs[0]], name=a1.name or "add_fold")
                idx = next(i for i, n in enumerate(graph.nodes) if n is a1)
                graph.nodes[idx] = fused
                remove_nodes(graph, [a2])
                folded += 1
                eliminated += 1
                applied = True
                break
            if not applied:
                return {"folded": folded, "eliminated": eliminated}


# ---------------------------------------------------------------------------
# identity elimination
# ---------------------------------------------------------------------------


class IdentityElim(Pass):
    name = "identity_elim"

    def run(self, graph: Graph) -> Dict[str, int]:
        eliminated = 0
        while True:
            ga = GraphAnalysis(graph)
            applied = False
            for node in graph.toposorted():
                src = self._identity_source(ga, node)
                if src is None:
                    continue
                remove_nodes(graph, [node])
                if not bypass_tensor(graph, src, node.outputs[0]):
                    graph.nodes.append(node)
                    continue
                eliminated += 1
                applied = True
                break
            if not applied:
                return {"eliminated": eliminated}

    @staticmethod
    def _identity_source(ga: GraphAnalysis, node: Node) -> Optional[str]:
        """Returns the input tensor the node is an identity of, else None."""
        t = node.op_type
        if t == "Cast":
            src = node.inputs[0]
            return src if node.attrs.get("to") == ga.dtype(src) else None
        if t in ("Mul", "Div", "Add", "Sub"):
            if len(node.inputs) != 2:
                return None
            consts = [(i, ga.const(n)) for i, n in enumerate(node.inputs)]
            for idx, c in consts:
                if c is None or c.size != 1:
                    continue
                other = node.inputs[1 - idx]
                if ga.dtype(other) != str(c.dtype):
                    continue  # identity value but dtype-promoting — keep
                if c.ndim:
                    osh = ga.shape(other)
                    if osh is None or c.ndim > len(osh):
                        continue  # rank-expanding broadcast — not an identity
                v = c.reshape(())[()]
                if t == "Mul" and v == 1:
                    return other
                if t == "Div" and idx == 1 and v == 1:
                    return other
                if t == "Add" and v == 0:
                    return other
                if t == "Sub" and idx == 1 and v == 0:
                    return other
            return None
        if t == "Transpose":
            s = ga.shape(node.inputs[0])
            if s is None:
                return None
            perm = node.attrs.get("perm")
            if perm is None:
                perm = list(range(len(s)))[::-1]
            return node.inputs[0] if list(perm) == list(range(len(s))) else None
        if t == "Reshape":
            s_in = ga.shape(node.inputs[0])
            s_out = ga.shape(node.outputs[0])
            if s_in is None or s_out is None or any(d is None for d in s_in) or any(d is None for d in s_out):
                return None
            return node.inputs[0] if tuple(s_in) == tuple(s_out) else None
        return None


# ---------------------------------------------------------------------------
# dead code elimination
# ---------------------------------------------------------------------------


class DeadCode(Pass):
    name = "dead_code"

    def run(self, graph: Graph) -> Dict[str, int]:
        live = {t.name for t in graph.outputs}
        keep: List[Node] = []
        eliminated = 0
        for node in reversed(graph.toposorted()):
            if any(o in live for o in node.outputs):
                keep.append(node)
                live.update(i for i in node.inputs if i)
            else:
                eliminated += 1
        if eliminated:
            alive = {id(n) for n in keep}
            graph.nodes[:] = [n for n in graph.nodes if id(n) in alive]
        used = {i for n in graph.nodes for i in n.inputs if i} | {t.name for t in graph.outputs}
        pruned = [k for k in graph.initializers if k not in used]
        for k in pruned:
            del graph.initializers[k]
        return {"eliminated": eliminated, "pruned_inits": len(pruned)}
