"""repro.passes — PQ-IR graph-optimization pass pipeline.

The paper's co-design contract hands the hardware compiler a standard-ops-only
pre-quantized graph; this package is the *optimization pipeline* that sits
between that artifact and backend codegen, in the spirit of QNN-style
compiler lowerings (Jain et al.) and the pass-structured onnx-mlir flow.

Optimization pipeline
=====================

::

    PQ-IR artifact (repro.core.pqir.Model)
        │
        ▼
    ┌──────────────────────────────────────────────────────────────┐
    │ PassManager (repro.passes.manager)                           │
    │   1. const_fold      evaluate all-initializer nodes          │
    │   2. identity_elim   same-dtype Cast, ×1, +0, no-op shapes   │
    │   3. sink_shapes     Reshape/Transpose/Flatten past          │
    │                      elementwise ops                         │
    │   4. mul_fold        §3.1 quant_scale·2⁻ⁿ pair → one Mul     │
    │   5. add_fold        integer bias Add pairs → one Add        │
    │   6. qdq_cancel      Dequantize→Quantize round trips         │
    │   7. dead_code       unused nodes + initializers             │
    │   (sweeps repeat until a fixpoint, bounded by max_iterations)│
    └──────────────────────────────────────────────────────────────┘
        │                         │
        │                         └── conformance hook (verify=True):
        │                             re-run repro.core.runtime on probe
        ▼                             inputs after every changing pass —
    optimized PQ-IR                   bit-exact on integer outputs, else
        │                             ConformanceError names the pass
        ▼
    repro.core.compile — declarative fusion patterns (qlinear / qconv /
    int8-LUT) expressed on repro.passes.rewrite, lowered through the typed
    repro.backend ExecutionPlan (buffer slots + kernel registry) onto the
    JAX/Pallas kernels

Layout
======

* :mod:`repro.passes.analysis`     — graph-wide dtype/shape inference and
  def-use maps (:class:`GraphAnalysis`), shared by passes and the compiler.
* :mod:`repro.passes.rewrite`      — the declarative pattern-rewrite engine:
  a fusion/canonicalization candidate is an :class:`~rewrite.OpSpec` chain
  (:class:`~rewrite.Pattern`) matched along single-consumer edges.
* :mod:`repro.passes.canonicalize` — semantics-preserving cleanups
  (const_fold, qdq_cancel, mul_fold, identity_elim, dead_code).
* :mod:`repro.passes.sink`         — Reshape/Transpose sinking.
* :mod:`repro.passes.manager`      — :class:`PassManager`, per-pass stats
  (:class:`PipelineReport`), the conformance hook, :func:`optimize`.

Every pass is individually toggleable (``PassManager(disable=("mul_fold",))``)
and every rewrite is chosen so the transformed float arithmetic is
IEEE-identical — the pipeline's output is interchangeable with its input for
any conforming runtime.
"""
from .analysis import GraphAnalysis, clone_graph, clone_model, infer_dtypes, infer_shapes  # noqa: F401
from .canonicalize import AddFold, ConstantFold, DeadCode, IdentityElim, MulFold, Pass, QdqCancel  # noqa: F401
from .manager import (  # noqa: F401
    ConformanceError,
    PassManager,
    PipelineReport,
    default_passes,
    make_probe_feeds,
    optimize,
)
from .rewrite import Match, OpSpec, Pattern, match_chain  # noqa: F401
from .sink import SinkShapes  # noqa: F401
