"""Backend kernel registry: kernel-id → per-backend implementations.

Kernel *selection* is data, not code: a :class:`~repro.backend.plan.PlanStep`
declares a kernel id (``"qlinear_matmul"``, ``"op.Relu"``, …) and the plan
interpreter resolves the implementation for the plan's backend here.  Adding a
backend means registering implementations — no conditionals inside the
compiler or the executor.

An implementation has the uniform signature::

    impl(step: PlanStep, args: List[Optional[jax.Array]]) -> List[jax.Array]

where ``args`` are the step's operands in declared order (slot values and
baked constants already resolved; ``None`` for absent optional operands) and
``step.params`` / ``step.consts`` carry the compile-time-specialized state
(static attributes, chosen tile sizes, pre-padded parameter tensors).

Registration is keyed by ``(backend, kernel_id)``.  The pseudo-backend
``"*"`` is the shared fallback: :func:`lookup` first tries the exact backend,
then ``"*"`` — so the generic jnp mirror registers once for every backend
while the fused kernels provide ``ref`` / ``interpret`` / ``pallas``
specializations.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

#: (backend, kernel_id) → implementation.
_REGISTRY: Dict[Tuple[str, str], Callable] = {}

#: The shared-fallback pseudo-backend.
ANY_BACKEND = "*"


class UnknownKernelError(KeyError):
    """No implementation registered for (backend, kernel id)."""


def register(kernel_id: str, backend: str = ANY_BACKEND) -> Callable:
    """Decorator: register ``fn`` as the ``kernel_id`` implementation for
    ``backend`` (``"*"`` = shared across all backends)."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(backend, kernel_id)] = fn
        return fn

    return deco


def lookup(backend: str, kernel_id: str) -> Callable:
    """Resolve the implementation for ``kernel_id`` on ``backend`` (falling
    back to the shared ``"*"`` registration)."""
    fn = _REGISTRY.get((backend, kernel_id)) or _REGISTRY.get((ANY_BACKEND, kernel_id))
    if fn is None:
        raise UnknownKernelError(
            f"no kernel {kernel_id!r} registered for backend {backend!r} "
            f"(known: {sorted(kernel_ids())})"
        )
    return fn


def kernel_ids() -> List[str]:
    """All registered kernel ids (across every backend)."""
    return sorted({kid for _, kid in _REGISTRY})


def backends_for(kernel_id: str) -> List[str]:
    """Backends providing ``kernel_id`` (``"*"`` = shared fallback)."""
    return sorted(b for b, kid in _REGISTRY if kid == kernel_id)
