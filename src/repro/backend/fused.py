"""Fused-kernel implementations for the backend registry.

Four kernel ids cover the paper's fusion patterns:

* ``qlinear_matmul`` — MatMulInteger→…→QuantizeLinear chain.  The ``ref``
  backend runs the pure-jnp oracle on the *unpadded* parameters; the
  ``interpret``/``pallas`` backends run the Pallas tile kernel on parameters
  the lowering already padded to tile multiples
  (:func:`repro.kernels.ops.specialize_qmatmul_params`), so nothing but the
  activation is ever padded per call.
* ``qlinear_conv2d`` — ConvInteger chain on XLA's int8 conv (shared impl:
  the epilogue is plain jnp on every backend).
* ``qact_lut`` — the exact 256-entry int8 activation LUT.
* ``qattention`` — the fused int8 attention region (score MatMulInteger,
  additive masking, max-shifted LUT-softmax, context MatMulInteger).  The
  ``ref`` backend runs the jnp oracle; ``interpret``/``pallas`` run the
  tiled kernel (:mod:`repro.kernels.qattention`).  Scalar constants ride in
  ``step.params`` (static under jit); the LUT is the one array const.

Step contract (see :mod:`repro.backend.plan`): ``args = [x]`` (the single
graph-tensor input), parameters in ``step.consts``, static config in
``step.params``.  ``params["x_uint8"]`` marks a uint8 activation whose +128
offset was folded into the bias *at plan time* — the impl only applies the
signed shift to x.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.pqir import DTYPES
from ..kernels import ops as kops
from ..kernels import ref as _ref
from .registry import register


def _as_signed(x, params):
    """uint8 activation → signed int8 (bias correction already folded)."""
    if params.get("x_uint8"):
        return (x.astype(jnp.int32) - 128).astype(jnp.int8)
    return x


@register("qlinear_matmul", backend="ref")
def _qlinear_matmul_ref(step, args):
    x = _as_signed(args[0], step.params)
    w, b, qs, qsh = step.consts
    p = step.params
    y = _ref.qmatmul_ref(
        x, w, b, qs, qsh,
        out_dtype=DTYPES[p["out_dtype"]], relu=p["relu"], two_mul=p["two_mul"],
    )
    return [y]


def _qlinear_matmul_tiled(step, args, *, interpret: bool):
    x = _as_signed(args[0], step.params)
    w2, b2, qs2, qsh2 = step.consts
    p = step.params
    if p.get("dynamic_batch"):
        raise RuntimeError(
            "batch-polymorphic template plan cannot execute directly: bind it "
            "to a bucket first (repro.backend.lowering.specialize_plan, or run "
            "through CompiledModel which caches specializations per bucket)"
        )
    y = kops.quantized_matmul_planned(
        x, w2, b2, qs2, qsh2, p["shape"],
        out_dtype=DTYPES[p["out_dtype"]], relu=p["relu"], two_mul=p["two_mul"],
        interpret=interpret,
    )
    return [y]


@register("qlinear_matmul", backend="interpret")
def _qlinear_matmul_interpret(step, args):
    return _qlinear_matmul_tiled(step, args, interpret=True)


@register("qlinear_matmul", backend="pallas")
def _qlinear_matmul_pallas(step, args):
    return _qlinear_matmul_tiled(step, args, interpret=False)


@register("qlinear_conv2d")
def _qlinear_conv2d(step, args):
    w, b, qs, qsh = step.consts
    p = step.params
    y = kops.quantized_conv2d(
        args[0], w, b, qs, qsh,
        strides=p["strides"], pads=p["pads"],
        out_dtype=DTYPES[p["out_dtype"]], relu=p["relu"], two_mul=p["two_mul"],
    )
    return [y]


@register("qattention", backend="ref")
def _qattention_ref(step, args):
    q, k, v, mask = args
    (lut,) = step.consts
    p = step.params
    y = _ref.qattention_ref(
        q, k, v, mask,
        jnp.float32(p["qk_scale"]), jnp.float32(p["big"]),
        jnp.float32(p["lut_scale"]), lut,
        jnp.float32(p["p_scale"]), jnp.float32(p["rescale"]),
        out_dtype=DTYPES[p["out_dtype"]],
    )
    return [y]


def _qattention_tiled(step, args, *, interpret: bool):
    from ..kernels import qattention as _qatt

    q, k, v, mask = args
    (lut,) = step.consts
    p = step.params
    if p.get("dynamic_attn"):
        raise RuntimeError(
            "axis-open attention template cannot execute directly: bind it to "
            "a bucket first (repro.backend.lowering.specialize_plan, or run "
            "through CompiledModel which caches specializations per bucket)"
        )
    y = _qatt.qattention(
        q, k, v, mask, lut,
        qk_scale=p["qk_scale"], big=p["big"], lut_scale=p["lut_scale"],
        p_scale=p["p_scale"], rescale=p["rescale"],
        out_dtype=DTYPES[p["out_dtype"]],
        bq=p["shape"].get("bq", _qatt.BQ),
        interpret=interpret,
    )
    return [y]


@register("qattention", backend="interpret")
def _qattention_interpret(step, args):
    return _qattention_tiled(step, args, interpret=True)


@register("qattention", backend="pallas")
def _qattention_pallas(step, args):
    return _qattention_tiled(step, args, interpret=False)


def _qact_lut(step, args, *, backend: str):
    (lut,) = step.consts
    return [kops.quantized_activation(args[0], lut, backend=backend)]


@register("qact_lut", backend="ref")
def _qact_lut_ref(step, args):
    return _qact_lut(step, args, backend="ref")


@register("qact_lut", backend="interpret")
def _qact_lut_interpret(step, args):
    return _qact_lut(step, args, backend="interpret")


@register("qact_lut", backend="pallas")
def _qact_lut_pallas(step, args):
    return _qact_lut(step, args, backend="pallas")
