"""Lowering: step drafts → buffer-planned :class:`ExecutionPlan`.

The compiler's builders emit :class:`StepDraft`\\ s — kernel id + symbolic
operands (graph-tensor names, baked constants, absent optionals) in execution
order.  :func:`build_plan` turns those into the typed plan:

* **slot allocation (liveness-planned):** every tensor gets an integer buffer
  slot; a slot returns to the free pool the moment its tensor's last reader
  has consumed it, so later intermediates reuse storage.  Inputs of a step
  are released *before* its outputs are allocated — an output may alias a
  dead input's slot, which is safe because the executor reads all operands
  before writing results.  Graph outputs are pinned (never freed).
* **static typing:** each produced value is annotated with the dtype/shape
  that :mod:`repro.passes.analysis` inferred on the optimized graph, making
  the plan self-describing for co-design inspection.

Batch polymorphism splits plan building in two: :func:`build_plan` with
``batch="dynamic"`` produces a shape-generic **template** (all of the above,
with the symbolic leading dim left open), and :func:`specialize_plan` lazily
binds a template to a concrete batch bucket — tile choice for the batch dim,
flat M — without re-running fusion, liveness planning, or parameter padding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Union

from ..core.pqir import Graph
from ..kernels import ops as kops
from ..passes.analysis import GraphAnalysis, bind_batch
from .plan import CONST, NONE, SLOT, Arg, ExecutionPlan, PlanStep, ValueInfo

#: Draft operand kinds: ("tensor", name) | ("const", value) | ("none", None)
DraftArg = Tuple[str, Any]


def tensor_arg(name: str) -> DraftArg:
    return ("tensor", name)


def const_arg(value: Any) -> DraftArg:
    return ("const", value)


def none_arg() -> DraftArg:
    return ("none", None)


@dataclasses.dataclass
class StepDraft:
    """A lowered-but-unplanned step: symbolic operands, no slots yet."""

    kernel: str
    args: List[DraftArg]
    outputs: List[str]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    consts: Tuple[Any, ...] = ()  # bag constants (read via step.consts)
    kind: str = "generic"
    name: str = ""


def build_plan(
    graph: Graph,
    analysis: GraphAnalysis,
    drafts: List[StepDraft],
    backend: str,
    batch: Union[str, int] = "static",
) -> ExecutionPlan:
    """Assign liveness-planned buffer slots and produce the ExecutionPlan.

    ``batch="dynamic"`` marks the result as an unbound template (the drafts
    must then carry batch-open shape records — see the compiler's fused
    builders); slot planning, liveness and value typing are identical either
    way, which is exactly the point: they are batch-independent."""
    out_names = {t.name for t in graph.outputs}

    uses: Dict[str, int] = {}
    for d in drafts:
        for kind, val in d.args:
            if kind == "tensor":
                uses[val] = uses.get(val, 0) + 1

    slot_of: Dict[str, int] = {}
    free: List[int] = []
    num_slots = 0

    def alloc(name: str) -> int:
        nonlocal num_slots
        if free:
            s = free.pop()
        else:
            s = num_slots
            num_slots += 1
        slot_of[name] = s
        return s

    def release(name: str) -> None:
        if name not in out_names and name in slot_of:
            free.append(slot_of.pop(name))

    inputs = tuple((t.name, alloc(t.name)) for t in graph.inputs)
    # graph inputs nobody reads die immediately
    for t in graph.inputs:
        if uses.get(t.name, 0) == 0:
            release(t.name)

    steps: List[PlanStep] = []
    for d in drafts:
        consts = list(d.consts)
        args: List[Arg] = []
        for kind, val in d.args:
            if kind == "tensor":
                args.append(Arg(SLOT, slot_of[val], val))
            elif kind == "const":
                consts.append(val)
                args.append(Arg(CONST, len(consts) - 1))
            else:
                args.append(Arg(NONE))
        # inputs whose last use this is free their slots now, so this step's
        # outputs may alias them (safe: operands are read before results land)
        for kind, val in d.args:
            if kind != "tensor":
                continue
            uses[val] -= 1
            if uses[val] == 0:
                release(val)
        out_slots = tuple(alloc(o) for o in d.outputs)
        for o in d.outputs:  # never-read, non-output results die immediately
            if uses.get(o, 0) == 0:
                release(o)
        out_info = tuple(ValueInfo(analysis.dtype(o), analysis.shape(o)) for o in d.outputs)
        steps.append(
            PlanStep(
                kernel=d.kernel,
                args=tuple(args),
                out_slots=out_slots,
                params=d.params,
                consts=tuple(consts),
                kind=d.kind,
                name=d.name,
                outputs=tuple(d.outputs),
                out_info=out_info,
            )
        )

    missing = [n for n in out_names if n not in slot_of]
    if missing:
        raise ValueError(f"graph outputs never lowered: {missing}")
    outputs = tuple((t.name, slot_of[t.name]) for t in graph.outputs)
    return ExecutionPlan(
        backend=backend,
        steps=steps,
        num_slots=num_slots,
        inputs=inputs,
        outputs=outputs,
        batch=batch,
    )


def specialize_plan(template: ExecutionPlan, batch: int) -> ExecutionPlan:
    """Bind a batch-polymorphic plan template to a concrete batch bucket.

    This is the *late* half of shape specialization: for every fused-qmatmul
    step carrying a batch-open shape record the flat M and the bm tile are
    computed for ``batch`` (:func:`repro.kernels.ops.bind_qmatmul_batch`),
    and every value's symbolic leading dim is substituted in ``out_info`` so
    the specialized plan renders fully concrete.  Everything else — steps,
    slots, liveness, padded parameter arrays — is shared with the template
    (no re-lowering, no array copies): a bucket specialization is O(steps).
    """
    if template.batch != "dynamic":
        raise ValueError(
            f"only a batch='dynamic' template can be specialized, "
            f"got a batch={template.batch!r} plan"
        )
    batch = int(batch)
    steps = []
    for step in template.steps:
        params = step.params
        if params.get("dynamic_batch"):
            params = {k: v for k, v in params.items() if k != "dynamic_batch"}
            params["shape"] = kops.bind_qmatmul_batch(step.params["shape"], batch)
        out_info = tuple(
            ValueInfo(info.dtype, bind_batch(info.shape, batch)) if info is not None else info
            for info in step.out_info
        )
        steps.append(dataclasses.replace(step, params=params, out_info=out_info))
    return dataclasses.replace(template, steps=steps, batch=batch)
