"""Lowering: step drafts → buffer-planned :class:`ExecutionPlan`.

The compiler's builders emit :class:`StepDraft`\\ s — kernel id + symbolic
operands (graph-tensor names, baked constants, absent optionals) in execution
order.  :func:`build_plan` turns those into the typed plan:

* **slot allocation (liveness-planned):** every tensor gets an integer buffer
  slot; a slot returns to the free pool the moment its tensor's last reader
  has consumed it, so later intermediates reuse storage.  Inputs of a step
  are released *before* its outputs are allocated — an output may alias a
  dead input's slot, which is safe because the executor reads all operands
  before writing results.  Graph outputs are pinned (never freed).
* **static typing:** each produced value is annotated with the dtype/shape
  that :mod:`repro.passes.analysis` inferred on the optimized graph, making
  the plan self-describing for co-design inspection.

Scenario specialization splits plan building in two: :func:`build_plan` with
``batch="dynamic"`` produces a shape-generic **template** (all of the above,
with the named symbolic axes left open — the classic batch-only case is just
``axes=("N",)``), and :func:`specialize_plan` lazily binds a template to
concrete per-axis buckets — flat M from the bound lead dims, the bm tile —
without re-running fusion, liveness planning, or parameter padding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Union

from ..core.pqir import Graph
from ..kernels import ops as kops
from ..obs import trace as _trace
from ..obs.provenance import PlanProvenance
from ..passes.analysis import BATCH_AXIS, GraphAnalysis, bind
from .plan import CONST, NONE, SLOT, Arg, ExecutionPlan, PlanStep, StateBinding, ValueInfo

#: Draft operand kinds: ("tensor", name) | ("const", value) | ("none", None)
DraftArg = Tuple[str, Any]


def tensor_arg(name: str) -> DraftArg:
    return ("tensor", name)


def const_arg(value: Any) -> DraftArg:
    return ("const", value)


def none_arg() -> DraftArg:
    return ("none", None)


@dataclasses.dataclass
class StepDraft:
    """A lowered-but-unplanned step: symbolic operands, no slots yet."""

    kernel: str
    args: List[DraftArg]
    outputs: List[str]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    consts: Tuple[Any, ...] = ()  # bag constants (read via step.consts)
    kind: str = "generic"
    name: str = ""


def build_plan(
    graph: Graph,
    analysis: GraphAnalysis,
    drafts: List[StepDraft],
    backend: str,
    batch: Union[str, int] = "static",
    axes: Tuple[str, ...] = (),
    provenance: Optional[PlanProvenance] = None,
) -> ExecutionPlan:
    """Assign liveness-planned buffer slots and produce the ExecutionPlan.

    ``batch="dynamic"`` marks the result as an unbound template open over the
    named ``axes`` (the drafts must then carry axis-open shape records — see
    the compiler's fused builders); slot planning, liveness and value typing
    are identical either way, which is exactly the point: they are
    independent of every dynamic axis.

    Graph ``states`` (the declared KV-cache pairs) lower to *persistent*
    slots: a state's input slot is pinned — excluded from liveness release —
    so its buffer identity survives the whole invocation (and, by contract,
    across invocations: the executor's caller feeds each state output back
    into its paired input).  The pairs are recorded as
    :class:`repro.backend.plan.StateBinding` on the plan."""
    out_names = {t.name for t in graph.outputs}
    state_inputs = {s.input for s in graph.states}
    pinned = out_names | state_inputs

    uses: Dict[str, int] = {}
    for d in drafts:
        for kind, val in d.args:
            if kind == "tensor":
                uses[val] = uses.get(val, 0) + 1

    slot_of: Dict[str, int] = {}
    free: List[int] = []
    num_slots = 0

    def alloc(name: str) -> int:
        nonlocal num_slots
        if free:
            s = free.pop()
        else:
            s = num_slots
            num_slots += 1
        slot_of[name] = s
        return s

    def release(name: str) -> None:
        if name not in pinned and name in slot_of:
            free.append(slot_of.pop(name))

    inputs = tuple((t.name, alloc(t.name)) for t in graph.inputs)
    # graph inputs nobody reads die immediately
    for t in graph.inputs:
        if uses.get(t.name, 0) == 0:
            release(t.name)

    steps: List[PlanStep] = []
    for d in drafts:
        consts = list(d.consts)
        args: List[Arg] = []
        for kind, val in d.args:
            if kind == "tensor":
                args.append(Arg(SLOT, slot_of[val], val))
            elif kind == "const":
                consts.append(val)
                args.append(Arg(CONST, len(consts) - 1))
            else:
                args.append(Arg(NONE))
        # inputs whose last use this is free their slots now, so this step's
        # outputs may alias them (safe: operands are read before results land)
        for kind, val in d.args:
            if kind != "tensor":
                continue
            uses[val] -= 1
            if uses[val] == 0:
                release(val)
        out_slots = tuple(alloc(o) for o in d.outputs)
        for o in d.outputs:  # never-read, non-output results die immediately
            if uses.get(o, 0) == 0:
                release(o)
        out_info = tuple(ValueInfo(analysis.dtype(o), analysis.shape(o)) for o in d.outputs)
        steps.append(
            PlanStep(
                kernel=d.kernel,
                args=tuple(args),
                out_slots=out_slots,
                params=d.params,
                consts=tuple(consts),
                kind=d.kind,
                name=d.name,
                outputs=tuple(d.outputs),
                out_info=out_info,
            )
        )

    missing = [n for n in out_names if n not in slot_of]
    if missing:
        raise ValueError(f"graph outputs never lowered: {missing}")
    outputs = tuple((t.name, slot_of[t.name]) for t in graph.outputs)
    in_specs = {t.name: t for t in graph.inputs}
    states = tuple(
        StateBinding(
            name=s.name,
            input=s.input,
            output=s.output,
            in_slot=slot_of[s.input],
            out_slot=slot_of[s.output],
            dtype=in_specs[s.input].dtype,
            shape=tuple(in_specs[s.input].shape),
        )
        for s in graph.states
    )
    if batch == "dynamic" and not axes:
        axes = (BATCH_AXIS,)
    return ExecutionPlan(
        backend=backend,
        steps=steps,
        num_slots=num_slots,
        inputs=inputs,
        outputs=outputs,
        batch=batch,
        axes=axes if batch == "dynamic" else (),
        provenance=provenance,
        states=states,
    )


def specialize_plan(
    template: ExecutionPlan,
    bindings: Union[int, Dict[str, int]],
    *,
    tuner: Optional[Any] = None,
) -> ExecutionPlan:
    """Bind a scenario-polymorphic plan template to concrete axis buckets.

    ``bindings`` maps axis names to padded buckets (``{"N": 8, "S": 128}``);
    a bare int is PR 4 sugar for ``{"N": int}``.  This is the *late* half of
    shape specialization: for every fused-qmatmul step carrying an axis-open
    shape record the flat M and the bm tile are computed from the bound lead
    dims (:func:`repro.kernels.ops.bind_qmatmul_axes`), and every value's
    symbolic dims are substituted in ``out_info`` so the specialized plan
    renders fully concrete.  Everything else — steps, slots, liveness,
    padded parameter arrays — is shared with the template (no re-lowering,
    no array copies): a bucket specialization is O(steps).

    Binding a *subset* of the template's axes yields a plan that is still a
    ``"dynamic"`` template over the remaining axes (and still refuses to
    execute); binding order never matters — the result is keyed/rendered on
    the sorted bindings.  Unknown axis names are rejected.  As a degenerate
    case, ``specialize_plan(plan, {})`` on a fully-static plan is a no-op
    (there is nothing to bind); a non-empty bindings dict on a static plan
    is still an error.

    ``tuner`` (an :class:`repro.backend.autotune.Autotuner`, or anything
    with its ``tune_step`` contract) routes each fully-bound fused step's
    tile choice through the measured per-cell search: the heuristic shape
    record goes in, a possibly re-tiled record and a source tag
    (``heuristic | tuned | cache``) come out.  The provenance tile record
    carries the tag for non-heuristic sources (``... [tuned]``), so
    ``plan.pretty(verbose=True)`` shows where every cell's tiles came from;
    heuristic cells render exactly as before.
    """
    if isinstance(bindings, dict):
        bindings = {str(a): int(v) for a, v in bindings.items()}
    else:
        bindings = {BATCH_AXIS: int(bindings)}
    if template.batch != "dynamic":
        if not bindings:
            return template  # nothing to bind: binding is a no-op on statics
        raise ValueError(
            f"only a batch='dynamic' template can be specialized, "
            f"got a batch={template.batch!r} plan"
        )
    unknown = sorted(set(bindings) - set(template.axes))
    if unknown:
        raise ValueError(
            f"unknown dynamic axes {unknown}: this template is open over "
            f"{list(template.axes)}"
        )
    remaining = tuple(a for a in template.axes if a not in bindings)
    with _trace.span(
        "backend.specialize",
        bindings=",".join(f"{a}={v}" for a, v in sorted(bindings.items())),
        partial=bool(remaining),
    ) as sp:
        steps = []
        tiles: Dict[str, str] = {}
        for step in template.steps:
            params = step.params
            if params.get("dynamic_attn"):
                # fused attention carries its own axis-open record (b/s/t/dh
                # rather than lead/m) and its own binder — it must NOT take
                # the qmatmul dynamic_batch path, whose binder and tuner
                # assume the (w2,b2,qs2,qsh2) consts layout
                if remaining:
                    params = dict(params)
                    params["shape"] = kops.bind_qattention_axes(
                        step.params["shape"], bindings, partial=True
                    )
                else:
                    params = {k: v for k, v in params.items() if k != "dynamic_attn"}
                    shape = kops.bind_qattention_axes(step.params["shape"], bindings)
                    source = "heuristic"
                    if tuner is not None:
                        shape, source = tuner.tune_step(
                            step, shape, backend=template.backend, bindings=bindings
                        )
                    params["shape"] = shape
                    rec = ",".join(
                        f"{k}={shape[k]}" for k in ("b", "s", "t", "dh", "bq") if k in shape
                    )
                    if source != "heuristic":
                        rec += f" [{source}]"
                    tiles[step.name or step.kernel] = rec
            elif params.get("dynamic_batch"):
                if remaining:
                    params = dict(params)
                    params["shape"] = kops.bind_qmatmul_axes(
                        step.params["shape"], bindings, partial=True
                    )
                else:
                    params = {k: v for k, v in params.items() if k != "dynamic_batch"}
                    shape = kops.bind_qmatmul_axes(step.params["shape"], bindings)
                    source = "heuristic"
                    if tuner is not None:
                        shape, source = tuner.tune_step(
                            step, shape, backend=template.backend, bindings=bindings
                        )
                    params["shape"] = shape
                    rec = ",".join(
                        f"{k}={shape[k]}" for k in ("m", "bm", "bk", "bn") if k in shape
                    )
                    if "bits" in shape:
                        # sub-8-bit weight lane: a hardware designer reads the
                        # precision off the cell record (activations stay int8)
                        rec += f",w{shape['bits']}/a8"
                    if source != "heuristic":
                        rec += f" [{source}]"
                    tiles[step.name or step.kernel] = rec
            out_info = tuple(
                ValueInfo(info.dtype, bind(info.shape, bindings)) if info is not None else info
                for info in step.out_info
            )
            steps.append(dataclasses.replace(step, params=params, out_info=out_info))
        # state buffers bind their seq extent like any other value: the
        # specialized plan knows the concrete KV-cache bucket it carries
        states = tuple(
            dataclasses.replace(s, shape=bind(s.shape, bindings)) for s in template.states
        )
        if remaining:
            return dataclasses.replace(
                template, steps=steps, batch="dynamic", axes=remaining, states=states
            )
        sp.set(**tiles)
        # a full bind is one visited scenario cell: record it on the shared
        # provenance so template *and* specializations show the history
        if template.provenance is not None:
            template.provenance.add_specialization(bindings, tiles)
        if template.axes == (BATCH_AXIS,):
            bound: Union[int, Tuple[Tuple[str, int], ...]] = bindings[BATCH_AXIS]
        else:
            bound = tuple(sorted(bindings.items()))
        return dataclasses.replace(template, steps=steps, batch=bound, axes=(), states=states)
