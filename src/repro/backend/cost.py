"""Hardware model + arithmetic-intensity cost estimates for the backend.

One importable home for the numbers that used to live only at the top of
``benchmarks/roofline.py``: the TPU v5e hardware constants and the
``T_comp`` / ``T_mem`` / ``T_coll`` roofline terms.  Two consumers share it:

* ``benchmarks/roofline.py`` — the paper's roofline analysis imports the
  constants and :func:`roofline_terms` instead of duplicating them, and
* :mod:`repro.backend.autotune` — the measured tile search *seeds* its
  candidate ranking with :func:`qmatmul_tile_cost` (an analytic
  max(T_comp, T_mem) per tile configuration), so only the ~6–10 most
  promising lattice points are ever timed, and prunes candidates whose
  working set cannot fit VMEM (:func:`qmatmul_vmem_bytes`).

The estimates are deliberately coarse — they rank candidates, they do not
replace measurement.  Everything here is analytic and deterministic.

Stdlib + dataclasses only; imports nothing from the rest of :mod:`repro`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip accelerator model used for roofline terms and tile costs."""

    name: str
    peak_bf16_flops: float  # FLOP/s, bf16 MXU peak
    peak_int8_flops: float  # FLOP/s, int8 double-rate MXU peak
    hbm_bw: float  # B/s
    ici_bw: float  # B/s per link
    chips: int  # chips in the reference (single-pod) fleet
    vmem_bytes: int  # on-chip vector memory per core
    mxu: int = 128  # systolic array dimension


#: TPU v5e: 197 TFLOP/s bf16 (394 int8), 819 GB/s HBM, ~50 GB/s/link ICI,
#: 256-chip pod, ~16 MB VMEM per core (see benchmarks/roofline.py docstring).
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    peak_int8_flops=394e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    chips=256,
    vmem_bytes=16 * 1024 * 1024,
)

# Flat aliases — the names benchmarks/roofline.py has always exported.
PEAK_BF16 = TPU_V5E.peak_bf16_flops
PEAK_INT8 = TPU_V5E.peak_int8_flops
HBM_BW = TPU_V5E.hbm_bw
ICI_BW = TPU_V5E.ici_bw
CHIPS = TPU_V5E.chips


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float = 0.0,
    *,
    hw: HardwareSpec = TPU_V5E,
    peak: float = 0.0,
) -> Dict[str, float]:
    """The per-device roofline terms (seconds):

        T_comp = FLOPs / peak        T_mem = HBM_bytes / HBM_bw
        T_coll = collective_bytes / link_bw

    ``peak`` defaults to the bf16 peak (the roofline benchmark's convention);
    pass ``hw.peak_int8_flops`` for int8-dominated kernels."""
    p = peak or hw.peak_bf16_flops
    return {
        "t_comp_s": flops / p,
        "t_mem_s": hbm_bytes / hw.hbm_bw,
        "t_coll_s": coll_bytes / hw.ici_bw,
    }


def roofline_fraction(
    model_flops: float, step_time_s: float, *, hw: HardwareSpec = TPU_V5E
) -> float:
    """Model-useful FLOP/s at the given step time as a fraction of the
    fleet's bf16 peak — the "roofline fraction" column of the paper-style
    report.  Lives here (not in benchmarks/roofline.py) so every consumer
    divides by the same fleet peak."""
    if not step_time_s:
        return 0.0
    return (model_flops / step_time_s) / (hw.chips * hw.peak_bf16_flops)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def qmatmul_hbm_bytes(
    m: int, k: int, n: int, bm: int, bk: int, bn: int, *, weight_bits: int = 8
) -> float:
    """Analytic minimum HBM traffic for the fused int8 qmatmul under the
    (M/bm, N/bn, K/bk) grid of :mod:`repro.kernels.qmatmul` (k innermost):

    * each ``(bm, bk)`` activation tile streams in once per ``j`` — the whole
      padded activation is read ``np/bn`` times,
    * each ``(bk, bn)`` weight tile streams in once per ``i`` — the padded
      weights are read ``mp/bm`` times,
    * bias/scale/shift rows (int32 + 2×f32 per output column) once per
      ``(i, j)``, and the int8 output is written once.

    ``weight_bits=4`` halves the weight term: the packed kernel streams the
    uint8 nibble array (kp/2 rows) and unpacks in VMEM — for the decode path
    (small M, weight-dominated traffic) this is the whole point of the lane.
    """
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    x_bytes = mp * kp * (np_ // bn)  # int8
    w_bytes = kp * np_ * (mp // bm) * weight_bits / 8.0  # int8, or packed int4
    epi_bytes = (4 + 4 + 4) * np_ * (mp // bm)  # bias (i32) + 2 × f32 rows
    out_bytes = mp * np_  # int8
    return float(x_bytes + w_bytes + epi_bytes + out_bytes)


def qmatmul_vmem_bytes(bm: int, bk: int, bn: int, *, weight_bits: int = 8) -> int:
    """Resident VMEM working set of one grid step: the int8 x/w tiles, the
    int8 output tile, three (1, bn) epilogue rows, and the int32 accumulator
    scratch — with double buffering on the streamed operands (the Pallas
    pipeline keeps two in-flight copies of each block).  A packed-int4 weight
    tile streams at half size (``bk/2 × bn`` uint8); the transient unpacked
    tile lives in registers/VPU, not the double-buffered stream."""
    w_tile = bk * bn * weight_bits // 8
    streamed = 2 * (bm * bk + w_tile + 3 * 4 * bn + bm * bn)
    acc = 4 * bm * bn
    return streamed + acc


def qattention_hbm_bytes(b: int, s: int, t: int, dh: int, bq: int) -> float:
    """Analytic HBM traffic for the fused int8 attention kernel under its
    ``(B, Sp/bq)`` grid (:mod:`repro.kernels.qattention`): per batch element
    the int8 Q tile streams once, the full-length int8 K and V blocks are
    resident per batch element but re-streamed once per query row-block
    (K/V block specs index on the batch dim only), the f32 mask streams
    once, and the int8 context output is written once.  The 256-entry exp
    LUT is noise and is not counted."""
    sp, tp, dp = _round_up(s, max(bq, 1)), _round_up(t, 128), _round_up(dh, 128)
    blocks = sp // max(bq, 1)
    q_bytes = sp * dp
    kv_bytes = 2 * tp * dp * blocks
    mask_bytes = 4 * sp * tp
    out_bytes = sp * dp
    return float(b * (q_bytes + kv_bytes + mask_bytes + out_bytes))


def qattention_vmem_bytes(t: int, dh: int, bq: int) -> int:
    """Resident VMEM working set of one grid step of the fused attention
    kernel: the int8 Q/out tiles and f32 mask tile (double-buffered streams),
    the full-length int8 K/V blocks, and the f32 score + int32 weight
    scratch rows."""
    tp, dp = _round_up(t, 128), _round_up(dh, 128)
    streamed = 2 * (bq * dp + 4 * bq * tp + bq * dp)
    resident = 2 * tp * dp
    scratch = (4 + 4) * bq * tp
    return streamed + resident + scratch


def qattention_tile_cost(
    b: int, s: int, t: int, dh: int, bq: int, *, hw: HardwareSpec = TPU_V5E
) -> float:
    """Analytic cost (seconds) of one fused attention launch at query tile
    ``bq``: ``max(T_comp, T_mem)`` over the padded problem.  Both int8
    contractions (QK^T and PV) count at the int8 MXU peak; the masked
    LUT-softmax between them is VPU work, charged as ~8 elementwise ops per
    score at the bf16 peak (coarse, but it penalizes tiny bq the same way
    re-streamed K/V traffic does, which is what the ranking needs)."""
    sp, tp, dp = _round_up(s, max(bq, 1)), _round_up(t, 128), _round_up(dh, 128)
    mxu_flops = 2.0 * b * sp * tp * dp * 2
    vpu_flops = 8.0 * b * sp * tp
    terms = roofline_terms(
        mxu_flops, qattention_hbm_bytes(b, s, t, dh, bq), hw=hw, peak=hw.peak_int8_flops
    )
    return max(terms["t_comp_s"] + vpu_flops / hw.peak_bf16_flops, terms["t_mem_s"])


def qmatmul_tile_cost(
    m: int, k: int, n: int, bm: int, bk: int, bn: int,
    *, hw: HardwareSpec = TPU_V5E, weight_bits: int = 8,
) -> float:
    """Analytic cost (seconds) of one fused qmatmul launch with the given
    tiles: ``max(T_comp, T_mem)`` over the *padded* problem.  Padding waste
    (a bucket of 8 run at bm=128 computes 16× the useful rows) and tile-
    dependent re-streaming both show up here, which is exactly what makes the
    ranking useful for seeding the measured search."""
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    flops = 2.0 * mp * kp * np_
    terms = roofline_terms(
        flops,
        qmatmul_hbm_bytes(m, k, n, bm, bk, bn, weight_bits=weight_bits),
        hw=hw,
        peak=hw.peak_int8_flops,
    )
    return max(terms["t_comp_s"], terms["t_mem_s"])
