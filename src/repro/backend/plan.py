"""Typed ExecutionPlan: the lowered form of a compiled PQ-IR artifact.

A plan is a flat list of :class:`PlanStep`\\ s over integer *buffer slots*.
Slots are storage, not tensors: liveness planning (see
:mod:`repro.backend.lowering`) lets intermediates reuse the slot of a value
that is already dead, so executing a deep model touches a small, fixed pool
of buffers instead of growing a name-keyed dict.  Each step declares

* a **kernel id** resolved through :mod:`repro.backend.registry` at
  execution time (``ref`` / ``interpret`` / ``pallas`` register per-id
  implementations — no backend conditionals in the executor),
* **args** — slot reads, baked constants, or absent optional operands,
* **static params** — everything specialized at *plan* time: ONNX attributes,
  output dtypes, and for the fused qmatmul path the chosen tile sizes and
  true (unpadded) problem shape,
* **consts** — parameter arrays baked into the step (for the shape-
  specialized qmatmul these are already padded to tile multiples, so the hot
  path never pads weights/bias/scales per call).

The plan's :meth:`ExecutionPlan.pretty` rendering is the co-design artifact a
hardware designer reads: one line per step with slots, dtypes/shapes, kernel
ids and static params.

Scenario specialization (named dynamic axes)
============================================

A plan's ``batch`` field says how its dynamic dimensions were handled:

* ``"static"`` — the classic path: shapes were specialized once at plan time
  (a symbolic dim falls back to default tiles).
* ``"dynamic"`` — the plan is a shape-generic **template**, open over the
  named axes in ``plan.axes`` (e.g. ``("N",)`` for the classic batch,
  ``("N", "S")`` for a batch × sequence grid): fusion, liveness slot
  planning and dtype inference are done, but the axis-dependent pieces
  (flat matmul M, bm tile choice) are left open.  Templates are not
  directly executable; they are *bound* to concrete per-axis buckets by
  :func:`repro.backend.lowering.specialize_plan` (which also accepts a
  *partial* bindings dict — the result is then still a template over the
  remaining axes).
* an ``int`` — a single-axis (batch) bucket specialization of a template.
* a tuple of ``(axis, bucket)`` pairs — a multi-axis specialization.

Specializations are produced lazily and held in a bounded
:class:`PlanCache` keyed by the sorted bindings tuple.

Per-axis bucketing
==================

Each dynamic axis carries its own bucketing policy mapping a true extent to
the padded bucket: :func:`batch_bucket` (next power of two — the default,
bounding specializations at log₂(max) while wasting ≤ 2× padding) or
:func:`bucket_multiple` (round up to a granularity — e.g. the serving
engine's ``prefill_bucket`` discipline for sequence lengths).
:func:`resolve_bucketing` normalizes a user-facing axis spec (``None`` |
int granularity | callable) to a policy function.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from ..core.cache import LruCache
from ..obs.provenance import PlanProvenance

#: Arg kinds.
SLOT, CONST, NONE = "slot", "const", "none"


@dataclasses.dataclass(frozen=True)
class Arg:
    """One operand reference of a :class:`PlanStep`.

    kind   "slot" (read buffer ``index``), "const" (read
           ``step.consts[index]``) or "none" (absent optional input)
    index  slot number or const index
    name   source PQ-IR tensor name (debug / dict-env baseline executor)
    """

    kind: str
    index: int = -1
    name: str = ""


@dataclasses.dataclass(frozen=True)
class ValueInfo:
    """Static dtype/shape of a produced value (best-effort; None = unknown)."""

    dtype: Optional[str]
    shape: Optional[Tuple[Optional[int], ...]]

    def __str__(self) -> str:
        dt = self.dtype or "?"
        if self.shape is None:
            return f"{dt}[?]"
        dims = ",".join("?" if d is None else str(d) for d in self.shape)
        return f"{dt}[{dims}]"


@dataclasses.dataclass
class PlanStep:
    """One lowered operation: kernel id + operand refs + static params."""

    kernel: str  # registry kernel id ("qlinear_matmul", "op.Relu", ...)
    args: Tuple[Arg, ...]
    out_slots: Tuple[int, ...]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    consts: Tuple[Any, ...] = ()
    kind: str = "generic"  # stats bucket: fused_qlinear|fused_qconv|fused_lut|generic
    name: str = ""  # source node / pattern name
    outputs: Tuple[str, ...] = ()  # source tensor names of out_slots
    out_info: Tuple[ValueInfo, ...] = ()

    @property
    def in_slots(self) -> Tuple[int, ...]:
        return tuple(a.index for a in self.args if a.kind == SLOT)

    def describe(self) -> str:
        ins = ", ".join(
            f"%{a.index}" if a.kind == SLOT else ("·" if a.kind == NONE else f"c{a.index}")
            for a in self.args
        )
        outs = ", ".join(
            f"%{s}:{info}" if info is not None else f"%{s}"
            for s, info in zip(self.out_slots, self.out_info or (None,) * len(self.out_slots))
        )
        rendered = (
            (k, _fmt_param(v)) for k, v in sorted(self.params.items())
        )
        params = ",".join(f"{k}={v}" for k, v in rendered if v is not None)
        consts = ",".join(_arr_sig(c) for c in self.consts)
        tail = ""
        if params:
            tail += f" {{{params}}}"
        if consts:
            tail += f" consts[{consts}]"
        src = f"  # {self.name}" if self.name else ""
        return f"{outs} = {self.kernel}({ins}){tail}{src}"


def _fmt_param(v: Any) -> Optional[str]:
    """Compact static-param rendering; nested records (the qmatmul shape
    spec, generic ONNX attrs) flatten inline so the tile choices and
    attributes the plan was specialized with are visible in the printout.
    Embedded arrays are elided (their values live in ``consts``)."""
    if isinstance(v, np.ndarray):
        return None
    if isinstance(v, dict):
        inner = ",".join(
            f"{k}={fv}" for k, fv in ((k, _fmt_param(val)) for k, val in sorted(v.items()))
            if fv is not None
        )
        return "{" + inner + "}"
    if isinstance(v, (list, tuple)):
        return "(" + ",".join(str(x) for x in v) + ")"
    return str(v)


def _arr_sig(c: Any) -> str:
    if c is None:
        return "·"
    if hasattr(c, "dtype") and hasattr(c, "shape"):
        return f"{c.dtype}{tuple(int(d) for d in c.shape)}"
    return type(c).__name__


@dataclasses.dataclass(frozen=True)
class StateBinding:
    """A planned persistent state slot: the lowered form of a PQ-IR
    :class:`repro.core.pqir.StateSpec`.

    The incoming state lands in buffer slot ``in_slot`` (a *pinned* slot —
    liveness planning never returns it to the free pool, so the buffer
    identity is stable across invocations) and the next state is produced at
    ``out_slot``.  ``shape`` may carry named symbolic dims (the KV cache's
    seq axis); ``specialize_plan`` binds them per bucket like any other
    value, so a specialized plan knows the concrete byte size of every
    state buffer it carries."""

    name: str
    input: str
    output: str
    in_slot: int
    out_slot: int
    dtype: Optional[str]
    shape: Optional[Tuple[Optional[Any], ...]]

    def describe(self) -> str:
        info = str(ValueInfo(self.dtype, self.shape))
        return f"{self.name}: %{self.in_slot} -> %{self.out_slot} {info}"


@dataclasses.dataclass
class ExecutionPlan:
    """A lowered, buffer-planned program for one backend.

    backend    kernel-resolution namespace ("ref" | "interpret" | "pallas")
    steps      lowered ops in execution order
    num_slots  size of the buffer pool (≤ number of distinct tensors thanks
               to liveness-driven slot reuse)
    inputs     (graph-input name, slot) feeds land here
    outputs    (graph-output name, slot) results are read from here
    states     persistent state slots (:class:`StateBinding`) carried across
               invocations — the int8 KV cache of the token path; () on
               stateless plans
    batch      "static" | "dynamic" (an unbound template) | int (a batch-
               bucket specialization) | tuple of (axis, bucket) pairs (a
               multi-axis specialization) — see the module docstring
    axes       named dynamic axes a "dynamic" template is still open over
               (() on static and fully-bound plans)
    provenance how this plan came to be (pass stats, fusion matches,
               specialization events, compile-time trace id) — shared by
               reference between a template and all of its specializations,
               so the record read from any of them shows the full history;
               rendered by ``pretty(verbose=True)``
    """

    backend: str
    steps: List[PlanStep]
    num_slots: int
    inputs: Tuple[Tuple[str, int], ...]
    outputs: Tuple[Tuple[str, int], ...]
    batch: Union[str, int, Tuple[Tuple[str, int], ...]] = "static"
    axes: Tuple[str, ...] = ()
    provenance: Optional[PlanProvenance] = None
    states: Tuple[StateBinding, ...] = ()

    # -- execution -----------------------------------------------------------
    def execute(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        """Slot-indexed interpretation (the hot path; jit-able as a whole)."""
        from .registry import lookup

        if self.batch == "dynamic":
            raise RuntimeError(
                f"shape-generic template plan (open axes {list(self.axes)}) "
                "cannot execute directly: bind it first with "
                "repro.backend.lowering.specialize_plan, or run through "
                "CompiledModel which caches specializations per bucket"
            )
        env: List[Any] = [None] * self.num_slots
        for name, slot in self.inputs:
            env[slot] = feeds[name]
        for step in self.steps:
            impl = lookup(self.backend, step.kernel)
            args = [
                env[a.index] if a.kind == SLOT
                else (step.consts[a.index] if a.kind == CONST else None)
                for a in step.args
            ]
            outs = impl(step, args)
            for slot, val in zip(step.out_slots, outs):
                env[slot] = val
        return {name: env[slot] for name, slot in self.outputs}

    def next_state_feeds(self, outputs: Dict[str, Any]) -> Dict[str, Any]:
        """Map one invocation's outputs to the next invocation's state feeds
        (the functional carry: ``present.* -> past_key_values.*``)."""
        return {s.input: outputs[s.output] for s in self.states}

    def execute_dict_env(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        """Name-keyed dict-env interpretation — the pre-plan execution model,
        kept as the baseline for the ``sys_plan_overhead`` benchmark.  Runs
        the *same* registry kernels; only the storage discipline differs
        (a monotonically growing dict vs the fixed slot pool)."""
        from .registry import lookup

        env: Dict[str, Any] = dict(feeds)
        for step in self.steps:
            impl = lookup(self.backend, step.kernel)
            args = [
                env[a.name] if a.kind == SLOT
                else (step.consts[a.index] if a.kind == CONST else None)
                for a in step.args
            ]
            outs = impl(step, args)
            for name, val in zip(step.outputs, outs):
                env[name] = val
        return {name: env[name] for name, _ in self.outputs}

    # -- introspection -------------------------------------------------------
    @property
    def kinds(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for s in self.steps:
            agg[s.kind] = agg.get(s.kind, 0) + 1
        return agg

    def _batch_str(self) -> str:
        """Rendered ``batch`` tag.  Single-axis forms are byte-identical to
        the PR 4 renderings (``dynamic`` / the bare bucket int); a multi-axis
        template additionally names its open axes, and a multi-axis
        specialization renders its bindings as ``(N=8,S=32)``."""
        if isinstance(self.batch, tuple):
            return "(" + ",".join(f"{a}={v}" for a, v in self.batch) + ")"
        if self.batch == "dynamic" and self.axes and self.axes != ("N",):
            return "dynamic, axes=[" + ",".join(self.axes) + "]"
        return str(self.batch)

    def pretty(self, verbose: bool = False) -> str:
        """Human-readable lowering — the artifact a hardware designer reads.
        ``verbose=True`` appends the provenance section (pass stats, fusion
        matches, specialization history) so the artifact explains not just
        *what* executes but *how it came to be*."""
        batch = "" if self.batch == "static" else f", batch={self._batch_str()}"
        head = (
            f"ExecutionPlan(backend={self.backend}, steps={len(self.steps)}, "
            f"slots={self.num_slots}{batch})"
        )
        ins = "  inputs:  " + ", ".join(f"{n} -> %{s}" for n, s in self.inputs)
        outs = "  outputs: " + ", ".join(f"%{s} -> {n}" for n, s in self.outputs)
        if self.states:
            outs += "\n  states:  " + ", ".join(s.describe() for s in self.states)
        body = [f"  {i:3d}: {s.describe()}" for i, s in enumerate(self.steps)]
        if verbose and self.provenance is not None:
            body.append(self.provenance.render(indent="  "))
        return "\n".join([head, ins, outs] + body)

    def __str__(self) -> str:
        return self.pretty()

    def __repr__(self) -> str:
        if self.batch == "static":
            batch = ""
        elif isinstance(self.batch, (str, int)) and not (self.axes and self.axes != ("N",)):
            batch = f", batch={self.batch!r}"  # PR 4 single-axis rendering
        else:
            batch = f", batch={self._batch_str()}"
        return (
            f"ExecutionPlan(backend={self.backend!r}, steps={len(self.steps)}, "
            f"slots={self.num_slots}, kinds={self.kinds}{batch})"
        )


# ---------------------------------------------------------------------------
# per-axis bucketing policies + the specialization cache
# ---------------------------------------------------------------------------


def batch_bucket(m: int) -> int:
    """The padded bucket for a true extent of ``m``: the smallest power of
    two ≥ m.  Power-of-two buckets bound the number of specializations (and
    jit traces) at log₂(max extent) while wasting at most 2× padding — the
    standard continuous-batching compromise, and the default policy for
    every dynamic axis."""
    if m < 1:
        raise ValueError(f"batch must be >= 1, got {m}")
    b = 1
    while b < m:
        b <<= 1
    return b


def bucket_multiple(n: int, granularity: int) -> int:
    """Round an extent up to a multiple of ``granularity`` — the serving
    engine's prefill discipline (prompts right-pad to ``prefill_bucket``
    multiples), reusable as a per-axis policy for sequence-length axes."""
    if n < 1:
        raise ValueError(f"batch must be >= 1, got {n}")
    if granularity < 1:
        raise ValueError(f"bucket granularity must be >= 1, got {granularity}")
    return -(-n // granularity) * granularity


def resolve_bucketing(spec) -> "Callable[[int], int]":
    """Normalize a per-axis bucketing spec to a policy function.

    ``None`` → power-of-two (:func:`batch_bucket`); an ``int`` g →
    round-up-to-multiple-of-g (:func:`bucket_multiple`); a callable is used
    as-is (must map a true extent ≥ 1 to a padded bucket ≥ that extent)."""
    if spec is None:
        return batch_bucket
    if isinstance(spec, int):
        if spec < 1:
            raise ValueError(f"bucket granularity must be >= 1, got {spec}")
        return lambda n, _g=spec: bucket_multiple(n, _g)
    if callable(spec):
        return spec
    raise TypeError(
        f"axis bucketing spec must be None (power-of-two), an int granularity "
        f"or a callable, got {spec!r}"
    )


def bindings_key(bindings: Dict[str, int]) -> Tuple[Tuple[str, int], ...]:
    """Canonical :class:`PlanCache` key: the sorted (axis, bucket) tuple —
    binding order never produces distinct specializations."""
    return tuple(sorted((str(a), int(v)) for a, v in bindings.items()))


class PlanCache(LruCache):
    """Bounded LRU of per-bucket plan specializations.

    Keyed by the sorted ``(axis, bucket)`` bindings tuple
    (:func:`bindings_key`); each value is the pair ``(specialized
    ExecutionPlan, jitted executor)``.  A bucket combination is specialized
    at most once while it stays resident (the acceptance criterion for
    scenario-specialized serving); ``misses`` therefore counts
    specializations and ``hits`` counts cache-served requests.  The bound
    keeps adversarial shape traffic from accumulating jit executors without
    limit — evicted buckets simply re-specialize on their next use.
    """

    DEFAULT_CAPACITY = 8
