"""Typed ExecutionPlan: the lowered form of a compiled PQ-IR artifact.

A plan is a flat list of :class:`PlanStep`\\ s over integer *buffer slots*.
Slots are storage, not tensors: liveness planning (see
:mod:`repro.backend.lowering`) lets intermediates reuse the slot of a value
that is already dead, so executing a deep model touches a small, fixed pool
of buffers instead of growing a name-keyed dict.  Each step declares

* a **kernel id** resolved through :mod:`repro.backend.registry` at
  execution time (``ref`` / ``interpret`` / ``pallas`` register per-id
  implementations — no backend conditionals in the executor),
* **args** — slot reads, baked constants, or absent optional operands,
* **static params** — everything specialized at *plan* time: ONNX attributes,
  output dtypes, and for the fused qmatmul path the chosen tile sizes and
  true (unpadded) problem shape,
* **consts** — parameter arrays baked into the step (for the shape-
  specialized qmatmul these are already padded to tile multiples, so the hot
  path never pads weights/bias/scales per call).

The plan's :meth:`ExecutionPlan.pretty` rendering is the co-design artifact a
hardware designer reads: one line per step with slots, dtypes/shapes, kernel
ids and static params.

Batch polymorphism
==================

A plan's ``batch`` field says how its leading (batch) dimension was handled:

* ``"static"`` — the classic path: shapes were specialized once at plan time
  (a symbolic batch falls back to default tiles).
* ``"dynamic"`` — the plan is a shape-generic **template**: fusion, liveness
  slot planning and dtype inference are done, but the batch-dependent pieces
  (flat matmul M, bm tile choice) are left open.  Templates are not directly
  executable on the tiled backends; they are *bound* to a concrete bucket by
  :func:`repro.backend.lowering.specialize_plan`.
* an ``int`` — a per-bucket specialization of a template, produced lazily and
  held in a bounded :class:`PlanCache` keyed by the padded batch bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from ..core.cache import LruCache

#: Arg kinds.
SLOT, CONST, NONE = "slot", "const", "none"


@dataclasses.dataclass(frozen=True)
class Arg:
    """One operand reference of a :class:`PlanStep`.

    kind   "slot" (read buffer ``index``), "const" (read
           ``step.consts[index]``) or "none" (absent optional input)
    index  slot number or const index
    name   source PQ-IR tensor name (debug / dict-env baseline executor)
    """

    kind: str
    index: int = -1
    name: str = ""


@dataclasses.dataclass(frozen=True)
class ValueInfo:
    """Static dtype/shape of a produced value (best-effort; None = unknown)."""

    dtype: Optional[str]
    shape: Optional[Tuple[Optional[int], ...]]

    def __str__(self) -> str:
        dt = self.dtype or "?"
        if self.shape is None:
            return f"{dt}[?]"
        dims = ",".join("?" if d is None else str(d) for d in self.shape)
        return f"{dt}[{dims}]"


@dataclasses.dataclass
class PlanStep:
    """One lowered operation: kernel id + operand refs + static params."""

    kernel: str  # registry kernel id ("qlinear_matmul", "op.Relu", ...)
    args: Tuple[Arg, ...]
    out_slots: Tuple[int, ...]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    consts: Tuple[Any, ...] = ()
    kind: str = "generic"  # stats bucket: fused_qlinear|fused_qconv|fused_lut|generic
    name: str = ""  # source node / pattern name
    outputs: Tuple[str, ...] = ()  # source tensor names of out_slots
    out_info: Tuple[ValueInfo, ...] = ()

    @property
    def in_slots(self) -> Tuple[int, ...]:
        return tuple(a.index for a in self.args if a.kind == SLOT)

    def describe(self) -> str:
        ins = ", ".join(
            f"%{a.index}" if a.kind == SLOT else ("·" if a.kind == NONE else f"c{a.index}")
            for a in self.args
        )
        outs = ", ".join(
            f"%{s}:{info}" if info is not None else f"%{s}"
            for s, info in zip(self.out_slots, self.out_info or (None,) * len(self.out_slots))
        )
        rendered = (
            (k, _fmt_param(v)) for k, v in sorted(self.params.items())
        )
        params = ",".join(f"{k}={v}" for k, v in rendered if v is not None)
        consts = ",".join(_arr_sig(c) for c in self.consts)
        tail = ""
        if params:
            tail += f" {{{params}}}"
        if consts:
            tail += f" consts[{consts}]"
        src = f"  # {self.name}" if self.name else ""
        return f"{outs} = {self.kernel}({ins}){tail}{src}"


def _fmt_param(v: Any) -> Optional[str]:
    """Compact static-param rendering; nested records (the qmatmul shape
    spec, generic ONNX attrs) flatten inline so the tile choices and
    attributes the plan was specialized with are visible in the printout.
    Embedded arrays are elided (their values live in ``consts``)."""
    if isinstance(v, np.ndarray):
        return None
    if isinstance(v, dict):
        inner = ",".join(
            f"{k}={fv}" for k, fv in ((k, _fmt_param(val)) for k, val in sorted(v.items()))
            if fv is not None
        )
        return "{" + inner + "}"
    if isinstance(v, (list, tuple)):
        return "(" + ",".join(str(x) for x in v) + ")"
    return str(v)


def _arr_sig(c: Any) -> str:
    if c is None:
        return "·"
    if hasattr(c, "dtype") and hasattr(c, "shape"):
        return f"{c.dtype}{tuple(int(d) for d in c.shape)}"
    return type(c).__name__


@dataclasses.dataclass
class ExecutionPlan:
    """A lowered, buffer-planned program for one backend.

    backend    kernel-resolution namespace ("ref" | "interpret" | "pallas")
    steps      lowered ops in execution order
    num_slots  size of the buffer pool (≤ number of distinct tensors thanks
               to liveness-driven slot reuse)
    inputs     (graph-input name, slot) feeds land here
    outputs    (graph-output name, slot) results are read from here
    batch      "static" | "dynamic" (an unbound template) | int (a bucket
               specialization of a template) — see the module docstring
    """

    backend: str
    steps: List[PlanStep]
    num_slots: int
    inputs: Tuple[Tuple[str, int], ...]
    outputs: Tuple[Tuple[str, int], ...]
    batch: Union[str, int] = "static"

    # -- execution -----------------------------------------------------------
    def execute(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        """Slot-indexed interpretation (the hot path; jit-able as a whole)."""
        from .registry import lookup

        env: List[Any] = [None] * self.num_slots
        for name, slot in self.inputs:
            env[slot] = feeds[name]
        for step in self.steps:
            impl = lookup(self.backend, step.kernel)
            args = [
                env[a.index] if a.kind == SLOT
                else (step.consts[a.index] if a.kind == CONST else None)
                for a in step.args
            ]
            outs = impl(step, args)
            for slot, val in zip(step.out_slots, outs):
                env[slot] = val
        return {name: env[slot] for name, slot in self.outputs}

    def execute_dict_env(self, feeds: Dict[str, Any]) -> Dict[str, Any]:
        """Name-keyed dict-env interpretation — the pre-plan execution model,
        kept as the baseline for the ``sys_plan_overhead`` benchmark.  Runs
        the *same* registry kernels; only the storage discipline differs
        (a monotonically growing dict vs the fixed slot pool)."""
        from .registry import lookup

        env: Dict[str, Any] = dict(feeds)
        for step in self.steps:
            impl = lookup(self.backend, step.kernel)
            args = [
                env[a.name] if a.kind == SLOT
                else (step.consts[a.index] if a.kind == CONST else None)
                for a in step.args
            ]
            outs = impl(step, args)
            for name, val in zip(step.outputs, outs):
                env[name] = val
        return {name: env[name] for name, _ in self.outputs}

    # -- introspection -------------------------------------------------------
    @property
    def kinds(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for s in self.steps:
            agg[s.kind] = agg.get(s.kind, 0) + 1
        return agg

    def pretty(self) -> str:
        """Human-readable lowering — the artifact a hardware designer reads."""
        batch = "" if self.batch == "static" else f", batch={self.batch}"
        head = (
            f"ExecutionPlan(backend={self.backend}, steps={len(self.steps)}, "
            f"slots={self.num_slots}{batch})"
        )
        ins = "  inputs:  " + ", ".join(f"{n} -> %{s}" for n, s in self.inputs)
        outs = "  outputs: " + ", ".join(f"%{s} -> {n}" for n, s in self.outputs)
        body = [f"  {i:3d}: {s.describe()}" for i, s in enumerate(self.steps)]
        return "\n".join([head, ins, outs] + body)

    def __str__(self) -> str:
        return self.pretty()

    def __repr__(self) -> str:
        batch = "" if self.batch == "static" else f", batch={self.batch!r}"
        return (
            f"ExecutionPlan(backend={self.backend!r}, steps={len(self.steps)}, "
            f"slots={self.num_slots}, kinds={self.kinds}{batch})"
        )


# ---------------------------------------------------------------------------
# per-bucket specialization cache
# ---------------------------------------------------------------------------


def batch_bucket(m: int) -> int:
    """The padded batch bucket for a true batch of ``m``: the smallest power
    of two ≥ m.  Power-of-two buckets bound the number of specializations
    (and jit traces) at log₂(max batch) while wasting at most 2× padding —
    the standard continuous-batching compromise."""
    if m < 1:
        raise ValueError(f"batch must be >= 1, got {m}")
    b = 1
    while b < m:
        b <<= 1
    return b


class PlanCache(LruCache):
    """Bounded LRU of per-bucket plan specializations.

    Keyed by the padded batch bucket; each value is the pair
    ``(specialized ExecutionPlan, jitted executor)``.  A bucket is
    specialized at most once while it stays resident (the acceptance
    criterion for batch-polymorphic serving); ``misses`` therefore counts
    specializations and ``hits`` counts cache-served requests.  The bound
    keeps adversarial shape traffic from accumulating jit executors without
    limit — evicted buckets simply re-specialize on their next use.
    """

    DEFAULT_CAPACITY = 8
