"""repro.backend — the typed lowering layer between optimized PQ-IR and
kernels.

This package is the third level of the compilation flow::

    PQ-IR artifact ──► repro.passes (graph optimization) ──► optimized PQ-IR
                                                                  │
                                                                  ▼
                                        repro.core.compile (pattern fusion)
                                                                  │
                                              StepDrafts          ▼
                                        repro.backend.lowering  ──►  ExecutionPlan
                                                                  │
                                                                  ▼
                                        repro.backend.registry  ──►  kernels
                                        (ref / interpret / pallas impls)

Plan format
===========

An :class:`~repro.backend.plan.ExecutionPlan` is a flat program over integer
**buffer slots**:

* ``plan.num_slots`` — size of the buffer pool.  Slots are *storage*:
  liveness planning in :mod:`repro.backend.lowering` frees a slot at its
  tensor's last read, so intermediates reuse memory instead of accumulating
  in a name-keyed dict (``plan.execute_dict_env`` keeps that old discipline
  around purely as the ``sys_plan_overhead`` benchmark baseline).
* ``plan.inputs`` / ``plan.outputs`` — (tensor name, slot) bindings for the
  artifact's external interface.
* ``plan.steps`` — one :class:`~repro.backend.plan.PlanStep` per lowered op:

  ============  =====================================================
  ``kernel``    registry kernel id (``"qlinear_matmul"``, ``"op.Relu"``)
  ``args``      operand refs: slot read / baked const / absent optional
  ``out_slots`` where results land
  ``params``    compile-time statics: ONNX attrs, out dtype, relu/two_mul
                flags, and the qmatmul shape record (m, k, n, kp, np,
                bm, bk, bn) chosen per static shape at plan time — or, on a
                dynamic *template*, the axis-open record (k, n, kp, np, bk,
                bn, lead — lead holds named symbolic axes) whose m/bm bind
                lazily per bucket combination via :func:`specialize_plan`
                (bindings dict) + :class:`PlanCache` (keyed on the sorted
                bindings)
  ``consts``    baked arrays — pre-padded to tile multiples on the fused
                qmatmul path, so the hot path never pads parameters per call
                (padding is batch-independent: bucket specializations share
                these arrays with the template)
  ``out_info``  inferred dtype/shape per result (co-design inspection)
  ============  =====================================================

``print(compiled.plan)`` renders one line per step with slots, dtypes/shapes
and static params — the artifact a hardware designer reads to see exactly
what the backend will execute.

Backend registry
================

Kernel selection is a table, not conditionals: implementations register as
``(backend, kernel_id)`` pairs in :mod:`repro.backend.registry` with the
uniform signature ``impl(step, args) -> [outputs]``.  The pseudo-backend
``"*"`` is the shared fallback (the generic jnp mirror in
:mod:`repro.backend.generic` registers every standard op once as
``op.<Name>``); ``ref`` / ``interpret`` / ``pallas`` register the fused
kernels (:mod:`repro.backend.fused`).  Adding a backend = registering
implementations for the kernel ids it specializes — the executor and the
compiler never change.
"""
from . import cost, fused, generic  # noqa: F401  (populate the registry on import)
from .autotune import (  # noqa: F401
    Autotuner,
    AutotuneCache,
    TuneJob,
    measure_median,
    seed_candidates,
    tile_candidates,
)
from .lowering import (  # noqa: F401
    StepDraft,
    build_plan,
    const_arg,
    none_arg,
    specialize_plan,
    tensor_arg,
)
from .plan import (  # noqa: F401
    Arg,
    ExecutionPlan,
    PlanCache,
    PlanStep,
    ValueInfo,
    batch_bucket,
    bindings_key,
    bucket_multiple,
    resolve_bucketing,
)
from .registry import UnknownKernelError, backends_for, kernel_ids, lookup, register  # noqa: F401

# last: artifact lazily imports repro.core.compile, which imports this package
from .artifact import ARTIFACT_SCHEMA, load_artifact, save_artifact, sidecar_path  # noqa: F401,E402
