"""Generic jnp op mirror — the fallback lowering for every standard op.

Each entry implements one ONNX-dialect operator as a jnp expression with the
same semantics as :mod:`repro.core.runtime` (the conformance oracle): exact
on integer paths, allclose on float paths.  The table is registered wholesale
in the backend registry under kernel ids ``op.<OpType>`` for the shared
``"*"`` backend, so any op the fusion patterns don't consume still compiles
on every backend.

Implementations take ``(attrs, ins)`` — the node's attribute dict and its
operand list (``None`` for absent optional inputs).  Shape-parameter
operands (Reshape target, Slice starts/ends, Squeeze axes, …) must be
compile-time constants: the lowering bakes initializers in as numpy arrays,
and :func:`_static_ints` rejects traced values with a clear error instead of
letting ``np.asarray`` fail on a tracer.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pqir import DTYPES
from .registry import register

_JOPS: Dict[str, Callable] = {}


def _jop(name):
    def deco(fn):
        _JOPS[name] = fn
        return fn

    return deco


def _static_ints(v, op: str, what: str) -> List[int]:
    """Concrete int list from a shape-parameter operand; rejects tracers."""
    if isinstance(v, jax.core.Tracer):
        raise NotImplementedError(
            f"compiler requires a constant {what} for {op} (got a traced value); "
            "the reference runtime supports the dynamic form"
        )
    return [int(s) for s in np.asarray(v).reshape(-1)]


@_jop("MatMulInteger")
def _j_matmuli(attrs, ins):
    a, b = ins[0], ins[1]
    a32 = a.astype(jnp.int32) - (ins[2].astype(jnp.int32) if len(ins) > 2 and ins[2] is not None else 0)
    b32 = b.astype(jnp.int32) - (ins[3].astype(jnp.int32) if len(ins) > 3 and ins[3] is not None else 0)
    if b32.ndim > 2:
        # stacked (batched) matmul — e.g. the attention QK^T / PV contractions;
        # jnp.matmul broadcasts leading dims with int32 accumulation (exact)
        return [jnp.matmul(a32, b32)]
    return [jax.lax.dot_general(a32, b32, (((a32.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32)]


@_jop("ConvInteger")
def _j_convi(attrs, ins):
    x, w = ins[0], ins[1]
    pads = tuple(attrs.get("pads", (0, 0, 0, 0)))
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int8) if x.dtype != jnp.uint8 else x.astype(jnp.int32),
        w.astype(jnp.int8),
        window_strides=tuple(attrs.get("strides", (1, 1))),
        padding=((pads[0], pads[2]), (pads[1], pads[3])),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=int(attrs.get("group", 1)),
        preferred_element_type=jnp.int32,
    )
    return [acc]


@_jop("QuantizeLinear")
def _j_ql(attrs, ins):
    x, scale = ins[0], ins[1]
    zp = ins[2] if len(ins) > 2 else jnp.zeros((), jnp.int8)
    info = jnp.iinfo(zp.dtype)
    y = jnp.rint(x.astype(jnp.float32) / scale.astype(jnp.float32)) + zp.astype(jnp.float32)
    return [jnp.clip(y, info.min, info.max).astype(zp.dtype)]


@_jop("DequantizeLinear")
def _j_dql(attrs, ins):
    x, scale = ins[0], ins[1]
    zp = ins[2].astype(jnp.int32) if len(ins) > 2 else 0
    return [(x.astype(jnp.int32) - zp).astype(jnp.float32) * scale.astype(jnp.float32)]


@_jop("Cast")
def _j_cast(attrs, ins):
    return [ins[0].astype(DTYPES[attrs["to"]])]


@_jop("Reshape")
def _j_reshape(attrs, ins):
    return [ins[0].reshape(tuple(_static_ints(ins[1], "Reshape", "target shape")))]


@_jop("Slice")
def _j_slice(attrs, ins):
    x = ins[0]
    starts = _static_ints(ins[1], "Slice", "starts")
    ends = _static_ints(ins[2], "Slice", "ends")
    axes = _static_ints(ins[3], "Slice", "axes") if len(ins) > 3 and ins[3] is not None else list(range(len(starts)))
    steps = _static_ints(ins[4], "Slice", "steps") if len(ins) > 4 and ins[4] is not None else [1] * len(starts)
    sl = [slice(None)] * x.ndim
    for s, e, a, st in zip(starts, ends, axes, steps):
        sl[a] = slice(s, e, st)
    return [x[tuple(sl)]]


@_jop("Squeeze")
def _j_squeeze(attrs, ins):
    axes = tuple(_static_ints(ins[1], "Squeeze", "axes")) if len(ins) > 1 and ins[1] is not None else None
    return [jnp.squeeze(ins[0], axis=axes)]


@_jop("Unsqueeze")
def _j_unsqueeze(attrs, ins):
    x = ins[0]
    for a in sorted(_static_ints(ins[1], "Unsqueeze", "axes")):
        x = jnp.expand_dims(x, a)
    return [x]


for _name, _fn in {
    "Mul": lambda attrs, ins: [ins[0] * ins[1]],
    "Add": lambda attrs, ins: [ins[0] + ins[1]],
    "Sub": lambda attrs, ins: [ins[0] - ins[1]],
    "Div": lambda attrs, ins: [ins[0] // ins[1] if jnp.issubdtype(ins[0].dtype, jnp.integer) else ins[0] / ins[1]],
    "Relu": lambda attrs, ins: [jnp.maximum(ins[0], jnp.zeros((), ins[0].dtype))],
    "Tanh": lambda attrs, ins: [jnp.tanh(ins[0]).astype(ins[0].dtype)],
    "Sigmoid": lambda attrs, ins: [jax.nn.sigmoid(ins[0].astype(jnp.float32)).astype(ins[0].dtype)],
    "Erf": lambda attrs, ins: [jax.lax.erf(ins[0].astype(jnp.float32)).astype(ins[0].dtype)],
    "Sqrt": lambda attrs, ins: [jnp.sqrt(ins[0])],
    "Pow": lambda attrs, ins: [jnp.power(ins[0], ins[1])],
    "Clip": lambda attrs, ins: [jnp.clip(ins[0], ins[1] if len(ins) > 1 else None, ins[2] if len(ins) > 2 else None)],
    "Softmax": lambda attrs, ins: [jax.nn.softmax(ins[0].astype(jnp.float32), axis=int(attrs.get("axis", -1))).astype(ins[0].dtype)],
    "MatMul": lambda attrs, ins: [ins[0] @ ins[1]],
    "Transpose": lambda attrs, ins: [jnp.transpose(ins[0], attrs.get("perm"))],
    "Flatten": lambda attrs, ins: [ins[0].reshape((int(np.prod(ins[0].shape[: int(attrs.get("axis", 1))])) if int(attrs.get("axis", 1)) else 1, -1))],
    "Concat": lambda attrs, ins: [jnp.concatenate(ins, axis=int(attrs["axis"]))],
    "Gather": lambda attrs, ins: [jnp.take(ins[0], ins[1].astype(jnp.int32), axis=int(attrs.get("axis", 0)))],
    "GlobalAveragePool": lambda attrs, ins: [ins[0].mean(axis=(2, 3), keepdims=True).astype(ins[0].dtype)],
    "ReduceMean": lambda attrs, ins: [ins[0].mean(axis=tuple(attrs.get("axes")) if attrs.get("axes") else None, keepdims=bool(attrs.get("keepdims", 1))).astype(ins[0].dtype)],
    "ReduceMax": lambda attrs, ins: [ins[0].max(axis=tuple(attrs.get("axes")) if attrs.get("axes") else None, keepdims=bool(attrs.get("keepdims", 1))).astype(ins[0].dtype)],
    "ReduceSum": lambda attrs, ins: [ins[0].sum(axis=tuple(attrs.get("axes")) if attrs.get("axes") else None, keepdims=bool(attrs.get("keepdims", 1)), dtype=ins[0].dtype)],
}.items():
    _JOPS[_name] = _fn


@_jop("Gemm")
def _j_gemm(attrs, ins):
    a, b = ins[0], ins[1]
    if attrs.get("transA", 0):
        a = a.T
    if attrs.get("transB", 0):
        b = b.T
    if jnp.issubdtype(a.dtype, jnp.integer):
        # integer Gemm: int32 accumulation, alpha/beta fixed at 1 (dialect
        # rule mirrored from repro.core.runtime)
        if float(attrs.get("alpha", 1.0)) != 1.0 or float(attrs.get("beta", 1.0)) != 1.0:
            raise NotImplementedError("integer Gemm requires alpha == beta == 1")
        y = jax.lax.dot_general(
            a.astype(jnp.int32), b.astype(jnp.int32),
            (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32,
        )
        if len(ins) > 2 and ins[2] is not None:
            y = y + ins[2].astype(jnp.int32)
        return [y]
    y = float(attrs.get("alpha", 1.0)) * (a @ b)
    if len(ins) > 2 and ins[2] is not None:
        y = y + float(attrs.get("beta", 1.0)) * ins[2]
    return [y.astype(ins[0].dtype)]


@_jop("MaxPool")
def _j_maxpool(attrs, ins):
    x = ins[0]
    kh, kw = attrs["kernel_shape"]
    sh, sw = tuple(attrs.get("strides", (kh, kw)))
    pads = tuple(attrs.get("pads", (0, 0, 0, 0)))
    init = -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min
    y = jax.lax.reduce_window(
        x, init, jax.lax.max, (1, 1, kh, kw), (1, 1, sh, sw),
        ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])),
    )
    return [y]


@_jop("AveragePool")
def _j_avgpool(attrs, ins):
    x = ins[0].astype(jnp.float32)
    kh, kw = attrs["kernel_shape"]
    sh, sw = tuple(attrs.get("strides", (kh, kw)))
    pads = tuple(attrs.get("pads", (0, 0, 0, 0)))
    y = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, kh, kw), (1, 1, sh, sw),
        ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])),
    ) / (kh * kw)
    return [y.astype(ins[0].dtype)]


# ---------------------------------------------------------------------------
# registry hookup: every generic op is a shared-backend kernel "op.<Name>"
# ---------------------------------------------------------------------------


def _make_impl(fn):
    def impl(step, args):
        return fn(step.params.get("attrs", {}), args)

    return impl


for _name, _fn in _JOPS.items():
    register(f"op.{_name}")(_make_impl(_fn))
