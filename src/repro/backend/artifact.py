"""AOT plan artifacts: a compiled model serialized as a versioned co-design
artifact that survives a process boundary.

The paper's pipeline ends in an :class:`~repro.backend.plan.ExecutionPlan` —
the typed, slot-planned, tile-annotated form a hardware designer reads.  This
module makes that plan (and everything needed to serve it) a *stable file*:

* **Schema** ``repro-plan-v1`` — one JSON document in the style of the
  autotuner's persisted cache (``repro-autotune-v1``): a ``schema`` field up
  front, deterministic key order, atomic writes (tempfile + ``os.replace``,
  same discipline as :class:`repro.core.cache.PersistentJsonStore`).
* **npz sidecar** — the plan's baked constants (padded weight/bias/scale
  arrays, LUTs) are numeric bulk, not structure: they live next to the JSON
  in ``<path stem>.npz``, keyed per step, with a sha256 digest recorded in
  the JSON so a mismatched or truncated sidecar is rejected at load.
* **Warm start** — :func:`save_artifact` records the *hot scenario cells*
  resident in the model's :class:`~repro.backend.plan.PlanCache` (and the
  tile choice + ``heuristic|tuned|cache`` source of every fused step in
  them).  :func:`load_artifact` rebuilds the compiled model **without
  re-running passes, fusion or lowering** — no ``compile.fuse`` /
  ``compile.lower`` span is ever emitted on load — and pre-seeds the plan
  cache by replaying each recorded cell through
  :func:`~repro.backend.lowering.specialize_plan` with a replay tuner that
  stamps the recorded tiles and source tags back in.  Serving the recorded
  traffic mix then specializes nothing new (cache misses stay at zero).
* **Provenance** — the ``PlanProvenance`` record round-trips through the
  artifact.  The loaded *live* record carries the passes/fusions history
  verbatim and re-records the hot cells as it re-seeds them (with their
  original source tags); the artifact JSON itself retains the full
  specialization history, including cells that had already been evicted.

``scripts/plan_diff.py`` renders a structural diff of two artifacts (steps,
tiles, buffer slots) — the hardware-designer workflow for comparing plan
versions without loading either one.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from typing import TYPE_CHECKING

from ..core import pqir
from ..kernels import ops as kops
from ..obs.provenance import PlanProvenance
from .lowering import specialize_plan
from .plan import (
    Arg,
    ExecutionPlan,
    PlanStep,
    StateBinding,
    ValueInfo,
    bindings_key,
    resolve_bucketing,
)

if TYPE_CHECKING:  # imported lazily at runtime: core.compile imports this package
    from ..core.compile import CompiledModel

__all__ = ["ARTIFACT_SCHEMA", "save_artifact", "load_artifact", "sidecar_path"]

#: Versioned schema id — load rejects anything else.
ARTIFACT_SCHEMA = "repro-plan-v1"

#: Shape-record tile fields recorded per hot cell (subset present per step).
#: ``bits`` rides along for sub-8-bit weight cells (absent means int8), so a
#: plan_diff of a w4 artifact against its w8 twin surfaces the precision.
#: ``b/s/t/dh/bq`` are the fused-attention record (``bq`` is its tuned tile).
_TILE_KEYS = ("m", "bm", "bk", "bn", "bits", "b", "s", "t", "dh", "bq")


def sidecar_path(path: str) -> str:
    """The npz sidecar belonging to an artifact JSON path (``x.json`` →
    ``x.npz``; extensionless paths just append ``.npz``)."""
    stem, ext = os.path.splitext(path)
    return (stem if ext else path) + ".npz"


# ---------------------------------------------------------------------------
# params encoding: JSON with typed markers for the non-JSON leaves
# ---------------------------------------------------------------------------

def _enc(v: Any) -> Any:
    """Encode one params value: tuples and ndarrays get typed markers so the
    decode side restores the exact in-memory form (plan params are compared
    structurally by tests and plan_diff)."""
    if isinstance(v, np.ndarray):
        return {"__ndarray__": pqir._encode_array(v)}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    if isinstance(v, tuple):
        return {"__tuple__": [_enc(x) for x in v]}
    if isinstance(v, list):
        return [_enc(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _enc(x) for k, x in v.items()}
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    raise TypeError(f"cannot serialize plan param of type {type(v).__name__}: {v!r}")


def _dec(v: Any) -> Any:
    if isinstance(v, dict):
        if "__ndarray__" in v:
            return pqir._decode_array(v["__ndarray__"])
        if "__tuple__" in v:
            return tuple(_dec(x) for x in v["__tuple__"])
        return {k: _dec(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_dec(x) for x in v]
    return v


def _shape_to_json(shape: Optional[Tuple]) -> Optional[List]:
    # dims may be int, named-axis str, or None (unknown) — all JSON-safe
    return None if shape is None else list(shape)


def _shape_from_json(shape: Optional[List]) -> Optional[Tuple]:
    return None if shape is None else tuple(shape)


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def _cell_records(cm: "CompiledModel") -> List[Dict[str, Any]]:
    """The hot-cell warm-start records: for every specialization resident in
    the plan cache (least- to most-recently used, so re-seeding preserves
    recency), the axis bindings plus each fused step's bound tiles and their
    provenance source tag."""
    if cm.plan_cache is None:
        return []
    sources = _tile_sources(cm.plan.provenance)
    shared = getattr(cm, "_shared_cache", False)
    own = cm.model.graph.name
    cells = []
    for key in cm.plan_cache.keys():
        bkey = key
        if shared:
            # fleet-shared cache: keys are (graph name, bindings key) — only
            # this model's cells belong in its artifact
            if not (isinstance(key, tuple) and len(key) == 2 and key[0] == own):
                continue
            bkey = key[1]
        entry = cm.plan_cache.peek(key)
        if entry is None:
            continue
        plan, _ = entry
        bindings = dict(bkey)
        if plan.batch == "dynamic":
            # a partially-bound template in the cache cannot be replayed as a
            # warm cell (it has no tiles of its own); skip it
            continue
        tiles: Dict[str, Any] = {}
        for step in plan.steps:
            shape = step.params.get("shape")
            if not isinstance(shape, dict) or not ("bm" in shape or "bq" in shape):
                continue
            name = step.name or step.kernel
            rec = {k: int(shape[k]) for k in _TILE_KEYS if k in shape}
            rec["source"] = sources.get((bkey, name), "heuristic")
            tiles[name] = rec
        cells.append({"bindings": bindings, "tiles": tiles})
    return cells


def _tile_sources(prov: Optional[PlanProvenance]) -> Dict[Tuple, str]:
    """(bindings key, step name) → tile source, parsed from the provenance
    specialization events (the latest event per cell wins — a tuned swap
    re-records the cell with its ``[tuned]`` tag)."""
    out: Dict[Tuple, str] = {}
    if prov is None:
        return out
    for ev in prov.specializations:
        for name, rec in ev.tiles:
            source = "heuristic"
            if rec.endswith("]") and " [" in rec:
                source = rec[rec.rindex(" [") + 2 : -1]
            out[(ev.bindings, name)] = source
    return out


def save_artifact(cm: "CompiledModel", path: str) -> str:
    """Serialize a compiled model (template or static plan, baked consts,
    provenance, hot scenario cells) to ``path`` + its npz sidecar.

    Both files are written atomically (tempfile in the destination directory,
    then ``os.replace``): a crashed save never leaves a half-written
    artifact, and a concurrent reader sees the old version or the new one.
    Returns ``path``.

    Axis bucketing specs must be declarative (``None`` = power-of-two, int =
    round-up granularity) — a custom *callable* policy cannot survive a
    process boundary and is rejected here rather than mis-serialized.
    """
    for axis, spec in cm.axis_specs.items():
        if spec is not None and not isinstance(spec, int):
            raise ValueError(
                f"axis {axis!r} uses a callable bucketing policy, which cannot "
                "be serialized — compile with a declarative spec (None or an "
                "int granularity) to make the model AOT-saveable"
            )
    plan = cm.plan
    arrays: Dict[str, np.ndarray] = {}
    steps_json: List[Dict[str, Any]] = []
    for i, step in enumerate(plan.steps):
        consts_json: List[Optional[Dict[str, Any]]] = []
        for j, c in enumerate(step.consts):
            if c is None:
                consts_json.append(None)
                continue
            key = f"s{i}_c{j}"
            arrays[key] = np.asarray(c)
            consts_json.append({"key": key, "jax": isinstance(c, jax.Array)})
        steps_json.append(
            {
                "kernel": step.kernel,
                "args": [[a.kind, a.index, a.name] for a in step.args],
                "out_slots": list(step.out_slots),
                "params": _enc(step.params),
                "consts": consts_json,
                "kind": step.kind,
                "name": step.name,
                "outputs": list(step.outputs),
                "out_info": [
                    None if info is None else [info.dtype, _shape_to_json(info.shape)]
                    for info in step.out_info
                ],
            }
        )
    doc = {
        "schema": ARTIFACT_SCHEMA,
        "model": cm.model.to_json(),
        "plan": {
            "backend": plan.backend,
            "num_slots": plan.num_slots,
            "inputs": [[n, s] for n, s in plan.inputs],
            "outputs": [[n, s] for n, s in plan.outputs],
            "batch": plan.batch if isinstance(plan.batch, str) else _enc(plan.batch),
            "axes": list(plan.axes),
            "steps": steps_json,
            # persistent state slots (the token path's int8 KV cache): name,
            # tensor endpoints, pinned slots, dtype and (possibly symbolic)
            # shape all round-trip, so a loaded plan still knows which
            # buffers it carries across invocations
            "states": [
                [s.name, s.input, s.output, s.in_slot, s.out_slot,
                 s.dtype, _shape_to_json(s.shape)]
                for s in plan.states
            ],
        },
        "provenance": None if plan.provenance is None else plan.provenance.to_dict(),
        "stats": {k: int(v) for k, v in cm.stats.items()},
        "axis_specs": {a: spec for a, spec in cm.axis_specs.items()},
        "plan_cache_capacity": cm.plan_cache_capacity,
        "cells": _cell_records(cm),
    }
    npz_path = sidecar_path(path)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    doc["sidecar"] = {
        "file": os.path.basename(npz_path),
        "sha256": hashlib.sha256(payload).hexdigest(),
    }
    _atomic_write(npz_path, payload)
    _atomic_write(
        path, json.dumps(doc, indent=1, sort_keys=True).encode("utf-8")
    )
    return path


def _atomic_write(path: str, payload: bytes) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".artifact-", dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------

class _ReplayTuner:
    """``tune_step`` provider that replays an artifact's recorded per-cell
    tiles instead of measuring: pre-seeding a loaded plan cache reproduces
    exactly the tiles (and provenance source tags) the saving process served,
    whether they came from the heuristic, a live search or the tuner's own
    persisted cache."""

    def __init__(self, cells: List[Dict[str, Any]]) -> None:
        self._tiles: Dict[Tuple, Dict[str, Any]] = {}
        for cell in cells:
            key = bindings_key({a: int(v) for a, v in cell["bindings"].items()})
            for name, rec in cell.get("tiles", {}).items():
                self._tiles[(key, name)] = rec

    def tune_step(self, step, shape, *, backend: str, bindings: Dict[str, int]):
        rec = self._tiles.get((bindings_key(bindings), step.name or step.kernel))
        if rec is None:
            return shape, "heuristic"
        if "bq" in rec:  # fused attention: the query row-block is the tile
            shape = dict(shape, bq=int(rec["bq"]))
        else:
            shape = kops.with_tiles(
                shape,
                bm=rec.get("bm"),
                bk=rec.get("bk"),
                bn=rec.get("bn"),
            )
        return shape, str(rec.get("source", "heuristic"))


def _load_doc(path: str) -> Dict[str, Any]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except json.JSONDecodeError as e:
        raise ValueError(f"{path}: not a valid plan artifact (corrupt JSON: {e})")
    if not isinstance(doc, dict) or "schema" not in doc:
        raise ValueError(f"{path}: not a valid plan artifact (no schema field)")
    if doc["schema"] != ARTIFACT_SCHEMA:
        raise ValueError(
            f"{path}: schema {doc['schema']!r} does not match expected "
            f"{ARTIFACT_SCHEMA!r}"
        )
    return doc


def _load_sidecar(path: str, doc: Dict[str, Any]) -> Dict[str, np.ndarray]:
    npz_path = os.path.join(
        os.path.dirname(os.path.abspath(path)), doc["sidecar"]["file"]
    )
    try:
        with open(npz_path, "rb") as f:
            payload = f.read()
    except FileNotFoundError:
        raise ValueError(f"{path}: missing npz sidecar {npz_path}")
    digest = hashlib.sha256(payload).hexdigest()
    if digest != doc["sidecar"]["sha256"]:
        raise ValueError(
            f"{path}: npz sidecar digest mismatch (artifact and sidecar are "
            "from different saves, or the sidecar is corrupt)"
        )
    with np.load(io.BytesIO(payload)) as npz:
        return {k: npz[k] for k in npz.files}


def load_artifact(
    path: str,
    *,
    registry=None,
    autotuner=None,
    plan_cache=None,
    warm: bool = False,
) -> "CompiledModel":
    """Reconstruct a :class:`CompiledModel` from an artifact — **zero
    re-compilation**: no optimization passes run, no fusion patterns match,
    no liveness planning happens (and no ``compile.fuse``/``compile.lower``
    span is emitted).  The plan cache is pre-seeded with every hot cell
    recorded at save time (recorded tiles + source tags replayed through
    :func:`specialize_plan`, so only ``backend.specialize`` spans appear);
    serving the recorded traffic therefore specializes nothing new.

    ``warm=True`` additionally executes each pre-seeded cell once on zero
    feeds, forcing the jit trace/compile up front — a replica warm-started
    this way serves its first real batch at steady-state latency.

    ``registry``/``autotuner``/``plan_cache`` attach exactly as on a fresh
    compile (the tuner only engages for *new* cells beyond the recorded set;
    a shared ``plan_cache`` receives the pre-seeded cells under their
    graph-qualified keys).
    """
    from ..core.compile import CompiledModel

    doc = _load_doc(path)
    arrays = _load_sidecar(path, doc)
    model = pqir.Model.from_json(doc["model"])
    model.validate()
    p = doc["plan"]
    steps = []
    for sj in p["steps"]:
        consts = tuple(
            None
            if cj is None
            else (jax.numpy.asarray(arrays[cj["key"]]) if cj["jax"] else arrays[cj["key"]])
            for cj in sj["consts"]
        )
        steps.append(
            PlanStep(
                kernel=sj["kernel"],
                args=tuple(Arg(k, i, n) for k, i, n in sj["args"]),
                out_slots=tuple(sj["out_slots"]),
                params=_dec(sj["params"]),
                consts=consts,
                kind=sj["kind"],
                name=sj["name"],
                outputs=tuple(sj["outputs"]),
                out_info=tuple(
                    None if ij is None else ValueInfo(ij[0], _shape_from_json(ij[1]))
                    for ij in sj["out_info"]
                ),
            )
        )
    prov = None
    if doc["provenance"] is not None:
        # passes/fusions carry over verbatim; the live record re-accumulates
        # its specialization history as the hot cells are re-seeded below
        # (the artifact JSON keeps the full saved history, evicted cells
        # included)
        pd = dict(doc["provenance"])
        pd["specializations"] = []
        prov = PlanProvenance.from_dict(pd)
    batch = p["batch"] if isinstance(p["batch"], str) else _dec(p["batch"])
    plan = ExecutionPlan(
        backend=p["backend"],
        steps=steps,
        num_slots=int(p["num_slots"]),
        inputs=tuple((n, int(s)) for n, s in p["inputs"]),
        outputs=tuple((n, int(s)) for n, s in p["outputs"]),
        batch=batch,
        axes=tuple(p["axes"]),
        provenance=prov,
        states=tuple(
            StateBinding(
                name=n, input=i, output=o, in_slot=int(isl), out_slot=int(osl),
                dtype=d, shape=_shape_from_json(sh),
            )
            for n, i, o, isl, osl, d, sh in p.get("states", [])
        ),
    )
    axis_specs = {
        a: (None if spec is None else int(spec))
        for a, spec in doc["axis_specs"].items()
    }
    cm = CompiledModel(
        model,
        plan,
        {k: int(v) for k, v in doc["stats"].items()},
        None,
        plan_cache_capacity=int(doc["plan_cache_capacity"]),
        dynamic_axes={a: resolve_bucketing(spec) for a, spec in axis_specs.items()},
        axis_specs=axis_specs,
        autotuner=autotuner,
        plan_cache=plan_cache,
    )
    cells = doc.get("cells", [])
    if cells and cm.plan_cache is not None:
        replay = _ReplayTuner(cells)
        for cell in cells:
            bindings = {a: int(v) for a, v in cell["bindings"].items()}
            spec = specialize_plan(plan, bindings, tuner=replay)
            fn = jax.jit(spec.execute)
            # direct put — no lookup, so hit/miss counters stay untouched and
            # "zero new specializations" is observable as misses == 0; routed
            # through cache_key so a shared (fleet) cache gets the same
            # graph-qualified key the model will look up with
            cm.plan_cache.put(cm.cache_key(bindings), (spec, fn))
            if warm:
                feeds = _zero_feeds(cm, bindings)
                if feeds is not None:
                    fn(feeds)
    if registry is not None:
        cm.attach_metrics(registry)
    return cm


def _zero_feeds(cm: "CompiledModel", bindings: Dict[str, int]):
    """Zero-filled feeds at a cell's bucket extents (jit priming only).
    Returns None when any input dim cannot be resolved to an int."""
    feeds = {}
    for t in cm.model.graph.inputs:
        dims = list(t.shape)
        for axis, by_input in cm.axis_input_pos.items():
            pos = by_input.get(t.name)
            if pos is not None and axis in bindings:
                dims[pos] = bindings[axis]
        if not all(isinstance(d, int) for d in dims):
            return None
        feeds[t.name] = jax.numpy.zeros(tuple(dims), np.dtype(t.dtype))
    return feeds


# ---------------------------------------------------------------------------
# CLI smoke (mirrors repro.backend.autotune's cold/warm discipline for CI)
# ---------------------------------------------------------------------------

def _smoke_model():
    from ..core.toolchain import MLPSpec, quantize_mlp

    rng = np.random.default_rng(11)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(16, 32)).astype(np.float32) * 0.2,
            rng.normal(size=(32, 8)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(32,)).astype(np.float32) * 0.1,
            rng.normal(size=(8,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(64, 16)).astype(np.float32)
    return quantize_mlp(spec, calib, name="aot_smoke")


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    from ..core.compile import compile_model
    from ..obs import trace as _trace

    ap = argparse.ArgumentParser(
        description="AOT artifact smoke: compile+serve+save, or warm-load and "
        "assert zero re-lowering + pre-seeded cache hits"
    )
    ap.add_argument("--smoke", action="store_true", required=True)
    ap.add_argument("--out", default="plan_artifact.json")
    ap.add_argument(
        "--expect-warm",
        action="store_true",
        help="load --out instead of compiling: fail unless no fuse/lower "
        "spans fire and every recorded cell is served without a new "
        "specialization",
    )
    args = ap.parse_args(argv)

    model = _smoke_model()
    rng = np.random.default_rng(12)
    xs = {b: rng.integers(-128, 128, (b, 16)).astype(np.int8) for b in (2, 8)}

    if not args.expect_warm:
        cm = compile_model(model, backend="ref", batch="dynamic")
        inp = cm.input_names[0]
        for x in xs.values():
            cm.run({inp: x})
        save_artifact(cm, args.out)
        print(
            f"saved {args.out} (+ sidecar): "
            f"{len(cm.plan.steps)} steps, {len(cm.plan_cache.keys())} hot cells"
        )
        return 0

    tracer = _trace.install()
    try:
        cm = load_artifact(args.out, warm=True)
        inp = cm.input_names[0]
        outs = [cm.run({inp: x}) for x in xs.values()]
    finally:
        _trace.uninstall()
    # the fresh compile runs outside the tracer: its fuse/lower spans are its
    # own business — the assertion below is about the *load* path only
    fresh = compile_model(_smoke_model(), backend="ref", batch="dynamic")
    for x, got in zip(xs.values(), outs):
        want = fresh.run({fresh.input_names[0]: x})
        for k in want:
            np.testing.assert_array_equal(got[k], want[k])
    relower = len(tracer.spans("compile.fuse")) + len(tracer.spans("compile.lower"))
    stats = cm.plan_cache.stats
    ok = relower == 0 and stats["misses"] == 0 and stats["hits"] == len(xs)
    print(
        f"warm load: fuse/lower spans={relower} plan-cache hits={stats['hits']} "
        f"misses={stats['misses']} (expected {len(xs)} hits, 0 misses)"
    )
    if not ok:
        print("FAIL: warm start re-lowered or re-specialized")
        return 1
    print("OK: zero re-lowering, all recorded cells served from the pre-seeded cache")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
