"""Measured per-cell tile autotuning for the fused qmatmul kernel.

``choose_tiles`` is a static heuristic: every scenario cell of the
(batch × sequence × …) grid runs the same default blocks regardless of its
actual flattened M.  This module closes the co-design loop the paper's
artifact enables — the backend *measures* what it actually runs fastest:

* **Search space** — the MXU-aligned (bm, bk, bn) lattice from the kernel's
  tile constraints (:func:`tile_candidates`).  ``bm`` ranges over
  32-multiples up to the padded M; ``bk``/``bn`` are constrained to
  *divisors* of the template's padded ``kp``/``np`` so every tuned
  specialization shares the pre-padded parameter arrays zero-copy
  (:func:`repro.kernels.ops.with_tiles` enforces this).  Candidates whose
  double-buffered working set overflows VMEM are pruned up front.
* **Cost-model seeding** — the lattice is ranked by the analytic
  ``max(T_comp, T_mem)`` intensity model (:mod:`repro.backend.cost`, the
  same numbers as ``benchmarks/roofline.py``) and only the top ``budget``
  candidates are ever timed (:func:`seed_candidates`; the heuristic tiles
  are always candidate #0, so a tuned cell can never regress past noise).
* **Measurement** — each candidate runs the real planned kernel
  (:func:`repro.kernels.ops.quantized_matmul_planned`) on deterministic
  seeded int8 activations through the shared warmup + median-of-k helper
  (:func:`measure_median`).  Timings route through the obs plane: one
  ``backend.autotune`` span per tuned (cell × step) with
  ``autotune.candidate`` child spans, plus ``autotune.*`` registry counters.
* **Persistence** — winners land in an on-disk JSON :class:`AutotuneCache`
  keyed by ``(kernel step, backend, axis bindings, shape key)``: a
  diffable, warm-startable co-design artifact (the tuned analogue of the
  golden plan renderings).  A second process pointed at the same file
  specializes every known cell with **zero** new measurements.
* **Integration** — :func:`repro.backend.lowering.specialize_plan` accepts
  ``tuner=``; each fused step's tile record in :class:`PlanProvenance` is
  tagged with its source (``heuristic`` renders untagged, ``[tuned]`` /
  ``[cache]`` otherwise).  :class:`repro.serving.compiled.
  CompiledModelServer` drives the search *non-blocking* via :class:`TuneJob`:
  a cell serves immediately on heuristic tiles, a bounded number of
  candidates is measured between ``step()`` batches, and the tuned executor
  swaps into the PlanCache atomically once the search finishes.

Determinism for tests/goldens: inject ``measure_fn`` (e.g. the cost model
itself) and the whole search — winners, provenance tags, cache files —
is reproducible bit-for-bit.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cache import PersistentJsonStore
from ..kernels import ops as kops
from ..kernels import qmatmul as _qmm
from ..obs import trace as _trace
from ..obs.metrics import MetricsRegistry, default_registry
from . import cost

#: Tile triple (bm, bk, bn).
Tiles = Tuple[int, int, int]

#: measure_fn contract: (step, bound shape record, backend) -> seconds.
MeasureFn = Callable[[Any, Dict[str, Any], str], float]

CACHE_SCHEMA = "repro-autotune-v1"


# ---------------------------------------------------------------------------
# shared stable-timing helper (also used by benchmarks/hillclimb_decode.py)
# ---------------------------------------------------------------------------


def measure_median(fn: Callable[[], Any], *, repeat: int = 5, warmup: int = 2) -> float:
    """Median-of-``repeat`` wall time of ``fn()`` in seconds, after ``warmup``
    discarded calls (the first of which absorbs jit compilation).

    The median — not the mean — is the estimator every timing comparison in
    this repo shares: one GC pause or scheduler blip lands in a single
    sample and cannot move the reported number, which is what makes
    tuned-vs-heuristic deltas reproducible on noisy CI runners."""
    if repeat < 1:
        raise ValueError(f"repeat must be >= 1, got {repeat}")
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    samples.sort()
    mid = len(samples) // 2
    if len(samples) % 2:
        return samples[mid]
    return 0.5 * (samples[mid - 1] + samples[mid])


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------

#: The lattice axes: bm over 32-multiples (sublane minimum) up to the default
#: block, bk/bn over 128-multiples up to 2x the default blocks.
_BM_LATTICE = (32, 64, 128, 256)
_BK_LATTICE = (128, 256, 512)
_BN_LATTICE = (128, 256, 512)

#: Query row-block lattice for the fused attention kernel (its one tile).
_BQ_LATTICE = (32, 64, 128, 256)


def is_attention_shape(shape: Dict[str, Any]) -> bool:
    """True for a fused-attention shape record (``{b, s, t, dh[, bq]}``) as
    opposed to a qmatmul record (``{m, k, n, kp, np, ...}``)."""
    return "dh" in shape and "t" in shape and "m" not in shape


def attention_candidates(
    s: int, t: int, dh: int, *, hw: cost.HardwareSpec = cost.TPU_V5E
) -> List[int]:
    """Legal ``bq`` values for a bound attention cell: sublane-aligned, no
    larger than the 32-rounded query count (a bigger block only adds query
    padding), working set within VMEM."""
    sp = max(32, (int(s) + 31) // 32 * 32)
    return [
        bq for bq in _BQ_LATTICE
        if bq <= sp and cost.qattention_vmem_bytes(t, dh, bq) <= hw.vmem_bytes
    ]


def seed_attention_candidates(
    shape: Dict[str, Any], *, budget: int, hw: cost.HardwareSpec = cost.TPU_V5E
) -> List[int]:
    """Measurement list for one bound attention record: the heuristic ``bq``
    first, then the rest of the lattice ranked by the analytic cost
    (:func:`repro.backend.cost.qattention_tile_cost`), truncated to
    ``budget``."""
    b, s, t, dh = (int(shape[f]) for f in ("b", "s", "t", "dh"))
    heuristic = int(shape["bq"])
    rest = [c for c in attention_candidates(s, t, dh, hw=hw) if c != heuristic]
    rest.sort(key=lambda c: (cost.qattention_tile_cost(b, s, t, dh, c, hw=hw), c))
    return [heuristic] + rest[: max(0, budget - 1)]


def tile_candidates(
    m: int, kp: int, np_: int, *, hw: cost.HardwareSpec = cost.TPU_V5E, weight_bits: int = 8
) -> List[Tiles]:
    """Every legal (bm, bk, bn) for a bound cell: MXU/sublane-aligned
    (:func:`repro.kernels.qmatmul.tile_aligned`), ``bk | kp`` and ``bn | np``
    (template padding reuse), ``bm`` no larger than the padded M (a bigger
    block would only add padding), and working set within VMEM (packed-int4
    weight tiles stream at half size, so some candidates are only legal at
    4 bits)."""
    mp = max(32, (int(m) + 31) // 32 * 32)
    out: List[Tiles] = []
    for bm in _BM_LATTICE:
        if bm > mp:
            continue
        for bk in _BK_LATTICE:
            if kp % bk:
                continue
            for bn in _BN_LATTICE:
                if np_ % bn:
                    continue
                if not _qmm.tile_aligned(bm, bk, bn):
                    continue
                if cost.qmatmul_vmem_bytes(bm, bk, bn, weight_bits=weight_bits) > hw.vmem_bytes:
                    continue
                out.append((bm, bk, bn))
    return out


def seed_candidates(
    shape: Dict[str, Any], *, budget: int, hw: cost.HardwareSpec = cost.TPU_V5E
) -> List[Tiles]:
    """The measurement list for one bound shape record: the heuristic tiles
    first (always measured — the search can only ever *add* information, not
    lose the baseline), then the remaining lattice ranked by the analytic
    intensity model, truncated to ``budget`` total.  The shape record's
    ``bits`` (4 ⇒ packed weights) feeds the cost model, so int4 cells are
    ranked on their true — halved — weight traffic."""
    m, k, n = int(shape["m"]), int(shape["k"]), int(shape["n"])
    bits = int(shape.get("bits", 8))
    heuristic: Tiles = (int(shape["bm"]), int(shape["bk"]), int(shape["bn"]))
    cands = tile_candidates(m, int(shape["kp"]), int(shape["np"]), hw=hw, weight_bits=bits)
    rest = [c for c in cands if c != heuristic]
    rest.sort(key=lambda c: (cost.qmatmul_tile_cost(m, k, n, *c, hw=hw, weight_bits=bits), c))
    return [heuristic] + rest[: max(0, budget - 1)]


# ---------------------------------------------------------------------------
# persistent tile cache (the co-design artifact)
# ---------------------------------------------------------------------------


class AutotuneCache:
    """On-disk tuned-tile store: ``{"schema": "repro-autotune-v1", "entries":
    {<key>: {...}}}`` via :class:`repro.core.cache.PersistentJsonStore`.

    Keys are ``<step>|<backend>|<cell>|<shape key>`` — e.g. ::

        fc0_matmul|interpret|N=8|m=8,k=256,n=256,kp=256,np=256

    and each entry records the winning tiles plus the full measurement
    evidence (per-candidate µs, the heuristic baseline), so a hardware
    designer can read *why* a tile won, diff two hardware generations'
    artifacts, or ship the file to pre-seed a fleet replica (ROADMAP item 3)
    which then tunes nothing at startup."""

    def __init__(self, path: str) -> None:
        self.store = PersistentJsonStore(path, schema=CACHE_SCHEMA)

    @property
    def path(self) -> str:
        return self.store.path

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        return self.store.get(key)

    def put(self, key: str, entry: Dict[str, Any]) -> None:
        self.store.put(key, entry)

    def __len__(self) -> int:
        return len(self.store)

    def __contains__(self, key: str) -> bool:
        return key in self.store


def cell_key(bindings: Dict[str, int]) -> str:
    """Deterministic cell rendering: sorted ``axis=bucket`` pairs."""
    return ",".join(f"{a}={v}" for a, v in sorted(bindings.items()))


def shape_key(shape: Dict[str, Any]) -> str:
    """Deterministic problem-shape rendering (tiles excluded — they are the
    *output* of the search, not part of its identity).  The weight bitwidth
    *is* identity (an int4 cell runs a different kernel on half the weight
    bytes); it is appended only when sub-8 so existing int8 cache keys stay
    byte-identical."""
    if is_attention_shape(shape):
        return ",".join(f"{f}={int(shape[f])}" for f in ("b", "s", "t", "dh"))
    key = ",".join(f"{f}={int(shape[f])}" for f in ("m", "k", "n", "kp", "np"))
    if shape.get("bits", 8) != 8:
        key += f",bits={int(shape['bits'])}"
    return key


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Resolution:
    """What the tuner knows about one (step × cell): tile overrides (None ⇒
    the heuristic stands) and where they came from."""

    tiles: Optional[Tiles]
    source: str  # "heuristic" | "tuned" | "cache"


class Autotuner:
    """Budgeted measured tile search, cached per (step, backend, cell, shape).

    One tuner instance is one *measurement session*: results resolved within
    it are remembered in-process (re-specializing a cell after PlanCache
    eviction re-measures nothing), and — when constructed with a ``cache``
    path — persist to the on-disk :class:`AutotuneCache` so the *next*
    session warm-starts with zero measurements.  ``measurements`` counts
    every candidate actually timed; the CI smoke asserts it stays 0 on a
    warm-started run.

    ``measure_fn`` injects the timing oracle (tests and golden pins pass the
    analytic cost model for bit-determinism); the default measures the real
    planned kernel via :func:`measure_median` on seeded int8 activations.
    """

    def __init__(
        self,
        *,
        budget: int = 8,
        repeat: int = 5,
        warmup: int = 2,
        cache: Optional[Any] = None,  # AutotuneCache | path | None
        measure_fn: Optional[MeasureFn] = None,
        seed: int = 0,
        hw: cost.HardwareSpec = cost.TPU_V5E,
        registry: Optional[MetricsRegistry] = None,
    ) -> None:
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.budget = budget
        self.repeat = repeat
        self.warmup = warmup
        if cache is None or isinstance(cache, AutotuneCache):
            self.cache = cache
        else:
            self.cache = AutotuneCache(str(cache))
        self.measure_fn = measure_fn
        self.seed = seed
        self.hw = hw
        self.registry = registry if registry is not None else default_registry()
        self.measurements = 0  # candidates actually timed this session
        self._session: Dict[str, _Resolution] = {}

    # -- identity ------------------------------------------------------------
    def key_for(self, step, shape: Dict[str, Any], backend: str, bindings: Dict[str, int]) -> str:
        return "|".join(
            [step.name or step.kernel, backend, cell_key(bindings), shape_key(shape)]
        )

    @staticmethod
    def tunable(shape: Dict[str, Any], backend: str) -> bool:
        """Only cells with a known flat M on a tiled backend are searchable —
        the ref oracle has no tiles, and an unknown M has no fixed cost."""
        return backend != "ref" and shape.get("m") is not None

    # -- resolution (what specialize_plan calls) ----------------------------
    def tune_step(
        self, step, shape: Dict[str, Any], *, backend: str, bindings: Dict[str, int]
    ) -> Tuple[Dict[str, Any], str]:
        """Resolve one bound step's tiles: session → disk cache → measured
        search (blocking).  Returns the (possibly re-tiled) shape record and
        its source tag."""
        if is_attention_shape(shape):
            return self._tune_attention(step, shape, backend, bindings)
        if not self.tunable(shape, backend):
            return shape, "heuristic"
        key = self.key_for(step, shape, backend, bindings)
        res = self._resolve_cached(key)
        if res is None:
            cands = self._search_list(shape)
            if len(cands) <= 1:
                # the lattice collapsed to the heuristic: nothing to measure
                res = self._session[key] = _Resolution(None, "heuristic")
            else:
                with _trace.span(
                    "backend.autotune",
                    step=step.name or step.kernel,
                    cell=cell_key(bindings),
                    candidates=len(cands),
                ) as sp:
                    res = self._run_search(key, step, shape, backend, cands)
                    sp.set(bm=res.tiles[0], bk=res.tiles[1], bn=res.tiles[2])
        return self._apply(shape, res), res.source

    def _tune_attention(
        self, step, shape: Dict[str, Any], backend: str, bindings: Dict[str, int]
    ) -> Tuple[Dict[str, Any], str]:
        """The fused attention kernel's one-dimensional search (``bq``),
        sharing the session/disk-cache/measurement plumbing but none of the
        qmatmul lattice: attention records have no flat M and no pre-padded
        parameter arrays to stay divisor-compatible with."""
        if backend == "ref":
            return shape, "heuristic"  # the jnp oracle has no tiles
        key = self.key_for(step, shape, backend, bindings)
        res = self._session.get(key)
        if res is None and self.cache is not None:
            entry = self.cache.get(key)
            if entry is not None:
                self.registry.counter("autotune.cache_hits").inc()
                res = self._session[key] = _Resolution((int(entry["bq"]),), "cache")
            else:
                self.registry.counter("autotune.cache_misses").inc()
        if res is None:
            cands = seed_attention_candidates(shape, budget=self.budget, hw=self.hw)
            if len(cands) <= 1:
                res = self._session[key] = _Resolution(None, "heuristic")
            else:
                with _trace.span(
                    "backend.autotune",
                    step=step.name or step.kernel,
                    cell=cell_key(bindings),
                    candidates=len(cands),
                ) as sp:
                    timings: Dict[int, float] = {}
                    for bq in cands:
                        cshape = {**shape, "bq": int(bq)}
                        with _trace.span("autotune.candidate", tiles=f"bq={bq}") as csp:
                            if self.measure_fn is not None:
                                t = float(self.measure_fn(step, cshape, backend))
                            else:
                                t = self._measure_real_attention(step, cshape, backend)
                            csp.set(us=round(t * 1e6, 3))
                        self.measurements += 1
                        self.registry.counter("autotune.measurements").inc()
                        timings[int(bq)] = t
                    heuristic = cands[0]
                    best = min(timings, key=lambda c: (timings[c], c != heuristic, c))
                    res = self._session[key] = _Resolution((best,), "tuned")
                    self.registry.counter("autotune.cells").inc()
                    sp.set(bq=best)
                    if self.cache is not None:
                        self.cache.put(
                            key,
                            {
                                "bq": best,
                                "best_us": round(timings[best] * 1e6, 3),
                                "heuristic_us": round(timings[heuristic] * 1e6, 3),
                                "measured": len(timings),
                                "candidates_us": {
                                    str(c): round(t * 1e6, 3)
                                    for c, t in sorted(timings.items())
                                },
                            },
                        )
        if res.tiles is None:
            return shape, res.source
        return {**shape, "bq": int(res.tiles[0])}, res.source

    def _measure_real_attention(self, step, shape: Dict[str, Any], backend: str) -> float:
        import jax  # deferred: keep module import light

        from ..core.pqir import DTYPES
        from ..kernels import qattention as _qatt

        (lut,) = step.consts
        p = step.params
        b, s, t, dh = (int(shape[f]) for f in ("b", "s", "t", "dh"))
        rng = np.random.default_rng(self.seed)
        q = jax.numpy.asarray(rng.integers(-127, 128, size=(b, s, dh), dtype=np.int8))
        k = jax.numpy.asarray(rng.integers(-127, 128, size=(b, t, dh), dtype=np.int8))
        v = jax.numpy.asarray(rng.integers(-127, 128, size=(b, t, dh), dtype=np.int8))
        mask = jax.numpy.ones((b, s, t), jax.numpy.float32)

        def thunk():
            y = _qatt.qattention(
                q, k, v, mask, lut,
                qk_scale=p["qk_scale"], big=p["big"], lut_scale=p["lut_scale"],
                p_scale=p["p_scale"], rescale=p["rescale"],
                out_dtype=DTYPES[p["out_dtype"]], bq=int(shape["bq"]),
                interpret=(backend == "interpret"),
            )
            jax.block_until_ready(y)

        return measure_median(thunk, repeat=self.repeat, warmup=self.warmup)

    def _resolve_cached(self, key: str) -> Optional[_Resolution]:
        res = self._session.get(key)
        if res is not None:
            return res
        if self.cache is not None:
            entry = self.cache.get(key)
            if entry is not None:
                self.registry.counter("autotune.cache_hits").inc()
                res = _Resolution((int(entry["bm"]), int(entry["bk"]), int(entry["bn"])), "cache")
                self._session[key] = res
                return res
            self.registry.counter("autotune.cache_misses").inc()
        return None

    def _search_list(self, shape: Dict[str, Any]) -> List[Tiles]:
        return seed_candidates(shape, budget=self.budget, hw=self.hw)

    def _run_search(
        self, key: str, step, shape: Dict[str, Any], backend: str, cands: Sequence[Tiles]
    ) -> _Resolution:
        timings: Dict[Tiles, float] = {}
        for cand in cands:
            timings[cand] = self.measure_candidate(step, shape, backend, cand)
        return self.finish(key, shape, cands[0], timings)

    # -- incremental primitives (TuneJob drives these) ----------------------
    def measure_candidate(
        self, step, shape: Dict[str, Any], backend: str, cand: Tiles
    ) -> float:
        """Time one candidate (seconds) through the obs plane."""
        cshape = self._apply(shape, _Resolution(cand, "tuned"))
        with _trace.span(
            "autotune.candidate", tiles=f"bm={cand[0]},bk={cand[1]},bn={cand[2]}"
        ) as sp:
            if self.measure_fn is not None:
                t = float(self.measure_fn(step, cshape, backend))
            else:
                t = self._measure_real(step, cshape, backend)
            sp.set(us=round(t * 1e6, 3))
        self.measurements += 1
        self.registry.counter("autotune.measurements").inc()
        return t

    def finish(
        self, key: str, shape: Dict[str, Any], heuristic: Tiles, timings: Dict[Tiles, float]
    ) -> _Resolution:
        """Close one search: pick the winner (ties break toward the heuristic,
        then lexicographically — determinism over luck), record it in the
        session and the on-disk artifact."""
        best = min(timings, key=lambda c: (timings[c], c != heuristic, c))
        res = _Resolution(best, "tuned")
        self._session[key] = res
        self.registry.counter("autotune.cells").inc()
        if self.cache is not None:
            self.cache.put(
                key,
                {
                    "bm": best[0],
                    "bk": best[1],
                    "bn": best[2],
                    "best_us": round(timings[best] * 1e6, 3),
                    "heuristic_us": round(timings[heuristic] * 1e6, 3),
                    "measured": len(timings),
                    "candidates_us": {
                        f"{c[0]},{c[1]},{c[2]}": round(t * 1e6, 3)
                        for c, t in sorted(timings.items())
                    },
                },
            )
        return res

    # -- mechanics -----------------------------------------------------------
    @staticmethod
    def _apply(shape: Dict[str, Any], res: _Resolution) -> Dict[str, Any]:
        if res.tiles is None:
            return shape
        bm, bk, bn = res.tiles
        return kops.with_tiles(shape, bm=bm, bk=bk, bn=bn)

    def _measure_real(self, step, shape: Dict[str, Any], backend: str) -> float:
        import jax  # deferred: keep module import light

        from ..core.pqir import DTYPES

        w2, b2, qs2, qsh2 = step.consts
        p = step.params
        rng = np.random.default_rng(self.seed)
        x = jax.numpy.asarray(
            rng.integers(-127, 128, size=(int(shape["m"]), int(shape["k"])), dtype=np.int8)
        )

        def thunk():
            y = kops.quantized_matmul_planned(
                x, w2, b2, qs2, qsh2, shape,
                out_dtype=DTYPES[p["out_dtype"]], relu=p["relu"], two_mul=p["two_mul"],
                interpret=(backend == "interpret"),
            )
            jax.block_until_ready(y)

        return measure_median(thunk, repeat=self.repeat, warmup=self.warmup)


# ---------------------------------------------------------------------------
# incremental background search (the serving integration)
# ---------------------------------------------------------------------------


class TuneJob:
    """The search for one scenario cell, sliced into bounded increments.

    Built from a plan *template* + bindings, it gathers every tunable fused
    step's candidate list up front (steps already resolved in the tuner's
    session or disk cache contribute no work), then :meth:`advance` measures
    at most ``max_candidates`` candidates per call — the unit the
    CompiledModelServer spends between batches, so serving latency bounds the
    tuning work it carries, never the other way round.  When the last
    candidate lands the winners are recorded exactly as the blocking path
    records them; a subsequent ``specialize_plan(..., tuner=...)`` for the
    cell is then a pure session lookup."""

    def __init__(self, tuner: Autotuner, template, bindings: Dict[str, int]) -> None:
        self.tuner = tuner
        self.bindings = {str(a): int(v) for a, v in bindings.items()}
        self.backend = template.backend
        self._items: List[Dict[str, Any]] = []
        for step in template.steps:
            if not step.params.get("dynamic_batch"):
                continue
            shape = kops.bind_qmatmul_axes(step.params["shape"], self.bindings)
            if not tuner.tunable(shape, self.backend):
                continue
            key = tuner.key_for(step, shape, self.backend, self.bindings)
            if tuner._resolve_cached(key) is not None:
                continue
            cands = tuner._search_list(shape)
            if len(cands) <= 1:
                tuner._session[key] = _Resolution(None, "heuristic")
                continue
            self._items.append(
                {"step": step, "shape": shape, "key": key, "cands": cands,
                 "i": 0, "timings": {}}
            )

    @property
    def done(self) -> bool:
        return not self._items

    @property
    def remaining(self) -> int:
        """Candidates still to measure."""
        return sum(len(it["cands"]) - it["i"] for it in self._items)

    def advance(self, max_candidates: int = 1) -> bool:
        """Measure up to ``max_candidates`` candidates; returns ``done``."""
        n = 0
        while self._items and n < max_candidates:
            it = self._items[0]
            cand = it["cands"][it["i"]]
            it["timings"][cand] = self.tuner.measure_candidate(
                it["step"], it["shape"], self.backend, cand
            )
            it["i"] += 1
            n += 1
            if it["i"] == len(it["cands"]):
                self.tuner.finish(it["key"], it["shape"], it["cands"][0], it["timings"])
                self._items.pop(0)
        return self.done


# ---------------------------------------------------------------------------
# CLI smoke (CI runs this twice: cold, then warm with --expect-cached)
# ---------------------------------------------------------------------------


def _smoke_artifact():
    from ..core.toolchain import MLPSpec, quantize_mlp

    rng = np.random.default_rng(4)
    d = 256
    spec = MLPSpec(
        weights=[rng.normal(0, 0.4, (d, d)).astype(np.float32) for _ in range(2)],
        biases=[rng.normal(0, 0.2, (d,)).astype(np.float32) for _ in range(2)],
        activations=["Relu", None],
    )
    calib = rng.normal(0, 1.0, (64, d)).astype(np.float32)
    return quantize_mlp(spec, calib, name="autotune_smoke")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="autotune smoke: tune a small dynamic MLP's cells on the "
        "interpret backend and persist the tile cache"
    )
    ap.add_argument("--smoke", action="store_true", help="run the smoke model")
    ap.add_argument("--budget", type=int, default=4, help="candidates per cell step")
    ap.add_argument("--cache", default="autotune_cache.json", help="tile cache path")
    ap.add_argument("--cells", default="8,64", help="comma-separated batch buckets")
    ap.add_argument(
        "--expect-cached", action="store_true",
        help="fail unless every cell resolves with zero new measurements "
        "(the warm-start acceptance check)",
    )
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("nothing to do: pass --smoke")

    from ..core.compile import compile_model

    tuner = Autotuner(budget=args.budget, repeat=3, warmup=1, cache=args.cache)
    cm = compile_model(_smoke_artifact(), backend="interpret", batch="dynamic", autotune=tuner)
    sources: Dict[int, set] = {}
    for cell in (int(c) for c in args.cells.split(",")):
        plan, _ = cm.specialized(cell)
        ev = plan.provenance.specializations[-1]
        sources[cell] = {
            rec.rsplit("[", 1)[-1].rstrip("]") if rec.endswith("]") else "heuristic"
            for _, rec in ev.tiles
        }
    print(
        f"autotune smoke: cells={sorted(sources)} measurements={tuner.measurements} "
        f"cache_entries={len(tuner.cache)} cache={tuner.cache.path}"
    )
    for cell, src in sorted(sources.items()):
        print(f"  cell N={cell}: tile sources {sorted(src)}")
    if args.expect_cached and tuner.measurements:
        print(
            f"FAIL: expected a pure warm start but performed "
            f"{tuner.measurements} measurement(s)"
        )
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
