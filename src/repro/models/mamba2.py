"""Mamba2 (SSD) block — used by the zamba2-7b hybrid (arXiv:2411.15242).

Implements the chunked State-Space-Dual algorithm (Dao & Gu 2024): within a
chunk the recurrence is computed as masked-decay attention (matmuls → MXU);
across chunks a (B, H, P, N) state is carried by a lax.scan.  Decode is the
O(1) single-step recurrence — which is why hybrids run the long_500k cell.

    h_t = exp(dt_t·A) h_{t-1} + dt_t · x_t ⊗ B_t
    y_t = C_t · h_t + D · x_t
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import linear, param, rmsnorm


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.d_state
    return d_inner, n_heads, conv_dim


def init_mamba2_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ssm = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": param(ks[0], (cfg.d_model, 2 * d_inner + 2 * ssm.d_state + n_heads), dtype=dtype),
        "conv_w": param(ks[1], (ssm.d_conv, conv_dim), 0.2, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": jnp.ones((d_inner,), dtype),
        "out_proj": param(ks[2], (d_inner, cfg.d_model), dtype=dtype),
    }


def init_mamba2_state(batch: int, cfg: ModelConfig) -> dict:
    ssm = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_dim), jnp.bfloat16),
        "ssd": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state), jnp.float32),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array):
    """Depthwise causal conv1d, width K: (B,S,C) with (B,K-1,C) history."""
    k = w.shape[0]
    full = jnp.concatenate([prev.astype(xbc.dtype), xbc], axis=1)  # (B, S+K-1, C)
    out = sum(full[:, i : i + xbc.shape[1]] * w[i] for i in range(k)) + b
    new_prev = full[:, -(k - 1) :] if k > 1 else prev
    return jax.nn.silu(out), new_prev.astype(jnp.bfloat16)


def _ssd_chunk(carry, inp, *, nh, p_dim):
    """One SSD chunk: intra-chunk masked attention + inter-chunk state."""
    s_prev = carry  # (B,H,P,N) f32
    xh, bm, cm, dt, la = inp  # (B,L,H,P), (B,L,N), (B,L,N), (B,L,H), (B,L,H)
    l_cum = jnp.cumsum(la, axis=1)  # (B,L,H) cumulative log-decay
    l_last = l_cum[:, -1]  # (B,H)

    # intra-chunk: att[i,j] = (C_i·B_j)·exp(l_i−l_j)·dt_j  for j ≤ i
    cb = jnp.einsum("bin,bjn->bij", cm, bm)  # (B,L,L)
    diff = l_cum[:, :, None, :] - l_cum[:, None, :, :]  # (B,L,L,H) = l_i − l_j
    li = jnp.tril(jnp.ones((xh.shape[1], xh.shape[1]), bool))
    m = jnp.where(li[None, :, :, None], jnp.exp(diff), 0.0) * dt[:, None, :, :]
    y_intra = jnp.einsum("bijh,bjhp->bihp", cb[..., None] * m, xh.astype(jnp.float32))

    # inter-chunk: carry-in state read by C with prefix decay
    y_inter = jnp.einsum("bin,bhpn->bihp", cm, s_prev) * jnp.exp(l_cum)[..., None]

    # state update: suffix-decayed outer products + fully decayed carry
    w_suffix = jnp.exp(l_last[:, None, :] - l_cum) * dt  # (B,L,H)
    s_contrib = jnp.einsum("bjh,bjn,bjhp->bhpn", w_suffix, bm, xh.astype(jnp.float32))
    s_new = jnp.exp(l_last)[:, :, None, None] * s_prev + s_contrib
    return s_new, (y_intra + y_inter).astype(xh.dtype)


def mamba2_mix(
    p: dict,
    x: jax.Array,  # (B, S, d)
    state: dict,
    cfg: ModelConfig,
    *,
    chunk: int = 256,
) -> Tuple[jax.Array, dict]:
    ssm = cfg.ssm
    b, s, _ = x.shape
    d_inner, nh, conv_dim = _dims(cfg)
    pdim, n = ssm.head_dim, ssm.d_state

    zxbcdt = linear(x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], state["conv"])
    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(b, s, nh, pdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])  # (H,) negative
    log_decay = dt * a  # (B,S,H)  = log(exp(dt·A))

    if s == 1:  # decode: single recurrence step
        s_prev = state["ssd"]
        kv = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], bm[:, 0].astype(jnp.float32), xh[:, 0].astype(jnp.float32))
        s_new = jnp.exp(log_decay[:, 0])[:, :, None, None] * s_prev + kv
        y = jnp.einsum("bn,bhpn->bhp", cm[:, 0].astype(jnp.float32), s_new)[:, None]
        y = y.reshape(b, 1, nh, pdim)
        ssd_state = s_new
    else:
        chunk = min(chunk, s)
        assert s % chunk == 0, (s, chunk)
        nc = s // chunk
        resh = lambda t_: jnp.moveaxis(t_.reshape((b, nc, chunk) + t_.shape[2:]), 1, 0)
        import functools

        # remat each chunk: backward stores only the (B,H,P,N) chunk-boundary
        # states, not the (B,L,L,H) intra-chunk decay matrices.
        step = jax.checkpoint(functools.partial(_ssd_chunk, nh=nh, p_dim=pdim))
        ssd_state, ys = jax.lax.scan(
            step,
            state["ssd"],
            (resh(xh), resh(bm.astype(jnp.float32)), resh(cm.astype(jnp.float32)), resh(dt), resh(log_decay)),
        )
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, pdim)

    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh.astype(y.dtype)
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(y.astype(x.dtype) * jax.nn.silu(z), p["gate_norm"], eps=cfg.norm_eps)
    out = linear(y, p["out_proj"])
    return out, {"conv": conv_state, "ssd": ssd_state}


def mamba2_block(p: dict, x: jax.Array, state: dict, cfg: ModelConfig, norm_scale: jax.Array) -> Tuple[jax.Array, dict]:
    h, state = mamba2_mix(p, rmsnorm(x, norm_scale, eps=cfg.norm_eps), state, cfg)
    return x + h, state
