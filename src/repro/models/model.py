"""Unified model API over all four families (decoder / enc-dec / rwkv6 /
hybrid):

    init_params(key, cfg)                  -> params pytree (f32 masters)
    init_cache(cfg, batch, max_len)        -> serving cache pytree
    loss_fn(params, batch, cfg)            -> (loss, metrics)       [train]
    prefill(params, batch, cfg, cache)     -> (last_logits, cache)  [serve]
    decode_step(params, tokens, pos, cache, cfg) -> (logits, cache) [serve]
    param_logical_axes(params)             -> logical-axes pytree (sharding)

Modality frontends ([audio]/[vlm]) are stubs per assignment: batches carry
precomputed frame/patch embeddings which are concatenated/consumed directly.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from . import attention as attn
from . import transformer as tfm
from .layers import embed, init_embedding, logits_from_embedding, param, rmsnorm
from .mamba2 import init_mamba2_layer, init_mamba2_state, mamba2_block
from .rwkv6 import init_rwkv6_layer, init_rwkv6_state, rwkv6_block

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 256 so the vocab dim always shards over
    the 16-way model axis (and stays MXU-lane aligned).  Padded logits are
    masked to -inf in _logits; labels never reach the padding."""
    return (cfg.vocab_size + 255) // 256 * 256


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    k_emb, k_layers, k_head, k_enc, k_shared = jax.random.split(key, 5)
    p: dict = {"embed": init_embedding(k_emb, padded_vocab(cfg), cfg.d_model, dtype)}
    p["final_norm"] = (
        jnp.zeros((cfg.d_model,), dtype) if cfg.norm_plus_one else jnp.ones((cfg.d_model,), dtype)
    )
    if not cfg.tie_embeddings:
        p["lm_head"] = param(k_head, (cfg.d_model, padded_vocab(cfg)), dtype=dtype)

    if cfg.family == "decoder":
        p["layers"] = _stack_init(lambda k: tfm.init_decoder_layer(k, cfg, dtype), k_layers, cfg.n_layers)
    elif cfg.family == "encdec":
        p["encoder"] = _stack_init(lambda k: tfm.init_encoder_layer(k, cfg, dtype), k_enc, cfg.n_encoder_layers)
        p["layers"] = _stack_init(lambda k: tfm.init_cross_layer(k, cfg, dtype), k_layers, cfg.n_layers)
        p["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
    elif cfg.family == "rwkv6":
        def init_rwkv(k):
            lp = init_rwkv6_layer(k, cfg, dtype)
            lp["ln1"] = jnp.ones((cfg.d_model,), dtype)
            lp["ln2"] = jnp.ones((cfg.d_model,), dtype)
            return lp

        p["layers"] = _stack_init(init_rwkv, k_layers, cfg.n_layers)
    elif cfg.family == "hybrid":
        hy = cfg.hybrid

        def init_mamba(k):
            lp = init_mamba2_layer(k, cfg, dtype)
            lp["ln"] = jnp.ones((cfg.d_model,), dtype)
            return lp

        n_grouped = hy.n_groups * hy.ssm_per_group
        grouped = _stack_init(init_mamba, k_layers, n_grouped)
        p["mamba_groups"] = jax.tree.map(
            lambda a: a.reshape((hy.n_groups, hy.ssm_per_group) + a.shape[1:]), grouped
        )
        if hy.tail_ssm_layers:
            p["mamba_tail"] = _stack_init(init_mamba, jax.random.fold_in(k_layers, 1), hy.tail_ssm_layers)
        p["shared_block"] = tfm.init_decoder_layer(k_shared, cfg, dtype)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return p


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 0) -> dict:
    dt = cfg.kv_cache_dtype
    if cfg.attn_type == "swa" and cfg.window:
        # ring buffer: SWA never attends past `window`, so the cache is capped
        # (long_500k: 524288 → 4096 slots per layer, a 128× memory cut)
        max_len = min(max_len, cfg.window)
    if cfg.family == "decoder":
        if cfg.attn_type == "mla":
            one = lambda: attn.init_mla_cache(batch, max_len, cfg, dt)
        else:
            spec = attn.KVCacheSpec(batch, max_len, cfg.n_kv_heads, cfg.hd(), dt)
            one = lambda: attn.init_kv_cache(spec)
        return {"layers": jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one())}
    if cfg.family == "encdec":
        spec = attn.KVCacheSpec(batch, max_len, cfg.n_kv_heads, cfg.hd(), dt)
        one = attn.init_kv_cache(spec)
        hd = cfg.hd()
        return {
            "layers": jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one),
            "cross_kv": (
                jnp.zeros((cfg.n_layers, batch, src_len, cfg.n_kv_heads, hd), jnp.bfloat16),
                jnp.zeros((cfg.n_layers, batch, src_len, cfg.n_kv_heads, hd), jnp.bfloat16),
            ),
        }
    if cfg.family == "rwkv6":
        one = init_rwkv6_state(batch, cfg)
        return {"layers": jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)}
    if cfg.family == "hybrid":
        hy = cfg.hybrid
        ms = init_mamba2_state(batch, cfg)
        spec = attn.KVCacheSpec(batch, max_len, cfg.n_kv_heads, cfg.hd(), dt)
        kv = attn.init_kv_cache(spec)
        cache = {
            "mamba_groups": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (hy.n_groups, hy.ssm_per_group) + a.shape), ms
            ),
            "shared_kv": jax.tree.map(lambda a: jnp.broadcast_to(a, (hy.n_groups,) + a.shape), kv),
        }
        if hy.tail_ssm_layers:
            cache["mamba_tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (hy.tail_ssm_layers,) + a.shape), ms
            )
        return cache
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# forward bodies per family
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch: Dict, cfg: ModelConfig, compute_dtype) -> Tuple[jax.Array, jax.Array]:
    """Token (+ frontend-stub) embedding.  Returns (x (B,S,d), pos (S,))."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, scale_by_sqrt_dim=cfg.embed_scale_sqrt_dim).astype(compute_dtype)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(compute_dtype), x], axis=1)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, pos


def _rwkv_stack(params, x, caches, cfg, mode):
    def body(carry, xs):
        h = carry
        p_l, st_l = xs
        h = shard(h, "batch", None, None)
        h, st_new = rwkv6_block(p_l, h, st_l, cfg, {"ln1": p_l["ln1"], "ln2": p_l["ln2"]})
        return h, st_new

    if mode == "train":
        body = tfm._remat(body, cfg.remat_policy)
    if cfg.scan_layers:
        x, new_states = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
    else:  # unrolled (roofline probes)
        outs = []
        for i in range(cfg.n_layers):
            sl = lambda a: a[i]
            x, st = body(x, (jax.tree.map(sl, params["layers"]), jax.tree.map(sl, caches["layers"])))
            outs.append(st)
        new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, {"layers": new_states}, jnp.zeros((), jnp.float32)


def init_hybrid_states(cfg: ModelConfig, batch: int) -> dict:
    """Mamba recurrence states only (training needs no KV cache)."""
    hy = cfg.hybrid
    ms = init_mamba2_state(batch, cfg)
    st = {
        "mamba_groups": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (hy.n_groups, hy.ssm_per_group) + a.shape), ms
        ),
        "shared_kv": None,
    }
    if hy.tail_ssm_layers:
        st["mamba_tail"] = jax.tree.map(lambda a: jnp.broadcast_to(a, (hy.tail_ssm_layers,) + a.shape), ms)
    return st


def _hybrid_stack(params, x, pos, caches, cfg, mode, q_chunk, kv_chunk):
    hy = cfg.hybrid
    zero_w = jnp.zeros((), jnp.int32)

    def mamba_scan(x, p_stack, st_stack):
        def body(h, xs):
            p_l, st_l = xs
            h = shard(h, "batch", None, None)
            h, st_new = mamba2_block(p_l, h, st_l, cfg, p_l["ln"])
            return h, st_new

        if mode == "train":
            body = tfm._remat(body, cfg.remat_policy)
        if cfg.scan_layers:
            return jax.lax.scan(body, x, (p_stack, st_stack))
        outs = []
        n = jax.tree.leaves(st_stack)[0].shape[0]
        for i in range(n):
            sl = lambda a: a[i]
            x, st = body(x, (jax.tree.map(sl, p_stack), jax.tree.map(sl, st_stack)))
            outs.append(st)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    def group_body(carry, xs):
        h, aux = carry
        p_g, st_g, kv_g = xs
        h, st_new = mamba_scan(h, p_g, st_g)
        h, kv_new, aux_l = tfm.decoder_block(
            params["shared_block"], h, pos, cfg,
            window=zero_w, cache=kv_g, mode=mode, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return (h, aux + aux_l), (st_new, kv_new)

    if mode == "train":
        group_body = tfm._remat(group_body, cfg.remat_policy)

    if cfg.scan_layers:
        (x, aux), (m_states, kv_states) = jax.lax.scan(
            group_body, (x, jnp.zeros((), jnp.float32)),
            (params["mamba_groups"], caches["mamba_groups"], caches.get("shared_kv")),
        )
    else:  # unrolled (roofline probes)
        carry = (x, jnp.zeros((), jnp.float32))
        m_list, kv_list = [], []
        for i in range(hy.n_groups):
            sl = lambda a: a[i]
            kv_g = None if caches.get("shared_kv") is None else jax.tree.map(sl, caches["shared_kv"])
            carry, (st, kv) = group_body(
                carry, (jax.tree.map(sl, params["mamba_groups"]), jax.tree.map(sl, caches["mamba_groups"]), kv_g)
            )
            m_list.append(st)
            kv_list.append(kv)
        x, aux = carry
        m_states = jax.tree.map(lambda *xs: jnp.stack(xs), *m_list)
        kv_states = None if kv_list[0] is None else jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
    new_cache = {"mamba_groups": m_states, "shared_kv": kv_states}
    if hy.tail_ssm_layers:
        x, tail_states = mamba_scan(x, params["mamba_tail"], caches["mamba_tail"])
        new_cache["mamba_tail"] = tail_states
    return x, new_cache, aux


def forward(
    params: dict,
    batch: Dict,
    cfg: ModelConfig,
    *,
    mode: str = "train",  # train | prefill | decode
    caches: Optional[dict] = None,
    pos: Optional[jax.Array] = None,  # (B,) decode positions
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (hidden (B,S,d), new_caches, aux_loss)."""
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype) if a.dtype == jnp.float32 and a.ndim >= 2 else a, t)
    params = cast(params)
    windows = jnp.asarray(tfm.layer_windows(cfg, cfg.n_layers), jnp.int32)

    if cfg.family == "rwkv6":
        x, _ = _embed_inputs(params, batch, cfg, compute_dtype)
        if caches is None:
            caches = init_cache(cfg, x.shape[0], 0)
        x, new_caches, aux = _rwkv_stack(params, x, caches, cfg, mode)
    elif cfg.family == "hybrid":
        x, xpos = _embed_inputs(params, batch, cfg, compute_dtype)
        p_eff = pos if mode == "decode" else xpos
        if caches is None:
            caches = init_hybrid_states(cfg, x.shape[0])
        x, new_caches, aux = _hybrid_stack(params, x, p_eff, caches, cfg, mode, q_chunk, kv_chunk)
    elif cfg.family == "encdec":
        x, xpos = _embed_inputs(params, batch, cfg, compute_dtype)
        p_eff = pos if mode == "decode" else xpos
        layer_caches = None if caches is None else caches["layers"]
        if mode == "decode":
            cross_kv = jax.tree.map(lambda a: a.astype(compute_dtype), caches["cross_kv"])
        else:
            src = batch["src_embeds"].astype(compute_dtype)
            enc_w = jnp.zeros((cfg.n_encoder_layers,), jnp.int32)
            enc_out, _, _ = tfm.run_decoder_stack(
                params["encoder"], src, jnp.arange(src.shape[1], dtype=jnp.int32), cfg,
                windows=enc_w, caches=None, mode="train", bidirectional=True,
                q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            enc_out = rmsnorm(enc_out, params["enc_final_norm"], eps=cfg.norm_eps)
            cross_kv = tfm.compute_cross_kv(params["layers"]["xattn"], enc_out, cfg)
        x, new_layer_caches, aux = tfm.run_decoder_stack(
            params["layers"], x, p_eff, cfg,
            windows=windows, caches=layer_caches, mode=mode, cross_kv=cross_kv,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        new_caches = None
        if caches is not None:
            new_caches = {
                "layers": new_layer_caches,
                "cross_kv": jax.tree.map(lambda a: a.astype(jnp.bfloat16), cross_kv),
            }
    else:  # decoder
        x, xpos = _embed_inputs(params, batch, cfg, compute_dtype)
        p_eff = pos if mode == "decode" else xpos
        layer_caches = None if caches is None else caches["layers"]
        x, new_layer_caches, aux = tfm.run_decoder_stack(
            params["layers"], x, p_eff, cfg,
            windows=windows, caches=layer_caches, mode=mode,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        new_caches = None if caches is None else {"layers": new_layer_caches}

    x = rmsnorm(x, params["final_norm"], eps=cfg.norm_eps, plus_one=cfg.norm_plus_one)
    return x, new_caches, aux


def _logits(params, x, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = logits_from_embedding(params["embed"], x, softcap=cfg.logit_softcap)
    else:
        logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        if cfg.logit_softcap is not None:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab_size:  # mask the padded tail
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return shard(logits, "batch", None, "vocab_act")


# ---------------------------------------------------------------------------
# train / serve entry points
# ---------------------------------------------------------------------------


def loss_fn(
    params: dict,
    batch: Dict,
    cfg: ModelConfig,
    *,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Dict]:
    """Next-token cross-entropy (one-hot einsum form — GSPMD-friendly over a
    model-sharded vocab) + MoE aux."""
    x, _, aux = forward(
        params, batch, cfg, mode="train",
        compute_dtype=compute_dtype, q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        x = x[:, batch["patch_embeds"].shape[1] :]  # loss over text positions only
    # next-token objective: position t predicts label t+1
    labels = jnp.concatenate(
        [batch["labels"][:, 1:], jnp.full_like(batch["labels"][:, :1], -1)], axis=1
    )
    logits = _logits(params, x, cfg)  # (B, S, V) f32
    m = logits.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, padded_vocab(cfg), dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    valid = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * valid
    loss = nll.sum() / jnp.maximum(valid.sum(), 1.0)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": valid.sum()}


def prefill(
    params: dict,
    batch: Dict,
    cfg: ModelConfig,
    caches: dict,
    *,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, dict]:
    """Run the prompt through the model, writing caches; returns logits at the
    last position (B, V)."""
    x, new_caches, _ = forward(
        params, batch, cfg, mode="prefill", caches=caches,
        compute_dtype=compute_dtype, q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    logits = _logits(params, x[:, -1:], cfg)[:, 0]
    return logits, new_caches


def decode_step(
    params: dict,
    tokens: jax.Array,  # (B, 1)
    pos: jax.Array,  # (B,) position of the new token
    caches: dict,
    cfg: ModelConfig,
    *,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, dict]:
    """One serving step: append one token per sequence, return (B, V) logits."""
    x, new_caches, _ = forward(
        params, {"tokens": tokens}, cfg, mode="decode", caches=caches, pos=pos,
        compute_dtype=compute_dtype,
    )
    logits = _logits(params, x, cfg)[:, 0]
    return logits, new_caches


# ---------------------------------------------------------------------------
# sharding: logical axes from param paths
# ---------------------------------------------------------------------------

_AXES_BY_NAME = {
    "table": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "wq": ("embed", "heads"),
    "wk": ("embed", "heads"),
    "wv": ("embed", "heads"),
    "wo": ("heads", "embed"),
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    "q_down": ("embed", "heads"),
    "q_up": ("embed", "heads"),
    "kv_down": ("embed", "heads"),
    "kv_up": ("embed", "heads"),
    "router": ("embed", None),
    "shared_gate_proj": ("embed", None),
    "shared_w_gate": ("embed", "mlp"),
    "shared_w_up": ("embed", "mlp"),
    "shared_w_down": ("mlp", "embed"),
    "in_proj": ("embed", "mlp"),
    "out_proj": ("mlp", "embed"),
    "conv_w": (None, "mlp"),
    "tm_maa_w1": ("embed", "mlp"),
    "tm_maa_w2": (None, None, "embed"),
    "td_w1": ("embed", None),
    "td_w2": (None, "embed"),
    "wr": ("embed", "heads"),
    "wg": ("embed", "heads"),
    "cm_wk": ("embed", "mlp"),
    "cm_wv": ("mlp", "embed"),
    "cm_wr": ("embed", "heads"),
}

_MOE_STACKED = {"w_gate", "w_up", "w_down"}  # under "moe": leading expert dim


def param_logical_axes(params: dict) -> dict:
    """Logical axes per leaf from path names; leading stack dims (layers,
    groups, experts) map to None/"expert"."""

    def leaf_axes(path, leaf) -> Tuple:
        names = [getattr(p_, "key", getattr(p_, "name", None)) for p_ in path]
        last = names[-1]
        scales_only = False
        if last in ("q8", "s"):  # W8A8-converted leaf: axes come from parent
            scales_only = last == "s"
            last = names[-2]
        base = _AXES_BY_NAME.get(last)
        in_moe = "moe" in names
        if base is None:
            return (None,) * leaf.ndim
        if in_moe and last in _MOE_STACKED:
            base = ("expert",) + base
        if scales_only:
            base = base[-1:]  # per-out-channel scales follow the out axis
        # pad leading stack dims (layer scan, hybrid groups) with None
        extra = leaf.ndim - len(base)
        return (None,) * extra + base

    return jax.tree_util.tree_map_with_path(leaf_axes, params)


def cache_logical_axes(caches: dict, model_axis: int = 16) -> dict:
    """Logical axes for serving caches.  KV tensors prefer head-sharding over
    the model axis (no attention collectives); when the arch's kv-head count
    doesn't divide the axis (gemma2: 4, mixtral: 8), fall back to sequence
    sharding — GSPMD partitions the softmax reduction with an all-reduce,
    which is what keeps batch=1 long_500k caches from replicating."""

    def leaf_axes(path, leaf) -> Tuple:
        names = [getattr(p_, "key", getattr(p_, "name", None)) for p_ in path]
        last = names[-1]
        if last in ("k", "v") and leaf.ndim >= 4:
            n_kv = leaf.shape[-2]
            if n_kv % model_axis == 0:
                base = ("batch", None, "kv_heads_act", None)
            else:
                base = ("batch", "seq_shard", None, None)
        elif last in ("ckv", "k_pe"):
            base = ("batch", "seq_shard", None)
        elif last == "wkv":
            base = ("batch", "kv_heads_act", None, None)
        elif last == "ssd":
            base = ("batch", "kv_heads_act", None, None)
        elif last in ("tm_shift", "cm_shift"):
            base = ("batch", None)
        elif last == "conv":
            base = ("batch", None, None)
        elif last in ("k_scale", "v_scale", "ckv_scale"):
            base = ("batch",) + (None,) * (leaf.ndim - 1)
        else:
            base = (None,) * leaf.ndim
        extra = leaf.ndim - len(base)
        return (None,) * extra + tuple(base)

    return jax.tree_util.tree_map_with_path(leaf_axes, caches)
