"""RWKV6 ("Finch") — attention-free recurrent LM block with data-dependent
decay (arXiv:2404.05892).

Per layer: a time-mix block (WKV6 recurrence) and a channel-mix block.  The
signature Finch feature — the per-channel, *data-dependent* decay ``w_t`` —
is implemented with the paper's LoRA parameterization:

    w_t = exp(-exp(time_decay + tanh(x_w @ A_w) @ B_w))

WKV6 recurrence per head (D = head dim), with bonus ``u`` for the current
token:

    y_t = r_t · (diag(u)·k_t·v_tᵀ + S_t)
    S_{t+1} = diag(w_t)·S_t + k_t·v_tᵀ

Train/prefill runs a lax.scan over time (state (B, H, D, D) f32); decode is a
single recurrence step.  State is O(1) in sequence length — this is why
rwkv6-3b *runs* the long_500k cell (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import linear, param, rmsnorm

_MIX = ("w", "k", "v", "r", "g")


def init_rwkv6_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    ssm = cfg.ssm
    hd = ssm.head_dim
    nh = d // hd
    r = ssm.lora_rank
    ks = jax.random.split(key, 16)
    p = {
        # time-mix (WKV6)
        "tm_maa_x": param(ks[0], (d,), 0.1, dtype),
        "tm_maa": param(ks[1], (5, d), 0.1, dtype),  # per-target baseline mus
        "tm_maa_w1": param(ks[2], (d, 5 * r), dtype=dtype),
        "tm_maa_w2": param(ks[3], (5, r, d), dtype=dtype),
        "time_decay": param(ks[4], (d,), 0.5, dtype),
        "td_w1": param(ks[5], (d, r), dtype=dtype),
        "td_w2": param(ks[6], (r, d), dtype=dtype),
        "time_faaaa": param(ks[7], (nh, hd), 0.5, dtype),  # bonus u
        "wr": param(ks[8], (d, d), dtype=dtype),
        "wk": param(ks[9], (d, d), dtype=dtype),
        "wv": param(ks[10], (d, d), dtype=dtype),
        "wg": param(ks[11], (d, d), dtype=dtype),
        "wo": param(ks[12], (d, d), dtype=dtype),
        "ln_x": jnp.ones((d,), dtype),  # per-head group norm scale
        # channel-mix
        "cm_maa_k": param(ks[13], (d,), 0.1, dtype),
        "cm_maa_r": param(ks[14], (d,), 0.1, dtype),
        "cm_wk": param(ks[15], (d, cfg.d_ff), dtype=dtype),
        "cm_wv": param(jax.random.fold_in(key, 99), (cfg.d_ff, d), dtype=dtype),
        "cm_wr": param(jax.random.fold_in(key, 98), (d, d), dtype=dtype),
    }
    return p


def init_rwkv6_state(batch: int, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    nh = d // hd
    return {
        "tm_shift": jnp.zeros((batch, d), jnp.bfloat16),
        "cm_shift": jnp.zeros((batch, d), jnp.bfloat16),
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
    }


def _ddlerp(p: dict, x: jax.Array, xx: jax.Array):
    """Finch data-dependent token-shift interpolation for the 5 targets."""
    base = x + (xx - x) * p["tm_maa_x"]
    lora = jnp.tanh(base @ p["tm_maa_w1"])  # (B,S,5r)
    lora = lora.reshape(lora.shape[:-1] + (5, -1))  # (B,S,5,r)
    deltas = jnp.einsum("bsfr,frd->bsfd", lora, p["tm_maa_w2"])  # (B,S,5,d)
    outs = []
    for i in range(5):
        mu = p["tm_maa"][i] + deltas[..., i, :]
        outs.append(x + (xx - x) * mu)
    return outs  # order _MIX: w, k, v, r, g


def _wkv_scan(r, k, v, w, u, state, *, chunk: int = 128):
    """Sequential WKV6.  r,k,v: (B,S,H,D); w: (B,S,H,D) decay in (0,1);
    u: (H,D); state: (B,H,D,D) f32.  Returns y (B,S,H,D), new state.

    Memory: time is chunked and each chunk is rematerialized — the backward
    pass stores only chunk-boundary states (S/chunk × B·H·D² f32) instead of
    per-step outer products, which at 4k×batch blew past HBM (EXPERIMENTS.md
    §Dry-run note)."""
    b, s, nh, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    seq_first = lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0)

    def step(st, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,D) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)  # (B,H,D,D)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, u[None, :, :, None] * kv + st)
        s_new = w_t[..., None] * st + kv
        return s_new, y

    @jax.checkpoint
    def chunk_body(st, inp):
        return jax.lax.scan(step, st, inp)

    resh = lambda a: seq_first(a).reshape(nc, chunk, b, nh, hd)
    final, ys = jax.lax.scan(chunk_body, state, (resh(r), resh(k), resh(v), resh(w)))
    ys = ys.reshape(s, b, nh, hd)
    return jnp.moveaxis(ys, 0, 1), final  # (B,S,H,D)


def rwkv6_time_mix(
    p: dict,
    x: jax.Array,  # (B, S, d)
    state: dict,
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict]:
    b, s, d = x.shape
    hd = cfg.ssm.head_dim
    nh = d // hd
    # token shift: previous token (state carries the last token across calls)
    prev = jnp.concatenate([state["tm_shift"][:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, prev)

    # data-dependent decay (the Finch contribution)
    dd = jnp.tanh(xw @ p["td_w1"]) @ p["td_w2"]
    w = jnp.exp(-jnp.exp((p["time_decay"] + dd).astype(jnp.float32)))  # (B,S,d) in (0,1)

    r = linear(xr, p["wr"]).reshape(b, s, nh, hd)
    k = linear(xk, p["wk"]).reshape(b, s, nh, hd)
    v = linear(xv, p["wv"]).reshape(b, s, nh, hd)
    g = jax.nn.silu(linear(xg, p["wg"]))
    wh = w.reshape(b, s, nh, hd)

    y, wkv_new = _wkv_scan(r, k, v, wh, p["time_faaaa"].astype(jnp.float32), state["wkv"])

    # per-head group norm then gate
    y = y.reshape(b, s, nh, hd)
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, d) * p["ln_x"].astype(jnp.float32)
    out = linear(y.astype(x.dtype) * g, p["wo"])
    new_state = {**state, "tm_shift": x[:, -1].astype(jnp.bfloat16), "wkv": wkv_new}
    return out, new_state


def rwkv6_channel_mix(p: dict, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    prev = jnp.concatenate([state["cm_shift"][:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    xk = x + (prev - x) * p["cm_maa_k"]
    xr = x + (prev - x) * p["cm_maa_r"]
    k = jnp.square(jax.nn.relu(linear(xk, p["cm_wk"])))
    kv = linear(k, p["cm_wv"])
    out = jax.nn.sigmoid(linear(xr, p["cm_wr"])) * kv
    return out, {**state, "cm_shift": x[:, -1].astype(jnp.bfloat16)}


def rwkv6_block(
    p: dict,
    x: jax.Array,
    state: dict,
    cfg: ModelConfig,
    norms: dict,
) -> Tuple[jax.Array, dict]:
    """Pre-norm residual block: time-mix then channel-mix."""
    h, state = rwkv6_time_mix(p, rmsnorm(x, norms["ln1"], eps=cfg.norm_eps), state, cfg)
    x = x + h
    h, state = rwkv6_channel_mix(p, rmsnorm(x, norms["ln2"], eps=cfg.norm_eps), state)
    return x + h, state
