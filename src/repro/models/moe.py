"""Mixture-of-Experts FFN: token-choice top-k routing with capacity-bounded
segment-sum dispatch (memory-lean, GSPMD-partitionable — no (T, E, C) dispatch
tensor is ever materialized).

Covers both assigned MoE archs:
  * qwen2-moe-a2.7b — 60 routed experts top-4 + gated shared expert
  * mixtral-8x22b   — 8 routed experts top-2, renormalized top-k probs

Sharding: expert weights (E, d, f) shard d over ``data`` (FSDP) and f over
``model`` (TP); the expert buffers (E, C, d) shard C over ``data`` and d over
``model``.  Router stays f32 (accuracy-critical, tiny — a deliberate
non-quantized island, DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from ..distributed.sharding import shard
from .layers import param


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    assert m is not None
    ks = jax.random.split(key, 8)
    d, f = cfg.d_model, m.d_ff_expert
    p = {
        "router": param(ks[0], (d, m.n_experts), scale=0.02, dtype=jnp.float32),
        "w_gate": param(ks[1], (m.n_experts, d, f), dtype=dtype),
        "w_up": param(ks[2], (m.n_experts, d, f), dtype=dtype),
        "w_down": param(ks[3], (m.n_experts, f, d), dtype=dtype),
    }
    if m.n_shared_experts:
        fs = m.d_ff_shared
        p.update(
            shared_gate_proj=param(ks[4], (d, 1), dtype=jnp.float32),
            shared_w_gate=param(ks[5], (d, fs), dtype=dtype),
            shared_w_up=param(ks[6], (d, fs), dtype=dtype),
            shared_w_down=param(ks[7], (fs, d), dtype=dtype),
        )
    return p


def _dispatch_shards(t: int) -> int:
    """Number of shard-local dispatch groups = size of the batch ('pod'×'data')
    mesh axes when a mesh is active.  Local dispatch keeps the scatter, its
    indices and the (E, C, d) buffers fully data-parallel — no token shuffling
    collectives, and per-device capacity is T_local·k/E instead of global
    (the classic replicated-expert MoE layout; EP-over-model stays available
    via the expert-weight sharding rules)."""
    from ..distributed.sharding import active_mesh

    mesh = active_mesh()
    if mesh is None:
        return 1
    nd = 1
    for ax in ("pod", "data"):
        nd *= mesh.shape.get(ax, 1)
    return nd if t % nd == 0 else 1


def _expert_einsum(buf: jax.Array, w) -> jax.Array:
    """(x,e,c,d) × (e,d,f) → (x,e,c,f); W8A8 path when the expert weights are
    pre-quantized (int8 contraction + per-channel rescale, per the paper)."""
    if isinstance(w, dict) and "q8" in w:
        bf = buf.astype(jnp.float32)
        absmax = jax.lax.stop_gradient(jnp.abs(bf).max())
        sx = jnp.maximum(absmax / 127.0, 1e-12)
        bq = jnp.clip(jnp.rint(bf / sx), -128, 127).astype(jnp.int8)
        acc = jnp.einsum("xecd,edf->xecf", bq, w["q8"], preferred_element_type=jnp.int32)
        return (acc.astype(jnp.float32) * (sx * w["s"][None, :, None, :])).astype(buf.dtype)
    return jnp.einsum("xecd,edf->xecf", buf, w.astype(buf.dtype))


def moe_ffn(
    p: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux load-balance loss scalar)."""
    m = cfg.moe
    renormalize = m.renormalize
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    nd = _dispatch_shards(t)
    tl = t // nd  # tokens per dispatch group
    cap = int(max(1, round(tl * k / e * m.capacity_factor)))
    cap = (cap + 7) // 8 * 8  # tile-friendly local capacity

    xf = x.reshape(nd, tl, d)
    xf = shard(xf, "batch", None, None)
    logits = (xf.astype(jnp.float32)) @ p["router"]  # (nd, Tl, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)  # (nd, Tl, k)
    if renormalize:
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert, local to the group:
    # one-hot cumsum over the group's flattened (token, slot) order.
    flat_e = gate_idx.reshape(nd, tl * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (nd, Tl*k, E)
    pos = ((jnp.cumsum(onehot, axis=1) - 1) * onehot).max(axis=-1)  # (nd, Tl*k)
    in_cap = pos < cap
    slot = jnp.where(in_cap, flat_e * cap + pos, e * cap)  # dead slot when over

    # dispatch: per-group scatter into (E*C, d) buffers (unique slots ⇒ copy)
    x_slots = jnp.repeat(xf, k, axis=1)  # (nd, Tl*k, d)
    seg = jax.vmap(lambda xs, sl: jax.ops.segment_sum(xs, sl, num_segments=e * cap + 1))
    buf = seg(x_slots, slot)[:, :-1]  # (nd, E*C, d)
    buf = buf.reshape(nd, e, cap, d)
    buf = shard(buf, "batch", None, None, None)

    # expert computation — swiglu per expert, big einsums on the MXU
    g = _expert_einsum(buf, p["w_gate"])
    u = _expert_einsum(buf, p["w_up"])
    g = shard(g, "batch", None, None, "mlp_act")
    h = jax.nn.silu(g) * u
    out = _expert_einsum(h, p["w_down"])
    out = shard(out, "batch", None, None, None)

    # combine: gather each slot's expert output, weight, sum over k slots
    out_flat = out.reshape(nd, e * cap, d)
    out_flat = shard(out_flat, "batch", None, None)
    take = jax.vmap(lambda of, sl: jnp.take(of, sl, axis=0))
    gathered = jnp.where(in_cap[..., None], take(out_flat, jnp.minimum(slot, e * cap - 1)), 0.0)
    gathered = shard(gathered, "batch", None, None)
    y = (gathered.reshape(nd, tl, k, d) * gate_w[..., None].astype(gathered.dtype)).sum(axis=2)
    y = shard(y, "batch", None, None)
    y = y.reshape(t, d)
    xf = xf.reshape(t, d)

    # shared expert(s) — qwen2-moe style, sigmoid-gated
    if "shared_w_gate" in p:
        from .layers import linear

        sg = jax.nn.silu(linear(xf, p["shared_w_gate"]))
        su = linear(xf, p["shared_w_up"])
        sh = linear(sg * su, p["shared_w_down"])
        gate = jax.nn.sigmoid(xf.astype(jnp.float32) @ p["shared_gate_proj"])
        y = y + sh * gate.astype(y.dtype)

    # load-balance aux loss (Switch-style): E * Σ_e f_e · P_e
    frac_tokens = jnp.mean(jax.nn.one_hot(flat_e, e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) * m.router_aux_loss

    return y.reshape(b, s, d), aux
