"""Transformer stacks: decoder (GQA/MLA/MoE variants), encoder, enc-dec —
assembled with lax.scan over stacked layer params (bounded HLO ⇒ tractable
XLA compiles at 512-way SPMD) and configurable remat.

Per-layer attention flavor variation (gemma2's local/global alternation,
mixtral's SWA) is data — a per-layer ``window`` array scanned alongside the
params — so one homogeneous scan body serves every arch.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from . import attention as attn
from .layers import init_mlp, mlp, param, rmsnorm
from .moe import init_moe, moe_ffn

# ---------------------------------------------------------------------------
# per-layer params
# ---------------------------------------------------------------------------


def init_decoder_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    k_attn, k_mlp = jax.random.split(key)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype) if cfg.norm_plus_one else jnp.ones((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype) if cfg.norm_plus_one else jnp.ones((cfg.d_model,), dtype)}
    if cfg.post_block_norm:
        p["ln1_post"] = jnp.zeros((cfg.d_model,), dtype) if cfg.norm_plus_one else jnp.ones((cfg.d_model,), dtype)
        p["ln2_post"] = jnp.zeros((cfg.d_model,), dtype) if cfg.norm_plus_one else jnp.ones((cfg.d_model,), dtype)
    if cfg.attn_type == "mla":
        p["attn"] = attn.init_mla(k_attn, cfg, dtype)
    else:
        p["attn"] = attn.init_attention(k_attn, cfg, dtype)
    if cfg.moe is not None:
        p["moe"] = init_moe(k_mlp, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def init_encoder_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    return init_decoder_layer(key, cfg, dtype)


def init_cross_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """Decoder layer + cross-attention sub-block (enc-dec)."""
    p = init_decoder_layer(key, cfg, dtype)
    k = jax.random.fold_in(key, 7)
    p["xattn"] = attn.init_attention(k, cfg, dtype)
    p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------


def _norm(x, scale, cfg):
    return rmsnorm(x, scale, eps=cfg.norm_eps, plus_one=cfg.norm_plus_one)


def decoder_block(
    p: dict,
    x: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig,
    *,
    window,  # int32 scalar (0 = full)
    cache: Optional[dict] = None,
    mode: str = "train",
    bidirectional: bool = False,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (hidden, new_cache, aux_loss).  ``cross_kv`` is this layer's
    precomputed encoder K/V (enc-dec only; cached at prefill for decode)."""
    x = shard(x, "batch", None, None)
    h = _norm(x, p["ln1"], cfg)
    if cfg.attn_type == "mla":
        a_out, new_cache = attn.mla_attention(
            p["attn"], h, pos, cfg, cache=cache, mode=mode, q_chunk=q_chunk, kv_chunk=kv_chunk
        )
    else:
        a_out, new_cache = attn.gqa_attention(
            p["attn"], h, pos, cfg,
            window=window, cache=cache, mode=mode, bidirectional=bidirectional,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    if cfg.post_block_norm:
        a_out = _norm(a_out, p["ln1_post"], cfg)
    x = x + a_out

    if cross_kv is not None:  # enc-dec cross attention
        h = _norm(x, p["ln_x"], cfg)
        x = x + attn.cross_attention(p["xattn"], h, cross_kv, cfg)

    h = _norm(x, p["ln2"], cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        f_out, aux = moe_ffn(p["moe"], h, cfg)
    else:
        f_out = mlp(p["mlp"], h, cfg.mlp_type)
    if cfg.post_block_norm:
        f_out = _norm(f_out, p["ln2_post"], cfg)
    return x + f_out, new_cache, aux


# ---------------------------------------------------------------------------
# stacks (scan over layers)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig, n_layers: int) -> np.ndarray:
    """Per-layer attention window sizes (0 = unlimited)."""
    if cfg.attn_type == "swa":
        return np.full((n_layers,), cfg.window or 0, np.int32)
    if cfg.attn_type == "local_global":
        w = np.zeros((n_layers,), np.int32)
        w[0::2] = cfg.window or 0  # even layers local (gemma2 convention)
        return w
    return np.zeros((n_layers,), np.int32)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = None if policy == "nothing_saveable" else jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint(fn, policy=pol)


def run_decoder_stack(
    stacked: dict,  # params with leading (L, ...) dim
    x: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig,
    *,
    windows: jax.Array,  # (L,) int32
    caches: Optional[dict] = None,  # stacked leading (L, ...)
    mode: str = "train",
    bidirectional: bool = False,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,  # stacked (L, ...)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """lax.scan over the layer stack."""

    def body(carry, xs):
        h, aux = carry
        p_l, w_l, c_l, x_kv = xs
        h2, c_new, aux_l = decoder_block(
            p_l, h, pos, cfg,
            window=w_l, cache=c_l, mode=mode, bidirectional=bidirectional,
            cross_kv=x_kv, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return (h2, aux + aux_l), c_new

    body = _remat(body, cfg.remat_policy if mode == "train" else "none")

    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (stacked, windows, caches, cross_kv)
        )
    else:
        n_layers = windows.shape[0]
        aux = jnp.zeros((), jnp.float32)
        new_list = []
        for i in range(n_layers):
            sl = lambda a: a[i]
            p_l = jax.tree.map(sl, stacked)
            c_l = None if caches is None else jax.tree.map(sl, caches)
            x_kv = None if cross_kv is None else jax.tree.map(sl, cross_kv)
            (x, aux), c_new = body((x, aux), (p_l, windows[i], c_l, x_kv))
            new_list.append(c_new)
        new_caches = None if caches is None else jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    return x, new_caches, aux


def compute_cross_kv(stacked_xattn: dict, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute per-layer encoder K/V for cross-attention (cached for
    decode): a small scan over stacked xattn params."""

    def body(_, p_l):
        return None, attn.encdec_cross_kv(p_l, enc_out, cfg)

    _, kv = jax.lax.scan(body, None, stacked_xattn)
    return kv  # tuple of (L, B, T, Hkv, Dh)
