"""Shared model layers: norms, RoPE, embeddings, gated MLPs.

Everything is a pure function over explicit param pytrees (no flax).  Param
creation goes through :func:`param` so every leaf gets a deterministic
initializer; sharding is resolved separately from *param path names* by
``repro.distributed.sharding`` (see param_logical_axes in model.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard


def param(key, shape, scale: float = 0.02, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def linear(x: jax.Array, w) -> jax.Array:
    """Matmul that dispatches on the weight representation.

    * plain array  — bf16/f32 GEMM;
    * {"q8": int8 (in,out), "s": f32 (out,)} — W8A8 per the paper: dynamic
      per-tensor symmetric activation quantization (round-half-even,
      saturate), int8×int8→int32 MatMulInteger on the MXU, rescale by
      (scale_x·scale_w) — see repro.core.convert.convert_params_w8a8.
    """
    if isinstance(w, dict) and "q8" in w:
        xf = x.astype(jnp.float32)
        absmax = jax.lax.stop_gradient(jnp.abs(xf).max())
        sx = jnp.maximum(absmax / 127.0, 1e-12)
        xq = jnp.clip(jnp.rint(xf / sx), -128, 127).astype(jnp.int8)
        acc = jax.lax.dot_general(
            xq, w["q8"], (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        return (acc.astype(jnp.float32) * (sx * w["s"])).astype(x.dtype)
    return x @ w


def zeros(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.ones(shape, dtype)


# -- norms -------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6, plus_one: bool = False) -> jax.Array:
    """RMSNorm in f32 (stability), output in input dtype.  ``plus_one`` is the
    gemma convention (weight stored as deviation from 1)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (xf * w).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


# -- rotary embeddings -------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); pos: (..., S) int32.  Rotates pairs
    (x[..., :D/2], x[..., D/2:]) — the "half split" convention."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (D/2,)
    ang = pos.astype(jnp.float32)[..., None] * inv  # (..., S, D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    if x.ndim == pos.ndim + 2:  # head axis present
        sin, cos = sin[..., None, :], cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLPs ---------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, mlp_type: str = "swiglu", dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": param(k1, (d_model, d_ff), dtype=dtype),
            "w_up": param(k2, (d_model, d_ff), dtype=dtype),
            "w_down": param(k3, (d_ff, d_model), dtype=dtype),
        }
    return {  # vanilla 2-matrix MLP (gelu/relu)
        "w_up": param(k2, (d_model, d_ff), dtype=dtype),
        "w_down": param(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp(params: dict, x: jax.Array, mlp_type: str = "swiglu") -> jax.Array:
    if mlp_type in ("swiglu", "geglu"):
        g = linear(x, params["w_gate"])
        u = linear(x, params["w_up"])
        g = shard(g, "batch", None, "mlp_act") if g.ndim == 3 else g
        act = jax.nn.silu(g) if mlp_type == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = act * u
        return linear(h, params["w_down"])
    h = jax.nn.gelu(linear(x, params["w_up"]), approximate=True)
    return linear(h, params["w_down"])


# -- embeddings ---------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": param(key, (vocab, d_model), scale=1.0, dtype=dtype)}


def embed(params: dict, tokens: jax.Array, *, scale_by_sqrt_dim: bool = False) -> jax.Array:
    x = jnp.take(params["table"], tokens, axis=0)
    if scale_by_sqrt_dim:
        x = x * jnp.sqrt(jnp.asarray(x.shape[-1], x.dtype))
    return x


def logits_from_embedding(params: dict, x: jax.Array, *, softcap: Optional[float] = None) -> jax.Array:
    """Tied-embedding readout (x @ table.T) with optional logit softcapping."""
    logits = x.astype(jnp.float32) @ params["table"].T.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def softcap_fn(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
