"""Attention: GQA (+qk_norm, softcap, sliding window, local/global), MLA,
cross-attention, chunked (flash-style) computation, and bf16/int8 KV caches.

Conventions
-----------
* q is kept grouped as (B, S, Hkv, G, Dh) — G = n_heads // n_kv_heads — so GQA
  never materializes repeated K/V.
* Train/prefill use :func:`chunked_attention`: a lax.scan over KV chunks inside
  a lax.scan over Q chunks with online softmax — O(S·chunk) memory, the pure-lax
  flash-attention analogue the dry-run lowers (a Pallas flash kernel would slot
  in here on real TPU; DESIGN.md §5).
* int8 KV cache implements the paper's symmetric scheme on the cache: per
  (batch, head) scales chosen at prefill, round-half-even, saturate — the
  decode path dequantizes on read (DESIGN.md §4: MLA/GQA cache quantization).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .layers import apply_rope, linear, param, rmsnorm, softcap_fn

NEG_INF = -2.0**30  # large-negative instead of -inf: keeps softmax NaN-free


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    hd = cfg.hd()
    ks = jax.random.split(key, 6)
    p = {
        "wq": param(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype=dtype),
        "wk": param(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": param(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": param(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_mla(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "q_down": param(ks[0], (cfg.d_model, cfg.q_lora_rank), dtype=dtype),
        "q_norm": jnp.ones((cfg.q_lora_rank,), dtype),
        "q_up": param(ks[1], (cfg.q_lora_rank, cfg.n_heads * qk_head), dtype=dtype),
        "kv_down": param(ks[2], (cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype=dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        "kv_up": param(
            ks[3],
            (cfg.kv_lora_rank, cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
            dtype=dtype,
        ),
        "wo": param(ks[4], (cfg.n_heads * cfg.v_head_dim, cfg.d_model), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, *, window, bidirectional):
    """(..., Sq, Skv) boolean validity.  ``window`` is a traced int32 scalar
    (0 = unlimited) so local/global alternation can live inside one scanned
    layer body."""
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    if bidirectional:
        m = jnp.ones(d.shape, bool)
    else:
        m = d >= 0
    m = m & jnp.where(window > 0, d < window, True)
    return m


# ---------------------------------------------------------------------------
# chunked (flash-style) attention
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,  # (B, Sq, Hkv, G, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    q_pos: jax.Array,  # (Sq,) int32
    kv_pos: jax.Array,  # (Skv,) int32
    *,
    scale: float,
    window,  # int32 scalar array (0 = none)
    softcap: Optional[float] = None,
    bidirectional: bool = False,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]  # may differ from dh (MLA: v_head_dim != qk dim)

    def _div(s, c):  # largest divisor of s that is ≤ c
        c = min(c, s)
        while s % c:
            c -= 1
        return c

    q_chunk = _div(sq, q_chunk)
    kv_chunk = _div(skv, kv_chunk)
    nq, nk = sq // q_chunk, skv // kv_chunk

    qc = q.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, hkv, dv).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(nq, q_chunk)
    kp = kv_pos.reshape(nk, kv_chunk)

    def q_step(_, qi):
        q_i, qp_i = qi  # (B, qc, Hkv, G, Dh), (qc,)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            k_j, v_j, kp_j = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i.astype(jnp.float32), k_j.astype(jnp.float32)) * scale
            s = softcap_fn(s, softcap)
            valid = _mask(qp_i, kp_j, window=window, bidirectional=bidirectional)
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            corr = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_j.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kp))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]  # (B,Hkv,G,qc,Dh)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B,qc,Hkv,G,Dh)

    _, outs = jax.lax.scan(q_step, None, (qc, qp))  # (nq, B, qc, Hkv, G, Dv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, Hkv, G, Dh)
    k: jax.Array,  # (B, T, Hkv, Dh)
    v: jax.Array,
    cur_pos: jax.Array,  # (B,) int32 — position of the new token
    kv_pos: jax.Array,  # (T,)
    *,
    scale: float,
    window,
    softcap: Optional[float] = None,
) -> jax.Array:
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = softcap_fn(s, softcap)
    kv_pos_b = jnp.broadcast_to(kv_pos if kv_pos.ndim == 2 else kv_pos[None, :], (q.shape[0], k.shape[1]))
    d = cur_pos[:, None] - kv_pos_b  # (B, T)
    valid = (d >= 0) & (kv_pos_b >= 0) & jnp.where(window > 0, d < window, True)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (bf16 | int8 per the paper's symmetric scheme)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    batch: int
    max_len: int
    n_kv_heads: int
    head_dim: int
    dtype: str  # "bf16" | "int8"


def init_kv_cache(spec: KVCacheSpec) -> dict:
    shape = (spec.batch, spec.max_len, spec.n_kv_heads, spec.head_dim)
    if spec.dtype == "int8":
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.ones((spec.batch, spec.n_kv_heads), jnp.float32),
            "v_scale": jnp.ones((spec.batch, spec.n_kv_heads), jnp.float32),
        }
    return {"k": jnp.zeros(shape, jnp.bfloat16), "v": jnp.zeros(shape, jnp.bfloat16)}


def _quantize_kv(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int8 quantization of (B, S, H, D) with per-(B, H) scales —
    round-half-even + saturate, the paper's QuantizeLinear semantics."""
    q = jnp.rint(x.astype(jnp.float32) / scale[:, None, :, None])
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def write_prefill_kv(cache: dict, k: jax.Array, v: jax.Array) -> dict:
    """Write a full prefill of K/V at positions [0, S)."""
    if "k_scale" in cache:
        k_scale = jnp.abs(k.astype(jnp.float32)).max(axis=(1, 3)) / 127.0 + 1e-8
        v_scale = jnp.abs(v.astype(jnp.float32)).max(axis=(1, 3)) / 127.0 + 1e-8
        kq, vq = _quantize_kv(k, k_scale), _quantize_kv(v, v_scale)
        return {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0)),
            "k_scale": k_scale,
            "v_scale": v_scale,
        }
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }


def write_decode_kv(cache: dict, k: jax.Array, v: jax.Array, pos: jax.Array) -> dict:
    """Insert one token's K/V at per-batch position ``pos`` (B,)."""
    b = k.shape[0]

    def upd(buf, val):
        # per-batch dynamic position: vmap a length-1 dynamic_update_slice
        def one(buf_b, val_b, p):
            return jax.lax.dynamic_update_slice(buf_b, val_b, (p, 0, 0))

        return jax.vmap(one)(buf, val, pos)

    out = dict(cache)
    if "k_scale" in cache:
        kq = _quantize_kv(k, cache["k_scale"])
        vq = _quantize_kv(v, cache["v_scale"])
        out["k"], out["v"] = upd(cache["k"], kq), upd(cache["v"], vq)
    else:
        out["k"], out["v"] = upd(cache["k"], k.astype(cache["k"].dtype)), upd(cache["v"], v.astype(cache["v"].dtype))
    return out


def read_kv(cache: dict) -> Tuple[jax.Array, jax.Array]:
    if "k_scale" in cache:
        k = cache["k"].astype(jnp.float32) * cache["k_scale"][:, None, :, None]
        v = cache["v"].astype(jnp.float32) * cache["v_scale"][:, None, :, None]
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# full attention blocks
# ---------------------------------------------------------------------------


def _split_heads(x, n_heads, hd):
    return x.reshape(x.shape[:-1] + (n_heads, hd))


def gqa_attention(
    p: dict,
    x: jax.Array,  # (B, S, d)
    pos: jax.Array,  # (S,) for train/prefill, (B,) current positions for decode
    cfg: ModelConfig,
    *,
    window,  # int32 scalar array; 0 = none
    cache: Optional[dict] = None,
    mode: str = "train",  # train | prefill | decode
    bidirectional: bool = False,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, _ = x.shape
    hd = cfg.hd()
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    q = _split_heads(linear(x, p["wq"]), cfg.n_heads, hd)  # (B,S,H,Dh)
    k = _split_heads(linear(x, p["wk"]), hkv, hd)
    v = _split_heads(linear(x, p["wv"]), hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], eps=cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], eps=cfg.norm_eps)
    rope_pos = pos[None, :] if mode != "decode" else pos[:, None]  # (B or 1, S)
    q = apply_rope(q, jnp.broadcast_to(rope_pos, (b, s)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(rope_pos, (b, s)), cfg.rope_theta)
    q = shard(q.reshape(b, s, hkv, g, hd), "batch", None, "kv_heads_act", None, None)
    k = shard(k, "batch", None, "kv_heads_act", None)
    v = shard(v, "batch", None, "kv_heads_act", None)
    scale = hd**-0.5

    new_cache = None
    if mode == "decode":
        assert cache is not None
        t_cache = cache["k"].shape[1]
        if cfg.attn_type == "swa" and cfg.window and t_cache <= cfg.window:
            # ring buffer: cache holds only the last `window` tokens.  Slot i
            # currently stores position p_i = pos − ((pos − i) mod T); slots
            # never written yet resolve to p_i < 0 and are masked out.
            new_cache = write_decode_kv(cache, k, v, pos % t_cache)
            idx = jnp.arange(t_cache, dtype=jnp.int32)
            kv_pos = pos[:, None] - jnp.mod(pos[:, None] - idx[None, :], t_cache)
        else:
            new_cache = write_decode_kv(cache, k, v, pos)
            kv_pos = jnp.arange(t_cache, dtype=jnp.int32)
        kf, vf = read_kv(new_cache)
        out = decode_attention(q, kf, vf, pos, kv_pos, scale=scale, window=window, softcap=cfg.attn_softcap)
    else:
        if cache is not None:
            t_cache = cache["k"].shape[1]
            if s > t_cache:
                # SWA ring cache shorter than the prompt: only the last
                # `window` tokens matter for future decode.  Position p lives
                # in slot p mod W ⇒ roll the tail slice into ring order.
                shift = (s - t_cache) % t_cache
                k_w = jnp.roll(k[:, s - t_cache :], shift, axis=1)
                v_w = jnp.roll(v[:, s - t_cache :], shift, axis=1)
                new_cache = write_prefill_kv(cache, k_w, v_w)
            else:
                new_cache = write_prefill_kv(cache, k, v)
        p_pos = jnp.asarray(pos, jnp.int32)
        out = chunked_attention(
            q, k, v, p_pos, p_pos,
            scale=scale, window=window, softcap=cfg.attn_softcap,
            bidirectional=bidirectional, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    out = out.reshape(b, s, cfg.n_heads * hd)
    return linear(out, p["wo"]), new_cache


def cross_attention(
    p: dict,
    x: jax.Array,  # (B, S, d) decoder side
    enc_kv: Tuple[jax.Array, jax.Array],  # precomputed (B, T, Hkv, Dh) k, v
    cfg: ModelConfig,
) -> jax.Array:
    b, s, _ = x.shape
    hd = cfg.hd()
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    q = _split_heads(linear(x, p["wq"]), cfg.n_heads, hd).reshape(b, s, hkv, g, hd)
    k, v = enc_kv
    t = k.shape[1]
    zero_w = jnp.zeros((), jnp.int32)
    out = chunked_attention(
        q, k, v,
        jnp.arange(s, dtype=jnp.int32), jnp.arange(t, dtype=jnp.int32),
        scale=hd**-0.5, window=zero_w, bidirectional=True,
        q_chunk=min(1024, s), kv_chunk=min(1024, t),
    )
    return linear(out.reshape(b, s, cfg.n_heads * hd), p["wo"])


def encdec_cross_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    hd = cfg.hd()
    k = _split_heads(linear(enc_out, p["wk"]), cfg.n_kv_heads, hd)
    v = _split_heads(linear(enc_out, p["wv"]), cfg.n_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, minicpm3/deepseek style)
# ---------------------------------------------------------------------------


def init_mla_cache(batch: int, max_len: int, cfg: ModelConfig, dtype: str = "bf16") -> dict:
    if dtype == "int8":
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), jnp.int8),
            "ckv_scale": jnp.ones((batch,), jnp.float32),
            "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), jnp.bfloat16),
        }
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), jnp.bfloat16),
        "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), jnp.bfloat16),
    }


def mla_attention(
    p: dict,
    x: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    mode: str = "train",
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[dict]]:
    """MLA with the compressed-latent KV cache (the memory win that makes MLA
    attractive; quantizing the latent is the paper's scheme applied to it)."""
    b, s, _ = x.shape
    nh = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    cq = rmsnorm(linear(x, p["q_down"]), p["q_norm"], eps=cfg.norm_eps)
    q = linear(cq, p["q_up"]).reshape(b, s, nh, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]

    ckv_full = linear(x, p["kv_down"])  # (B,S,rank+dr)
    ckv, k_pe = ckv_full[..., : cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank :]
    ckv = rmsnorm(ckv, p["kv_norm"], eps=cfg.norm_eps)

    rope_pos = pos[None, :] if mode != "decode" else pos[:, None]
    rope_pos = jnp.broadcast_to(rope_pos, (b, s))
    q_pe = apply_rope(q_pe, rope_pos, cfg.rope_theta)
    k_pe = apply_rope(k_pe[:, :, None, :], rope_pos, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if mode == "decode":
        assert cache is not None
        new_cache = dict(cache)
        if "ckv_scale" in cache:
            ckv_q = jnp.clip(jnp.rint(ckv.astype(jnp.float32) / cache["ckv_scale"][:, None, None]), -128, 127).astype(jnp.int8)
        else:
            ckv_q = ckv.astype(cache["ckv"].dtype)

        def one(buf, val, pp):
            return jax.lax.dynamic_update_slice(buf, val, (pp, 0))

        new_cache["ckv"] = jax.vmap(one)(cache["ckv"], ckv_q, pos)
        new_cache["k_pe"] = jax.vmap(one)(cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), pos)
        ckv_all = new_cache["ckv"].astype(jnp.float32)
        if "ckv_scale" in cache:
            ckv_all = ckv_all * cache["ckv_scale"][:, None, None]
        k_pe_all = new_cache["k_pe"]
        t = ckv_all.shape[1]
    else:
        if cache is not None:
            new_cache = dict(cache)
            if "ckv_scale" in cache:
                sc = jnp.abs(ckv.astype(jnp.float32)).max(axis=(1, 2)) / 127.0 + 1e-8
                ckv_q = jnp.clip(jnp.rint(ckv.astype(jnp.float32) / sc[:, None, None]), -128, 127).astype(jnp.int8)
                new_cache["ckv_scale"] = sc
            else:
                ckv_q = ckv.astype(cache["ckv"].dtype)
            new_cache["ckv"] = jax.lax.dynamic_update_slice(cache["ckv"], ckv_q, (0, 0, 0))
            new_cache["k_pe"] = jax.lax.dynamic_update_slice(cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, 0, 0))
        ckv_all, k_pe_all, t = ckv, k_pe, s

    # up-project latents to per-head K (nope) and V
    kv = linear(ckv_all.astype(x.dtype), p["kv_up"]).reshape(b, t, nh, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe_all[:, :, None, :].astype(x.dtype), (b, t, nh, dr))], axis=-1)
    qh = jnp.concatenate([q_nope, q_pe], axis=-1).reshape(b, s, nh, 1, dn + dr)
    scale = (dn + dr) ** -0.5
    zero_w = jnp.zeros((), jnp.int32)
    if mode == "decode":
        kv_pos = jnp.arange(t, dtype=jnp.int32)
        out = decode_attention(qh, k, v, pos, kv_pos, scale=scale, window=zero_w)
    else:
        pp = jnp.asarray(pos, jnp.int32)
        out = chunked_attention(qh, k, v, pp, pp, scale=scale, window=zero_w, q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, s, nh * dv)
    return linear(out, p["wo"]), new_cache
