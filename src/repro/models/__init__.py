"""Model zoo: composable JAX blocks covering the 10 assigned architectures."""
from . import attention, layers, mamba2, model, moe, rwkv6, transformer  # noqa: F401
