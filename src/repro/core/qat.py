"""Quantization-aware training: fake-quant with straight-through estimator.

The co-design loop (DESIGN.md §2): train with fake-quant in JAX → calibrate →
export a pre-quantized artifact → the hardware compiler consumes it.  The
fake-quant forward matches the artifact semantics (symmetric, round-half-even,
saturate) so QAT "sees" serving-time numerics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fake_quant(x: jax.Array, scale, *, qmin: int = -128, qmax: int = 127, axis: Optional[int] = None) -> jax.Array:
    """quantize→dequantize with STE gradients (identity inside the clip range)."""
    s = jnp.asarray(scale, jnp.float32)
    if axis is not None and s.ndim:
        shape = [1] * x.ndim
        shape[axis] = -1
        s = s.reshape(shape)
    xf = x.astype(jnp.float32)
    q = jnp.clip(jnp.rint(xf / s), qmin, qmax)
    deq = (q * s).astype(x.dtype)
    # STE: forward = deq, backward = identity (with clip-range gating)
    gate = ((xf >= qmin * s) & (xf <= qmax * s)).astype(x.dtype)
    return x * gate + jax.lax.stop_gradient(deq - x * gate)


def fake_quant_weight_per_channel(w: jax.Array, *, axis: int = -1) -> jax.Array:
    """Per-output-channel symmetric weight fake-quant (scale from |w|max)."""
    red = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
    absmax = jax.lax.stop_gradient(jnp.abs(w.astype(jnp.float32)).max(axis=red, keepdims=True))
    s = jnp.maximum(absmax / 127.0, 1e-12)
    xf = w.astype(jnp.float32)
    q = jnp.clip(jnp.rint(xf / s), -128, 127)
    deq = (q * s).astype(w.dtype)
    return w + jax.lax.stop_gradient(deq - w)


def fake_quant_activation(x: jax.Array) -> jax.Array:
    """Dynamic per-tensor activation fake-quant (absmax scale)."""
    absmax = jax.lax.stop_gradient(jnp.abs(x.astype(jnp.float32)).max())
    s = jnp.maximum(absmax / 127.0, 1e-12)
    return fake_quant(x, s)


def qat_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """A linear layer as QAT sees it: int8-faithful weights and activations."""
    return fake_quant_activation(x) @ fake_quant_weight_per_channel(w)
