"""Calibration observers — the quantizer-side half of the co-design contract.

The paper (§3) notes multiple ways to determine ``scale_X``: profiling the
maximum numerical range, or building profile histograms and saturating the
range before mapping.  Because the quantization process is *separated* from
the hardware compilation stage, the choice of observer is free — these are
three standard ones.  All produce a single symmetric ``absmax`` estimate that
:func:`repro.core.quant.choose_scale` maps onto the integer range.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .quant import choose_scale, dequantize, quantize


class AbsMaxObserver:
    """Profile the maximum numerical range (paper's first suggested approach)."""

    def __init__(self) -> None:
        self.absmax = 0.0
        self.count = 0

    def observe(self, x: np.ndarray) -> None:
        if x.size:
            self.absmax = max(self.absmax, float(np.abs(x).max()))
            self.count += x.size

    def scale(self, dtype: str = "int8") -> float:
        return choose_scale(self.absmax, dtype)


class PercentileObserver:
    """Histogram-based range saturation (paper's second suggested approach).

    Keeps a fixed-width histogram of |x| and saturates the range at the given
    percentile before mapping onto the integer range.
    """

    def __init__(self, percentile: float = 99.99, bins: int = 2048) -> None:
        if not 0.0 < percentile <= 100.0:
            raise ValueError("percentile must be in (0, 100]")
        self.percentile = percentile
        self.bins = bins
        self._hist = np.zeros(bins, dtype=np.int64)
        self._width: Optional[float] = None

    def observe(self, x: np.ndarray) -> None:
        ax = np.abs(np.asarray(x, dtype=np.float32)).ravel()
        if not ax.size:
            return
        amax = float(ax.max())
        if self._width is None:
            self._width = max(amax, 1e-12) / self.bins
        if amax > self._width * self.bins:
            # Grow the histogram range by an integer factor, rebinning counts.
            factor = int(np.ceil(amax / (self._width * self.bins)))
            idx = np.arange(self.bins) // factor
            new_hist = np.zeros(self.bins, dtype=np.int64)
            np.add.at(new_hist, idx, self._hist)
            self._hist = new_hist
            self._width *= factor
        idx = np.minimum((ax / self._width).astype(np.int64), self.bins - 1)
        np.add.at(self._hist, idx, 1)

    def absmax(self) -> float:
        total = int(self._hist.sum())
        if total == 0 or self._width is None:
            return 0.0
        target = total * (self.percentile / 100.0)
        cum = np.cumsum(self._hist)
        bin_idx = int(np.searchsorted(cum, target))
        return float((bin_idx + 1) * self._width)

    def scale(self, dtype: str = "int8") -> float:
        return choose_scale(self.absmax(), dtype)


class MSEObserver:
    """Grid-search the saturation point that minimizes quantization MSE
    (the paper's "minimize the overall quantization error" approach)."""

    def __init__(self, num_candidates: int = 64, max_samples: int = 1 << 16) -> None:
        self.num_candidates = num_candidates
        self.max_samples = max_samples
        self._samples: list[np.ndarray] = []
        self._absmax = 0.0
        self._held = 0

    def observe(self, x: np.ndarray) -> None:
        x = np.asarray(x, dtype=np.float32).ravel()
        if not x.size:
            return
        self._absmax = max(self._absmax, float(np.abs(x).max()))
        if self._held < self.max_samples:
            take = min(x.size, self.max_samples - self._held)
            # Deterministic stride subsample to stay unbiased w.r.t. layout.
            stride = max(1, x.size // take)
            sub = x[::stride][:take]
            self._samples.append(sub)
            self._held += sub.size

    def absmax(self, dtype: str = "int8") -> float:
        if not self._samples or self._absmax == 0.0:
            return self._absmax
        data = np.concatenate(self._samples)
        best_amax, best_err = self._absmax, np.inf
        for frac in np.linspace(1.0 / self.num_candidates, 1.0, self.num_candidates):
            amax = self._absmax * float(frac)
            s = choose_scale(amax, dtype)
            err = float(np.mean((dequantize(quantize(data, s, dtype), s) - data) ** 2))
            if err < best_err:
                best_err, best_amax = err, amax
        return best_amax

    def scale(self, dtype: str = "int8") -> float:
        return choose_scale(self.absmax(dtype), dtype)


OBSERVERS = {
    "absmax": AbsMaxObserver,
    "percentile": PercentileObserver,
    "mse": MSEObserver,
}


@dataclasses.dataclass
class CalibrationResult:
    """Per-tensor activation scales keyed by tensor name."""

    scales: dict
    dtypes: dict

    def scale(self, name: str) -> float:
        return self.scales[name]


def make_observer(kind: str, **kwargs):
    try:
        return OBSERVERS[kind](**kwargs)
    except KeyError:
        raise ValueError(f"unknown observer kind {kind!r}; have {sorted(OBSERVERS)}") from None
