"""Small bounded LRU cache shared by the serving layers and the plan cache.

Both per-shape caches in the system — the serving engine's prefill-function
cache (keyed by prompt bucket) and the backend's :class:`~repro.backend.plan.
PlanCache` (keyed by sorted per-axis bucket bindings) — used to be plain
dicts that grew without bound under adversarial/long-tail traffic.  This is
the one eviction policy they share: least-recently-used, with
hit/miss/eviction counters and the single :attr:`LruCache.hit_rate`
accounting site, so every cache surfaces the same numbers in serving
metrics.
"""
from __future__ import annotations

import json
import os
import tempfile
from collections import OrderedDict
from typing import Any, Dict, Hashable, Optional

from ..obs import trace as _trace
from ..obs.metrics import MetricsRegistry


class LruCache:
    """Bounded mapping with least-recently-used eviction.

    ``get`` refreshes recency and counts a hit/miss; ``put`` inserts (or
    refreshes) and evicts the oldest entries beyond ``capacity``.  ``in`` /
    ``len`` are pure reads — they never touch recency or the counters.

    ``scope`` names the cache on the observability plane (``"plan"``,
    ``"prefill"``, ...): a scoped cache emits ``cache.<scope>.hit`` /
    ``.miss`` / ``.evict`` instant events when a tracer is installed, and
    :meth:`attach_metrics` registers the canonical ``cache.<scope>.<field>``
    gauges in a :class:`~repro.obs.metrics.MetricsRegistry`.  Unscoped
    caches never touch the obs plane.
    """

    def __init__(self, capacity: int = 8, *, scope: Optional[str] = None) -> None:
        if capacity < 1:
            raise ValueError(f"LruCache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.scope = scope
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Hashable, default: Any = None) -> Any:
        if key in self._entries:
            self.hits += 1
            if _trace.enabled and self.scope:
                _trace.event(f"cache.{self.scope}.hit", key=str(key))
            self._entries.move_to_end(key)
            return self._entries[key]
        self.misses += 1
        if _trace.enabled and self.scope:
            _trace.event(f"cache.{self.scope}.miss", key=str(key))
        return default

    def peek(self, key: Hashable, default: Any = None) -> Any:
        """Read an entry without touching hit/miss counters, recency order or
        the obs plane — for introspection (e.g. serializing the resident
        entries into an AOT artifact), never for the serving path."""
        return self._entries.get(key, default)

    def put(self, key: Hashable, value: Any) -> None:
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = value
        while len(self._entries) > self.capacity:
            evicted, _ = self._entries.popitem(last=False)
            self.evictions += 1
            if _trace.enabled and self.scope:
                _trace.event(f"cache.{self.scope}.evict", key=str(evicted))

    def attach_metrics(self, registry: MetricsRegistry, scope: Optional[str] = None) -> None:
        """Publish this cache's stats into ``registry`` under the canonical
        ``cache.<scope>.<field>`` keys (live callback gauges — snapshots
        always read the current counters, never a stale copy)."""
        scope = scope or self.scope
        if not scope:
            raise ValueError("attach_metrics needs a cache scope name")
        registry.attach_cache(scope, self)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        """Keys from least- to most-recently used (pure read)."""
        return list(self._entries.keys())

    @property
    def hit_rate(self) -> float:
        """hits / (hits + misses), 0.0 before any lookup.  This is the one
        place hit accounting turns into a rate — every cache consumer
        (CompiledModel.cache_stats, the serving engine's prefill metrics,
        the compiled-model server summary) surfaces this same number."""
        looked = self.hits + self.misses
        return (self.hits / looked) if looked else 0.0

    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "size": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }

    def __repr__(self) -> str:
        s = self.stats
        return (
            f"{type(self).__name__}(size={s['size']}/{s['capacity']}, "
            f"hits={s['hits']}, misses={s['misses']}, evictions={s['evictions']})"
        )


class PersistentJsonStore:
    """A string-keyed dict persisted as one schema-tagged JSON file.

    The on-disk co-design artifacts (the autotune tile cache, and anything
    shaped like it) share three requirements this class owns:

    * **diffable** — keys are written sorted with stable indentation, so two
      runs producing the same state produce byte-identical files and a tuned
      entry shows up as a clean one-hunk diff in review;
    * **atomic** — :meth:`save` writes to a temp file in the target directory
      and ``os.replace``\\ s it over the destination, so a crash mid-write can
      never leave a truncated artifact for the next process to warm-start
      from;
    * **schema-checked** — the file carries ``{"schema": ..., "entries":
      {...}}``; loading a file with a different schema tag raises instead of
      silently misreading a foreign format.

    A missing file is an empty store (the cold-start case).  ``put`` saves
    immediately — entries are few and each one cost real measurement time,
    so losing them to a crash would be the expensive failure mode.
    """

    def __init__(self, path: str, *, schema: str) -> None:
        self.path = str(path)
        self.schema = schema
        self.entries: Dict[str, Any] = {}
        self.load()

    def load(self) -> None:
        """(Re-)read the file; a missing file leaves the store empty."""
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            data = json.load(f)
        got = data.get("schema")
        if got != self.schema:
            raise ValueError(
                f"{self.path}: schema {got!r} does not match expected {self.schema!r}"
            )
        entries = data.get("entries", {})
        if not isinstance(entries, dict):
            raise ValueError(f"{self.path}: 'entries' must be an object")
        self.entries = entries

    def save(self) -> None:
        """Atomic write: temp file in the destination directory + rename."""
        payload = {"schema": self.schema, "entries": self.entries}
        d = os.path.dirname(os.path.abspath(self.path))
        fd, tmp = tempfile.mkstemp(prefix=".store-", suffix=".json", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get(self, key: str, default: Any = None) -> Any:
        return self.entries.get(str(key), default)

    def put(self, key: str, value: Any) -> None:
        self.entries[str(key)] = value
        self.save()

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return str(key) in self.entries
