"""PQ-IR: the pre-quantized model artifact (ONNX dialect).

This is the interchange format at the heart of the paper: a graph of
*standard ONNX operators only* with all quantization parameters embedded as
initializers (paper goals 1 & 3).  The container image has no ``onnx``
package, so the artifact is serialized as JSON with base64 raw tensor data —
the operator vocabulary, attribute names and dtype semantics follow the ONNX
spec exactly, so emitting protobuf instead would be a mechanical change
(see DESIGN.md §3, assumption 2).

Executability by "standard tools" (paper goal 2) is modeled by
:mod:`repro.core.runtime`, an op-by-op numpy interpreter with ONNX semantics —
our ONNXRuntime stand-in and the conformance oracle for every compiled
backend.
"""
from __future__ import annotations

import base64
import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Standard-operator vocabulary (paper goal 3: no custom operators).
# Names and semantics follow the ONNX operator set.
# ---------------------------------------------------------------------------
STANDARD_OPS = frozenset(
    {
        # quantized compute
        "MatMulInteger",
        "ConvInteger",
        # quant/dequant & rescale plumbing
        "QuantizeLinear",
        "DequantizeLinear",
        "Cast",
        "Mul",
        "Add",
        "Sub",
        "Div",
        # activations
        "Relu",
        "Tanh",
        "Sigmoid",
        "Softmax",
        "Erf",
        # float compute (for mixed-precision sections & fp32 baselines)
        "MatMul",
        "Gemm",
        "Conv",
        # shape plumbing
        "Reshape",
        "Transpose",
        "Flatten",
        "Concat",
        "Slice",
        "Gather",
        "Squeeze",
        "Unsqueeze",
        # pooling / norm
        "MaxPool",
        "AveragePool",
        "GlobalAveragePool",
        "ReduceMean",
        "ReduceMax",
        "ReduceSum",
        "Sqrt",
        "Pow",
        "Clip",
    }
)

DTYPES = {
    "float32": np.float32,
    "float16": np.float16,
    "int8": np.int8,
    "uint8": np.uint8,
    "int32": np.int32,
    "int64": np.int64,
    "bool": np.bool_,
}
_NP2NAME = {np.dtype(v): k for k, v in DTYPES.items()}


def dtype_name(arr_or_dtype) -> str:
    d = arr_or_dtype.dtype if hasattr(arr_or_dtype, "dtype") else np.dtype(arr_or_dtype)
    try:
        return _NP2NAME[np.dtype(d)]
    except KeyError:
        raise ValueError(f"unsupported dtype {d}") from None


@dataclasses.dataclass
class TensorInfo:
    name: str
    dtype: str
    shape: Tuple[Optional[int], ...]

    def to_json(self) -> dict:
        return {"name": self.name, "dtype": self.dtype, "shape": list(self.shape)}

    @staticmethod
    def from_json(d: dict) -> "TensorInfo":
        return TensorInfo(d["name"], d["dtype"], tuple(d["shape"]))


@dataclasses.dataclass
class Node:
    op_type: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    name: str = ""

    def to_json(self) -> dict:
        return {
            "op_type": self.op_type,
            "inputs": list(self.inputs),
            "outputs": list(self.outputs),
            "attrs": _attrs_to_json(self.attrs),
            "name": self.name,
        }

    @staticmethod
    def from_json(d: dict) -> "Node":
        return Node(d["op_type"], list(d["inputs"]), list(d["outputs"]), _attrs_from_json(d.get("attrs", {})), d.get("name", ""))


def _attrs_to_json(attrs: Dict[str, Any]) -> dict:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, np.ndarray):
            out[k] = {"__tensor__": _encode_array(v)}
        elif isinstance(v, (np.integer,)):
            out[k] = int(v)
        elif isinstance(v, (np.floating,)):
            out[k] = float(v)
        elif isinstance(v, (list, tuple)):
            out[k] = [int(x) if isinstance(x, (np.integer, int)) else x for x in v]
        else:
            out[k] = v
    return out


def _attrs_from_json(attrs: dict) -> Dict[str, Any]:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, dict) and "__tensor__" in v:
            out[k] = _decode_array(v["__tensor__"])
        else:
            out[k] = v
    return out


def _encode_array(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {
        "dtype": dtype_name(a),
        "shape": list(a.shape),
        "data": base64.b64encode(a.tobytes()).decode("ascii"),
    }


def _decode_array(d: dict) -> np.ndarray:
    raw = base64.b64decode(d["data"])
    return np.frombuffer(raw, dtype=DTYPES[d["dtype"]]).reshape(d["shape"]).copy()


@dataclasses.dataclass
class StateSpec:
    """A named state slot: a (graph input, graph output) pair the runtime
    carries across invocations — ONNX's past/present KV-cache convention
    (``past_key_values.*`` → ``present.*``) codified on the graph itself.

    The graph stays purely functional: a state is *declared*, not mutated.
    Each invocation reads the current state through ``input`` and produces
    the next state at ``output``; the serving layer (or the plan executor)
    feeds each ``output`` back into its ``input`` on the next call.  Both
    ends are ordinary declared tensors, so every standard tool that ignores
    ``states`` still executes the graph correctly one call at a time."""

    name: str
    input: str
    output: str

    def to_json(self) -> dict:
        return {"name": self.name, "input": self.input, "output": self.output}

    @staticmethod
    def from_json(d: dict) -> "StateSpec":
        return StateSpec(d["name"], d["input"], d["output"])


@dataclasses.dataclass
class Graph:
    name: str
    inputs: List[TensorInfo]
    outputs: List[TensorInfo]
    nodes: List[Node]
    initializers: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    states: List[StateSpec] = dataclasses.field(default_factory=list)

    # -- validation ---------------------------------------------------------
    def validate(self, *, standard_ops_only: bool = True) -> None:
        """Structural validation + paper-goal-3 check (standard ops only).

        Rejects: non-standard ops, duplicate graph input/output names, graph
        inputs shadowing initializers, any tensor produced twice, node inputs
        that are neither graph inputs, initializers, nor produced by any node
        (checked order-independently — the node list need not be topologically
        sorted), and cyclic graphs."""
        seen_inputs = set()
        for t in self.inputs:
            if t.name in seen_inputs:
                raise ValueError(f"duplicate graph input {t.name!r}")
            if t.name in self.initializers:
                raise ValueError(f"graph input {t.name!r} shadows an initializer")
            seen_inputs.add(t.name)
        produced = set(seen_inputs) | set(self.initializers)
        for node in self.nodes:
            if standard_ops_only and node.op_type not in STANDARD_OPS:
                raise ValueError(
                    f"non-standard operator {node.op_type!r} in node {node.name!r} "
                    "(paper goal 3 forbids custom operators)"
                )
            for o in node.outputs:
                if o in produced:
                    raise ValueError(f"tensor {o!r} produced twice")
                produced.add(o)
        for node in self.nodes:
            for i in node.inputs:
                if i and i not in produced:
                    raise ValueError(
                        f"node {node.name!r} consumes undefined tensor {i!r} "
                        "(not a graph input, initializer, or any node's output)"
                    )
        seen_outputs = set()
        for t in self.outputs:
            if t.name in seen_outputs:
                raise ValueError(f"duplicate graph output {t.name!r}")
            seen_outputs.add(t.name)
            if t.name not in produced:
                raise ValueError(f"graph output {t.name!r} never produced")
        in_specs = {t.name: t for t in self.inputs}
        out_specs = {t.name: t for t in self.outputs}
        seen_states: set = set()
        for s in self.states:
            if s.name in seen_states:
                raise ValueError(f"duplicate state {s.name!r}")
            seen_states.add(s.name)
            ti, to = in_specs.get(s.input), out_specs.get(s.output)
            if ti is None:
                raise ValueError(f"state {s.name!r} reads {s.input!r}, which is not a graph input")
            if to is None:
                raise ValueError(f"state {s.name!r} writes {s.output!r}, which is not a graph output")
            if ti.dtype != to.dtype:
                raise ValueError(
                    f"state {s.name!r} dtype mismatch: reads {ti.dtype}, writes {to.dtype}"
                )
            if len(ti.shape) != len(to.shape):
                raise ValueError(
                    f"state {s.name!r} rank mismatch: reads {ti.shape}, writes {to.shape}"
                )
        self.toposorted()  # raises on cycles

    def toposorted(self) -> List[Node]:
        """Nodes in executable order (stable Kahn topo-sort)."""
        produced = {t.name for t in self.inputs} | set(self.initializers)
        remaining = list(self.nodes)
        ordered: List[Node] = []
        while remaining:
            progressed = False
            nxt = []
            for node in remaining:
                if all((not i) or (i in produced) for i in node.inputs):
                    ordered.append(node)
                    produced.update(node.outputs)
                    progressed = True
                else:
                    nxt.append(node)
            remaining = nxt
            if not progressed:
                bad = [n.name or n.op_type for n in remaining]
                raise ValueError(f"graph has a cycle or missing producers: {bad}")
        return ordered

    def consumers(self) -> Dict[str, List[Node]]:
        out: Dict[str, List[Node]] = {}
        for node in self.nodes:
            for i in node.inputs:
                if i:
                    out.setdefault(i, []).append(node)
        return out

    def producers(self) -> Dict[str, Node]:
        out: Dict[str, Node] = {}
        for node in self.nodes:
            for o in node.outputs:
                out[o] = node
        return out

    def to_json(self) -> dict:
        doc = {
            "name": self.name,
            "inputs": [t.to_json() for t in self.inputs],
            "outputs": [t.to_json() for t in self.outputs],
            "nodes": [n.to_json() for n in self.nodes],
            "initializers": {k: _encode_array(v) for k, v in self.initializers.items()},
        }
        if self.states:  # stateless graphs stay byte-identical to pre-state JSON
            doc["states"] = [s.to_json() for s in self.states]
        return doc

    @staticmethod
    def from_json(d: dict) -> "Graph":
        return Graph(
            name=d["name"],
            inputs=[TensorInfo.from_json(t) for t in d["inputs"]],
            outputs=[TensorInfo.from_json(t) for t in d["outputs"]],
            nodes=[Node.from_json(n) for n in d["nodes"]],
            initializers={k: _decode_array(v) for k, v in d.get("initializers", {}).items()},
            states=[StateSpec.from_json(s) for s in d.get("states", [])],
        )


@dataclasses.dataclass
class Model:
    """Top-level artifact.  ``metadata`` carries provenance only — NO
    quantization parameters live here (paper goal 1: everything needed to run
    is embedded in the graph itself)."""

    graph: Graph
    opset: int = 13
    ir_version: int = 8
    producer: str = "repro-pqir"
    metadata: Dict[str, str] = dataclasses.field(default_factory=dict)

    def validate(self, *, standard_ops_only: bool = True) -> None:
        self.graph.validate(standard_ops_only=standard_ops_only)

    def to_json(self) -> dict:
        return {
            "ir_version": self.ir_version,
            "opset": self.opset,
            "producer": self.producer,
            "metadata": dict(self.metadata),
            "graph": self.graph.to_json(),
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    @staticmethod
    def from_json(d: dict) -> "Model":
        return Model(
            graph=Graph.from_json(d["graph"]),
            opset=d.get("opset", 13),
            ir_version=d.get("ir_version", 8),
            producer=d.get("producer", ""),
            metadata=d.get("metadata", {}),
        )

    @staticmethod
    def load(path: str) -> "Model":
        with open(path) as f:
            return Model.from_json(json.load(f))


class GraphBuilder:
    """Convenience builder used by :mod:`repro.core.patterns` and the exporter."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.inputs: List[TensorInfo] = []
        self.outputs: List[TensorInfo] = []
        self.nodes: List[Node] = []
        self.initializers: Dict[str, np.ndarray] = {}
        self.states: List[StateSpec] = []
        self._counter = 0

    def fresh(self, hint: str) -> str:
        self._counter += 1
        return f"{hint}_{self._counter}"

    def add_input(self, name: str, dtype: str, shape: Sequence[Optional[int]]) -> str:
        self.inputs.append(TensorInfo(name, dtype, tuple(shape)))
        return name

    def add_output(self, name: str, dtype: str, shape: Sequence[Optional[int]]) -> str:
        self.outputs.append(TensorInfo(name, dtype, tuple(shape)))
        return name

    def add_initializer(self, name: str, value: np.ndarray) -> str:
        if name in self.initializers:
            raise ValueError(f"initializer {name!r} already exists")
        self.initializers[name] = np.asarray(value)
        return name

    def add_node(self, op_type: str, inputs: Iterable[str], outputs: Iterable[str], name: str = "", **attrs) -> Node:
        node = Node(op_type, list(inputs), list(outputs), attrs, name or self.fresh(op_type.lower()))
        self.nodes.append(node)
        return node

    def op(self, op_type: str, inputs: Iterable[str], out_hint: str = "t", name: str = "", **attrs) -> str:
        """Add a single-output node, returning the fresh output tensor name."""
        out = self.fresh(out_hint)
        self.add_node(op_type, inputs, [out], name=name, **attrs)
        return out

    def add_state(self, name: str, input: str, output: str) -> StateSpec:
        """Declare a persistent state slot pairing an existing graph input
        (the incoming state) with an existing graph output (the next state)."""
        spec = StateSpec(name, input, output)
        self.states.append(spec)
        return spec

    def build(self, validate: bool = True, **model_kwargs) -> Model:
        g = Graph(self.name, self.inputs, self.outputs, self.nodes, self.initializers, states=self.states)
        m = Model(graph=g, **model_kwargs)
        if validate:
            m.validate()
        return m
