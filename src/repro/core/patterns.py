"""Builders for the paper's canonical pre-quantized ONNX patterns (Figs 1–6).

Each builder emits exactly the operator sequence shown in the paper into a
:class:`repro.core.pqir.GraphBuilder`:

* Fig 1 — FC, rescale as **two** Mul ops (integer Quant_scale + 2**-N shift)
* Fig 2 — FC + ReLU, rescale as **one** Mul op
* Fig 3 — Conv2D, rescale as one Mul op
* Fig 4 — FC + int8 Tanh (rescale maps accumulator onto tanh's input range,
  y_scale maps int8 onto tanh's output range)
* Fig 5 — FC + fp16 Tanh (mixed int8/fp16 flow)
* Fig 6 — FC + fp16 Sigmoid (output uint8, sigmoid ≥ 0)

The rounding/clipping stage is always ``QuantizeLinear(scale=1, zero_point=0)``
whose *zero_point dtype selects the output dtype* — exactly the paper's usage.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .pqir import GraphBuilder
from .quant import QuantizedLinearParams, Rescale

# Default activation-range conventions for the Fig.4–6 patterns.
TANH_INPUT_ABSMAX = 4.0  # |tanh(4)| ≈ 0.9993: "full input range of tanh"
SIGMOID_INPUT_ABSMAX = 8.0

# Attention-region codification constants, shared by the emitter below, the
# kernel oracle (repro.kernels.ref.qattention_ref), the Pallas kernel and the
# compiler's region matcher.  The chain is bit-exact only because all four
# agree on these values and on the op order.
ATTN_BIG = 30000.0  # additive penalty driving masked scores below any real one
ATTN_LUT_SCALE = 0.125  # score-delta quantization step; must keep lut[0] == 0
ATTN_P_SCALE = 127.0  # probability quantization scale


def build_exp_lut(lut_scale: float = ATTN_LUT_SCALE) -> np.ndarray:
    """The 256-entry uint8 exp table the attention region gathers from:
    ``lut[i] = round(exp((i - 128) · lut_scale) · 255)`` clipped to uint8.
    Index 128 (score delta 0, the row max) maps to 255.  Index 0 (a delta
    clipped at −128 steps — masked or far-off keys) must map to exactly 0:
    that is what makes zero-padded keys contribute nothing to the softmax
    denominator, i.e. what makes bucket padding bit-exact."""
    i = np.arange(256, dtype=np.float64)
    vals = np.rint(np.exp(np.minimum(i - 128.0, 0.0) * float(lut_scale)) * 255.0)
    lut = np.clip(vals, 0, 255).astype(np.uint8)
    if lut[0] != 0:
        raise ValueError(
            f"lut_scale={lut_scale} too small: lut[0]={lut[0]} != 0 breaks "
            "zero-padding exactness (need exp(-128*scale)*255 < 0.5)"
        )
    return lut


def emit_qattention(
    gb: GraphBuilder,
    q: str,  # ("N", S, dh) int8 per-head queries
    k: str,  # ("N", T, dh) int8 per-head keys
    v: str,  # ("N", T, dh) int8 per-head values
    mask: str,  # ("N", S, T) f32 {0, 1} validity/causality mask
    prefix: str,
    *,
    qk_scale: float,  # s_q * s_k / sqrt(dh)
    rescale: float,  # s_v / (p_scale * s_out)
    big: float = ATTN_BIG,
    lut_scale: float = ATTN_LUT_SCALE,
    p_scale: float = ATTN_P_SCALE,
    out_dtype: str = "int8",
) -> str:
    """The codified int8 attention region: MatMulInteger score accumulation,
    additive {0, −big} masking, max-shifted LUT-softmax (exp as a 256-entry
    uint8 Gather — no transcendentals anywhere in the artifact), integer
    renormalization, and a second MatMulInteger against V.  Every op is
    integer or IEEE-exact f32 elementwise, so the region evaluates bit-
    identically on the numpy reference runtime, the jnp oracle and the fused
    Pallas kernel — which is what lets the compiler collapse all ~25 nodes
    into one ``qattention`` kernel step without a tolerance budget.

    Returns the int8 per-head context tensor name."""
    kt = gb.op("Transpose", [k], out_hint=f"{prefix}_kT", perm=[0, 2, 1])
    acc = gb.op("MatMulInteger", [q, kt], out_hint=f"{prefix}_scores_acc")
    f = gb.op("Cast", [acc], out_hint=f"{prefix}_scores_f32", to="float32")
    c = gb.add_initializer(f"{prefix}_qk_scale", np.float32(qk_scale))
    f = gb.op("Mul", [f, c], out_hint=f"{prefix}_scores")
    sm = gb.op("Mul", [f, mask], out_hint=f"{prefix}_scores_masked")
    one = gb.add_initializer(f"{prefix}_one", np.float32(1.0))
    big_c = gb.add_initializer(f"{prefix}_big", np.float32(big))
    pen = gb.op("Sub", [mask, one], out_hint=f"{prefix}_mask_m1")
    pen = gb.op("Mul", [pen, big_c], out_hint=f"{prefix}_penalty")
    masked = gb.op("Add", [sm, pen], out_hint=f"{prefix}_masked")
    mx = gb.op("ReduceMax", [masked], out_hint=f"{prefix}_rowmax", axes=[2], keepdims=1)
    d = gb.op("Sub", [masked, mx], out_hint=f"{prefix}_delta")
    ls = gb.add_initializer(f"{prefix}_lut_scale", np.float32(lut_scale))
    zp8 = gb.add_initializer(f"{prefix}_zp_i8", np.zeros((), dtype="int8"))
    dq = gb.op("QuantizeLinear", [d, ls, zp8], out_hint=f"{prefix}_delta_q")
    idx = gb.op("Cast", [dq], out_hint=f"{prefix}_idx32", to="int32")
    off = gb.add_initializer(f"{prefix}_idx_off", np.int32(128))
    idx = gb.op("Add", [idx, off], out_hint=f"{prefix}_idx")
    lut = gb.add_initializer(f"{prefix}_exp_lut", build_exp_lut(lut_scale))
    w = gb.op("Gather", [lut, idx], out_hint=f"{prefix}_w", axis=0)
    wi = gb.op("Cast", [w], out_hint=f"{prefix}_w_i32", to="int32")
    den = gb.op("ReduceSum", [wi], out_hint=f"{prefix}_den", axes=[2], keepdims=1)
    denf = gb.op("Cast", [den], out_hint=f"{prefix}_den_f32", to="float32")
    wf = gb.op("Cast", [w], out_hint=f"{prefix}_w_f32", to="float32")
    p = gb.op("Div", [wf, denf], out_hint=f"{prefix}_p")
    ps = gb.add_initializer(f"{prefix}_p_scale", np.float32(p_scale))
    pf = gb.op("Mul", [p, ps], out_hint=f"{prefix}_p_scaled")
    one_q = gb.add_initializer(f"{prefix}_pq_scale", np.float32(1.0))
    pq = gb.op("QuantizeLinear", [pf, one_q, zp8], out_hint=f"{prefix}_p_q")
    ctx = gb.op("MatMulInteger", [pq, v], out_hint=f"{prefix}_ctx_acc")
    cf = gb.op("Cast", [ctx], out_hint=f"{prefix}_ctx_f32", to="float32")
    r = gb.add_initializer(f"{prefix}_att_rescale", np.float32(rescale))
    cf = gb.op("Mul", [cf, r], out_hint=f"{prefix}_ctx_scaled")
    out_zp = gb.add_initializer(f"{prefix}_out_zp", np.zeros((), dtype=out_dtype))
    return gb.op("QuantizeLinear", [cf, one_q, out_zp], out_hint=f"{prefix}_ctx_q")


def _codify_scale(value, channel_tail: int) -> np.ndarray:
    """A rescale constant as codified in the artifact: a f32 scalar, or — per
    channel — a f32 vector reshaped to broadcast along the output-feature
    axis (``channel_tail`` trailing singleton dims: 0 for FC's (..., N)
    accumulators, 2 for conv's NCHW)."""
    v = np.asarray(value, np.float32)
    if v.ndim == 0:
        return v
    return v.reshape((-1,) + (1,) * channel_tail)


def emit_rescale(
    gb: GraphBuilder,
    x: str,
    rescale: Rescale,
    prefix: str,
    *,
    two_mul: bool = True,
    channel_tail: int = 0,
) -> str:
    """Cast(int32→f32) then the §3.1 codification: 2 Muls (integer scale +
    right-shift) or 1 Mul (plain fp32 multiplier).

    ``rescale`` may be a per-channel :class:`repro.core.quant.RescaleVector`,
    in which case the Mul constants are vectors along the output-feature axis
    (``channel_tail`` positions the channel axis for conv's NCHW layout)."""
    f = gb.op("Cast", [x], out_hint=f"{prefix}_f32", to="float32")
    if two_mul:
        qs = gb.add_initializer(f"{prefix}_quant_scale", _codify_scale(rescale.quant_scale, channel_tail))
        sh = gb.add_initializer(f"{prefix}_quant_shift", _codify_scale(rescale.quant_shift, channel_tail))
        f = gb.op("Mul", [f, qs], out_hint=f"{prefix}_scaled")
        f = gb.op("Mul", [f, sh], out_hint=f"{prefix}_shifted")
    else:
        m = gb.add_initializer(f"{prefix}_quant_multiplier", _codify_scale(rescale.multiplier, channel_tail))
        f = gb.op("Mul", [f, m], out_hint=f"{prefix}_scaled")
    return f


def emit_round_clip(gb: GraphBuilder, x: str, prefix: str, out_dtype: str = "int8") -> str:
    """QuantizeLinear(scale=1, zp=0) — pure rounding+clipping; zp dtype picks
    the output dtype (int8 vs uint8), per the paper."""
    one = gb.add_initializer(f"{prefix}_ql_scale", np.float32(1.0))
    zp = gb.add_initializer(f"{prefix}_ql_zp", np.zeros((), dtype=out_dtype))
    return gb.op("QuantizeLinear", [x, one, zp], out_hint=f"{prefix}_q")


def fc_layer(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    two_mul: bool = True,
    activation: Optional[str] = None,
) -> str:
    """Fig 1 (activation=None, two_mul=True) / Fig 2 (activation="Relu",
    two_mul=False) fully-connected pattern.  Returns the int8/uint8 output
    tensor name.

    Sub-8-bit weights (``p.bits == 4``) codify QONNX-style: the weight
    initializer stays an (unpacked) int8 tensor with values in [-8, 7] and
    the bitwidth rides as a ``weight_bits`` attribute on the integer-matmul
    node — the reference runtime ignores it, the compiler packs on it."""
    w = gb.add_initializer(f"{prefix}_weight_q", p.weight_q)
    attrs = {"weight_bits": p.bits} if p.bits != 8 else {}
    acc = gb.op("MatMulInteger", [x, w], out_hint=f"{prefix}_acc", **attrs)
    if p.bias_q is not None:
        b = gb.add_initializer(f"{prefix}_bias_q", p.bias_q)
        acc = gb.op("Add", [acc, b], out_hint=f"{prefix}_biased")
    f = emit_rescale(gb, acc, p.rescale, prefix, two_mul=two_mul)
    if activation is not None:
        f = gb.op(activation, [f], out_hint=f"{prefix}_{activation.lower()}")
    return emit_round_clip(gb, f, prefix, p.out_dtype)


def fc_layer_gemm(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    two_mul: bool = True,
    activation: Optional[str] = None,
    trans_b: bool = False,
) -> str:
    """The Fig 1/2 pattern as some MLP exporters codify it: one integer
    ``Gemm`` (X @ W [+ B], int32 accumulation, alpha = beta = 1) instead of
    MatMulInteger + Add.  Compiles onto the same fused qlinear kernel."""
    w_q = p.weight_q.T if trans_b else p.weight_q
    w = gb.add_initializer(f"{prefix}_weight_q", np.ascontiguousarray(w_q))
    ins = [x, w]
    if p.bias_q is not None:
        ins.append(gb.add_initializer(f"{prefix}_bias_q", p.bias_q))
    attrs = {"transB": 1} if trans_b else {}
    if p.bits != 8:
        attrs["weight_bits"] = p.bits
    acc = gb.op("Gemm", ins, out_hint=f"{prefix}_acc", **attrs)
    f = emit_rescale(gb, acc, p.rescale, prefix, two_mul=two_mul)
    if activation is not None:
        f = gb.op(activation, [f], out_hint=f"{prefix}_{activation.lower()}")
    return emit_round_clip(gb, f, prefix, p.out_dtype)


def conv_layer(
    gb: GraphBuilder,
    x: str,
    weight_q: np.ndarray,
    bias_q: Optional[np.ndarray],
    rescale: Rescale,
    prefix: str,
    *,
    strides=(1, 1),
    pads=(0, 0, 0, 0),
    two_mul: bool = False,
    activation: Optional[str] = None,
    out_dtype: str = "int8",
    weight_bits: int = 8,
) -> str:
    """Fig 3 convolution pattern.  ``weight_q`` is (M, C, kH, kW) int8;
    ``bias_q`` is int32 (M,), added broadcast as (1, M, 1, 1).  ``rescale``
    may be per-channel (one multiplier per output channel M).  ``weight_bits``
    rides as a node attribute like the FC builders (conv stays unpacked —
    only the matmul lane has a packed kernel today)."""
    w = gb.add_initializer(f"{prefix}_weight_q", weight_q)
    attrs = {"weight_bits": weight_bits} if weight_bits != 8 else {}
    acc = gb.op(
        "ConvInteger", [x, w], out_hint=f"{prefix}_acc",
        strides=list(strides), pads=list(pads), **attrs,
    )
    if bias_q is not None:
        b = gb.add_initializer(f"{prefix}_bias_q", bias_q.reshape(1, -1, 1, 1).astype(np.int32))
        acc = gb.op("Add", [acc, b], out_hint=f"{prefix}_biased")
    f = emit_rescale(gb, acc, rescale, prefix, two_mul=two_mul, channel_tail=2)
    if activation is not None:
        f = gb.op(activation, [f], out_hint=f"{prefix}_{activation.lower()}")
    return emit_round_clip(gb, f, prefix, out_dtype)


def _dql(gb: GraphBuilder, x: str, scale: float, prefix: str) -> str:
    s = gb.add_initializer(f"{prefix}_dq_scale", np.float32(scale))
    zp = gb.add_initializer(f"{prefix}_dq_zp", np.zeros((), dtype="int8"))
    return gb.op("DequantizeLinear", [x, s, zp], out_hint=f"{prefix}_deq")


def _ql(gb: GraphBuilder, x: str, scale: float, prefix: str, out_dtype: str) -> str:
    s = gb.add_initializer(f"{prefix}_q_scale", np.float32(scale))
    zp = gb.add_initializer(f"{prefix}_q_zp", np.zeros((), dtype=out_dtype))
    return gb.op("QuantizeLinear", [x, s, zp], out_hint=f"{prefix}_req")


def fc_int8_tanh(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    input_absmax: float = TANH_INPUT_ABSMAX,
) -> str:
    """Fig 4: int8 tanh.  The FC rescale maps the accumulator onto the full
    int8-quantized tanh input range [−input_absmax, +input_absmax]; y_scale
    maps int8 onto tanh's output range (−1, 1)."""
    q = fc_layer(gb, x, p, prefix, two_mul=True)
    deq = _dql(gb, q, input_absmax / 127.0, prefix)
    t = gb.op("Tanh", [deq], out_hint=f"{prefix}_tanh")
    return _ql(gb, t, 1.0 / 127.0, prefix, "int8")


def fc_fp16_tanh(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    input_absmax: float = TANH_INPUT_ABSMAX,
) -> str:
    """Fig 5: mixed int8/fp16 tanh flow (Cast→f16, Tanh in f16, Cast→f32)."""
    q = fc_layer(gb, x, p, prefix, two_mul=True)
    deq = _dql(gb, q, input_absmax / 127.0, prefix)
    h = gb.op("Cast", [deq], out_hint=f"{prefix}_f16", to="float16")
    t = gb.op("Tanh", [h], out_hint=f"{prefix}_tanh16")
    f = gb.op("Cast", [t], out_hint=f"{prefix}_back32", to="float32")
    return _ql(gb, f, 1.0 / 127.0, prefix, "int8")


def fc_fp16_sigmoid(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    input_absmax: float = SIGMOID_INPUT_ABSMAX,
) -> str:
    """Fig 6: mixed int8/fp16 sigmoid; single-Mul rescale; **uint8** output
    (sigmoid is always positive)."""
    q = fc_layer(gb, x, p, prefix, two_mul=False)
    deq = _dql(gb, q, input_absmax / 127.0, prefix)
    h = gb.op("Cast", [deq], out_hint=f"{prefix}_f16", to="float16")
    s = gb.op("Sigmoid", [h], out_hint=f"{prefix}_sig16")
    f = gb.op("Cast", [s], out_hint=f"{prefix}_back32", to="float32")
    return _ql(gb, f, 1.0 / 255.0, prefix, "uint8")
