"""Builders for the paper's canonical pre-quantized ONNX patterns (Figs 1–6).

Each builder emits exactly the operator sequence shown in the paper into a
:class:`repro.core.pqir.GraphBuilder`:

* Fig 1 — FC, rescale as **two** Mul ops (integer Quant_scale + 2**-N shift)
* Fig 2 — FC + ReLU, rescale as **one** Mul op
* Fig 3 — Conv2D, rescale as one Mul op
* Fig 4 — FC + int8 Tanh (rescale maps accumulator onto tanh's input range,
  y_scale maps int8 onto tanh's output range)
* Fig 5 — FC + fp16 Tanh (mixed int8/fp16 flow)
* Fig 6 — FC + fp16 Sigmoid (output uint8, sigmoid ≥ 0)

The rounding/clipping stage is always ``QuantizeLinear(scale=1, zero_point=0)``
whose *zero_point dtype selects the output dtype* — exactly the paper's usage.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .pqir import GraphBuilder
from .quant import QuantizedLinearParams, Rescale

# Default activation-range conventions for the Fig.4–6 patterns.
TANH_INPUT_ABSMAX = 4.0  # |tanh(4)| ≈ 0.9993: "full input range of tanh"
SIGMOID_INPUT_ABSMAX = 8.0


def _codify_scale(value, channel_tail: int) -> np.ndarray:
    """A rescale constant as codified in the artifact: a f32 scalar, or — per
    channel — a f32 vector reshaped to broadcast along the output-feature
    axis (``channel_tail`` trailing singleton dims: 0 for FC's (..., N)
    accumulators, 2 for conv's NCHW)."""
    v = np.asarray(value, np.float32)
    if v.ndim == 0:
        return v
    return v.reshape((-1,) + (1,) * channel_tail)


def emit_rescale(
    gb: GraphBuilder,
    x: str,
    rescale: Rescale,
    prefix: str,
    *,
    two_mul: bool = True,
    channel_tail: int = 0,
) -> str:
    """Cast(int32→f32) then the §3.1 codification: 2 Muls (integer scale +
    right-shift) or 1 Mul (plain fp32 multiplier).

    ``rescale`` may be a per-channel :class:`repro.core.quant.RescaleVector`,
    in which case the Mul constants are vectors along the output-feature axis
    (``channel_tail`` positions the channel axis for conv's NCHW layout)."""
    f = gb.op("Cast", [x], out_hint=f"{prefix}_f32", to="float32")
    if two_mul:
        qs = gb.add_initializer(f"{prefix}_quant_scale", _codify_scale(rescale.quant_scale, channel_tail))
        sh = gb.add_initializer(f"{prefix}_quant_shift", _codify_scale(rescale.quant_shift, channel_tail))
        f = gb.op("Mul", [f, qs], out_hint=f"{prefix}_scaled")
        f = gb.op("Mul", [f, sh], out_hint=f"{prefix}_shifted")
    else:
        m = gb.add_initializer(f"{prefix}_quant_multiplier", _codify_scale(rescale.multiplier, channel_tail))
        f = gb.op("Mul", [f, m], out_hint=f"{prefix}_scaled")
    return f


def emit_round_clip(gb: GraphBuilder, x: str, prefix: str, out_dtype: str = "int8") -> str:
    """QuantizeLinear(scale=1, zp=0) — pure rounding+clipping; zp dtype picks
    the output dtype (int8 vs uint8), per the paper."""
    one = gb.add_initializer(f"{prefix}_ql_scale", np.float32(1.0))
    zp = gb.add_initializer(f"{prefix}_ql_zp", np.zeros((), dtype=out_dtype))
    return gb.op("QuantizeLinear", [x, one, zp], out_hint=f"{prefix}_q")


def fc_layer(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    two_mul: bool = True,
    activation: Optional[str] = None,
) -> str:
    """Fig 1 (activation=None, two_mul=True) / Fig 2 (activation="Relu",
    two_mul=False) fully-connected pattern.  Returns the int8/uint8 output
    tensor name.

    Sub-8-bit weights (``p.bits == 4``) codify QONNX-style: the weight
    initializer stays an (unpacked) int8 tensor with values in [-8, 7] and
    the bitwidth rides as a ``weight_bits`` attribute on the integer-matmul
    node — the reference runtime ignores it, the compiler packs on it."""
    w = gb.add_initializer(f"{prefix}_weight_q", p.weight_q)
    attrs = {"weight_bits": p.bits} if p.bits != 8 else {}
    acc = gb.op("MatMulInteger", [x, w], out_hint=f"{prefix}_acc", **attrs)
    if p.bias_q is not None:
        b = gb.add_initializer(f"{prefix}_bias_q", p.bias_q)
        acc = gb.op("Add", [acc, b], out_hint=f"{prefix}_biased")
    f = emit_rescale(gb, acc, p.rescale, prefix, two_mul=two_mul)
    if activation is not None:
        f = gb.op(activation, [f], out_hint=f"{prefix}_{activation.lower()}")
    return emit_round_clip(gb, f, prefix, p.out_dtype)


def fc_layer_gemm(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    two_mul: bool = True,
    activation: Optional[str] = None,
    trans_b: bool = False,
) -> str:
    """The Fig 1/2 pattern as some MLP exporters codify it: one integer
    ``Gemm`` (X @ W [+ B], int32 accumulation, alpha = beta = 1) instead of
    MatMulInteger + Add.  Compiles onto the same fused qlinear kernel."""
    w_q = p.weight_q.T if trans_b else p.weight_q
    w = gb.add_initializer(f"{prefix}_weight_q", np.ascontiguousarray(w_q))
    ins = [x, w]
    if p.bias_q is not None:
        ins.append(gb.add_initializer(f"{prefix}_bias_q", p.bias_q))
    attrs = {"transB": 1} if trans_b else {}
    if p.bits != 8:
        attrs["weight_bits"] = p.bits
    acc = gb.op("Gemm", ins, out_hint=f"{prefix}_acc", **attrs)
    f = emit_rescale(gb, acc, p.rescale, prefix, two_mul=two_mul)
    if activation is not None:
        f = gb.op(activation, [f], out_hint=f"{prefix}_{activation.lower()}")
    return emit_round_clip(gb, f, prefix, p.out_dtype)


def conv_layer(
    gb: GraphBuilder,
    x: str,
    weight_q: np.ndarray,
    bias_q: Optional[np.ndarray],
    rescale: Rescale,
    prefix: str,
    *,
    strides=(1, 1),
    pads=(0, 0, 0, 0),
    two_mul: bool = False,
    activation: Optional[str] = None,
    out_dtype: str = "int8",
    weight_bits: int = 8,
) -> str:
    """Fig 3 convolution pattern.  ``weight_q`` is (M, C, kH, kW) int8;
    ``bias_q`` is int32 (M,), added broadcast as (1, M, 1, 1).  ``rescale``
    may be per-channel (one multiplier per output channel M).  ``weight_bits``
    rides as a node attribute like the FC builders (conv stays unpacked —
    only the matmul lane has a packed kernel today)."""
    w = gb.add_initializer(f"{prefix}_weight_q", weight_q)
    attrs = {"weight_bits": weight_bits} if weight_bits != 8 else {}
    acc = gb.op(
        "ConvInteger", [x, w], out_hint=f"{prefix}_acc",
        strides=list(strides), pads=list(pads), **attrs,
    )
    if bias_q is not None:
        b = gb.add_initializer(f"{prefix}_bias_q", bias_q.reshape(1, -1, 1, 1).astype(np.int32))
        acc = gb.op("Add", [acc, b], out_hint=f"{prefix}_biased")
    f = emit_rescale(gb, acc, rescale, prefix, two_mul=two_mul, channel_tail=2)
    if activation is not None:
        f = gb.op(activation, [f], out_hint=f"{prefix}_{activation.lower()}")
    return emit_round_clip(gb, f, prefix, out_dtype)


def _dql(gb: GraphBuilder, x: str, scale: float, prefix: str) -> str:
    s = gb.add_initializer(f"{prefix}_dq_scale", np.float32(scale))
    zp = gb.add_initializer(f"{prefix}_dq_zp", np.zeros((), dtype="int8"))
    return gb.op("DequantizeLinear", [x, s, zp], out_hint=f"{prefix}_deq")


def _ql(gb: GraphBuilder, x: str, scale: float, prefix: str, out_dtype: str) -> str:
    s = gb.add_initializer(f"{prefix}_q_scale", np.float32(scale))
    zp = gb.add_initializer(f"{prefix}_q_zp", np.zeros((), dtype=out_dtype))
    return gb.op("QuantizeLinear", [x, s, zp], out_hint=f"{prefix}_req")


def fc_int8_tanh(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    input_absmax: float = TANH_INPUT_ABSMAX,
) -> str:
    """Fig 4: int8 tanh.  The FC rescale maps the accumulator onto the full
    int8-quantized tanh input range [−input_absmax, +input_absmax]; y_scale
    maps int8 onto tanh's output range (−1, 1)."""
    q = fc_layer(gb, x, p, prefix, two_mul=True)
    deq = _dql(gb, q, input_absmax / 127.0, prefix)
    t = gb.op("Tanh", [deq], out_hint=f"{prefix}_tanh")
    return _ql(gb, t, 1.0 / 127.0, prefix, "int8")


def fc_fp16_tanh(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    input_absmax: float = TANH_INPUT_ABSMAX,
) -> str:
    """Fig 5: mixed int8/fp16 tanh flow (Cast→f16, Tanh in f16, Cast→f32)."""
    q = fc_layer(gb, x, p, prefix, two_mul=True)
    deq = _dql(gb, q, input_absmax / 127.0, prefix)
    h = gb.op("Cast", [deq], out_hint=f"{prefix}_f16", to="float16")
    t = gb.op("Tanh", [h], out_hint=f"{prefix}_tanh16")
    f = gb.op("Cast", [t], out_hint=f"{prefix}_back32", to="float32")
    return _ql(gb, f, 1.0 / 127.0, prefix, "int8")


def fc_fp16_sigmoid(
    gb: GraphBuilder,
    x: str,
    p: QuantizedLinearParams,
    prefix: str,
    *,
    input_absmax: float = SIGMOID_INPUT_ABSMAX,
) -> str:
    """Fig 6: mixed int8/fp16 sigmoid; single-Mul rescale; **uint8** output
    (sigmoid is always positive)."""
    q = fc_layer(gb, x, p, prefix, two_mul=False)
    deq = _dql(gb, q, input_absmax / 127.0, prefix)
    h = gb.op("Cast", [deq], out_hint=f"{prefix}_f16", to="float16")
    s = gb.op("Sigmoid", [h], out_hint=f"{prefix}_sig16")
    f = gb.op("Cast", [s], out_hint=f"{prefix}_back32", to="float32")
    return _ql(gb, f, 1.0 / 255.0, prefix, "uint8")
