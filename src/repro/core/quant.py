"""Symmetric quantization math (paper §3) and integer rescale decomposition (§3.1).

The paper codifies symmetric (zero_point = 0) quantization:

    X = scale_X * X_q                                  (eq. 1)
    Y_intermediate = W_q · X_q + B_q   (int32)         (eq. 5)
    B_q = B / (scale_W * scale_X)      (int32)         (eq. 6)
    Y_q = rescale(Y_intermediate, (scale_W*scale_X)/scale_Y)   (eq. 3/4)

and, for hardware expressiveness (§3.1), decomposes the floating-point rescale
multiplier ``M`` into an integer ``Quant_scale`` (stored as FLOAT, hence exact
only up to 2**24) and a right bit-shift ``Quant_shift = 2**-N``::

    M ≈ Quant_scale * 2**-N

Paper anchors reproduced by :func:`decompose_multiplier` and asserted in tests:

* ``M = 0.25   -> (Quant_scale=1,        N=2)``   (reduced form)
* ``M = 1/3    -> (Quant_scale=11184810, N=25)``  (unreduced floor form)
* largest exactly-representable integer in FLOAT: ``2**24 = 16_777_216``
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import numpy as np

# Largest integer exactly representable in IEEE-754 binary32 (paper §3.1).
MAX_EXACT_FLOAT_INT = 2**24  # 16_777_216

_INT_RANGES = {
    "int4": (-8, 7),
    "int8": (-128, 127),
    "uint8": (0, 255),
    "int16": (-32768, 32767),
    "int32": (-(2**31), 2**31 - 1),
}

#: Sub-byte dtypes have no numpy representation; on the numpy side they are
#: stored *unpacked* in the narrowest container that holds their range
#: (QONNX-style: the bitwidth is metadata, the container is int8).
_STORAGE_DTYPES = {"int4": "int8"}

#: Weight bitwidths with a first-class lowering lane.
SUPPORTED_WEIGHT_BITS = (4, 8)


def qrange(dtype: str) -> Tuple[int, int]:
    """(qmin, qmax) for a quantized dtype name."""
    try:
        return _INT_RANGES[dtype]
    except KeyError:
        raise ValueError(f"unsupported quantized dtype: {dtype!r}") from None


def storage_dtype(dtype: str) -> str:
    """The numpy container dtype for a quantized dtype name (int4 → int8)."""
    qrange(dtype)  # validate
    return _STORAGE_DTYPES.get(dtype, dtype)


def weight_dtype_for_bits(bits: int) -> str:
    """The quantized weight dtype name for a signed weight bitwidth."""
    if bits == 8:
        return "int8"
    if bits == 4:
        return "int4"
    raise ValueError(f"unsupported weight bitwidth: {bits!r} (supported: {SUPPORTED_WEIGHT_BITS})")


def round_half_even(x: np.ndarray) -> np.ndarray:
    """ONNX QuantizeLinear rounding: round half to even (numpy rint)."""
    return np.rint(x)


def saturate(x: np.ndarray, dtype: str) -> np.ndarray:
    qmin, qmax = qrange(dtype)
    return np.clip(x, qmin, qmax).astype(storage_dtype(dtype))


def choose_scale(absmax: float, dtype: str = "int8") -> float:
    """Map the profiled numerical range symmetrically onto the integer range.

    For int8 the full range [-absmax, absmax] maps onto [-127, 127] (we use the
    symmetric 127 rather than 128 so that +/- ranges are balanced, matching
    common accelerator practice).  For uint8 (non-negative data, e.g. post-ReLU
    or sigmoid outputs) [0, absmax] maps onto [0, 255].
    """
    if absmax <= 0.0 or not math.isfinite(absmax):
        return 1.0
    if dtype == "uint8":
        return absmax / 255.0
    qmin, qmax = qrange(dtype)
    return absmax / float(qmax)


def choose_scales(absmax: np.ndarray, dtype: str = "int8") -> np.ndarray:
    """Vector form of :func:`choose_scale`: one scale per channel, with the
    same degenerate-range guard (non-positive / non-finite absmax → 1.0)."""
    absmax = np.asarray(absmax, np.float64)
    qmax = float(qrange(dtype)[1])  # symmetric qmax for int8, full 255 for uint8
    ok = np.isfinite(absmax) & (absmax > 0.0)
    return np.where(ok, absmax / qmax, 1.0).astype(np.float32)


def quantize(x: np.ndarray, scale: Union[float, np.ndarray], dtype: str = "int8") -> np.ndarray:
    """X_q = saturate(round(X / scale)) — eq. (1) inverted, with round+clip."""
    scale = np.asarray(scale, dtype=np.float32)
    q = round_half_even(np.asarray(x, dtype=np.float32) / scale)
    return saturate(q, dtype)


def dequantize(x_q: np.ndarray, scale: Union[float, np.ndarray]) -> np.ndarray:
    """X = scale_X * X_q — eq. (1)."""
    return np.asarray(x_q, dtype=np.float32) * np.asarray(scale, dtype=np.float32)


def quantize_bias(b: np.ndarray, scale_w: Union[float, np.ndarray], scale_x: float) -> np.ndarray:
    """B_q = B / (scale_W * scale_X), stored as int32 — eq. (6).

    ``scale_w`` may be per-output-channel (vector); the bias then inherits the
    per-channel scale of the MatMulInteger/ConvInteger accumulator.
    """
    denom = np.asarray(scale_w, dtype=np.float64) * float(scale_x)
    q = np.rint(np.asarray(b, dtype=np.float64) / denom)
    return saturate(q, "int32")


@dataclasses.dataclass(frozen=True)
class Rescale:
    """The §3.1 hardware rescale: ``multiplier ≈ quant_scale * 2**-shift``.

    ``quant_scale`` is an integer stored as FLOAT in the artifact (hence the
    2**24 exactness bound); ``shift`` is the right bit-shift N.  ``multiplier``
    retains the original fp32 value for the 1-Mul codification mode.
    """

    quant_scale: int
    shift: int
    multiplier: float

    @property
    def quant_shift(self) -> float:
        """The FLOAT constant codified in the second Mul operator: 2**-shift."""
        return float(2.0 ** (-self.shift))

    @property
    def realized(self) -> float:
        """The multiplier value actually realized by (quant_scale, shift)."""
        return float(self.quant_scale) * self.quant_shift

    @property
    def per_channel(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class RescaleVector:
    """Per-channel §3.1 rescale: one (quant_scale, shift) pair per output
    channel, codified as two *vector* Mul constants along the output-feature
    axis.  Same exactness contract as :class:`Rescale`, applied elementwise:
    every ``quant_scale`` is an integer ≤ 2**24 (exact as FLOAT) and every
    ``quant_shift`` is a power of two."""

    quant_scale: np.ndarray  # int64 (C,) — integer values, stored as FLOAT in the artifact
    shift: np.ndarray  # int64 (C,) — per-channel right bit-shift N
    multiplier: np.ndarray  # float32 (C,) — original fp32 multipliers

    @property
    def quant_shift(self) -> np.ndarray:
        """The FLOAT vector codified in the second Mul: 2**-shift per channel."""
        return (2.0 ** (-self.shift.astype(np.float64))).astype(np.float32)

    @property
    def realized(self) -> np.ndarray:
        return self.quant_scale.astype(np.float64) * 2.0 ** (-self.shift.astype(np.float64))

    @property
    def per_channel(self) -> bool:
        return True

    def __len__(self) -> int:
        return int(self.quant_scale.shape[0])


def decompose_multipliers(
    multipliers: np.ndarray,
    *,
    max_scale_bits: int = 24,
    reduce: bool = False,
    max_shift: int = 62,
) -> RescaleVector:
    """Per-channel §3.1 decomposition: apply :func:`decompose_multiplier` to
    each channel's multiplier independently (each channel gets its own shift,
    maximizing per-channel precision)."""
    ms = np.asarray(multipliers, dtype=np.float64).reshape(-1)
    parts = [
        decompose_multiplier(float(m), max_scale_bits=max_scale_bits, reduce=reduce, max_shift=max_shift)
        for m in ms
    ]
    return RescaleVector(
        quant_scale=np.asarray([p.quant_scale for p in parts], np.int64),
        shift=np.asarray([p.shift for p in parts], np.int64),
        multiplier=ms.astype(np.float32),
    )


def decompose_multiplier(
    multiplier: float,
    *,
    max_scale_bits: int = 24,
    reduce: bool = False,
    max_shift: int = 62,
) -> Rescale:
    """Decompose a positive fp32 rescale multiplier into (quant_scale, shift).

    Picks the largest shift N (≤ ``max_shift``) such that
    ``floor(multiplier * 2**N) < 2**max_scale_bits`` — i.e. maximal precision
    while the integer quant_scale stays exactly representable as FLOAT —
    then ``quant_scale = floor(multiplier * 2**N)`` (floor matches the paper's
    1/3 → 11184810 example; round would give 11184811).

    With ``reduce=True`` the pair is canonicalized losslessly by halving even
    quant_scales (0.25 → (1, 2) as in the paper's first example, instead of
    the unreduced (8388608, 25)).
    """
    if not (multiplier > 0.0 and math.isfinite(multiplier)):
        raise ValueError(f"rescale multiplier must be positive finite, got {multiplier}")
    limit = 1 << max_scale_bits
    # Largest N with multiplier * 2**N < limit  =>  N < log2(limit / multiplier).
    n = int(math.floor(math.log2(limit / multiplier)))
    # Guard against float log edge cases.
    while multiplier * (2.0**n) >= limit:
        n -= 1
    while n + 1 <= max_shift and multiplier * (2.0 ** (n + 1)) < limit:
        n += 1
    n = min(n, max_shift)
    if n < 0:
        # Multiplier too large to gain fractional precision; clamp shift at 0.
        n = 0
    qs = int(math.floor(multiplier * (2.0**n)))
    qs = max(qs, 1)
    if reduce:
        while qs % 2 == 0 and n > 0:
            qs //= 2
            n -= 1
    return Rescale(quant_scale=qs, shift=n, multiplier=float(multiplier))


def apply_rescale_reference(
    acc_i32: np.ndarray,
    rescale: Rescale,
    out_dtype: str = "int8",
    *,
    two_mul: bool = True,
) -> np.ndarray:
    """Reference (numpy) semantics of the codified rescale + round + clip.

    Follows the artifact op-for-op so compiled backends can be checked for
    bit-exactness: Cast(int32→f32) → Mul(quant_scale as f32) → Mul(2**-N) →
    QuantizeLinear(scale=1, zp=0) ≡ round-half-even + saturate.
    With ``two_mul=False`` a single Mul by the fp32 multiplier is used
    (the paper's 1-Mul codification).

    ``rescale`` may be a per-channel :class:`RescaleVector`; its vectors
    broadcast along the accumulator's last (output-feature) axis.
    """
    x = acc_i32.astype(np.float32)
    if two_mul:
        x = x * np.asarray(rescale.quant_scale, np.float32)
        x = x * np.asarray(rescale.quant_shift, np.float32)
    else:
        x = x * np.asarray(rescale.multiplier, np.float32)
    return saturate(round_half_even(x), out_dtype)


@dataclasses.dataclass(frozen=True)
class QuantizedLinearParams:
    """Everything the artifact embeds for one pre-quantized linear layer."""

    weight_q: np.ndarray  # int8 container, shape (in, out) for MatMulInteger(X, W)
    bias_q: Optional[np.ndarray]  # int32, shape (out,)
    scale_x: float
    scale_w: np.ndarray  # scalar or per-channel (out,)
    scale_y: float
    rescale: Union[Rescale, RescaleVector]  # RescaleVector iff per_channel
    in_dtype: str = "int8"  # int8 or uint8 activations
    out_dtype: str = "int8"
    bits: int = 8  # weight bitwidth; 4 ⇒ weight_q values in [-8, 7], still int8-stored

    @property
    def per_channel(self) -> bool:
        return np.ndim(self.scale_w) > 0


def quantize_linear_layer(
    w: np.ndarray,
    b: Optional[np.ndarray],
    scale_x: float,
    scale_y: float,
    *,
    per_channel: bool = False,
    in_dtype: str = "int8",
    out_dtype: str = "int8",
    reduce: bool = False,
    bits: int = 8,
) -> QuantizedLinearParams:
    """Quantizer-side preparation of one FC layer (eqs. 2–6).

    ``w`` has shape (in, out) — MatMulInteger computes X(…,in) @ W(in,out).
    Per-channel scales are along the output-feature axis.

    ``bits=4`` quantizes weights onto [-8, 7] (scale chosen against qmax=7);
    the §3.1 rescale decomposition is elementwise on the int32 accumulator,
    so it is untouched by the weight bitwidth — only the multiplier value
    changes through the coarser ``scale_w``.
    """
    w_dtype = weight_dtype_for_bits(bits)
    w = np.asarray(w, dtype=np.float32)
    if per_channel:
        scale_w = choose_scales(np.abs(w).max(axis=0), w_dtype)
    else:
        scale_w = np.float32(choose_scale(float(np.abs(w).max()), w_dtype))
    w_q = quantize(w, scale_w, w_dtype)
    b_q = None if b is None else quantize_bias(b, scale_w, scale_x)
    if per_channel:
        # True per-channel rescale: every output channel carries its own
        # multiplier M_c = scale_w[c] * scale_x / scale_y, decomposed
        # independently into (quant_scale_c, shift_c).
        rescale = decompose_multipliers(scale_w.astype(np.float64) * scale_x / scale_y, reduce=reduce)
    else:
        rescale = decompose_multiplier(float(scale_w) * scale_x / scale_y, reduce=reduce)
    return QuantizedLinearParams(
        weight_q=w_q,
        bias_q=b_q,
        scale_x=float(scale_x),
        scale_w=np.asarray(scale_w),
        scale_y=float(scale_y),
        rescale=rescale,
        in_dtype=in_dtype,
        out_dtype=out_dtype,
        bits=bits,
    )


def fc_reference(x_q: np.ndarray, p: QuantizedLinearParams, *, two_mul: bool = True) -> np.ndarray:
    """End-to-end reference for the Fig.1 pattern on already-quantized input."""
    acc = x_q.astype(np.int32) @ p.weight_q.astype(np.int32)
    if p.bias_q is not None:
        acc = acc + p.bias_q.astype(np.int32)
    return apply_rescale_reference(acc, p.rescale, p.out_dtype, two_mul=two_mul)
