"""Reference interpreter for PQ-IR — the "standard ONNX tool" of paper goal 2.

Executes a :class:`repro.core.pqir.Model` op-by-op with numpy, following ONNX
operator semantics (round-half-even QuantizeLinear, int32 accumulation in
MatMulInteger/ConvInteger, dtype-preserving activations so fp16 sections stay
fp16).  Every compiled backend (the JAX/Pallas TPU path in
:mod:`repro.core.compile`) is conformance-tested against this interpreter —
bit-exactly on integer paths.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List

import numpy as np

from .pqir import DTYPES, Graph, Model, Node

_OPS: Dict[str, Callable] = {}


def op(name: str):
    def deco(fn):
        _OPS[name] = fn
        return fn

    return deco


def _zp(inputs: List[np.ndarray], idx: int) -> np.ndarray:
    """Optional zero-point input (defaults to 0)."""
    if len(inputs) > idx and inputs[idx] is not None:
        return inputs[idx].astype(np.int32)
    return np.int32(0)


# -- quantized compute -------------------------------------------------------


@op("MatMulInteger")
def _matmul_integer(node: Node, inputs):
    a, b = inputs[0], inputs[1]
    a32 = a.astype(np.int32) - _zp(inputs, 2)
    b32 = b.astype(np.int32) - _zp(inputs, 3)
    return [a32 @ b32]


@op("ConvInteger")
def _conv_integer(node: Node, inputs):
    x, w = inputs[0], inputs[1]
    x32 = x.astype(np.int32) - _zp(inputs, 2)
    w32 = w.astype(np.int32) - _zp(inputs, 3)
    return [_conv2d_int32(x32, w32, node.attrs)]


def _conv2d_int32(x: np.ndarray, w: np.ndarray, attrs) -> np.ndarray:
    """NCHW int32 convolution (zero-padded; symmetric quantization ⇒ zp=0
    padding is exact)."""
    strides = tuple(attrs.get("strides", (1, 1)))
    pads = tuple(attrs.get("pads", (0, 0, 0, 0)))  # (top, left, bottom, right)
    dil = tuple(attrs.get("dilations", (1, 1)))
    group = int(attrs.get("group", 1))
    n, c, h, wd = x.shape
    m, cg, kh, kw = w.shape
    assert c == cg * group, f"channel mismatch: {c} vs {cg}*{group}"
    xp = np.pad(x, ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])))
    oh = (xp.shape[2] - (dil[0] * (kh - 1) + 1)) // strides[0] + 1
    ow = (xp.shape[3] - (dil[1] * (kw - 1) + 1)) // strides[1] + 1
    out = np.zeros((n, m, oh, ow), dtype=np.int64)
    mg = m // group
    for g in range(group):
        xg = xp[:, g * cg : (g + 1) * cg]
        wg = w[g * mg : (g + 1) * mg]
        for i in range(kh):
            for j in range(kw):
                patch = xg[
                    :,
                    :,
                    i * dil[0] : i * dil[0] + oh * strides[0] : strides[0],
                    j * dil[1] : j * dil[1] + ow * strides[1] : strides[1],
                ]
                # (n, cg, oh, ow) x (mg, cg) -> (n, mg, oh, ow)
                out[:, g * mg : (g + 1) * mg] += np.einsum(
                    "nchw,mc->nmhw", patch.astype(np.int64), wg[:, :, i, j].astype(np.int64)
                )
    return out.astype(np.int32)


# -- quantize / dequantize ---------------------------------------------------


@op("QuantizeLinear")
def _quantize_linear(node: Node, inputs):
    x, y_scale = inputs[0], inputs[1]
    y_zp = inputs[2] if len(inputs) > 2 else np.zeros((), dtype=np.int8)
    out_dtype = y_zp.dtype
    info = np.iinfo(out_dtype)
    y = np.rint(x.astype(np.float32) / y_scale.astype(np.float32)) + y_zp.astype(np.float32)
    return [np.clip(y, info.min, info.max).astype(out_dtype)]


@op("DequantizeLinear")
def _dequantize_linear(node: Node, inputs):
    x, x_scale = inputs[0], inputs[1]
    x_zp = inputs[2].astype(np.int32) if len(inputs) > 2 else np.int32(0)
    return [((x.astype(np.int32) - x_zp).astype(np.float32) * x_scale.astype(np.float32))]


@op("Cast")
def _cast(node: Node, inputs):
    to = node.attrs["to"]
    return [inputs[0].astype(DTYPES[to])]


# -- elementwise -------------------------------------------------------------


@op("Mul")
def _mul(node: Node, inputs):
    return [inputs[0] * inputs[1]]


@op("Add")
def _add(node: Node, inputs):
    return [inputs[0] + inputs[1]]


@op("Sub")
def _sub(node: Node, inputs):
    return [inputs[0] - inputs[1]]


@op("Div")
def _div(node: Node, inputs):
    a, b = inputs
    if np.issubdtype(a.dtype, np.integer):
        return [a // b]
    return [a / b]


@op("Relu")
def _relu(node: Node, inputs):
    x = inputs[0]
    return [np.maximum(x, np.zeros((), dtype=x.dtype))]


@op("Tanh")
def _tanh(node: Node, inputs):
    x = inputs[0]
    return [np.tanh(x).astype(x.dtype)]


def stable_sigmoid(x: np.ndarray) -> np.ndarray:
    """Numerically stable logistic in float32, cast back to ``x.dtype``.

    The naive ``1/(1+exp(-x))`` overflows ``exp`` for large-magnitude
    negative inputs (int-dequantized activations easily reach them).  The
    two-branch form only ever exponentiates ``-|x|`` ∈ (-inf, 0], which
    cannot overflow; both branches are algebraically identical to the naive
    form.  The LUT fusion bakes this exact function (see
    ``repro.core.compile._NP_ACT``), so compiled LUTs stay bit-exact
    against this reference."""
    x = np.asarray(x)
    z = x.astype(np.float32)
    e = np.exp(-np.abs(z))
    y = np.where(z >= 0, 1.0 / (1.0 + e), e / (1.0 + e))
    return y.astype(x.dtype)


@op("Sigmoid")
def _sigmoid(node: Node, inputs):
    return [stable_sigmoid(inputs[0])]


@op("Erf")
def _erf(node: Node, inputs):
    x = inputs[0]
    return [np.vectorize(math.erf, otypes=[np.float64])(x.astype(np.float64)).astype(x.dtype)]


@op("Sqrt")
def _sqrt(node: Node, inputs):
    return [np.sqrt(inputs[0]).astype(inputs[0].dtype)]


@op("Pow")
def _pow(node: Node, inputs):
    return [np.power(inputs[0], inputs[1]).astype(inputs[0].dtype)]


@op("Clip")
def _clip(node: Node, inputs):
    x = inputs[0]
    lo = inputs[1] if len(inputs) > 1 else None
    hi = inputs[2] if len(inputs) > 2 else None
    return [np.clip(x, lo, hi).astype(x.dtype)]


@op("Softmax")
def _softmax(node: Node, inputs):
    x = inputs[0].astype(np.float32)
    axis = int(node.attrs.get("axis", -1))
    m = x - x.max(axis=axis, keepdims=True)
    e = np.exp(m)
    return [(e / e.sum(axis=axis, keepdims=True)).astype(inputs[0].dtype)]


# -- float compute -----------------------------------------------------------


@op("MatMul")
def _matmul(node: Node, inputs):
    return [inputs[0] @ inputs[1]]


@op("Gemm")
def _gemm(node: Node, inputs):
    a, b = inputs[0], inputs[1]
    alpha = float(node.attrs.get("alpha", 1.0))
    beta = float(node.attrs.get("beta", 1.0))
    if node.attrs.get("transA", 0):
        a = a.T
    if node.attrs.get("transB", 0):
        b = b.T
    if np.issubdtype(a.dtype, np.integer):
        # Integer Gemm (the form quantized MLP exporters emit in place of
        # MatMulInteger + Add): accumulate in int32; alpha/beta must be the
        # default 1 so the op stays exact.
        if alpha != 1.0 or beta != 1.0:
            raise NotImplementedError("integer Gemm requires alpha == beta == 1")
        y = a.astype(np.int32) @ b.astype(np.int32)
        if len(inputs) > 2 and inputs[2] is not None:
            y = y + inputs[2].astype(np.int32)
        return [y]
    y = alpha * (a @ b)
    if len(inputs) > 2 and inputs[2] is not None:
        y = y + beta * inputs[2]
    return [y.astype(inputs[0].dtype)]


@op("Conv")
def _conv(node: Node, inputs):
    x, w = inputs[0], inputs[1]
    acc = _conv2d_f32(x.astype(np.float32), w.astype(np.float32), node.attrs)
    if len(inputs) > 2 and inputs[2] is not None:
        acc = acc + inputs[2].reshape(1, -1, 1, 1)
    return [acc.astype(x.dtype)]


def _conv2d_f32(x, w, attrs):
    strides = tuple(attrs.get("strides", (1, 1)))
    pads = tuple(attrs.get("pads", (0, 0, 0, 0)))
    dil = tuple(attrs.get("dilations", (1, 1)))
    group = int(attrs.get("group", 1))
    n, c, h, wd = x.shape
    m, cg, kh, kw = w.shape
    xp = np.pad(x, ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])))
    oh = (xp.shape[2] - (dil[0] * (kh - 1) + 1)) // strides[0] + 1
    ow = (xp.shape[3] - (dil[1] * (kw - 1) + 1)) // strides[1] + 1
    out = np.zeros((n, m, oh, ow), dtype=np.float32)
    mg = m // group
    for g in range(group):
        xg = xp[:, g * cg : (g + 1) * cg]
        wg = w[g * mg : (g + 1) * mg]
        for i in range(kh):
            for j in range(kw):
                patch = xg[
                    :,
                    :,
                    i * dil[0] : i * dil[0] + oh * strides[0] : strides[0],
                    j * dil[1] : j * dil[1] + ow * strides[1] : strides[1],
                ]
                out[:, g * mg : (g + 1) * mg] += np.einsum("nchw,mc->nmhw", patch, wg[:, :, i, j])
    return out


# -- shape plumbing ----------------------------------------------------------


@op("Reshape")
def _reshape(node: Node, inputs):
    shape = [int(s) for s in inputs[1]]
    return [inputs[0].reshape(shape)]


@op("Transpose")
def _transpose(node: Node, inputs):
    perm = node.attrs.get("perm")
    return [np.transpose(inputs[0], perm)]


@op("Flatten")
def _flatten(node: Node, inputs):
    axis = int(node.attrs.get("axis", 1))
    x = inputs[0]
    lead = int(np.prod(x.shape[:axis])) if axis else 1
    return [x.reshape(lead, -1)]


@op("Concat")
def _concat(node: Node, inputs):
    return [np.concatenate(inputs, axis=int(node.attrs["axis"]))]


@op("Slice")
def _slice(node: Node, inputs):
    x = inputs[0]
    starts, ends = inputs[1], inputs[2]
    axes = inputs[3] if len(inputs) > 3 else np.arange(len(starts))
    steps = inputs[4] if len(inputs) > 4 else np.ones(len(starts), dtype=np.int64)
    sl = [slice(None)] * x.ndim
    for s, e, a, st in zip(starts, ends, axes, steps):
        sl[int(a)] = slice(int(s), int(e), int(st))
    return [x[tuple(sl)]]


@op("Gather")
def _gather(node: Node, inputs):
    axis = int(node.attrs.get("axis", 0))
    return [np.take(inputs[0], inputs[1].astype(np.int64), axis=axis)]


@op("Squeeze")
def _squeeze(node: Node, inputs):
    axes = tuple(int(a) for a in inputs[1]) if len(inputs) > 1 else None
    return [np.squeeze(inputs[0], axis=axes)]


@op("Unsqueeze")
def _unsqueeze(node: Node, inputs):
    x = inputs[0]
    for a in sorted(int(a) for a in inputs[1]):
        x = np.expand_dims(x, a)
    return [x]


# -- pooling / reductions ----------------------------------------------------


def _pool2d(x: np.ndarray, attrs, reducer) -> np.ndarray:
    kh, kw = attrs["kernel_shape"]
    strides = tuple(attrs.get("strides", (kh, kw)))
    pads = tuple(attrs.get("pads", (0, 0, 0, 0)))
    if any(pads):
        fill = -np.inf if reducer is np.max else 0.0
        x = np.pad(x, ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])), constant_values=fill)
    n, c, h, w = x.shape
    oh = (h - kh) // strides[0] + 1
    ow = (w - kw) // strides[1] + 1
    windows = np.empty((n, c, oh, ow, kh * kw), dtype=np.float32)
    for i in range(kh):
        for j in range(kw):
            windows[..., i * kw + j] = x[:, :, i : i + oh * strides[0] : strides[0], j : j + ow * strides[1] : strides[1]]
    return reducer(windows, axis=-1)


@op("MaxPool")
def _maxpool(node: Node, inputs):
    x = inputs[0]
    return [_pool2d(x.astype(np.float32), node.attrs, np.max).astype(x.dtype)]


@op("AveragePool")
def _avgpool(node: Node, inputs):
    x = inputs[0]
    return [_pool2d(x.astype(np.float32), node.attrs, np.mean).astype(x.dtype)]


@op("GlobalAveragePool")
def _gap(node: Node, inputs):
    x = inputs[0]
    return [x.mean(axis=(2, 3), keepdims=True).astype(x.dtype)]


@op("ReduceMean")
def _reduce_mean(node: Node, inputs):
    axes = tuple(node.attrs.get("axes", None) or range(inputs[0].ndim))
    keep = bool(node.attrs.get("keepdims", 1))
    x = inputs[0]
    return [x.mean(axis=axes, keepdims=keep).astype(x.dtype)]


@op("ReduceMax")
def _reduce_max(node: Node, inputs):
    axes = tuple(node.attrs.get("axes", None) or range(inputs[0].ndim))
    keep = bool(node.attrs.get("keepdims", 1))
    x = inputs[0]
    return [x.max(axis=axes, keepdims=keep).astype(x.dtype)]


@op("ReduceSum")
def _reduce_sum(node: Node, inputs):
    axes = tuple(node.attrs.get("axes", None) or range(inputs[0].ndim))
    keep = bool(node.attrs.get("keepdims", 1))
    x = inputs[0]
    # accumulate in the input dtype (int32 sums stay int32, exact)
    return [x.sum(axis=axes, keepdims=keep, dtype=x.dtype)]


# ---------------------------------------------------------------------------


class ReferenceRuntime:
    """Op-by-op executor with ONNX semantics (the conformance oracle)."""

    def __init__(self, model: Model, *, validate: bool = True) -> None:
        if validate:
            model.validate()
        self.model = model
        self._order = model.graph.toposorted()

    def run(self, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        g = self.model.graph
        env: Dict[str, np.ndarray] = {}
        for t in g.inputs:
            if t.name not in feeds:
                raise KeyError(f"missing feed for graph input {t.name!r}")
            arr = np.asarray(feeds[t.name])
            if arr.dtype != DTYPES[t.dtype]:
                raise TypeError(f"feed {t.name!r} dtype {arr.dtype} != declared {t.dtype}")
            env[t.name] = arr
        env.update(g.initializers)
        for node in self._order:
            fn = _OPS.get(node.op_type)
            if fn is None:
                raise NotImplementedError(f"reference runtime has no op {node.op_type!r}")
            ins = [env[i] if i else None for i in node.inputs]
            outs = fn(node, ins)
            for name, val in zip(node.outputs, outs):
                env[name] = val
        return {t.name: env[t.name] for t in g.outputs}

    def __call__(self, **feeds: np.ndarray) -> Dict[str, np.ndarray]:
        return self.run(feeds)


def run_model(model: Model, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    return ReferenceRuntime(model).run(feeds)
