"""W8A8 serving-side quantized layers for the model zoo.

``QuantizedLinear`` holds exactly what the artifact embeds (int8 weights,
int32 bias, integer scale + shift) and computes with the same integer
semantics as the compiled kernels — this is the paper's technique running as
a *first-class feature* inside the big-model serving path, not just the MLP
examples.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .quant import Rescale, decompose_multiplier


@dataclasses.dataclass
class QuantizedLinear:
    """Static (pre-quantized) linear: y_q = requant(x_q @ W_q + B_q)."""

    weight_q: jax.Array  # (in, out) int8
    bias_q: Optional[jax.Array]  # (out,) int32
    quant_scale: jax.Array  # (out,) f32 integer-valued
    quant_shift: jax.Array  # (out,) f32 = 2^-N
    scale_x: float
    scale_y: float
    out_dtype: str = "int8"

    def __call__(self, x_q: jax.Array, *, backend: str = "ref") -> jax.Array:
        return kops.quantized_matmul(
            x_q, self.weight_q, self.bias_q, self.quant_scale, self.quant_shift,
            out_dtype=jnp.int8 if self.out_dtype == "int8" else jnp.uint8,
            backend=backend,
        )


def prepare_quantized_linear(
    w: np.ndarray,  # (in, out) f32
    b: Optional[np.ndarray],
    scale_x: float,
    scale_y: float,
    *,
    per_channel: bool = True,
) -> QuantizedLinear:
    """Quantizer-side preparation (per-channel §3 math + §3.1 decomposition)."""
    w = np.asarray(w, np.float32)
    if per_channel:
        absmax = np.maximum(np.abs(w).max(axis=0), 1e-12)
        scale_w = absmax / 127.0
    else:
        scale_w = np.full((w.shape[1],), max(float(np.abs(w).max()), 1e-12) / 127.0, np.float32)
    w_q = np.clip(np.rint(w / scale_w), -128, 127).astype(np.int8)
    b_q = None
    if b is not None:
        b_q = np.clip(np.rint(b / (scale_w * scale_x)), -(2**31), 2**31 - 1).astype(np.int32)
    mults = scale_w * scale_x / scale_y
    resc = [decompose_multiplier(float(m)) for m in mults]
    qs = np.array([r.quant_scale for r in resc], np.float32)
    qsh = np.array([r.quant_shift for r in resc], np.float32)
    return QuantizedLinear(
        weight_q=jnp.asarray(w_q),
        bias_q=None if b_q is None else jnp.asarray(b_q),
        quant_scale=jnp.asarray(qs),
        quant_shift=jnp.asarray(qsh),
        scale_x=float(scale_x),
        scale_y=float(scale_y),
    )


def dynamic_quantize(x: jax.Array):
    """Per-tensor dynamic activation quantization (serving fallback when no
    static calibration is available)."""
    absmax = jnp.abs(x.astype(jnp.float32)).max()
    s = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.rint(x.astype(jnp.float32) / s), -128, 127).astype(jnp.int8)
    return q, s
