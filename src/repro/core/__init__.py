"""repro.core — the paper's contribution: pre-quantized model codification.

Quantizer side:  quant / calibrate / toolchain / export  (hardware-agnostic)
Artifact:        pqir (ONNX-dialect, standard ops only, scales embedded)
Compiler side:   runtime (reference oracle) / compile (JAX+Pallas TPU backend)
"""
from . import calibrate, patterns, pqir, quant, runtime, toolchain  # noqa: F401
from .pqir import Graph, GraphBuilder, Model, Node, TensorInfo  # noqa: F401
from .quant import (  # noqa: F401
    MAX_EXACT_FLOAT_INT,
    QuantizedLinearParams,
    Rescale,
    decompose_multiplier,
    dequantize,
    quantize,
    quantize_bias,
    quantize_linear_layer,
)
from .runtime import ReferenceRuntime, run_model  # noqa: F401
