"""The hardware-specific compilation stage: PQ-IR → typed ExecutionPlan →
JAX/Pallas kernels.

This is the *other side* of the paper's co-design contract, structured as a
three-level flow (QNN / onnx-mlir style multi-level lowering):

1. **Optimize** — the artifact first runs through the
   :mod:`repro.passes` pipeline (constant folding, identity/dead-node
   elimination, Reshape/Transpose/Flatten sinking, §3.1 two-Mul rescale and
   integer Add-bias folding, Quantize/Dequantize round-trip cancellation).
   Every pass is semantics-preserving — bit-exact on integer paths — and the
   caller's artifact is never mutated (the pipeline clones it).

2. **Fuse** — fusion candidates are *declarative pattern specs*
   (:class:`repro.passes.rewrite.Pattern`): an op chain with
   dtype/arity/constness preconditions and capture names, matched along
   single-consumer edges by the shared pattern-rewrite engine.  The specs in
   this module describe the paper's kernels:

     QLINEAR_PATTERN: {MatMulInteger|ConvInteger → [Add] → Cast(f32) →
                       Mul [→ Mul] → [Relu] → QuantizeLinear(1,0)}
         ⇒ one fused int8 MXU kernel (repro.kernels.qmatmul), or XLA int8
           conv + fused epilogue (repro.kernels.ops.quantized_conv2d).
           The rescale Mul constants may be scalar or per-channel vectors
           along the output-feature axis; per-channel multiplier/shift
           arrays ride through plan-time specialization pre-padded to tile
           multiples like every other qmatmul parameter.
     GEMM_PATTERN:    same epilogue anchored on an integer Gemm (the form
                      Gemm-based MLP exports emit) ⇒ same fused kernel;
                      transB and the C bias operand fold at plan time.
     LUT_PATTERN:     {DequantizeLinear(int8) → [Cast f16] → Tanh|Sigmoid →
                       [Cast f32] → QuantizeLinear}
         ⇒ exact 256-entry VMEM LUT (repro.kernels.qact_lut), built with
           reference-runtime semantics (incl. the fp16 casts) ⇒ bit-exact.

3. **Lower** — matches and fallback nodes become
   :class:`repro.backend.StepDraft`\\ s, and :func:`repro.backend.build_plan`
   turns them into a typed, liveness-planned :class:`ExecutionPlan`
   (integer buffer slots, per-step kernel ids resolved through the backend
   registry, shapes/dtypes from :mod:`repro.passes.analysis`).  Shape
   specialization happens *here*, at plan time: fused-qmatmul parameters are
   pre-padded to tile multiples and (bm, bk, bn) chosen per static shape, so
   the hot path never pads weights/bias/scales per call.  uint8 activations
   fold to the signed-int8 MXU fast path at plan time too (bias correction
   computed once).  ``CompiledModel.plan`` is printable — the artifact a
   hardware designer reads.

4. **Specialize (late)** — with ``dynamic_axes={...}`` (or its single-axis
   sugar ``batch="dynamic"``) the lowering stops one step earlier: the plan
   is a shape-generic *template* open over the artifact's **named symbolic
   axes** (``("N", "S", 64)`` input signatures; legacy ``(None, …)`` inputs
   contribute the implicit batch axis ``"N"``).  Fusion, slot liveness,
   dtype inference, and the axis-independent parameter padding are all done
   once; the axis-dependent M/bm stay symbolic.  Executing the artifact then
   binds the template to a per-axis *bucket* combination on demand
   (:func:`repro.backend.specialize_plan` with a bindings dict — tile choice
   for the flattened lead dims, nothing re-lowered) through a bounded
   :class:`repro.backend.PlanCache` keyed on the sorted bindings, so one
   compiled artifact serves a whole (batch × sequence × …) scenario grid
   with at most one specialization — and one jit trace — per visited bucket
   combination.  Each axis carries its own bucketing policy (power-of-two
   default; an int granularity rounds up to multiples, matching the serving
   engine's prefill buckets).  Zero padding along an axis is only exact when
   no op mixes information across it, so dynamic compilation *proves* each
   requested axis elementwise-safe independently
   (:func:`repro.passes.analysis.axis_mixing_nodes`) and rejects the graph
   otherwise.  This is the serving-side contract
   :mod:`repro.serving.compiled` builds its micro-batching server on.

Adding a fusion means adding a Pattern + a builder; adding a backend means
registering kernels — there is no hand-written chain-walking or backend
conditional left here.  Anything unmatched falls back to the generic jnp op
mirror (:mod:`repro.backend.generic`), so *every* valid artifact compiles.
Conformance: integer paths are bit-exact vs :mod:`repro.core.runtime`; float
fallbacks are allclose.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..backend import StepDraft, build_plan, const_arg, none_arg, specialize_plan, tensor_arg
from ..backend.generic import _JOPS  # noqa: F401  (re-export; conformance sweep)
from ..backend.plan import ExecutionPlan, PlanCache, bindings_key, resolve_bucketing
from ..kernels import ops as kops
from ..kernels.qact_lut import build_lut
from ..obs import trace as _trace
from ..obs.metrics import MetricsRegistry
from ..obs.provenance import PlanProvenance
from ..passes import PassManager, PipelineReport
from ..passes.analysis import (
    BATCH_AXIS,
    GraphAnalysis,
    axis_inputs,
    axis_mixing_nodes,
    axis_positions,
    graph_axes,
    implicit_batch_graph,
)
from ..passes.rewrite import Match, OpSpec, Pattern, match_chain, ql_params
from . import runtime
from .pqir import Model, Node

# ---------------------------------------------------------------------------
# fusion: declarative pattern specs + plan-step builders
# ---------------------------------------------------------------------------

# activation references the LUT builder bakes; Sigmoid uses the same
# overflow-safe form as the reference runtime so LUTs stay bit-exact vs it
_NP_ACT = {"Tanh": np.tanh, "Sigmoid": runtime.stable_sigmoid}


def _is_round_clip_ql(ga: GraphAnalysis, node: Node) -> bool:
    """QuantizeLinear(scale=1, zp=0) — the paper's pure rounding+clipping
    stage whose zp dtype selects the output dtype."""
    scale, zp = ql_params(ga, node)
    return (
        scale is not None and zp is not None
        and scale.size == 1 and np.asarray(zp).size == 1
        and float(scale) == 1.0 and int(np.asarray(zp)) == 0
    )


def _is_sym_scalar_q(ga: GraphAnalysis, node: Node) -> bool:
    """Scalar-scale, zero-zero-point (symmetric) quantize/dequantize."""
    scale, zp = ql_params(ga, node)
    return (
        scale is not None and zp is not None
        and scale.size == 1 and np.asarray(zp).size == 1
        and int(np.asarray(zp)) == 0
    )


def _dql_int8_sym(ga: GraphAnalysis, node: Node) -> bool:
    return ga.dtype(node.inputs[0]) == "int8" and _is_sym_scalar_q(ga, node)


def _gemm_q_anchor(ga: GraphAnalysis, node: Node) -> bool:
    """Integer Gemm usable as a fused-qlinear core: int8/uint8 activation,
    constant 2-D int8 weight, optional constant integer bias, default
    alpha/beta, no transA (transB folds into the constant at plan time)."""
    if ga.dtype(node.inputs[0]) not in ("int8", "uint8"):
        return False
    if node.attrs.get("transA", 0):
        return False
    if float(node.attrs.get("alpha", 1.0)) != 1.0 or float(node.attrs.get("beta", 1.0)) != 1.0:
        return False
    w = ga.const(node.inputs[1])
    if w is None or w.ndim != 2 or w.dtype != np.int8:
        return False
    if len(node.inputs) > 2 and node.inputs[2]:
        c = ga.const(node.inputs[2])
        if c is None or not np.issubdtype(c.dtype, np.integer):
            return False
    return True


#: The Fig 1/2 epilogue every qlinear core shares:
#: [Add bias] → Cast(f32) → Mul [→ Mul] → [Relu] → QuantizeLinear(1, 0).
#: The Mul constants may be scalars or per-channel vectors along the
#: output-feature axis — the builder validates the broadcast direction.
_QL_EPILOGUE = (
    OpSpec("Add", capture="bias", optional=True, const_operand="bias_c"),
    OpSpec("Cast", attrs={"to": "float32"}),
    OpSpec("Mul", capture="mul1", const_operand="mul1_c"),
    OpSpec("Mul", capture="mul2", optional=True, const_operand="mul2_c"),
    OpSpec("Relu", capture="relu", optional=True),
    OpSpec("QuantizeLinear", capture="ql", where=_is_round_clip_ql),
)

QLINEAR_PATTERN = Pattern(
    "qlinear",
    (OpSpec(("MatMulInteger", "ConvInteger"), capture="core", arity=2, const_inputs={1: "weight"}),)
    + _QL_EPILOGUE,
)

#: Gemm-codified FC chains (some MLP exporters emit one integer Gemm instead
#: of MatMulInteger + Add) lower onto the same fused qlinear kernel.
GEMM_PATTERN = Pattern(
    "qlinear_gemm",
    (OpSpec("Gemm", capture="core", const_inputs={1: "weight"}, where=_gemm_q_anchor),)
    + _QL_EPILOGUE,
)

LUT_PATTERN = Pattern(
    "qact_lut",
    (
        OpSpec("DequantizeLinear", capture="dql", where=_dql_int8_sym),
        OpSpec("Cast", capture="to16", optional=True, attrs={"to": "float16"}),
        OpSpec(("Tanh", "Sigmoid"), capture="act"),
        OpSpec("Cast", capture="to32", optional=True, attrs={"to": "float32"}),
        OpSpec("QuantizeLinear", capture="ql", where=_is_sym_scalar_q),
    ),
    # the fp16 down-cast and up-cast appear together or not at all
    where=lambda m: (m.node("to16") is None) == (m.node("to32") is None),
)


def _channel_const(c, n_out: int, tail: int, acc_ndim: Optional[int]) -> Optional[np.ndarray]:
    """Normalize a captured epilogue constant to a scalar ``()`` or an
    ``(n_out,)`` vector that broadcasts along the accumulator's
    output-feature axis (``tail`` = trailing spatial singleton dims: 0 for
    the (..., N) matmul layout, 2 for conv's NCHW).  Any other broadcast
    direction (per-row constants, rank-expanding constants whose extra
    leading dims would grow the output shape) returns None — the chain then
    stays unfused rather than fusing incorrectly.  ``acc_ndim`` is the
    accumulator rank when statically known (None ⇒ only rank ≤ 1 constants
    are provably non-expanding)."""
    c = np.asarray(c)
    if c.ndim > (acc_ndim if acc_ndim is not None else 1):
        return None  # broadcasting would prepend dims to the output
    if c.size == 1:
        return c.reshape(())
    shape = c.shape
    if tail:
        if len(shape) <= tail or any(d != 1 for d in shape[len(shape) - tail:]):
            return None
        shape = shape[: len(shape) - tail]
    if not shape or shape[-1] != c.size or c.size != n_out:
        return None
    return c.reshape(-1)


def _static_m(shape) -> Optional[int]:
    """Product of the leading (batch) dims if fully known, else None (a
    symbolic dim — named or unknown — makes the flat M unknowable here)."""
    if shape is None or len(shape) < 1:
        return None
    lead = shape[:-1]
    m = 1
    for d in lead:
        if not isinstance(d, int):
            return None
        m *= int(d)
    return m


def _symbolic_lead(shape) -> Optional[tuple]:
    """The activation's leading dims for an axis-open shape record: named
    axes (strings) mark the symbolic dims — or, on legacy graphs, ``None``
    in the leading position marks the implicit batch; other dims stay
    concrete so late binding can compute the flat M as their product with
    the axis bindings substituted.  A wholly unknown shape returns None —
    binding then leaves M unknown and keeps the default bm rather than
    stamping a flat M it cannot actually know."""
    if shape is None or len(shape) < 2:
        return None
    return tuple(shape[:-1])


def _build_qlinear(compiler: "Compiler", m: Match) -> Optional[StepDraft]:
    """Lower a QLINEAR/GEMM_PATTERN match onto the fused int8 matmul / conv,
    shape-specializing the matmul parameters at plan time.  Returns None
    (fall back unfused) when an epilogue constant does not broadcast along
    the output-feature axis."""
    core = m.anchor
    is_conv = core.op_type == "ConvInteger"
    is_gemm = core.op_type == "Gemm"
    ga = compiler.analysis
    # QONNX-style sub-8-bit weights: the bitwidth rides as a node attribute
    # on the integer core op (weights stay an unpacked int8 initializer, so
    # the reference runtime needs no change); the tiled lowering packs on it.
    weight_bits = int(core.attrs.get("weight_bits", 8))
    zp = ga.const(m.node("ql").inputs[2]) if len(m.node("ql").inputs) > 2 else np.zeros((), np.int8)
    out_dtype = str(np.asarray(zp).dtype)
    relu = m.node("relu") is not None

    w = np.asarray(m.consts["weight"])
    if is_gemm and core.attrs.get("transB", 0):
        w = np.ascontiguousarray(w.T)
    n_out = int(w.shape[0]) if is_conv else int(w.shape[1])
    tail = 2 if is_conv else 0
    # conv accumulators are NCHW by construction; matmul/Gemm rank comes from
    # shape inference (unknown ⇒ _channel_const only admits rank ≤ 1 consts)
    acc_shape = ga.shape(core.outputs[0])
    acc_ndim = 4 if is_conv else (len(acc_shape) if acc_shape is not None else None)

    two_mul = "mul2" in m
    qs = _channel_const(np.asarray(m.consts["mul1_c"], np.float32), n_out, tail, acc_ndim)
    qsh = (
        _channel_const(np.asarray(m.consts["mul2_c"], np.float32), n_out, tail, acc_ndim)
        if two_mul else np.float32(1.0)
    )
    if qs is None or qsh is None:
        return None

    b = None
    if is_gemm and len(core.inputs) > 2 and core.inputs[2]:
        b = _channel_const(ga.const(core.inputs[2]), n_out, 0, acc_ndim)
        if b is None:
            return None
        b = b.astype(np.int32)
    add_c = m.consts.get("bias_c")
    if add_c is not None:
        bc = _channel_const(add_c, n_out, tail, acc_ndim)
        if bc is None:
            return None
        # int32 addition wraps associatively, so folding the Gemm C operand
        # and a trailing Add into one bias is exact even under overflow
        with np.errstate(over="ignore"):
            b = bc.astype(np.int32) if b is None else b + bc.astype(np.int32)
    x_name = core.inputs[0]
    params = {"out_dtype": out_dtype, "relu": relu, "two_mul": two_mul}

    if is_conv:
        attrs = core.attrs
        params.update(
            strides=tuple(attrs.get("strides", (1, 1))),
            pads=tuple(attrs.get("pads", (0, 0, 0, 0))),
        )
        if weight_bits != 8:
            # conv has no packed lane — the bitwidth still renders in the plan
            params["weight_bits"] = weight_bits
        consts = (
            jnp.asarray(w),
            None if b is None else jnp.asarray(b),
            jnp.asarray(qs),
            jnp.asarray(np.asarray(qsh, np.float32)),
        )
        return StepDraft(
            "qlinear_conv2d", [tensor_arg(x_name)], [m.out_tensor],
            params=params, consts=consts, kind="fused_qconv", name=core.name,
        )

    if compiler.backend == "ref":
        # pure-jnp oracle: unpadded params, uint8 handled by int32 widening;
        # int4 stays *unpacked* here — this path is what the packed kernels
        # are pinned bit-exact against
        if weight_bits != 8:
            params["weight_bits"] = weight_bits
        consts = (
            jnp.asarray(w),
            None if b is None else jnp.asarray(b),
            jnp.asarray(qs),
            jnp.asarray(np.asarray(qsh, np.float32)),
        )
        return StepDraft(
            "qlinear_matmul", [tensor_arg(x_name)], [m.out_tensor],
            params=params, consts=consts, kind="fused_qlinear", name=core.name,
        )

    # tiled Pallas path: fold uint8 → signed int8 and pre-pad at plan time
    # (the uint8 bias fold and the K/N padding are both batch-independent,
    # so they belong to the template either way)
    if ga.dtype(x_name) == "uint8":
        b = np.asarray(kops.fold_uint8_input(jnp.asarray(w), None if b is None else jnp.asarray(b)))
        params["x_uint8"] = True
    if compiler.batch == "dynamic":
        # axis-open template: leave the axis-dependent (m, bm) binding to
        # per-bucket-combination specialization (specialize_plan / PlanCache)
        consts, shape = kops.template_qmatmul_params(
            w, b, qs, np.asarray(qsh, np.float32), weight_bits=weight_bits
        )
        shape["lead"] = _symbolic_lead(ga.shape(x_name))
        params["shape"] = shape
        params["dynamic_batch"] = True
    else:
        consts, shape = kops.specialize_qmatmul_params(
            w, b, qs, np.asarray(qsh, np.float32),
            m=_static_m(ga.shape(x_name)), weight_bits=weight_bits,
        )
        params["shape"] = shape
    return StepDraft(
        "qlinear_matmul", [tensor_arg(x_name)], [m.out_tensor],
        params=params, consts=consts, kind="fused_qlinear", name=core.name,
    )


def _build_lut(compiler: "Compiler", m: Match) -> StepDraft:
    """Lower a LUT_PATTERN match onto the exact 256-entry VMEM LUT."""
    ga = compiler.analysis
    in_scale, _ = ql_params(ga, m.node("dql"))
    out_scale, out_zp = ql_params(ga, m.node("ql"))
    compute_dtype = "float16" if m.node("to16") is not None else "float32"
    out_dtype = str(np.asarray(out_zp).dtype)
    act = m.node("act").op_type

    lut = build_lut(_NP_ACT[act], float(in_scale), float(out_scale), out_dtype, compute_dtype)
    return StepDraft(
        "qact_lut", [tensor_arg(m.node("dql").inputs[0])], [m.out_tensor],
        params={"act": act, "out_dtype": out_dtype}, consts=(jnp.asarray(lut),),
        kind="fused_lut", name=m.node("act").name,
    )


#: The compiler's fusion table: (declarative pattern, plan-step builder).
#: New fusions plug in here — describe the chain as data, lower in a builder.
FUSIONS = (
    (QLINEAR_PATTERN, _build_qlinear),
    (GEMM_PATTERN, _build_qlinear),
    (LUT_PATTERN, _build_lut),
)


# ---------------------------------------------------------------------------
# fused int8 attention: a DAG region, matched programmatically
# ---------------------------------------------------------------------------
#
# The ~25-node attention region emitted by repro.core.patterns.emit_qattention
# is a DAG, not a single-consumer chain (the mask fans into three nodes, the
# masked scores fan into ReduceMax and Sub, the LUT weights fan into the
# numerator and denominator branches), so the declarative chain matcher
# cannot describe it.  _match_qattention walks the emitted structure
# explicitly, anchored on the score MatMulInteger — the only MatMulInteger
# whose *both* operands are non-const, which is also what keeps it disjoint
# from QLINEAR_PATTERN's constant-weight anchor.


def _f32_scalar(ga: GraphAnalysis, name: str) -> Optional[float]:
    c = ga.const(name)
    if c is None:
        return None
    c = np.asarray(c)
    if c.size != 1 or c.dtype != np.float32:
        return None
    return float(c.reshape(()))


def _scalar_operand(ga: GraphAnalysis, node: Node, data: str) -> Optional[float]:
    """The f32 scalar constant operand of a binary node whose other operand
    is ``data`` (either position)."""
    ins = list(node.inputs)
    if data not in ins:
        return None
    other = ins[1] if ins[0] == data else ins[0]
    return _f32_scalar(ga, other)


def _is_zero_zp_ql(ga: GraphAnalysis, node: Node, scale: Optional[float], dtype: str = "int8") -> bool:
    """QuantizeLinear with the given scalar scale (None = any scalar) and a
    zero zero-point of the given dtype."""
    s, zp = ql_params(ga, node)
    if s is None or zp is None or np.asarray(s).size != 1 or np.asarray(zp).size != 1:
        return False
    if scale is not None and float(np.asarray(s)) != scale:
        return False
    return str(np.asarray(zp).dtype) == dtype and int(np.asarray(zp)) == 0


def _match_qattention(ga: GraphAnalysis, anchor: Node) -> Optional[dict]:
    """Match the codified int8 attention region rooted at its score
    MatMulInteger.  Strict by construction: every internal tensor must be
    consumed only inside the region (single_consumer, or the exact expected
    fan-out for the mask / masked-scores / LUT-weight tensors), every
    epilogue constant must be the expected scalar, and the LUT must satisfy
    ``lut[0] == 0`` — the property zero-padding exactness rests on.  Returns
    the capture dict for :func:`_build_qattention`, or None."""

    def nxt(tensor: str, op: str) -> Optional[Node]:
        n = ga.single_consumer(tensor)
        return n if n is not None and n.op_type == op else None

    if anchor.op_type != "MatMulInteger" or len(anchor.inputs) != 2:
        return None
    q, kt = anchor.inputs
    if ga.is_const(q) or ga.is_const(kt):
        return None
    tr = ga.producers.get(kt)
    if tr is None or tr.op_type != "Transpose" or ga.single_consumer(kt) is not anchor:
        return None
    if list(tr.attrs.get("perm", [])) != [0, 2, 1]:
        return None
    k = tr.inputs[0]
    if ga.dtype(q) != "int8" or ga.dtype(k) != "int8":
        return None

    cast1 = nxt(anchor.outputs[0], "Cast")
    if cast1 is None or cast1.attrs.get("to") != "float32":
        return None
    mul_c = nxt(cast1.outputs[0], "Mul")
    if mul_c is None:
        return None
    qk_scale = _scalar_operand(ga, mul_c, cast1.outputs[0])
    if qk_scale is None:
        return None
    sm = nxt(mul_c.outputs[0], "Mul")
    if sm is None:
        return None
    mask = sm.inputs[1] if sm.inputs[0] == mul_c.outputs[0] else sm.inputs[0]
    if ga.is_const(mask) or ga.dtype(mask) != "float32":
        return None
    masked = nxt(sm.outputs[0], "Add")
    if masked is None:
        return None
    pen_t = masked.inputs[1] if masked.inputs[0] == sm.outputs[0] else masked.inputs[0]
    pen = ga.producers.get(pen_t)
    if pen is None or pen.op_type != "Mul" or ga.single_consumer(pen_t) is not masked:
        return None
    sub1_t = pen.inputs[0] if _f32_scalar(ga, pen.inputs[1]) is not None else pen.inputs[1]
    big = _scalar_operand(ga, pen, sub1_t)
    sub1 = ga.producers.get(sub1_t)
    if big is None or sub1 is None or sub1.op_type != "Sub":
        return None
    if ga.single_consumer(sub1_t) is not pen:
        return None
    if sub1.inputs[0] != mask or _f32_scalar(ga, sub1.inputs[1]) != 1.0:
        return None

    # masked scores fan into exactly {ReduceMax, Sub}
    mt = masked.outputs[0]
    cons = ga.consumers.get(mt, [])
    if mt in ga.out_names or len(cons) != 2:
        return None
    mx = next((n for n in cons if n.op_type == "ReduceMax"), None)
    d = next((n for n in cons if n.op_type == "Sub"), None)
    if mx is None or d is None:
        return None
    if list(mx.attrs.get("axes", [])) != [2] or not mx.attrs.get("keepdims", 1):
        return None
    if ga.single_consumer(mx.outputs[0]) is not d or list(d.inputs) != [mt, mx.outputs[0]]:
        return None

    dq = nxt(d.outputs[0], "QuantizeLinear")
    if dq is None or not _is_zero_zp_ql(ga, dq, None, "int8"):
        return None
    lut_scale = float(np.asarray(ga.const(dq.inputs[1])))
    idx32 = nxt(dq.outputs[0], "Cast")
    if idx32 is None or idx32.attrs.get("to") != "int32":
        return None
    idxadd = nxt(idx32.outputs[0], "Add")
    if idxadd is None:
        return None
    off_t = idxadd.inputs[1] if idxadd.inputs[0] == idx32.outputs[0] else idxadd.inputs[0]
    off = ga.const(off_t)
    if off is None or np.asarray(off).size != 1 or int(np.asarray(off)) != 128:
        return None
    gather = nxt(idxadd.outputs[0], "Gather")
    if gather is None or int(gather.attrs.get("axis", 0)) != 0:
        return None
    lut = ga.const(gather.inputs[0])
    if lut is None or lut.shape != (256,) or lut.dtype != np.uint8 or lut[0] != 0:
        return None

    # LUT weights fan into exactly the int32 (denominator) and f32
    # (numerator) casts
    wt = gather.outputs[0]
    wcons = ga.consumers.get(wt, [])
    if wt in ga.out_names or len(wcons) != 2 or any(n.op_type != "Cast" for n in wcons):
        return None
    wi = next((n for n in wcons if n.attrs.get("to") == "int32"), None)
    wf = next((n for n in wcons if n.attrs.get("to") == "float32"), None)
    if wi is None or wf is None:
        return None
    den = nxt(wi.outputs[0], "ReduceSum")
    if den is None or list(den.attrs.get("axes", [])) != [2] or not den.attrs.get("keepdims", 1):
        return None
    denf = nxt(den.outputs[0], "Cast")
    if denf is None or denf.attrs.get("to") != "float32":
        return None
    p = nxt(wf.outputs[0], "Div")
    if p is None or ga.single_consumer(denf.outputs[0]) is not p:
        return None
    if list(p.inputs) != [wf.outputs[0], denf.outputs[0]]:
        return None
    pmul = nxt(p.outputs[0], "Mul")
    if pmul is None:
        return None
    p_scale = _scalar_operand(ga, pmul, p.outputs[0])
    if p_scale is None:
        return None
    pq = nxt(pmul.outputs[0], "QuantizeLinear")
    if pq is None or not _is_zero_zp_ql(ga, pq, 1.0, "int8"):
        return None

    ctx = nxt(pq.outputs[0], "MatMulInteger")
    if ctx is None or ctx.inputs[0] != pq.outputs[0]:
        return None
    v = ctx.inputs[1]
    if ga.is_const(v) or ga.dtype(v) != "int8":
        return None
    cf = nxt(ctx.outputs[0], "Cast")
    if cf is None or cf.attrs.get("to") != "float32":
        return None
    cmul = nxt(cf.outputs[0], "Mul")
    if cmul is None:
        return None
    rescale = _scalar_operand(ga, cmul, cf.outputs[0])
    if rescale is None:
        return None
    out_ql = ga.single_consumer(cmul.outputs[0])
    if out_ql is None or out_ql.op_type != "QuantizeLinear":
        return None
    s_out, zp_out = ql_params(ga, out_ql)
    if (
        s_out is None or zp_out is None or np.asarray(s_out).size != 1
        or float(np.asarray(s_out)) != 1.0 or int(np.asarray(zp_out)) != 0
    ):
        return None

    sq, sk = ga.shape(q), ga.shape(k)
    if sq is None or sk is None or len(sq) != 3 or len(sk) != 3:
        return None
    if not isinstance(sq[2], int):
        return None
    nodes = (
        tr, anchor, cast1, mul_c, sm, sub1, pen, masked, mx, d, dq, idx32,
        idxadd, gather, wi, den, denf, wf, p, pmul, pq, ctx, cf, cmul, out_ql,
    )
    return {
        "nodes": nodes,
        "q": q, "k": k, "v": v, "mask": mask,
        "out": out_ql.outputs[0],
        "out_dtype": str(np.asarray(zp_out).dtype),
        "qk_scale": qk_scale, "big": big, "lut_scale": lut_scale,
        "p_scale": p_scale, "rescale": rescale, "lut": lut,
        "b": tuple(sq[:1]), "s": sq[1], "t": sk[1], "dh": int(sq[2]),
        "anchor": anchor,
    }


def qattention_exempt_nodes(ga: GraphAnalysis) -> frozenset:
    """Names of every node inside a matched attention region — the regions
    the per-axis elementwise proof skips (see
    :func:`repro.passes.analysis.axis_mixing_nodes`).  The skip is sound
    because the region's own masking semantics make zero padding exact along
    any axis: a zero-padded key carries a zero mask, its score is driven to
    −big, and its LUT weight is exactly ``lut[0] == 0`` (the matcher checks
    this), so padded positions contribute nothing to the softmax denominator
    or the context; padded query rows produce finite garbage (the
    denominator can never be 0) that run-time slicing discards."""
    exempt = set()
    for node in ga.graph.nodes:
        if node.op_type != "MatMulInteger":
            continue
        m = _match_qattention(ga, node)
        if m is not None:
            exempt.update(n.name for n in m["nodes"])
    return frozenset(exempt)


def _build_qattention(compiler: "Compiler", m: dict) -> Optional[StepDraft]:
    """Lower a matched attention region onto the fused ``qattention`` kernel.
    Scalar constants ride in ``params`` (static under jit); the LUT is the
    step's one array const.  With dynamic axes the shape record stays open
    (``dynamic_attn``) and is bound per bucket by ``specialize_plan``; a
    static compile with symbolic dims falls back unfused instead."""
    shape = {"b": m["b"], "s": m["s"], "t": m["t"], "dh": m["dh"]}
    params = {
        "out_dtype": m["out_dtype"],
        "qk_scale": m["qk_scale"], "big": m["big"],
        "lut_scale": m["lut_scale"], "p_scale": m["p_scale"],
        "rescale": m["rescale"],
    }
    if compiler.batch == "dynamic":
        params["shape"] = shape
        params["dynamic_attn"] = True
    else:
        dims = list(m["b"]) + [m["s"], m["t"]]
        if not all(isinstance(d, int) for d in dims):
            return None  # symbolic dims without dynamic axes: stay unfused
        params["shape"] = kops.bind_qattention_axes(shape, {})
    return StepDraft(
        "qattention",
        [tensor_arg(m["q"]), tensor_arg(m["k"]), tensor_arg(m["v"]), tensor_arg(m["mask"])],
        [m["out"]],
        params=params, consts=(jnp.asarray(m["lut"]),),
        kind="fused_qattention", name=m["anchor"].name,
    )


class Compiler:
    def __init__(
        self,
        model: Model,
        *,
        backend: str = "ref",
        fuse: bool = True,
        optimize: bool = True,
        verify_passes: bool = False,
        batch: str = "static",
        dynamic_axes: Optional[Dict[str, object]] = None,
        plan_cache_capacity: int = PlanCache.DEFAULT_CAPACITY,
        plan_cache: Optional[PlanCache] = None,
        autotune=None,
    ) -> None:
        model.validate()
        self.autotuner = _resolve_autotuner(autotune)
        if batch not in ("static", "dynamic"):
            raise ValueError(f"batch must be 'static' or 'dynamic', got {batch!r}")
        if batch == "dynamic" and dynamic_axes is None:
            # PR 4 sugar: dynamic over the (implicit or named) batch axis
            dynamic_axes = {BATCH_AXIS: None}
        if dynamic_axes:
            batch = "dynamic"
        available = graph_axes(model.graph)
        if batch == "dynamic":
            missing = sorted(set(dynamic_axes) - set(available))
            if missing:
                raise ValueError(
                    f"dynamic axes {missing} are not symbolic in any graph input "
                    f"signature (available: {list(available) or 'none'}) — "
                    "declare them as named dims, e.g. ('N', 'S', 64), or use a "
                    "(None, ...) leading dim for the implicit batch axis"
                )
            # an axis may appear at several positions of one signature (an
            # attention mask is ("N", "S", "S")): run-time padding/slicing
            # handles every occurrence (axis_input_positions below)
        if optimize:
            model, self.pass_report = PassManager(verify=verify_passes).run(model)
        else:
            self.pass_report = PipelineReport(
                nodes_before=len(model.graph.nodes), nodes_after=len(model.graph.nodes)
            )
        # provenance: the how-this-plan-came-to-be record the plan will carry
        tracer = _trace.current()
        self.provenance = PlanProvenance(
            nodes_before=self.pass_report.nodes_before,
            nodes_after=self.pass_report.nodes_after,
            pass_iterations=self.pass_report.iterations,
            trace_id=tracer.trace_id if tracer is not None else None,
        )
        for e in self.pass_report.entries:
            if e.changed:
                self.provenance.add_pass(e.iteration, e.name, e.counters)
        self.model = model
        self.graph = model.graph
        self.backend = backend
        self.fuse = fuse
        self.batch = batch
        # preserve the graph's axis declaration order for stable plan axes
        if batch == "dynamic":
            self.dynamic_axes = {
                a: resolve_bucketing(dynamic_axes.get(a)) for a in available if a in dynamic_axes
            }
            # raw (pre-resolution) bucketing specs: what an AOT artifact
            # serializes, since the resolved policies are callables
            self.axis_specs = {
                a: dynamic_axes.get(a) for a in available if a in dynamic_axes
            }
        else:
            self.dynamic_axes = {}
            self.axis_specs = {}
        self.plan_cache_capacity = plan_cache_capacity
        self.plan_cache = plan_cache
        self.inits = {k: v for k, v in self.graph.initializers.items()}
        self.analysis = GraphAnalysis(self.graph)
        if batch == "dynamic":
            # zero padding along a dynamic axis is only exact when no op
            # mixes information across it — prove each requested axis
            # independently and reject (rather than silently mis-serve)
            # graphs with e.g. a global ReduceMean or an axis-folding Reshape.
            # Matched attention regions are exempt: their masking semantics
            # make zero padding exact by construction (the region reduces
            # over keys whose padded LUT weight is exactly 0 — see
            # qattention_exempt_nodes), which the per-op proof cannot see.
            implicit = implicit_batch_graph(self.graph)
            exempt = qattention_exempt_nodes(self.analysis)
            for axis in self.dynamic_axes:
                problems = axis_mixing_nodes(
                    self.analysis, axis, implicit=implicit, exempt=exempt
                )
                if problems:
                    raise ValueError(
                        f"dynamic axis {axis!r} needs every op to be "
                        "batch-elementwise along it; cannot prove that for:\n  "
                        + "\n  ".join(problems)
                        + "\ncompile with batch='static' instead"
                    )
        self.stats = {
            "fused_qlinear": 0,
            "fused_qconv": 0,
            "fused_lut": 0,
            "fused_qattention": 0,
            "generic": 0,
            "folded": self.pass_report.total("folded"),
            "eliminated": self.pass_report.total("eliminated"),
        }

    # -- main ---------------------------------------------------------------
    def compile(self) -> "CompiledModel":
        order = self.graph.toposorted()
        consumed = set()
        drafts: List[StepDraft] = []
        # attention regions are DAGs whose members straddle the anchor in
        # topo order (the K-Transpose precedes it, V may be produced after
        # it): match them up front, skip members as they stream past, and
        # emit the fused step at the region's sink — the one position where
        # every region input is guaranteed already produced
        attn_emit, attn_skip = ({}, set())
        if self.fuse:
            attn_emit, attn_skip = self._qattention_regions()
        with _trace.span("compile.fuse", nodes=len(order)) as fuse_span:
            for node in order:
                if id(node) in consumed or id(node) in attn_skip:
                    continue
                if id(node) in attn_emit:
                    draft = attn_emit[id(node)]
                else:
                    draft = self._fused_draft(node, consumed) if self.fuse else None
                    if draft is None:
                        draft = self._generic_draft(node)
                drafts.append(draft)
                self.stats[draft.kind] += 1
            fuse_span.set(
                fused=len(self.provenance.fusions),
                generic=self.stats["generic"],
            )
        with _trace.span("compile.lower", steps=len(drafts)) as lower_span:
            plan = build_plan(
                self.graph, self.analysis, drafts, self.backend,
                batch=self.batch, axes=tuple(self.dynamic_axes),
                provenance=self.provenance,
            )
            lower_span.set(slots=plan.num_slots)
        self.stats["plan_slots"] = plan.num_slots
        return CompiledModel(
            self.model, plan, self.stats, self.pass_report,
            plan_cache_capacity=self.plan_cache_capacity,
            plan_cache=self.plan_cache,
            dynamic_axes=self.dynamic_axes,
            axis_specs=self.axis_specs,
            autotuner=self.autotuner,
        )

    def _qattention_regions(self):
        """Match every attention region once, up front.  Returns
        ``(emit, skip)``: ``emit`` maps the id of each region's sink node
        (its final QuantizeLinear — last in any topo order, since every
        other member is its ancestor) to the fused StepDraft; ``skip`` holds
        the ids of all other member nodes."""
        emit: Dict[int, StepDraft] = {}
        skip: set = set()
        for node in self.graph.nodes:
            if node.op_type != "MatMulInteger":
                continue
            qm = _match_qattention(self.analysis, node)
            if qm is None:
                continue
            draft = _build_qattention(self, qm)
            if draft is None:
                continue
            sink = qm["nodes"][-1]
            emit[id(sink)] = draft
            skip.update(id(n) for n in qm["nodes"] if n is not sink)
            self.provenance.add_fusion(
                "qattention", node.name,
                tuple(n.name for n in qm["nodes"]), qm["out"],
            )
        return emit, skip

    def _fused_draft(self, node: Node, consumed: set) -> Optional[StepDraft]:
        for pattern, builder in FUSIONS:
            if node.op_type not in pattern.anchor_ops:
                continue
            m = match_chain(self.analysis, node, pattern)
            if m is None:
                continue
            draft = builder(self, m)
            if draft is None:
                continue
            consumed.update(id(n) for n in m.nodes)
            self.provenance.add_fusion(
                pattern.name, m.anchor.name,
                tuple(n.name for n in m.nodes), m.out_tensor,
            )
            return draft
        return None

    def _generic_draft(self, node: Node) -> StepDraft:
        if node.op_type not in _JOPS:
            raise NotImplementedError(f"compiler has no lowering for op {node.op_type!r}")
        args = []
        for name in node.inputs:
            if not name:
                args.append(none_arg())
            elif name in self.inits:
                args.append(const_arg(np.asarray(self.inits[name])))
            else:
                args.append(tensor_arg(name))
        return StepDraft(
            f"op.{node.op_type}", args, list(node.outputs),
            params={"attrs": node.attrs}, kind="generic", name=node.name,
        )


class CompiledModel:
    """A compiled artifact: typed ExecutionPlan + jitted slot-indexed
    executor + fusion report.  ``print(cm.plan)`` shows the full lowering.

    With dynamic axes the held plan is a shape-generic *template*:
    :meth:`run` reads each dynamic axis's true extent off the feeds, pads
    every axis-carrying feed to that axis's bucket (per-axis bucketing
    policy — power-of-two by default), binds the template to the bucket
    combination through a bounded :class:`~repro.backend.plan.PlanCache`
    keyed on the sorted bindings (at most one specialization and one jit
    trace per resident combination), executes, and slices results back to
    the true extents along every axis position they carry.  Zero padding is
    exact because dynamic compilation *proves* it per axis: the compiler
    rejects any graph with an op it cannot show to be elementwise along each
    requested axis (:func:`repro.passes.analysis.axis_mixing_nodes`), and
    the conformance sweep pins dynamic == per-shape-static == reference,
    bit for bit, over the whole bucket grid."""

    def __init__(
        self,
        model: Model,
        plan: ExecutionPlan,
        stats: Dict[str, int],
        pass_report: Optional[PipelineReport] = None,
        *,
        plan_cache_capacity: int = PlanCache.DEFAULT_CAPACITY,
        plan_cache: Optional[PlanCache] = None,
        dynamic_axes: Optional[Dict[str, object]] = None,
        axis_specs: Optional[Dict[str, object]] = None,
        autotuner=None,
    ) -> None:
        self.model = model
        self.plan = plan
        #: per-axis raw bucketing specs (None / int / callable) as declared at
        #: compile time — the serializable counterpart of ``dynamic_axes``,
        #: whose values are already-resolved policy callables
        self.plan_cache_capacity = plan_cache_capacity
        if plan.batch == "dynamic":
            self.axis_specs: Dict[str, object] = (
                dict(axis_specs) if axis_specs is not None else {a: None for a in plan.axes}
            )
        else:
            self.axis_specs = {}
        #: optional repro.backend.autotune.Autotuner — when set, every lazy
        #: specialization routes its tile choice through the measured search
        self.autotuner = autotuner
        self.steps = plan.steps
        self.stats = stats
        self.pass_report = pass_report if pass_report is not None else PipelineReport()
        self.input_names = [t.name for t in model.graph.inputs]
        self.output_names = [t.name for t in model.graph.outputs]
        if plan.batch == "dynamic":
            # a shared cache (plan_cache=) pools specializations across
            # several artifacts (e.g. a prefill and a decode plan serving one
            # token path); cache_key() then prefixes the graph name so the
            # artifacts never collide on identical bindings
            self._shared_cache = plan_cache is not None
            self.plan_cache: Optional[PlanCache] = (
                plan_cache if plan_cache is not None
                else PlanCache(plan_cache_capacity, scope="plan")
            )
            self.dynamic_axes: Dict[str, object] = {
                a: resolve_bucketing(None) for a in plan.axes
            }
            if dynamic_axes:
                self.dynamic_axes.update(dynamic_axes)
            implicit = implicit_batch_graph(model.graph)
            # where each dynamic axis sits in each input: axis -> {input:
            # (pos, ...)} — every occurrence (a mask signature like
            # ("N", "S", "S") carries an axis twice and every position must
            # be padded); the single-int *_pos views keep the first position
            # for backward compatibility
            self.axis_input_positions: Dict[str, Dict[str, tuple]] = {}
            for axis in self.dynamic_axes:
                by_input = {}
                for t in model.graph.inputs:
                    pos = axis_positions(tuple(t.shape), axis, implicit=implicit)
                    if pos:
                        by_input[t.name] = pos
                self.axis_input_positions[axis] = by_input
            self.axis_input_pos: Dict[str, Dict[str, int]] = {
                axis: {name: pos[0] for name, pos in by_input.items()}
                for axis, by_input in self.axis_input_positions.items()
            }
            # axis-carrying outputs get sliced back to the true extents;
            # positions come from the declared signature with the plan's
            # inferred value shapes as fallback, so an output mis-declared
            # with a concrete dim is still recognized as axis-carrying
            inferred = {
                name: info.shape
                for step in plan.steps
                for name, info in zip(step.outputs, step.out_info)
            }
            self.output_axis_positions: Dict[str, Dict[str, tuple]] = {}
            for t in model.graph.outputs:
                by_axis = {}
                for axis in self.dynamic_axes:
                    pos = axis_positions(tuple(t.shape), axis, implicit=implicit)
                    if not pos:
                        pos = axis_positions(inferred.get(t.name), axis, implicit=implicit)
                    if pos:
                        by_axis[axis] = pos
                if by_axis:
                    self.output_axis_positions[t.name] = by_axis
            self.output_axis_pos: Dict[str, Dict[str, int]] = {
                name: {axis: pos[0] for axis, pos in by_axis.items()}
                for name, by_axis in self.output_axis_positions.items()
            }
            self._jitted = None  # a template is only executable once bound
        else:
            self._shared_cache = False
            self.plan_cache = None
            self.dynamic_axes = {}
            self.axis_input_positions = {}
            self.axis_input_pos = {}
            self.output_axis_positions = {}
            self.output_axis_pos = {}
            self._jitted = jax.jit(self._execute)

    @property
    def backend(self) -> str:
        return self.plan.backend

    @property
    def is_dynamic(self) -> bool:
        return self.plan.batch == "dynamic"

    # -- PR 4 single-axis views (the batch axis) ----------------------------
    @property
    def batch_input_names(self) -> List[str]:
        """Inputs carrying the batch axis (PR 4 compat view)."""
        return list(self.axis_input_pos.get(BATCH_AXIS, {}))

    @property
    def batch_output_names(self) -> set:
        """Outputs carrying the batch axis (PR 4 compat view)."""
        return {k for k, v in self.output_axis_pos.items() if BATCH_AXIS in v}

    def _execute(self, feeds: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        return self.plan.execute(feeds)

    def run(self, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        if self.is_dynamic:
            return self._run_dynamic(feeds)
        with _trace.span("run.execute"):
            res = self._jitted({k: jnp.asarray(v) for k, v in feeds.items()})
            return {k: np.asarray(v) for k, v in res.items()}

    def __call__(self, **feeds) -> Dict[str, np.ndarray]:
        return self.run(feeds)

    def lower(self, feeds: Dict[str, jax.ShapeDtypeStruct]):
        if self.is_dynamic:
            raise NotImplementedError(
                "lower() needs a bound plan — use specialized(bindings) and "
                "inspect/lower the per-bucket executor instead"
            )
        return self._jitted.lower(feeds)

    # -- scenario-specialized execution -------------------------------------
    def bucket_for(self, axis: str, extent: int) -> int:
        """The padded bucket for a true extent along ``axis`` under that
        axis's bucketing policy."""
        return int(self.dynamic_axes[axis](int(extent)))

    def cache_key(self, bindings) -> tuple:
        """The plan-cache key for a bucket combination.  On a private cache
        this is exactly :func:`~repro.backend.plan.bindings_key` (existing
        keys, artifacts and tests stay valid); on a shared cache the graph
        name is prefixed so two artifacts pooling one cache (prefill +
        decode) never collide on identical bindings."""
        if not isinstance(bindings, dict):
            bindings = {BATCH_AXIS: int(bindings)}
        key = bindings_key(bindings)
        return (self.model.graph.name, key) if self._shared_cache else key

    def specialized(self, bindings):
        """The (plan, jitted executor) pair for a bucket combination,
        specializing lazily through the bounded plan cache.  ``bindings`` is
        an axis→bucket dict (a bare int is sugar for the batch axis).
        ``cache_stats`` counts a miss (== one specialization) only on first
        use of a resident combination; binding order never splits cache
        entries (keys are the sorted bindings)."""
        if not self.is_dynamic:
            raise ValueError("specialized() is only meaningful on a dynamic compile")
        if not isinstance(bindings, dict):
            bindings = {BATCH_AXIS: int(bindings)}
        unknown = sorted(set(bindings) - set(self.dynamic_axes))
        if unknown:
            raise ValueError(
                f"unknown dynamic axes {unknown}: this artifact is open over "
                f"{list(self.dynamic_axes)}"
            )
        key = self.cache_key(bindings)
        entry = self.plan_cache.get(key)
        if entry is None:
            plan = specialize_plan(self.plan, bindings, tuner=self.autotuner)
            entry = (plan, jax.jit(plan.execute))
            self.plan_cache.put(key, entry)
        return entry

    @property
    def cache_stats(self) -> Dict[str, int]:
        """Plan-cache counters (size/capacity/hits/misses/evictions/
        hit_rate); misses double as the number of specializations.  These
        legacy flat keys stay for one release — the canonical scheme is
        ``cache.plan.<field>`` in a :class:`~repro.obs.metrics.
        MetricsRegistry` (see :meth:`attach_metrics`)."""
        if self.plan_cache is None:
            return {}
        return self.plan_cache.stats

    def attach_metrics(self, registry: MetricsRegistry) -> None:
        """Publish this artifact's plan-cache stats into ``registry`` under
        the canonical ``cache.plan.*`` keys (live callback gauges)."""
        if self.plan_cache is not None:
            self.plan_cache.attach_metrics(registry)

    def _run_dynamic(self, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        extents: Dict[str, int] = {}
        for axis, by_input in self.axis_input_positions.items():
            vals = {
                int(np.asarray(feeds[name]).shape[pos])
                for name, positions in by_input.items()
                if name in feeds
                for pos in positions
            }
            if len(vals) != 1:
                raise ValueError(
                    f"inputs {sorted(by_input)} carrying dynamic axis {axis!r} "
                    f"must all be fed with one common extent, got {sorted(vals)}"
                )
            extents[axis] = vals.pop()
        bindings = {axis: self.bucket_for(axis, ext) for axis, ext in extents.items()}
        _, fn = self.specialized(bindings)
        with _trace.span("run.pad"):
            padded: Dict[str, jax.Array] = {}
            for name, v in feeds.items():
                v = np.asarray(v)
                widths = [(0, 0)] * v.ndim
                grow = False
                for axis, by_input in self.axis_input_positions.items():
                    for pos in by_input.get(name, ()):
                        if v.shape[pos] != bindings[axis]:
                            # zero slabs are exact: dynamic compilation proved
                            # every op elementwise along the axis (or the
                            # region's masking makes padding inert), and the
                            # padding is sliced away below
                            widths[pos] = (0, bindings[axis] - v.shape[pos])
                            grow = True
                padded[name] = jnp.asarray(np.pad(v, widths) if grow else v)
        with _trace.span("run.execute") as ex_span:
            if _trace.enabled:
                ex_span.set(**{f"bucket_{a}": b for a, b in sorted(bindings.items())})
            res = fn(padded)
        with _trace.span("run.slice"):
            out: Dict[str, np.ndarray] = {}
            for k, v in res.items():
                v = np.asarray(v)
                by_axis = self.output_axis_positions.get(k)
                if by_axis:
                    slicer = [slice(None)] * v.ndim
                    for axis, positions in by_axis.items():
                        for pos in positions:
                            slicer[pos] = slice(0, extents[axis])
                    v = v[tuple(slicer)]
                out[k] = v
            return out


def _resolve_autotuner(autotune):
    """Normalize the ``compile_model(autotune=...)`` sugar to an Autotuner
    (or None): True → in-memory session, a path → persistent tile cache,
    a tuner instance → as-is.  Tuners are duck-typed on the ``tune_step``
    contract (not ``isinstance``) so injected test doubles — and the module
    run under ``python -m``, where the class exists twice — both work."""
    if not autotune:
        return None
    from ..backend.autotune import Autotuner

    if autotune is True:
        return Autotuner()
    if hasattr(autotune, "tune_step"):
        return autotune
    return Autotuner(cache=str(autotune))


def compile_model(
    model: Model,
    *,
    backend: str = "ref",
    fuse: bool = True,
    optimize: bool = True,
    verify_passes: bool = False,
    batch: str = "static",
    dynamic_axes: Optional[Dict[str, object]] = None,
    plan_cache_capacity: int = PlanCache.DEFAULT_CAPACITY,
    plan_cache: Optional[PlanCache] = None,
    autotune=None,
) -> CompiledModel:
    """Compile a PQ-IR artifact for the TPU backend.

    backend:       "pallas" (real TPU lowering), "interpret" (Pallas
                   interpreter — CPU-validatable), "ref" (pure-jnp fused ops;
                   what the dry-run lowers).
    optimize:      run the :mod:`repro.passes` pipeline first (the caller's
                   artifact is cloned, never mutated).
    verify_passes: turn on the pipeline's reference-runtime conformance hook
                   (asserts each pass is semantics-preserving on probe
                   inputs before the backend ever sees the graph).
    batch:         "static" specializes shapes once at plan time (classic
                   behavior); "dynamic" is single-axis sugar for
                   ``dynamic_axes={"N": None}`` — a batch-polymorphic plan
                   *template* bound lazily to power-of-two batch buckets at
                   run time.
    dynamic_axes:  named symbolic axes to leave open in the plan template,
                   mapped to per-axis bucketing specs: ``None`` →
                   power-of-two buckets, an int g → round up to multiples of
                   g (sequence-length style), a callable → custom policy.
                   Axes must appear in the graph's input signatures (named
                   dims like ``("N", "S", 64)``; a legacy ``(None, …)``
                   leading dim is the implicit batch axis ``"N"``).  One
                   artifact then serves the whole scenario grid with at most
                   one specialization per visited bucket combination.
    plan_cache_capacity:
                   bound on resident per-bucket specializations (dynamic
                   mode; LRU-evicted beyond this).
    plan_cache:    an existing :class:`~repro.backend.plan.PlanCache` to
                   share across artifacts (e.g. one cache serving a prefill
                   and a decode plan of the same token path).  Keys are then
                   prefixed with the graph name (``cm.cache_key``), so pooled
                   artifacts never collide; capacity/accounting are the
                   shared cache's.
    autotune:      measured per-cell tile search (dynamic mode, tiled
                   backends): ``True`` → an in-memory
                   :class:`repro.backend.autotune.Autotuner` session, a path
                   → a session persisted to that JSON tile cache (warm
                   starts perform zero measurements), an Autotuner instance
                   → shared/injected (tests pass one with a deterministic
                   ``measure_fn``).  Each lazy specialization then measures
                   a budgeted, cost-model-seeded candidate list and the plan
                   provenance tags every cell's tile source.
    """
    with _trace.span(
        "compile", graph=model.graph.name, backend=backend,
        batch="dynamic" if (dynamic_axes or batch == "dynamic") else batch,
    ):
        return Compiler(
            model, backend=backend, fuse=fuse, optimize=optimize,
            verify_passes=verify_passes, batch=batch, dynamic_axes=dynamic_axes,
            plan_cache_capacity=plan_cache_capacity, plan_cache=plan_cache,
            autotune=autotune,
        ).compile()
