"""The hardware-specific compilation stage: PQ-IR → fused JAX/Pallas executable.

This is the *other side* of the paper's co-design contract, structured as a
two-stage flow:

1. **Optimize** — the artifact first runs through the
   :mod:`repro.passes` pipeline (constant folding, identity/dead-node
   elimination, Reshape/Transpose sinking, §3.1 two-Mul rescale folding,
   Quantize/Dequantize round-trip cancellation).  Every pass is
   semantics-preserving — bit-exact on integer paths — and the caller's
   artifact is never mutated (the pipeline clones it).

2. **Fuse + lower** — fusion candidates are *declarative pattern specs*
   (:class:`repro.passes.rewrite.Pattern`): an op chain with
   dtype/arity/constness preconditions and capture names, matched along
   single-consumer edges by the shared pattern-rewrite engine.  The specs in
   this module describe the paper's kernels:

     QLINEAR_PATTERN: {MatMulInteger|ConvInteger → [Add] → Cast(f32) →
                       Mul [→ Mul] → [Relu] → QuantizeLinear(1,0)}
         ⇒ one fused int8 MXU kernel (repro.kernels.qmatmul), or XLA int8
           conv + fused epilogue (repro.kernels.ops.quantized_conv2d)
     LUT_PATTERN:     {DequantizeLinear(int8) → [Cast f16] → Tanh|Sigmoid →
                       [Cast f32] → QuantizeLinear}
         ⇒ exact 256-entry VMEM LUT (repro.kernels.qact_lut), built with
           reference-runtime semantics (incl. the fp16 casts) ⇒ bit-exact.

Adding a fusion means adding a Pattern + a builder — there is no hand-written
chain-walking left here.  Anything unmatched falls back to a generic jnp op
mirror, so *every* valid artifact compiles.  Conformance: integer paths are
bit-exact vs :mod:`repro.core.runtime`; float fallbacks are allclose.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..kernels.qact_lut import build_lut
from ..passes import PassManager, PipelineReport
from ..passes.analysis import GraphAnalysis
from ..passes.rewrite import Match, OpSpec, Pattern, match_chain, ql_params
from .pqir import DTYPES, Model, Node

# ---------------------------------------------------------------------------
# generic jnp op mirror (fallback path)
# ---------------------------------------------------------------------------

_JOPS: Dict[str, Callable] = {}


def _jop(name):
    def deco(fn):
        _JOPS[name] = fn
        return fn

    return deco


@_jop("MatMulInteger")
def _j_matmuli(node, ins):
    a, b = ins[0], ins[1]
    a32 = a.astype(jnp.int32) - (ins[2].astype(jnp.int32) if len(ins) > 2 and ins[2] is not None else 0)
    b32 = b.astype(jnp.int32) - (ins[3].astype(jnp.int32) if len(ins) > 3 and ins[3] is not None else 0)
    return [jax.lax.dot_general(a32, b32, (((a32.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32)]


@_jop("ConvInteger")
def _j_convi(node, ins):
    x, w = ins[0], ins[1]
    pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int8) if x.dtype != jnp.uint8 else x.astype(jnp.int32),
        w.astype(jnp.int8),
        window_strides=tuple(node.attrs.get("strides", (1, 1))),
        padding=((pads[0], pads[2]), (pads[1], pads[3])),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=int(node.attrs.get("group", 1)),
        preferred_element_type=jnp.int32,
    )
    return [acc]


@_jop("QuantizeLinear")
def _j_ql(node, ins):
    x, scale = ins[0], ins[1]
    zp = ins[2] if len(ins) > 2 else jnp.zeros((), jnp.int8)
    info = jnp.iinfo(zp.dtype)
    y = jnp.rint(x.astype(jnp.float32) / scale.astype(jnp.float32)) + zp.astype(jnp.float32)
    return [jnp.clip(y, info.min, info.max).astype(zp.dtype)]


@_jop("DequantizeLinear")
def _j_dql(node, ins):
    x, scale = ins[0], ins[1]
    zp = ins[2].astype(jnp.int32) if len(ins) > 2 else 0
    return [(x.astype(jnp.int32) - zp).astype(jnp.float32) * scale.astype(jnp.float32)]


@_jop("Cast")
def _j_cast(node, ins):
    return [ins[0].astype(DTYPES[node.attrs["to"]])]


for _name, _fn in {
    "Mul": lambda node, ins: [ins[0] * ins[1]],
    "Add": lambda node, ins: [ins[0] + ins[1]],
    "Sub": lambda node, ins: [ins[0] - ins[1]],
    "Div": lambda node, ins: [ins[0] // ins[1] if jnp.issubdtype(ins[0].dtype, jnp.integer) else ins[0] / ins[1]],
    "Relu": lambda node, ins: [jnp.maximum(ins[0], jnp.zeros((), ins[0].dtype))],
    "Tanh": lambda node, ins: [jnp.tanh(ins[0]).astype(ins[0].dtype)],
    "Sigmoid": lambda node, ins: [jax.nn.sigmoid(ins[0].astype(jnp.float32)).astype(ins[0].dtype)],
    "Erf": lambda node, ins: [jax.lax.erf(ins[0].astype(jnp.float32)).astype(ins[0].dtype)],
    "Sqrt": lambda node, ins: [jnp.sqrt(ins[0])],
    "Pow": lambda node, ins: [jnp.power(ins[0], ins[1])],
    "Clip": lambda node, ins: [jnp.clip(ins[0], ins[1] if len(ins) > 1 else None, ins[2] if len(ins) > 2 else None)],
    "Softmax": lambda node, ins: [jax.nn.softmax(ins[0].astype(jnp.float32), axis=int(node.attrs.get("axis", -1))).astype(ins[0].dtype)],
    "MatMul": lambda node, ins: [ins[0] @ ins[1]],
    "Reshape": lambda node, ins: [ins[0].reshape(tuple(int(s) for s in np.asarray(ins[1])))],
    "Transpose": lambda node, ins: [jnp.transpose(ins[0], node.attrs.get("perm"))],
    "Flatten": lambda node, ins: [ins[0].reshape((int(np.prod(ins[0].shape[: int(node.attrs.get("axis", 1))])) if int(node.attrs.get("axis", 1)) else 1, -1))],
    "Concat": lambda node, ins: [jnp.concatenate(ins, axis=int(node.attrs["axis"]))],
    "Gather": lambda node, ins: [jnp.take(ins[0], ins[1].astype(jnp.int32), axis=int(node.attrs.get("axis", 0)))],
    "GlobalAveragePool": lambda node, ins: [ins[0].mean(axis=(2, 3), keepdims=True).astype(ins[0].dtype)],
    "ReduceMean": lambda node, ins: [ins[0].mean(axis=tuple(node.attrs.get("axes")) if node.attrs.get("axes") else None, keepdims=bool(node.attrs.get("keepdims", 1))).astype(ins[0].dtype)],
}.items():
    _JOPS[_name] = _fn


@_jop("Gemm")
def _j_gemm(node, ins):
    a, b = ins[0], ins[1]
    if node.attrs.get("transA", 0):
        a = a.T
    if node.attrs.get("transB", 0):
        b = b.T
    y = float(node.attrs.get("alpha", 1.0)) * (a @ b)
    if len(ins) > 2 and ins[2] is not None:
        y = y + float(node.attrs.get("beta", 1.0)) * ins[2]
    return [y.astype(ins[0].dtype)]


@_jop("MaxPool")
def _j_maxpool(node, ins):
    x = ins[0]
    kh, kw = node.attrs["kernel_shape"]
    sh, sw = tuple(node.attrs.get("strides", (kh, kw)))
    pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
    init = -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min
    y = jax.lax.reduce_window(
        x, init, jax.lax.max, (1, 1, kh, kw), (1, 1, sh, sw),
        ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])),
    )
    return [y]


@_jop("AveragePool")
def _j_avgpool(node, ins):
    x = ins[0].astype(jnp.float32)
    kh, kw = node.attrs["kernel_shape"]
    sh, sw = tuple(node.attrs.get("strides", (kh, kw)))
    pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
    y = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, kh, kw), (1, 1, sh, sw),
        ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])),
    ) / (kh * kw)
    return [y.astype(ins[0].dtype)]


# ---------------------------------------------------------------------------
# fusion: declarative pattern specs + kernel builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Step:
    fn: Callable
    inputs: List[str]  # graph-tensor inputs (non-initializer)
    outputs: List[str]
    kind: str  # "fused_qlinear" | "fused_qconv" | "fused_lut" | "generic"


_NP_ACT = {"Tanh": np.tanh, "Sigmoid": lambda x: (1.0 / (1.0 + np.exp(-x.astype(np.float32)))).astype(x.dtype)}


def _is_round_clip_ql(ga: GraphAnalysis, node: Node) -> bool:
    """QuantizeLinear(scale=1, zp=0) — the paper's pure rounding+clipping
    stage whose zp dtype selects the output dtype."""
    scale, zp = ql_params(ga, node)
    return (
        scale is not None and zp is not None
        and scale.size == 1 and np.asarray(zp).size == 1
        and float(scale) == 1.0 and int(np.asarray(zp)) == 0
    )


def _is_sym_scalar_q(ga: GraphAnalysis, node: Node) -> bool:
    """Scalar-scale, zero-zero-point (symmetric) quantize/dequantize."""
    scale, zp = ql_params(ga, node)
    return (
        scale is not None and zp is not None
        and scale.size == 1 and np.asarray(zp).size == 1
        and int(np.asarray(zp)) == 0
    )


def _dql_int8_sym(ga: GraphAnalysis, node: Node) -> bool:
    return ga.dtype(node.inputs[0]) == "int8" and _is_sym_scalar_q(ga, node)


QLINEAR_PATTERN = Pattern(
    "qlinear",
    (
        OpSpec(("MatMulInteger", "ConvInteger"), capture="core", arity=2, const_inputs={1: "weight"}),
        OpSpec("Add", capture="bias", optional=True, const_operand="bias_c"),
        OpSpec("Cast", attrs={"to": "float32"}),
        OpSpec("Mul", capture="mul1", const_operand="mul1_c"),
        OpSpec("Mul", capture="mul2", optional=True, const_operand="mul2_c"),
        OpSpec("Relu", capture="relu", optional=True),
        OpSpec("QuantizeLinear", capture="ql", where=_is_round_clip_ql),
    ),
)

LUT_PATTERN = Pattern(
    "qact_lut",
    (
        OpSpec("DequantizeLinear", capture="dql", where=_dql_int8_sym),
        OpSpec("Cast", capture="to16", optional=True, attrs={"to": "float16"}),
        OpSpec(("Tanh", "Sigmoid"), capture="act"),
        OpSpec("Cast", capture="to32", optional=True, attrs={"to": "float32"}),
        OpSpec("QuantizeLinear", capture="ql", where=_is_sym_scalar_q),
    ),
    # the fp16 down-cast and up-cast appear together or not at all
    where=lambda m: (m.node("to16") is None) == (m.node("to32") is None),
)


def _build_qlinear(compiler: "Compiler", m: Match) -> Step:
    """Lower a QLINEAR_PATTERN match onto the fused int8 matmul / conv."""
    core = m.anchor
    is_conv = core.op_type == "ConvInteger"
    zp = compiler.analysis.const(m.node("ql").inputs[2]) if len(m.node("ql").inputs) > 2 else np.zeros((), np.int8)
    out_dtype = DTYPES[str(np.asarray(zp).dtype)]
    relu = m.node("relu") is not None

    muls = [np.asarray(m.consts["mul1_c"], np.float32)]
    if "mul2" in m:
        muls.append(np.asarray(m.consts["mul2_c"], np.float32))
    two_mul = len(muls) == 2
    qs = jnp.asarray(muls[0])
    qsh = jnp.asarray(muls[1]) if two_mul else jnp.asarray(np.float32(1.0))
    wj = jnp.asarray(m.consts["weight"])
    bias = m.consts.get("bias_c")
    bj = None if bias is None else jnp.asarray(np.asarray(bias).reshape(-1).astype(np.int32))
    backend = compiler.backend

    if is_conv:
        attrs = core.attrs

        def fn(x, _w=wj, _b=bj, _qs=qs, _qsh=qsh):
            return [
                kops.quantized_conv2d(
                    x, _w, _b, _qs, _qsh,
                    strides=tuple(attrs.get("strides", (1, 1))),
                    pads=tuple(attrs.get("pads", (0, 0, 0, 0))),
                    out_dtype=out_dtype, relu=relu, two_mul=two_mul,
                )
            ]

        kind = "fused_qconv"
    else:

        def fn(x, _w=wj, _b=bj, _qs=qs, _qsh=qsh):
            return [
                kops.quantized_matmul(
                    x, _w, _b, _qs, _qsh,
                    out_dtype=out_dtype, relu=relu, two_mul=two_mul, backend=backend,
                )
            ]

        kind = "fused_qlinear"
    return Step(fn, [core.inputs[0]], [m.out_tensor], kind)


def _build_lut(compiler: "Compiler", m: Match) -> Step:
    """Lower a LUT_PATTERN match onto the exact 256-entry VMEM LUT."""
    ga = compiler.analysis
    in_scale, _ = ql_params(ga, m.node("dql"))
    out_scale, out_zp = ql_params(ga, m.node("ql"))
    compute_dtype = "float16" if m.node("to16") is not None else "float32"
    out_dtype = str(np.asarray(out_zp).dtype)
    act = m.node("act").op_type

    lut = build_lut(_NP_ACT[act], float(in_scale), float(out_scale), out_dtype, compute_dtype)
    lut_j = jnp.asarray(lut)
    backend = compiler.backend

    def fn(x, _lut=lut_j):
        return [kops.quantized_activation(x, _lut, backend=backend)]

    return Step(fn, [m.node("dql").inputs[0]], [m.out_tensor], "fused_lut")


#: The compiler's fusion table: (declarative pattern, kernel builder).
#: New fusions plug in here — describe the chain as data, lower in a builder.
FUSIONS = (
    (QLINEAR_PATTERN, _build_qlinear),
    (LUT_PATTERN, _build_lut),
)


class Compiler:
    def __init__(
        self,
        model: Model,
        *,
        backend: str = "ref",
        fuse: bool = True,
        optimize: bool = True,
        verify_passes: bool = False,
    ) -> None:
        model.validate()
        if optimize:
            model, self.pass_report = PassManager(verify=verify_passes).run(model)
        else:
            self.pass_report = PipelineReport(
                nodes_before=len(model.graph.nodes), nodes_after=len(model.graph.nodes)
            )
        self.model = model
        self.graph = model.graph
        self.backend = backend
        self.fuse = fuse
        self.inits = {k: v for k, v in self.graph.initializers.items()}
        self.analysis = GraphAnalysis(self.graph)
        self.steps: List[Step] = []
        self.stats = {
            "fused_qlinear": 0,
            "fused_qconv": 0,
            "fused_lut": 0,
            "generic": 0,
            "folded": self.pass_report.total("folded"),
            "eliminated": self.pass_report.total("eliminated"),
        }

    # -- main ---------------------------------------------------------------
    def compile(self) -> "CompiledModel":
        order = self.graph.toposorted()
        consumed = set()
        for node in order:
            if id(node) in consumed:
                continue
            step = self._fused_step(node, consumed) if self.fuse else None
            if step is None:
                step = self._generic_step(node)
            self.steps.append(step)
            self.stats[step.kind] += 1
        return CompiledModel(self.model, self.steps, self.stats, self.pass_report)

    def _fused_step(self, node: Node, consumed: set) -> Optional[Step]:
        for pattern, builder in FUSIONS:
            if node.op_type not in pattern.anchor_ops:
                continue
            m = match_chain(self.analysis, node, pattern)
            if m is None:
                continue
            step = builder(self, m)
            if step is None:
                continue
            consumed.update(id(n) for n in m.nodes)
            return step
        return None

    def _generic_step(self, node: Node) -> Step:
        fn_impl = _JOPS.get(node.op_type)
        if fn_impl is None:
            raise NotImplementedError(f"compiler has no lowering for op {node.op_type!r}")
        graph_inputs = []
        slots = []  # per node-input: ("env", idx) or ("const", array)
        for name in node.inputs:
            if not name:
                slots.append(("none", None))
            elif name in self.inits:
                slots.append(("const", jnp.asarray(self.inits[name])))
            else:
                slots.append(("env", len(graph_inputs)))
                graph_inputs.append(name)

        def fn(*args, _impl=fn_impl, _node=node, _slots=slots):
            ins = []
            for kind, v in _slots:
                if kind == "none":
                    ins.append(None)
                elif kind == "const":
                    ins.append(v)
                else:
                    ins.append(args[v])
            return _impl(_node, ins)

        return Step(fn, graph_inputs, list(node.outputs), "generic")


class CompiledModel:
    """A compiled artifact: jitted end-to-end executable + fusion report."""

    def __init__(
        self,
        model: Model,
        steps: List[Step],
        stats: Dict[str, int],
        pass_report: Optional[PipelineReport] = None,
    ) -> None:
        self.model = model
        self.steps = steps
        self.stats = stats
        self.pass_report = pass_report if pass_report is not None else PipelineReport()
        self.input_names = [t.name for t in model.graph.inputs]
        self.output_names = [t.name for t in model.graph.outputs]
        self._jitted = jax.jit(self._execute)

    def _execute(self, feeds: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        env = dict(feeds)
        for step in self.steps:
            outs = step.fn(*[env[n] for n in step.inputs])
            for name, v in zip(step.outputs, outs):
                env[name] = v
        return {o: env[o] for o in self.output_names}

    def run(self, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        res = self._jitted({k: jnp.asarray(v) for k, v in feeds.items()})
        return {k: np.asarray(v) for k, v in res.items()}

    def __call__(self, **feeds) -> Dict[str, np.ndarray]:
        return self.run(feeds)

    def lower(self, feeds: Dict[str, jax.ShapeDtypeStruct]):
        return self._jitted.lower(feeds)


def compile_model(
    model: Model,
    *,
    backend: str = "ref",
    fuse: bool = True,
    optimize: bool = True,
    verify_passes: bool = False,
) -> CompiledModel:
    """Compile a PQ-IR artifact for the TPU backend.

    backend:       "pallas" (real TPU lowering), "interpret" (Pallas
                   interpreter — CPU-validatable), "ref" (pure-jnp fused ops;
                   what the dry-run lowers).
    optimize:      run the :mod:`repro.passes` pipeline first (the caller's
                   artifact is cloned, never mutated).
    verify_passes: turn on the pipeline's reference-runtime conformance hook
                   (asserts each pass is semantics-preserving on probe
                   inputs before the backend ever sees the graph).
    """
    return Compiler(
        model, backend=backend, fuse=fuse, optimize=optimize, verify_passes=verify_passes
    ).compile()
