"""The hardware-specific compilation stage: PQ-IR → fused JAX/Pallas executable.

This is the *other side* of the paper's co-design contract.  The quantizer
emitted a standard-ops-only artifact; this compiler recognizes the paper's
patterns and lowers them onto TPU-native fused kernels:

  {MatMulInteger → Add → Cast → Mul (→ Mul) → [Relu] → QuantizeLinear(1,0)}
      ⇒ one fused int8 MXU kernel (repro.kernels.qmatmul)
  {ConvInteger → Add → Cast → Mul (→ Mul) → [Relu] → QuantizeLinear(1,0)}
      ⇒ XLA int8 conv + fused epilogue (repro.kernels.ops.quantized_conv2d)
  {DequantizeLinear → [Cast f16] → Tanh|Sigmoid → [Cast f32] → QuantizeLinear}
      on an int8 tensor
      ⇒ exact 256-entry VMEM LUT (repro.kernels.qact_lut), built with
        reference-runtime semantics (incl. the fp16 casts) ⇒ bit-exact.

Anything unmatched falls back to a generic jnp op mirror, so *every* valid
artifact compiles.  Conformance: integer paths are bit-exact vs
:mod:`repro.core.runtime`; float fallbacks are allclose.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..kernels.qact_lut import build_lut
from .pqir import DTYPES, Graph, Model, Node

# ---------------------------------------------------------------------------
# light dtype inference (enough to validate fusion preconditions)
# ---------------------------------------------------------------------------


def infer_dtypes(graph: Graph) -> Dict[str, str]:
    dt: Dict[str, str] = {t.name: t.dtype for t in graph.inputs}
    for name, arr in graph.initializers.items():
        dt[name] = str(arr.dtype)
    for node in graph.toposorted():
        o = node.outputs[0]
        t = node.op_type
        if t in ("MatMulInteger", "ConvInteger"):
            dt[o] = "int32"
        elif t == "QuantizeLinear":
            dt[o] = dt.get(node.inputs[2], "int8") if len(node.inputs) > 2 else "int8"
        elif t == "DequantizeLinear":
            dt[o] = "float32"
        elif t == "Cast":
            dt[o] = node.attrs["to"]
        elif t in ("Shape",):
            dt[o] = "int64"
        else:
            dt[o] = dt.get(node.inputs[0], "float32")
        for extra in node.outputs[1:]:
            dt[extra] = dt[o]
    return dt


# ---------------------------------------------------------------------------
# generic jnp op mirror (fallback path)
# ---------------------------------------------------------------------------

_JOPS: Dict[str, Callable] = {}


def _jop(name):
    def deco(fn):
        _JOPS[name] = fn
        return fn

    return deco


@_jop("MatMulInteger")
def _j_matmuli(node, ins):
    a, b = ins[0], ins[1]
    a32 = a.astype(jnp.int32) - (ins[2].astype(jnp.int32) if len(ins) > 2 and ins[2] is not None else 0)
    b32 = b.astype(jnp.int32) - (ins[3].astype(jnp.int32) if len(ins) > 3 and ins[3] is not None else 0)
    return [jax.lax.dot_general(a32, b32, (((a32.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32)]


@_jop("ConvInteger")
def _j_convi(node, ins):
    x, w = ins[0], ins[1]
    pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int8) if x.dtype != jnp.uint8 else x.astype(jnp.int32),
        w.astype(jnp.int8),
        window_strides=tuple(node.attrs.get("strides", (1, 1))),
        padding=((pads[0], pads[2]), (pads[1], pads[3])),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=int(node.attrs.get("group", 1)),
        preferred_element_type=jnp.int32,
    )
    return [acc]


@_jop("QuantizeLinear")
def _j_ql(node, ins):
    x, scale = ins[0], ins[1]
    zp = ins[2] if len(ins) > 2 else jnp.zeros((), jnp.int8)
    info = jnp.iinfo(zp.dtype)
    y = jnp.rint(x.astype(jnp.float32) / scale.astype(jnp.float32)) + zp.astype(jnp.float32)
    return [jnp.clip(y, info.min, info.max).astype(zp.dtype)]


@_jop("DequantizeLinear")
def _j_dql(node, ins):
    x, scale = ins[0], ins[1]
    zp = ins[2].astype(jnp.int32) if len(ins) > 2 else 0
    return [(x.astype(jnp.int32) - zp).astype(jnp.float32) * scale.astype(jnp.float32)]


@_jop("Cast")
def _j_cast(node, ins):
    return [ins[0].astype(DTYPES[node.attrs["to"]])]


for _name, _fn in {
    "Mul": lambda node, ins: [ins[0] * ins[1]],
    "Add": lambda node, ins: [ins[0] + ins[1]],
    "Sub": lambda node, ins: [ins[0] - ins[1]],
    "Div": lambda node, ins: [ins[0] // ins[1] if jnp.issubdtype(ins[0].dtype, jnp.integer) else ins[0] / ins[1]],
    "Relu": lambda node, ins: [jnp.maximum(ins[0], jnp.zeros((), ins[0].dtype))],
    "Tanh": lambda node, ins: [jnp.tanh(ins[0]).astype(ins[0].dtype)],
    "Sigmoid": lambda node, ins: [jax.nn.sigmoid(ins[0].astype(jnp.float32)).astype(ins[0].dtype)],
    "Erf": lambda node, ins: [jax.lax.erf(ins[0].astype(jnp.float32)).astype(ins[0].dtype)],
    "Sqrt": lambda node, ins: [jnp.sqrt(ins[0])],
    "Pow": lambda node, ins: [jnp.power(ins[0], ins[1])],
    "Clip": lambda node, ins: [jnp.clip(ins[0], ins[1] if len(ins) > 1 else None, ins[2] if len(ins) > 2 else None)],
    "Softmax": lambda node, ins: [jax.nn.softmax(ins[0].astype(jnp.float32), axis=int(node.attrs.get("axis", -1))).astype(ins[0].dtype)],
    "MatMul": lambda node, ins: [ins[0] @ ins[1]],
    "Reshape": lambda node, ins: [ins[0].reshape(tuple(int(s) for s in np.asarray(ins[1])))],
    "Transpose": lambda node, ins: [jnp.transpose(ins[0], node.attrs.get("perm"))],
    "Flatten": lambda node, ins: [ins[0].reshape((int(np.prod(ins[0].shape[: int(node.attrs.get("axis", 1))])) if int(node.attrs.get("axis", 1)) else 1, -1))],
    "Concat": lambda node, ins: [jnp.concatenate(ins, axis=int(node.attrs["axis"]))],
    "Gather": lambda node, ins: [jnp.take(ins[0], ins[1].astype(jnp.int32), axis=int(node.attrs.get("axis", 0)))],
    "GlobalAveragePool": lambda node, ins: [ins[0].mean(axis=(2, 3), keepdims=True).astype(ins[0].dtype)],
    "ReduceMean": lambda node, ins: [ins[0].mean(axis=tuple(node.attrs.get("axes")) if node.attrs.get("axes") else None, keepdims=bool(node.attrs.get("keepdims", 1))).astype(ins[0].dtype)],
}.items():
    _JOPS[_name] = _fn


@_jop("Gemm")
def _j_gemm(node, ins):
    a, b = ins[0], ins[1]
    if node.attrs.get("transA", 0):
        a = a.T
    if node.attrs.get("transB", 0):
        b = b.T
    y = float(node.attrs.get("alpha", 1.0)) * (a @ b)
    if len(ins) > 2 and ins[2] is not None:
        y = y + float(node.attrs.get("beta", 1.0)) * ins[2]
    return [y.astype(ins[0].dtype)]


@_jop("MaxPool")
def _j_maxpool(node, ins):
    x = ins[0]
    kh, kw = node.attrs["kernel_shape"]
    sh, sw = tuple(node.attrs.get("strides", (kh, kw)))
    pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
    init = -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min
    y = jax.lax.reduce_window(
        x, init, jax.lax.max, (1, 1, kh, kw), (1, 1, sh, sw),
        ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])),
    )
    return [y]


@_jop("AveragePool")
def _j_avgpool(node, ins):
    x = ins[0].astype(jnp.float32)
    kh, kw = node.attrs["kernel_shape"]
    sh, sw = tuple(node.attrs.get("strides", (kh, kw)))
    pads = tuple(node.attrs.get("pads", (0, 0, 0, 0)))
    y = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, kh, kw), (1, 1, sh, sw),
        ((0, 0), (0, 0), (pads[0], pads[2]), (pads[1], pads[3])),
    ) / (kh * kw)
    return [y.astype(ins[0].dtype)]


# ---------------------------------------------------------------------------
# fusion
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Step:
    fn: Callable
    inputs: List[str]  # graph-tensor inputs (non-initializer)
    outputs: List[str]
    kind: str  # "fused_qlinear" | "fused_qconv" | "fused_lut" | "generic"


_NP_ACT = {"Tanh": np.tanh, "Sigmoid": lambda x: (1.0 / (1.0 + np.exp(-x.astype(np.float32)))).astype(x.dtype)}


class Compiler:
    def __init__(self, model: Model, *, backend: str = "ref", fuse: bool = True) -> None:
        model.validate()
        self.model = model
        self.graph = model.graph
        self.backend = backend
        self.fuse = fuse
        self.inits = {k: v for k, v in self.graph.initializers.items()}
        self.dtypes = infer_dtypes(self.graph)
        self.consumers = self.graph.consumers()
        self.out_names = {t.name for t in self.graph.outputs}
        self.steps: List[Step] = []
        self.stats = {"fused_qlinear": 0, "fused_qconv": 0, "fused_lut": 0, "generic": 0}

    # -- helpers ------------------------------------------------------------
    def _single_consumer(self, tensor: str) -> Optional[Node]:
        if tensor in self.out_names:
            return None
        cons = self.consumers.get(tensor, [])
        return cons[0] if len(cons) == 1 else None

    def _init_val(self, name: str) -> Optional[np.ndarray]:
        return self.inits.get(name)

    # -- chain matchers -------------------------------------------------------
    def _match_qlinear(self, node: Node):
        """Match MatMulInteger/ConvInteger → [Add] → Cast → Mul [→ Mul] →
        [Relu] → QuantizeLinear(scale=1, zp=0).  Returns (step, consumed)."""
        is_conv = node.op_type == "ConvInteger"
        x_name, w_name = node.inputs[0], node.inputs[1]
        w = self._init_val(w_name)
        if w is None or len(node.inputs) > 2:
            return None
        cur = node.outputs[0]
        chain = [node]
        nxt = self._single_consumer(cur)
        bias = None
        if nxt is not None and nxt.op_type == "Add":
            other = nxt.inputs[1] if nxt.inputs[0] == cur else nxt.inputs[0]
            b = self._init_val(other)
            if b is not None:
                bias = b
                chain.append(nxt)
                cur = nxt.outputs[0]
                nxt = self._single_consumer(cur)
        if nxt is None or nxt.op_type != "Cast" or nxt.attrs.get("to") != "float32":
            return None
        chain.append(nxt)
        cur = nxt.outputs[0]
        nxt = self._single_consumer(cur)
        muls = []
        while nxt is not None and nxt.op_type == "Mul" and len(muls) < 2:
            other = nxt.inputs[1] if nxt.inputs[0] == cur else nxt.inputs[0]
            mv = self._init_val(other)
            if mv is None:
                break
            muls.append(np.asarray(mv, np.float32))
            chain.append(nxt)
            cur = nxt.outputs[0]
            nxt = self._single_consumer(cur)
        if not muls:
            return None
        relu = False
        if nxt is not None and nxt.op_type == "Relu":
            relu = True
            chain.append(nxt)
            cur = nxt.outputs[0]
            nxt = self._single_consumer(cur)
        if nxt is None or nxt.op_type != "QuantizeLinear":
            return None
        scale = self._init_val(nxt.inputs[1])
        zp = self._init_val(nxt.inputs[2]) if len(nxt.inputs) > 2 else np.zeros((), np.int8)
        if scale is None or zp is None or float(scale) != 1.0 or int(np.asarray(zp)) != 0:
            return None
        chain.append(nxt)
        out_name = nxt.outputs[0]
        out_dtype = DTYPES[str(np.asarray(zp).dtype)]

        two_mul = len(muls) == 2
        qs = jnp.asarray(muls[0])
        qsh = jnp.asarray(muls[1]) if two_mul else jnp.asarray(np.float32(1.0))
        wj = jnp.asarray(w)
        bj = None if bias is None else jnp.asarray(np.asarray(bias).reshape(-1).astype(np.int32))
        backend = self.backend
        if is_conv:
            attrs = node.attrs

            def fn(x, _w=wj, _b=bj, _qs=qs, _qsh=qsh):
                return [
                    kops.quantized_conv2d(
                        x, _w, _b, _qs, _qsh,
                        strides=tuple(attrs.get("strides", (1, 1))),
                        pads=tuple(attrs.get("pads", (0, 0, 0, 0))),
                        out_dtype=out_dtype, relu=relu, two_mul=two_mul,
                    )
                ]

            kind = "fused_qconv"
        else:

            def fn(x, _w=wj, _b=bj, _qs=qs, _qsh=qsh):
                return [
                    kops.quantized_matmul(
                        x, _w, _b, _qs, _qsh,
                        out_dtype=out_dtype, relu=relu, two_mul=two_mul, backend=backend,
                    )
                ]

            kind = "fused_qlinear"
        return Step(fn, [x_name], [out_name], kind), chain

    def _match_lut(self, node: Node):
        """Match DequantizeLinear(int8) → [Cast f16] → Tanh|Sigmoid →
        [Cast f32] → QuantizeLinear."""
        if node.op_type != "DequantizeLinear":
            return None
        x_name = node.inputs[0]
        if self.dtypes.get(x_name) != "int8":
            return None
        in_scale = self._init_val(node.inputs[1])
        in_zp = self._init_val(node.inputs[2]) if len(node.inputs) > 2 else np.zeros((), np.int8)
        if in_scale is None or int(np.asarray(in_zp)) != 0:
            return None
        chain = [node]
        cur = node.outputs[0]
        nxt = self._single_consumer(cur)
        compute_dtype = "float32"
        if nxt is not None and nxt.op_type == "Cast" and nxt.attrs.get("to") == "float16":
            compute_dtype = "float16"
            chain.append(nxt)
            cur = nxt.outputs[0]
            nxt = self._single_consumer(cur)
        if nxt is None or nxt.op_type not in _NP_ACT:
            return None
        act = nxt.op_type
        chain.append(nxt)
        cur = nxt.outputs[0]
        nxt = self._single_consumer(cur)
        if compute_dtype == "float16":
            if nxt is None or nxt.op_type != "Cast" or nxt.attrs.get("to") != "float32":
                return None
            chain.append(nxt)
            cur = nxt.outputs[0]
            nxt = self._single_consumer(cur)
        if nxt is None or nxt.op_type != "QuantizeLinear":
            return None
        out_scale = self._init_val(nxt.inputs[1])
        out_zp = self._init_val(nxt.inputs[2]) if len(nxt.inputs) > 2 else np.zeros((), np.int8)
        if out_scale is None or int(np.asarray(out_zp)) != 0:
            return None
        chain.append(nxt)
        out_name = nxt.outputs[0]
        out_dtype = str(np.asarray(out_zp).dtype)

        lut = build_lut(_NP_ACT[act], float(in_scale), float(out_scale), out_dtype, compute_dtype)
        lut_j = jnp.asarray(lut)
        backend = self.backend

        def fn(x, _lut=lut_j):
            return [kops.quantized_activation(x, _lut, backend=backend)]

        return Step(fn, [x_name], [out_name], "fused_lut"), chain

    # -- main ---------------------------------------------------------------
    def compile(self) -> "CompiledModel":
        order = self.graph.toposorted()
        consumed = set()
        for node in order:
            if id(node) in consumed:
                continue
            if self.fuse:
                m = None
                if node.op_type in ("MatMulInteger", "ConvInteger"):
                    m = self._match_qlinear(node)
                elif node.op_type == "DequantizeLinear":
                    m = self._match_lut(node)
                if m is not None:
                    step, chain = m
                    for n in chain:
                        consumed.add(id(n))
                    self.steps.append(step)
                    self.stats[step.kind] += 1
                    continue
            self.steps.append(self._generic_step(node))
            self.stats["generic"] += 1
        return CompiledModel(self.model, self.steps, self.stats)

    def _generic_step(self, node: Node) -> Step:
        fn_impl = _JOPS.get(node.op_type)
        if fn_impl is None:
            raise NotImplementedError(f"compiler has no lowering for op {node.op_type!r}")
        graph_inputs = []
        slots = []  # per node-input: ("env", idx) or ("const", array)
        for name in node.inputs:
            if not name:
                slots.append(("none", None))
            elif name in self.inits:
                slots.append(("const", jnp.asarray(self.inits[name])))
            else:
                slots.append(("env", len(graph_inputs)))
                graph_inputs.append(name)

        def fn(*args, _impl=fn_impl, _node=node, _slots=slots):
            ins = []
            for kind, v in _slots:
                if kind == "none":
                    ins.append(None)
                elif kind == "const":
                    ins.append(v)
                else:
                    ins.append(args[v])
            return _impl(_node, ins)

        return Step(fn, graph_inputs, list(node.outputs), "generic")


class CompiledModel:
    """A compiled artifact: jitted end-to-end executable + fusion report."""

    def __init__(self, model: Model, steps: List[Step], stats: Dict[str, int]) -> None:
        self.model = model
        self.steps = steps
        self.stats = stats
        self.input_names = [t.name for t in model.graph.inputs]
        self.output_names = [t.name for t in model.graph.outputs]
        self._jitted = jax.jit(self._execute)

    def _execute(self, feeds: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        env = dict(feeds)
        for step in self.steps:
            outs = step.fn(*[env[n] for n in step.inputs])
            for name, v in zip(step.outputs, outs):
                env[name] = v
        return {o: env[o] for o in self.output_names}

    def run(self, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        res = self._jitted({k: jnp.asarray(v) for k, v in feeds.items()})
        return {k: np.asarray(v) for k, v in res.items()}

    def __call__(self, **feeds) -> Dict[str, np.ndarray]:
        return self.run(feeds)

    def lower(self, feeds: Dict[str, jax.ShapeDtypeStruct]):
        return self._jitted.lower(feeds)


def compile_model(model: Model, *, backend: str = "ref", fuse: bool = True) -> CompiledModel:
    """Compile a PQ-IR artifact for the TPU backend.

    backend: "pallas" (real TPU lowering), "interpret" (Pallas interpreter —
    CPU-validatable), "ref" (pure-jnp fused ops; what the dry-run lowers).
    """
    return Compiler(model, backend=backend, fuse=fuse).compile()
