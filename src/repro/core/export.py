"""Export: JAX models → pre-quantized PQ-IR artifacts.

Closes the co-design loop: a model trained (optionally with QAT) in this
framework is calibrated on sample data and emitted as a standard-ops-only
pre-quantized artifact — which the *same* framework's hardware compiler
(:mod:`repro.core.compile`) or any conforming runtime can execute.

``export_mlp_params`` handles the paper-scale MLP/CNN cases end-to-end;
``export_linear_stack`` is the generic N-layer path used by the QAT example.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .calibrate import make_observer
from .pqir import GraphBuilder, Model
from .quant import choose_scale, quantize_linear_layer
from . import patterns


def export_linear_stack(
    weights: Sequence[np.ndarray],  # (in, out) f32 each
    biases: Sequence[Optional[np.ndarray]],
    activations: Sequence[Optional[str]],  # None | "Relu" | "Tanh" | "Sigmoid"
    calib_inputs: np.ndarray,
    *,
    observer: str = "absmax",
    name: str = "exported_model",
    two_mul: bool = True,
    tanh_mode: str = "int8",
) -> Model:
    """Calibrate + emit a pre-quantized artifact for a stack of linears."""
    from .toolchain import MLPSpec, quantize_mlp

    spec = MLPSpec(list(map(np.asarray, weights)), [None if b is None else np.asarray(b) for b in biases], list(activations))
    return quantize_mlp(spec, np.asarray(calib_inputs, np.float32), observer=observer, name=name, two_mul=two_mul, tanh_mode=tanh_mode)


def export_quant_report(model: Model) -> dict:
    """Summarize the embedded quantization parameters of an artifact —
    useful for co-design reviews (which layers got which scales/shifts)."""
    report = {"name": model.graph.name, "layers": []}
    for node in model.graph.nodes:
        if node.op_type not in ("MatMulInteger", "ConvInteger"):
            continue
        prefix = node.name.rsplit("_", 1)[0] if node.name else node.inputs[1].rsplit("_", 2)[0]
        init = model.graph.initializers
        w_name = node.inputs[1]
        entry = {"op": node.op_type, "weight": w_name, "weight_shape": list(init[w_name].shape)}
        for key in list(init):
            if key.startswith(prefix := w_name.rsplit("_weight_q", 1)[0]):
                if key.endswith("quant_scale"):
                    entry["quant_scale"] = int(float(init[key]))
                elif key.endswith("quant_shift"):
                    entry["quant_shift_bits"] = int(round(-np.log2(float(init[key]))))
                elif key.endswith("quant_multiplier"):
                    entry["quant_multiplier"] = float(init[key])
        report["layers"].append(entry)
    return report
