"""Quantizer-side toolchain: fp32 model + calibration data → PQ-IR artifact.

This is the "independent development" half of the paper's co-design story:
everything here runs with *no knowledge of the target hardware* — it profiles
activations, picks scales, quantizes weights/biases per §3, decomposes the
rescale multipliers per §3.1, and emits a standard-ops-only artifact.  The
hardware team consumes the artifact via :mod:`repro.core.compile`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from . import patterns
from .calibrate import make_observer
from .pqir import GraphBuilder, Model
from .quant import (
    choose_scale,
    choose_scales,
    decompose_multiplier,
    decompose_multipliers,
    quantize,
    quantize_bias,
    quantize_linear_layer,
)


@dataclasses.dataclass
class MLPSpec:
    """A float MLP: x @ W1 + b1 -> act -> … -> logits."""

    weights: List[np.ndarray]  # each (in, out), float32
    biases: List[Optional[np.ndarray]]
    activations: List[Optional[str]]  # per layer: None|"Relu"|"Tanh"|"Sigmoid"|...

    def forward(self, x: np.ndarray) -> List[np.ndarray]:
        """Returns the list of per-layer pre-activation/post-activation outputs
        (used for calibration)."""
        outs = []
        h = x.astype(np.float32)
        for w, b, act in zip(self.weights, self.biases, self.activations):
            h = h @ w
            if b is not None:
                h = h + b
            if act == "Relu":
                h = np.maximum(h, 0.0)
            elif act == "Tanh":
                h = np.tanh(h)
            elif act == "Sigmoid":
                h = 1.0 / (1.0 + np.exp(-h))
            outs.append(h)
        return outs


def quantize_mlp(
    spec: MLPSpec,
    calib_data: np.ndarray,
    *,
    observer: str = "absmax",
    name: str = "prequantized_mlp",
    two_mul: bool = True,
    per_channel: bool = False,
    tanh_mode: str = "int8",  # "int8" (Fig 4) or "fp16" (Fig 5)
    weight_bits: int = 8,
) -> Model:
    """Produce a complete pre-quantized MLP artifact (the paper's §4 example
    generalized to N layers).

    ``weight_bits=4`` codifies every FC layer's weights on [-8, 7] (QONNX-style
    sub-8-bit lane): the graph carries a ``weight_bits`` attr per core op and
    the backend packs two nibbles per byte at plan time."""
    n_layers = len(spec.weights)
    # ---- calibration pass (quantizer side, hardware-agnostic) ----
    obs_in = make_observer(observer)
    obs_in.observe(calib_data)
    layer_outs = spec.forward(calib_data)
    obs_layers = []
    for h in layer_outs:
        o = make_observer(observer)
        o.observe(h)
        obs_layers.append(o)

    gb = GraphBuilder(name)
    in_dtype = "int8"
    scale_x = obs_in.scale(in_dtype)
    x = gb.add_input("input_q", in_dtype, (None, spec.weights[0].shape[0]))
    cur_scale = scale_x
    for i, (w, b, act) in enumerate(zip(spec.weights, spec.biases, spec.activations)):
        prefix = f"fc{i}"
        last = i == n_layers - 1
        out_dtype = "uint8" if act == "Sigmoid" else "int8"
        if act in ("Tanh", "Sigmoid"):
            # Activation patterns fix their own output scale convention.
            scale_y = (1.0 / 127.0) if act == "Tanh" else (1.0 / 255.0)
            absmax = patterns.TANH_INPUT_ABSMAX if act == "Tanh" else patterns.SIGMOID_INPUT_ABSMAX
            # FC rescale maps accumulator onto the activation's input range.
            p = quantize_linear_layer(
                w, b, cur_scale, absmax / 127.0, per_channel=per_channel, in_dtype=in_dtype, out_dtype="int8",
                bits=weight_bits,
            )
            if act == "Tanh":
                fn = patterns.fc_int8_tanh if tanh_mode == "int8" else patterns.fc_fp16_tanh
                x = fn(gb, x, p, prefix, input_absmax=absmax)
            else:
                x = patterns.fc_fp16_sigmoid(gb, x, p, prefix, input_absmax=absmax)
        else:
            scale_y = choose_scale(_absmax_of(obs_layers[i]), out_dtype)
            p = quantize_linear_layer(
                w, b, cur_scale, scale_y, per_channel=per_channel, in_dtype=in_dtype, out_dtype=out_dtype,
                bits=weight_bits,
            )
            x = patterns.fc_layer(gb, x, p, prefix, two_mul=two_mul, activation=act)
        cur_scale = scale_y
        in_dtype = out_dtype
    gb.add_output(x, in_dtype, (None, spec.weights[-1].shape[1]))
    model = gb.build()
    model.metadata.update({"source": "repro.toolchain.quantize_mlp", "input_scale": repr(scale_x), "output_scale": repr(cur_scale)})
    return model


def _absmax_of(obs) -> float:
    a = obs.absmax
    return float(a() if callable(a) else a)


@dataclasses.dataclass
class ConvLayerSpec:
    weight: np.ndarray  # (M, C, kH, kW) float32
    bias: Optional[np.ndarray]
    strides: Sequence[int] = (1, 1)
    pads: Sequence[int] = (0, 0, 0, 0)
    activation: Optional[str] = None  # None | "Relu"


@dataclasses.dataclass
class CNNSpec:
    """Conv stack + optional trailing FC head (LeNet-style)."""

    convs: List[ConvLayerSpec]
    head: Optional[MLPSpec] = None

    def forward_convs(self, x: np.ndarray) -> List[np.ndarray]:
        from .runtime import _conv2d_f32  # reuse reference conv

        outs = []
        h = x.astype(np.float32)
        for c in self.convs:
            attrs = {"strides": tuple(c.strides), "pads": tuple(c.pads)}
            h = _conv2d_f32(h, c.weight.astype(np.float32), attrs)
            if c.bias is not None:
                h = h + c.bias.reshape(1, -1, 1, 1)
            if c.activation == "Relu":
                h = np.maximum(h, 0.0)
            outs.append(h)
        return outs


def quantize_cnn(
    spec: CNNSpec,
    calib_data: np.ndarray,
    *,
    observer: str = "absmax",
    name: str = "prequantized_cnn",
    two_mul: bool = False,
    per_channel: bool = False,
) -> Model:
    """Produce the paper's §5 CNN artifact (ConvInteger pattern), optionally
    followed by a flattened FC head.  With ``per_channel=True`` every conv
    filter (output channel) and FC output feature gets its own weight scale
    and §3.1 rescale decomposition, codified as vector Mul constants."""
    obs_in = make_observer(observer)
    obs_in.observe(calib_data)
    conv_outs = spec.forward_convs(calib_data)

    gb = GraphBuilder(name)
    scale_x = obs_in.scale("int8")
    n, c, h, w = calib_data.shape
    x = gb.add_input("input_q", "int8", (None, c, h, w))
    cur_scale = scale_x
    for i, (conv, out_f32) in enumerate(zip(spec.convs, conv_outs)):
        prefix = f"conv{i}"
        o = make_observer(observer)
        o.observe(out_f32)
        scale_y = choose_scale(_absmax_of(o), "int8")
        if per_channel:
            # One weight scale per conv filter (output channel M), quantized
            # against the (C, kH, kW) slice it scales.
            scale_w = choose_scales(np.abs(conv.weight).max(axis=(1, 2, 3)), "int8")
            w_q = quantize(conv.weight, scale_w.reshape(-1, 1, 1, 1), "int8")
            rescale = decompose_multipliers(scale_w.astype(np.float64) * cur_scale / scale_y)
        else:
            scale_w = choose_scale(float(np.abs(conv.weight).max()), "int8")
            w_q = quantize(conv.weight, scale_w, "int8")
            rescale = decompose_multiplier(scale_w * cur_scale / scale_y)
        b_q = None
        if conv.bias is not None:
            b_q = quantize_bias(conv.bias, scale_w, cur_scale)
        x = patterns.conv_layer(
            gb,
            x,
            w_q,
            b_q,
            rescale,
            prefix,
            strides=conv.strides,
            pads=conv.pads,
            two_mul=two_mul,
            activation=conv.activation,
        )
        cur_scale = scale_y
        last_shape = out_f32.shape
    if spec.head is not None:
        # Flatten NCHW → (N, C*H*W) then reuse the FC pattern.
        x = gb.op("Flatten", [x], out_hint="flat", axis=1)
        flat_dim = int(np.prod(last_shape[1:]))
        h_in = conv_outs[-1].reshape(conv_outs[-1].shape[0], -1)
        head_outs = spec.head.forward(h_in)
        for j, (wgt, b, act) in enumerate(zip(spec.head.weights, spec.head.biases, spec.head.activations)):
            o = make_observer(observer)
            o.observe(head_outs[j])
            out_dtype = "uint8" if act == "Sigmoid" else "int8"
            scale_y = choose_scale(_absmax_of(o), out_dtype)
            p = quantize_linear_layer(
                wgt, b, cur_scale, scale_y, per_channel=per_channel, in_dtype="int8", out_dtype=out_dtype
            )
            x = patterns.fc_layer(gb, x, p, f"head{j}", two_mul=two_mul, activation=act)
            cur_scale = scale_y
        gb.add_output(x, out_dtype, (None, spec.head.weights[-1].shape[1]))
    else:
        gb.add_output(x, "int8", (None,) + tuple(last_shape[1:]))
    model = gb.build()
    model.metadata.update({"source": "repro.toolchain.quantize_cnn", "input_scale": repr(scale_x), "output_scale": repr(cur_scale)})
    return model
