"""Model-zoo W8A8 conversion — the paper's technique as a first-class serving
feature for all 10 architectures.

``convert_params_w8a8(params)`` walks the param tree and replaces every large
GEMM weight with the pre-quantized representation ``{"q8": int8, "s": f32
per-out-channel scales}``; :func:`repro.models.layers.linear` (and the MoE
expert einsums) then compute the paper's MatMulInteger → rescale chain with
int8 operands on the MXU.  Decode is bandwidth-bound, so halving weight bytes
is a direct attack on the dominant roofline term (EXPERIMENTS.md §Perf).

Deliberately kept in higher precision (DESIGN.md §4): MoE routers, norms,
LoRA/decay side-channels (rwkv6), embeddings, and the logits readout.
``export_arch_quant_manifest`` emits the artifact-side record of every
quantized tensor with its §3.1 integer scale+shift decomposition, so the
conversion is *codified*, not implicit.
"""
from __future__ import annotations

from typing import Dict, Set

import jax
import jax.numpy as jnp
import numpy as np

from .quant import decompose_multiplier

# weight leaves (by path-leaf name) that convert to W8A8
W8A8_NAMES: Set[str] = {
    "wq", "wk", "wv", "wo", "wr", "wg",
    "w_gate", "w_up", "w_down",
    "shared_w_gate", "shared_w_up", "shared_w_down",
    "q_down", "q_up", "kv_down", "kv_up",
    "in_proj", "out_proj",
    "cm_wk", "cm_wv", "cm_wr",
}


def _quantize_leaf(w: jax.Array) -> Dict[str, jax.Array]:
    """Symmetric per-out-channel int8.  Only the contraction dim (-2) is
    reduced; leading stack dims (layer scan, expert, hybrid group) keep their
    own scales, so scanned slices see ({"q8": (in,out)}, {"s": (out,)})."""
    wf = w.astype(jnp.float32)
    absmax = jnp.abs(wf).max(axis=w.ndim - 2)
    s = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.rint(wf / jnp.expand_dims(s, w.ndim - 2)), -128, 127).astype(jnp.int8)
    return {"q8": q, "s": s}


def convert_params_w8a8(params) -> dict:
    def conv(path, leaf):
        names = [str(getattr(k, "key", "")) for k in path]
        if names[-1] in W8A8_NAMES and leaf.ndim >= 2:
            return _quantize_leaf(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(conv, params, is_leaf=lambda x: not isinstance(x, dict))


def export_arch_quant_manifest(params_q) -> dict:
    """Codify the conversion: every quantized tensor with its per-channel
    scale stats and the §3.1 (Quant_scale, shift) decomposition of a unit
    rescale — the hardware-facing record the artifact would embed."""
    entries = []

    def walk(path, leaf):
        if isinstance(leaf, dict) or not hasattr(leaf, "shape"):
            return leaf
        return leaf

    flat = jax.tree_util.tree_flatten_with_path(params_q)[0]
    seen = set()
    for path, leaf in flat:
        names = [str(getattr(k, "key", "")) for k in path]
        if names[-1] == "s" and len(names) >= 2 and names[-2] not in seen:
            base = "/".join(names[:-1])
            s = np.asarray(leaf, np.float64).ravel()
            r = decompose_multiplier(float(np.median(s)))
            entries.append(
                {
                    "tensor": base,
                    "channels": int(s.size),
                    "scale_min": float(s.min()),
                    "scale_max": float(s.max()),
                    "quant_scale_median": r.quant_scale,
                    "quant_shift_bits_median": r.shift,
                }
            )
    return {"format": "pq-w8a8/v1", "tensors": entries}
