"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

* Fixed decode batch of ``slots``; finished/empty slots are refilled from the
  request queue each cycle (per-slot KV regions are written independently, so
  admission is a host-side decision — the decode step never re-compiles).
* Prefill runs per admitted request (right-padded to a bucket length to bound
  recompiles), then its KV cache is scattered into the slot's region.
* ``kv_cache_dtype="int8"`` serves with the paper's symmetric int8 cache.

The engine core (queue, slot bookkeeping, sampling, metrics) is model-
agnostic: all model execution goes through a *token-path adapter* with four
methods — ``init_cache`` / ``prefill`` / ``decode`` / ``scatter``.  Two
adapters exist:

* :class:`OpaqueModelAdapter` (default) — the original jitted-JAX seam:
  ``repro.models.model`` prefill/decode with one jitted prefill per prompt
  bucket and a single jitted decode step.
* :class:`repro.serving.token_path.CompiledTokenAdapter` — the PQ-IR lane:
  prefill and decode are :class:`~repro.core.compile.CompiledModel` plans
  sharing one :class:`~repro.backend.plan.PlanCache`, the KV cache is the
  plan's persistent int8 state slots, and every decode step executes a
  pre-specialized ExecutionPlan (zero per-step re-lowering).

At fleet scale the same structure runs per model replica with the scheduler
sharded by a front-end router; the engine here is single-replica but the
step functions are the pjit-able ones from repro.launch.steps.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..backend.plan import PlanCache, bucket_multiple
from ..configs.base import ModelConfig
from ..models import model as M
from ..obs import trace as _trace
from ..obs.metrics import MetricsRegistry


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    # filled by the engine:
    generated: Optional[List[int]] = None
    done: bool = False
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass
class EngineConfig:
    slots: int = 4
    max_len: int = 256
    prefill_bucket: int = 32  # prompts right-padded to a multiple of this
    greedy: bool = True
    temperature: float = 1.0  # sampling path only (greedy=False)
    top_k: int = 0  # 0 ⇒ sample the full vocab
    seed: int = 0  # host-side sampling rng seed
    # resident jitted prefill fns (LRU beyond); None = one per possible
    # prompt bucket (max_len // prefill_bucket) so steady traffic over the
    # full bucket range never thrashes — the bound exists for configs where
    # that product is large, not to cause recompiles in the common case
    prefill_cache_size: Optional[int] = None


def _prefill_capacity(ecfg: "EngineConfig") -> int:
    """Resolve the prefill-cache bound: explicit config wins, else one slot
    per reachable prompt bucket (prompts are padded to multiples of
    ``prefill_bucket`` and capped by ``max_len``)."""
    if ecfg.prefill_cache_size is not None:
        return ecfg.prefill_cache_size
    return max(1, ecfg.max_len // ecfg.prefill_bucket)


#: Module-level fallback sampler state: callers that don't thread an rng
#: (the engine always does — see ``ServeEngine._select``) draw from one
#: seeded stream instead of a fresh ``default_rng()`` per call, so unseeded
#: use is reproducible run-to-run.  Reset it with :func:`seed_sampler`.
_FALLBACK_RNG = np.random.default_rng(0)


def seed_sampler(seed: int) -> None:
    """Re-seed the module fallback rng used when ``sample_token`` is called
    without an explicit generator."""
    global _FALLBACK_RNG
    _FALLBACK_RNG = np.random.default_rng(seed)


def sample_token(
    logits: np.ndarray,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> int:
    """Sample one token id from a logits row (host-side, numpy).

    ``temperature <= 0`` degenerates to argmax; ``top_k > 0`` restricts
    sampling to the k highest logits (ties at the k-th value are all kept,
    so the candidate set is never smaller than k).  Without an explicit
    ``rng`` the seeded module fallback stream is used (:func:`seed_sampler`),
    never a fresh unseeded generator per call."""
    z = np.asarray(logits, np.float64).reshape(-1)
    if temperature <= 0.0:
        return int(z.argmax())
    if top_k and top_k < z.size:
        kth = np.partition(z, -top_k)[-top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z / temperature
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    rng = rng if rng is not None else _FALLBACK_RNG
    return int(rng.choice(z.size, p=p))


class OpaqueModelAdapter:
    """The engine's original jitted-JAX token path, behind the adapter seam.

    One jitted prefill per prompt bucket (bounded LRU — adversarial
    prompt-length traffic would otherwise pin one jitted fn per bucket
    forever; sizes surface in the engine metrics), one jitted decode step.
    The prefill cache is the same :class:`PlanCache` (LRU + uniform
    hit/miss/hit_rate accounting) the compiled-model path uses for its
    per-bucket plan specializations — the prefill path is the token engine's
    instance of exactly that per-shape discipline.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        compute_dtype=jnp.float32,
        prefill_cache_capacity: int = 8,
    ) -> None:
        self.params = params
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self._decode = jax.jit(
            lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg, compute_dtype=compute_dtype)
        )
        self.prefill_cache: PlanCache = PlanCache(prefill_cache_capacity, scope="prefill")

    def init_cache(self, slots: int, max_len: int):
        return M.init_cache(self.cfg, slots, max_len)

    def _prefill_fn(self, plen: int):
        jitted = self.prefill_cache.get(plen)
        if jitted is None:
            cfg, dt = self.cfg, self.compute_dtype

            def fn(params, tokens, cache):
                return M.prefill(params, {"tokens": tokens}, cfg, cache, compute_dtype=dt, q_chunk=min(plen, 512), kv_chunk=min(plen, 512))

            jitted = jax.jit(fn)
            self.prefill_cache.put(plen, jitted)
        return jitted

    def prefill(self, padded: np.ndarray, plen: int, max_len: int):
        """Run one right-padded prompt ``(1, bucket)``; returns the logits row
        for the true last prompt token and the single-request KV cache."""
        bucket = padded.shape[1]
        pcache = M.init_cache(self.cfg, 1, max_len)
        logits, pcache = self._prefill_fn(bucket)(self.params, jnp.asarray(padded), pcache)
        return self._logits_at(padded, plen, logits, pcache)

    def _logits_at(self, padded, plen, last_logits, pcache):
        """Logits for the true last prompt token (bucket may extend past it)."""
        if plen == padded.shape[1]:
            return last_logits[0], pcache
        # re-run a single decode on position plen-1's token? simpler: prefill
        # returns last-position logits; for bucketed prompts recompute from the
        # cached hidden is avoided by decoding token plen-1 explicitly.
        tok = jnp.asarray(padded[:, plen - 1 : plen])
        pos = jnp.full((1,), plen - 1, jnp.int32)
        logits, _ = self._decode(self.params, tok, pos, pcache)
        return logits[0], pcache

    def decode(self, toks: np.ndarray, pos: np.ndarray, cache):
        """One batched decode step over all slots; positions are per-slot."""
        return self._decode(self.params, jnp.asarray(toks), jnp.asarray(pos), cache)

    def scatter(self, cache, slot: int, pcache):
        """Write a prefilled single-request cache into one slot's region."""
        def scat(dst, src):
            if dst.ndim == src.ndim and dst.shape[1:] == src.shape[1:] and src.shape[0] == 1:
                return dst.at[slot : slot + 1].set(src)
            # stacked layer dim first: (L, B, ...) — batch is axis 1
            return dst.at[:, slot : slot + 1].set(src)

        return jax.tree.map(scat, cache, pcache)


class ServeEngine:
    def __init__(
        self,
        params=None,
        cfg: Optional[ModelConfig] = None,
        ecfg: EngineConfig = None,
        *,
        compute_dtype=jnp.float32,
        registry: Optional[MetricsRegistry] = None,
        adapter=None,
    ) -> None:
        if ecfg is None:
            raise ValueError("ServeEngine requires an EngineConfig")
        # cache length must cover the largest prefill bucket (same round-up-
        # to-multiple policy the compiled-model grid uses for sequence axes)
        ecfg = dataclasses.replace(
            ecfg, max_len=bucket_multiple(ecfg.max_len, ecfg.prefill_bucket)
        )
        self.ecfg = ecfg
        if adapter is None:
            if params is None or cfg is None:
                raise ValueError(
                    "ServeEngine needs either (params, cfg) for the default "
                    "OpaqueModelAdapter or an explicit adapter="
                )
            adapter = OpaqueModelAdapter(
                params, cfg, compute_dtype=compute_dtype,
                prefill_cache_capacity=_prefill_capacity(ecfg),
            )
        self.adapter = adapter
        self.params = getattr(adapter, "params", params)
        self.cfg = getattr(adapter, "cfg", cfg)
        self.compute_dtype = compute_dtype
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}  # slot -> request
        self.slot_pos = np.zeros((ecfg.slots,), np.int32)
        self.slot_live = np.zeros((ecfg.slots,), bool)
        self.slot_budget = np.zeros((ecfg.slots,), np.int32)
        self.cache = adapter.init_cache(ecfg.slots, ecfg.max_len)
        self._rng = np.random.default_rng(ecfg.seed)
        # per-instance registry unless the caller injects a shared one; the
        # adapter's prefill cache (when it keeps one) publishes its canonical
        # cache.prefill.* gauges and the flat prefill_cache_* keys below stay
        # as read-only aliases
        self.registry = registry if registry is not None else MetricsRegistry()
        self._prefill_cache: Optional[PlanCache] = getattr(adapter, "prefill_cache", None)
        if self._prefill_cache is not None:
            self._prefill_cache.attach_metrics(self.registry)
        self.metrics = {
            "decode_steps": 0,
            "prefills": 0,
            "completed": 0,
            "prefill_cache_size": 0,
            "prefill_cache_hits": 0,
            "prefill_cache_evictions": 0,
            "prefill_cache_hit_rate": 0.0,
        }

    def _count(self, key: str, n: int = 1) -> None:
        """One accounting site: the flat alias dict and the canonical
        ``engine.<key>`` registry counter move together."""
        self.metrics[key] += n
        self.registry.counter(f"engine.{key}").inc(n)

    def _select(self, logits_row) -> int:
        """Next-token choice for one slot: argmax (greedy) or
        temperature/top-k sampling."""
        if self.ecfg.greedy:
            return int(np.asarray(logits_row).argmax())
        return sample_token(
            np.asarray(logits_row),
            temperature=self.ecfg.temperature,
            top_k=self.ecfg.top_k,
            rng=self._rng,
        )

    # -- request lifecycle ----------------------------------------------------
    def submit(self, req: Request) -> None:
        """Admission-time validation, then enqueue (same discipline as
        ``CompiledModelServer.submit``: reject at the boundary, never let a
        bad request reach the batched hot loop)."""
        plen = len(req.prompt)
        if plen < 1:
            raise ValueError("prompt must contain at least one token")
        if req.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {req.max_new_tokens}")
        bucket = bucket_multiple(plen, self.ecfg.prefill_bucket)
        if bucket > self.ecfg.max_len or (req.max_new_tokens > 1 and plen >= self.ecfg.max_len):
            # the per-slot KV cache is init_cache(cfg, 1, max_len): a prefill
            # bucket beyond it (or a decode position at max_len) would clip
            # the cache write silently — reject instead
            raise ValueError(
                f"prompt of {plen} tokens (prefill bucket {bucket}) does not fit the "
                f"per-slot KV cache (max_len={self.ecfg.max_len}); shorten the prompt "
                "or raise EngineConfig.max_len"
            )
        req.t_submit = time.monotonic()
        req.generated = []
        self.queue.append(req)

    def _sync_cache_metrics(self) -> None:
        if self._prefill_cache is None:
            return
        stats = self._prefill_cache.stats
        self.metrics["prefill_cache_size"] = stats["size"]
        self.metrics["prefill_cache_hits"] = stats["hits"]
        self.metrics["prefill_cache_evictions"] = stats["evictions"]
        self.metrics["prefill_cache_hit_rate"] = stats["hit_rate"]

    def _admit(self) -> None:
        for slot in range(self.ecfg.slots):
            # a request whose budget is exhausted by the prefill token never
            # occupies the slot, so keep admitting until it is actually filled
            while not self.slot_live[slot] and self.queue:
                req = self.queue.popleft()
                plen = len(req.prompt)
                bucket = bucket_multiple(plen, self.ecfg.prefill_bucket)
                padded = np.zeros((1, bucket), np.int32)
                padded[0, :plen] = req.prompt
                # prefill writes [0, bucket); only [0, plen) is meaningful — the
                # causal mask means padding beyond plen is never attended by
                # positions < plen, and decode continues exactly at plen.
                with _trace.span("engine.prefill", uid=req.uid, plen=plen, bucket=bucket):
                    first_logits, pcache = self.adapter.prefill(
                        padded, plen, self.ecfg.max_len
                    )
                self._sync_cache_metrics()
                tok = self._select(first_logits)
                req.generated.append(tok)
                req.t_first = time.monotonic()
                self._count("prefills")
                if req.max_new_tokens <= 1:
                    # the prefill token already spent the whole budget: done at
                    # admit — decoding the slot once more would emit a second
                    # token and violate max_new_tokens
                    req.done = True
                    req.t_done = req.t_first
                    self._count("completed")
                    continue
                self.cache = self.adapter.scatter(self.cache, slot, pcache)
                self.active[slot] = req
                self.slot_pos[slot] = plen
                self.slot_live[slot] = True
                self.slot_budget[slot] = req.max_new_tokens - 1

    # -- main loop --------------------------------------------------------------
    def step(self) -> None:
        """One engine cycle: admit + one batched decode step."""
        self._admit()
        if not self.slot_live.any():
            return
        toks = np.zeros((self.ecfg.slots, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.generated[-1]
        with _trace.span("engine.decode", live=int(self.slot_live.sum())):
            logits, self.cache = self.adapter.decode(toks, self.slot_pos, self.cache)
        self._count("decode_steps")
        if self.ecfg.greedy:
            # argmax on device: transfers `slots` ints, not slots×vocab floats
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            pick = lambda slot: int(nxt[slot])  # noqa: E731
        else:
            logits_np = np.asarray(logits)
            pick = lambda slot: self._select(logits_np[slot])  # noqa: E731
        for slot in list(self.active):
            if not self.slot_live[slot]:
                continue
            req = self.active[slot]
            req.generated.append(pick(slot))
            self.slot_pos[slot] += 1
            self.slot_budget[slot] -= 1
            if self.slot_budget[slot] <= 0 or self.slot_pos[slot] >= self.ecfg.max_len - 1:
                req.done = True
                req.t_done = time.monotonic()
                self._count("completed")
                self.slot_live[slot] = False
                del self.active[slot]

    def run_until_drained(self, max_cycles: int = 10_000) -> None:
        for _ in range(max_cycles):
            if not self.queue and not self.active:
                return
            self.step()
        raise RuntimeError("serve loop did not drain")
