"""The transformer token path codified in PQ-IR: prefill + decode artifacts
with the int8 KV cache as persistent plan state.

This module is the paper's co-design story applied to serving: the whole
transformer block — joint QKV projection, per-head fused int8 attention,
output projection, saturating residuals, MLP — is *codified* as two PQ-IR
graphs and compiled once each:

* **prefill** — ``tokens ("N","S")`` + causal ``mask ("N","S","S")`` in,
  f32 logits and the per-layer int8 K/V rows out.  Compiles to a two-axis
  ``("N","S")`` artifact; prompts run at their (batch, prompt-bucket) cell.
* **decode** — ``tokens ("N",1)`` + scatter ``onehot ("N","S",1)`` + validity
  ``mask ("N",1,"S")`` in, with the per-layer KV caches declared as
  :class:`repro.core.pqir.StateSpec` **state slots**: the lowering pins their
  buffers across invocations and ``specialize_plan`` binds their seq extent
  per bucket.  One token per step, zero re-lowering per step.

The KV update is itself codified — int8 elementwise, exact under padding::

    new_kv = kv * (1 - onehot) + kv_new * onehot

Both graphs share one :class:`~repro.backend.plan.PlanCache` (graph-qualified
keys), so a serving engine holds exactly one specialization per visited
(batch × seq-bucket) cell across prefill *and* decode.

Every layer's projections ride the fused qlinear lane (sub-8-bit weights
included — ``bits_*`` config fields), attention rides the fused ``qattention``
kernel, and the jnp mirrors (:func:`prefill_jax` / :func:`decode_jax`) are
bit-exact against the compiled artifacts — the differential sweep in
``tests/test_token_path.py`` pins all three runtimes against each other.

:class:`CompiledTokenAdapter` plugs the compiled pair into
:class:`repro.serving.engine.ServeEngine` behind the same adapter seam the
opaque-JAX model uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..backend.plan import PlanCache
from ..core import pqir
from ..core.compile import CompiledModel, compile_model
from ..core.patterns import (
    ATTN_BIG,
    ATTN_LUT_SCALE,
    ATTN_P_SCALE,
    build_exp_lut,
    emit_qattention,
    emit_round_clip,
    fc_layer,
)
from ..core.quant import QuantizedLinearParams, quantize_linear_layer
from ..kernels import ref as _ref

__all__ = [
    "TokenPathConfig",
    "TokenPathParams",
    "make_token_params",
    "build_prefill_model",
    "build_decode_model",
    "prefill_jax",
    "decode_jax",
    "CompiledTokenPath",
    "CompiledTokenAdapter",
]


@dataclasses.dataclass(frozen=True)
class TokenPathConfig:
    """Shape + precision config for the codified transformer block.

    Activations live on one shared int8 scale (``act_scale``) — residual adds
    are then plain saturating code-domain adds, and the attention rescale
    collapses to ``1 / p_scale``.  ``bits_*`` select the weight lane per
    projection (4 ⇒ QONNX-style ``weight_bits`` attribute, packed-int4 kernel
    on the tiled backends), so one model mixes w4 and w8 layers."""

    vocab: int = 128
    d_model: int = 64
    n_heads: int = 2
    d_ff: int = 128
    n_layers: int = 2
    act_scale: float = 0.05
    lm_scale: float = 0.01
    bits_qkv: int = 4
    bits_o: int = 8
    bits_up: int = 8
    bits_down: int = 4

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def qk_scale(self) -> float:
        return float(self.act_scale * self.act_scale / np.sqrt(self.d_head))

    @property
    def att_rescale(self) -> float:
        # s_v / (p_scale * s_out) with s_v == s_out == act_scale
        return float(1.0 / ATTN_P_SCALE)


@dataclasses.dataclass
class TokenPathParams:
    """Pre-quantized parameters of the token path (what the artifact embeds)."""

    embedding: np.ndarray  # (vocab, d_model) int8 codes; row 0 all-zero
    layers: List[Dict[str, QuantizedLinearParams]]
    lm_head: np.ndarray  # (d_model, vocab) int8
    lm_scale: float


def make_token_params(cfg: TokenPathConfig, seed: int = 0) -> TokenPathParams:
    """Deterministic pre-quantized parameters.  Weights are drawn small enough
    that activations stay inside int8 on typical inputs (bit-exactness never
    depends on this — saturation is itself exact — it just keeps the logits
    informative)."""
    rng = np.random.default_rng(seed)
    emb = rng.integers(-40, 41, (cfg.vocab, cfg.d_model)).astype(np.int8)
    emb[0] = 0  # token 0 doubles as padding: zero embedding
    s = cfg.act_scale

    def lin(n_in: int, n_out: int, bits: int) -> QuantizedLinearParams:
        w = rng.normal(size=(n_in, n_out)).astype(np.float32) * (0.6 / np.sqrt(n_in))
        b = rng.normal(size=(n_out,)).astype(np.float32) * 0.02
        return quantize_linear_layer(w, b, s, s, bits=bits)

    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                "qkv": lin(cfg.d_model, 3 * cfg.d_model, cfg.bits_qkv),
                "o": lin(cfg.d_model, cfg.d_model, cfg.bits_o),
                "up": lin(cfg.d_model, cfg.d_ff, cfg.bits_up),
                "down": lin(cfg.d_ff, cfg.d_model, cfg.bits_down),
            }
        )
    head = rng.integers(-64, 65, (cfg.d_model, cfg.vocab)).astype(np.int8)
    return TokenPathParams(emb, layers, head, cfg.lm_scale)


# ---------------------------------------------------------------------------
# PQ-IR emission
# ---------------------------------------------------------------------------

def _slice_feat(gb: pqir.GraphBuilder, x: str, lo: int, hi: int, prefix: str) -> str:
    """Slice [lo, hi) of the trailing feature axis (axis 2)."""
    st = gb.add_initializer(f"{prefix}_starts", np.array([lo], np.int64))
    en = gb.add_initializer(f"{prefix}_ends", np.array([hi], np.int64))
    ax = gb.add_initializer(f"{prefix}_axes", np.array([2], np.int64))
    return gb.op("Slice", [x, st, en, ax], out_hint=f"{prefix}_out")


def _residual(gb: pqir.GraphBuilder, a: str, b: str, prefix: str) -> str:
    """Saturating int8 residual: both operands share act_scale, so the add is
    code-domain — Cast f32 (exact for int8), Add, round+clip back to int8."""
    fa = gb.op("Cast", [a], out_hint=f"{prefix}_a_f", to="float32")
    fb = gb.op("Cast", [b], out_hint=f"{prefix}_b_f", to="float32")
    sm = gb.op("Add", [fa, fb], out_hint=f"{prefix}_sum")
    return emit_round_clip(gb, sm, prefix)


def _kv_update(gb: pqir.GraphBuilder, state: str, new: str, onehot: str, prefix: str) -> str:
    """``new_kv = kv·(1-onehot) + kv_new·onehot`` — int8 elementwise (codes are
    bounded by ±127·1, so no overflow), exact under zero padding: padded rows
    have onehot 0 and state 0, contributing 0."""
    one = gb.add_initializer(f"{prefix}_one", np.int8(1))
    keep = gb.op("Sub", [one, onehot], out_hint=f"{prefix}_keep")
    kept = gb.op("Mul", [state, keep], out_hint=f"{prefix}_kept")
    put = gb.op("Mul", [new, onehot], out_hint=f"{prefix}_put")
    return gb.op("Add", [kept, put], out_hint=f"{prefix}_new")


def _attention(
    gb: pqir.GraphBuilder,
    cfg: TokenPathConfig,
    q_full: str,
    k_full: str,
    v_full: str,
    mask: str,
    prefix: str,
) -> str:
    """Per-head fused attention regions + head concat over the feature axis."""
    dh = cfg.d_head
    heads = []
    for h in range(cfg.n_heads):
        qh = _slice_feat(gb, q_full, h * dh, (h + 1) * dh, f"{prefix}_q{h}")
        kh = _slice_feat(gb, k_full, h * dh, (h + 1) * dh, f"{prefix}_k{h}")
        vh = _slice_feat(gb, v_full, h * dh, (h + 1) * dh, f"{prefix}_v{h}")
        heads.append(
            emit_qattention(
                gb, qh, kh, vh, mask, f"{prefix}_att{h}",
                qk_scale=cfg.qk_scale, rescale=cfg.att_rescale,
            )
        )
    if len(heads) == 1:
        return heads[0]
    return gb.op("Concat", heads, out_hint=f"{prefix}_ctx", axis=2)


def _mlp(gb, x: str, p: Dict[str, QuantizedLinearParams], prefix: str) -> str:
    up = fc_layer(gb, x, p["up"], f"{prefix}_up", activation="Relu")
    return fc_layer(gb, up, p["down"], f"{prefix}_down")


def _lm_head(gb, cfg: TokenPathConfig, params: TokenPathParams, x: str) -> str:
    """Unfused f32 logits: MatMulInteger → Cast → Mul(lm_scale)."""
    w = gb.add_initializer("lm_head_q", params.lm_head)
    acc = gb.op("MatMulInteger", [x, w], out_hint="lm_acc")
    f = gb.op("Cast", [acc], out_hint="lm_f", to="float32")
    sc = gb.add_initializer("lm_scale", np.float32(params.lm_scale))
    return gb.op("Mul", [f, sc], out_hint="logits")


def build_prefill_model(cfg: TokenPathConfig, params: TokenPathParams) -> pqir.Model:
    """The two-axis prefill artifact: logits + per-layer K/V cache rows.

    Outputs: ``logits ("N","S",V) f32`` first, then the K and V cache rows
    ``("N","S",D) int8`` per layer, in the same (k, v) × layer order as the
    decode graph's declared states — :class:`CompiledTokenPath` zips the two,
    so a prefilled cache feeds decode directly."""
    D, V = cfg.d_model, cfg.vocab
    gb = pqir.GraphBuilder("token_prefill")
    gb.add_input("tokens", "int32", ("N", "S"))
    gb.add_input("mask", "float32", ("N", "S", "S"))
    table = gb.add_initializer("embedding_q", params.embedding)
    x = gb.op("Gather", [table, "tokens"], out_hint="emb", axis=0)
    kv_outs: List[Tuple[str, str]] = []
    for l, p in enumerate(params.layers):
        pfx = f"l{l}"
        qkv = fc_layer(gb, x, p["qkv"], f"{pfx}_qkv")
        qf = _slice_feat(gb, qkv, 0, D, f"{pfx}_qs")
        kf = _slice_feat(gb, qkv, D, 2 * D, f"{pfx}_ks")
        vf = _slice_feat(gb, qkv, 2 * D, 3 * D, f"{pfx}_vs")
        ctx = _attention(gb, cfg, qf, kf, vf, "mask", pfx)
        o = fc_layer(gb, ctx, p["o"], f"{pfx}_o")
        x1 = _residual(gb, x, o, f"{pfx}_res1")
        x = _residual(gb, x1, _mlp(gb, x1, p, pfx), f"{pfx}_res2")
        kv_outs.append((kf, vf))
    logits = _lm_head(gb, cfg, params, x)
    gb.add_output(logits, "float32", ("N", "S", V))
    for l, (kf, vf) in enumerate(kv_outs):
        # renamed via identity-free aliasing: the Slice outputs *are* the
        # cache rows; expose them under the decode state-input names
        gb.add_output(kf, "int8", ("N", "S", D))
        gb.add_output(vf, "int8", ("N", "S", D))
    return gb.build(opset=17)


def build_decode_model(cfg: TokenPathConfig, params: TokenPathParams) -> pqir.Model:
    """The one-token decode artifact with KV state slots.

    Inputs: ``tokens ("N",1)``, ``onehot ("N","S",1) int8`` (scatter position
    of the new K/V row), ``mask ("N",1,"S")`` (validity: positions ≤ current),
    plus per-layer state inputs ``k_cache_l`` / ``v_cache_l ("N","S",D)``.
    Each state's updated tensor is both a graph output and a declared
    :class:`~repro.core.pqir.StateSpec`, so the lowering pins its buffers."""
    D, V = cfg.d_model, cfg.vocab
    gb = pqir.GraphBuilder("token_decode")
    gb.add_input("tokens", "int32", ("N", 1))
    gb.add_input("onehot", "int8", ("N", "S", 1))
    gb.add_input("mask", "float32", ("N", 1, "S"))
    for l in range(cfg.n_layers):
        gb.add_input(f"k_cache_{l}", "int8", ("N", "S", D))
        gb.add_input(f"v_cache_{l}", "int8", ("N", "S", D))
    table = gb.add_initializer("embedding_q", params.embedding)
    x = gb.op("Gather", [table, "tokens"], out_hint="emb", axis=0)
    updates: List[Tuple[str, str]] = []
    for l, p in enumerate(params.layers):
        pfx = f"l{l}"
        qkv = fc_layer(gb, x, p["qkv"], f"{pfx}_qkv")
        qf = _slice_feat(gb, qkv, 0, D, f"{pfx}_qs")
        kn = _slice_feat(gb, qkv, D, 2 * D, f"{pfx}_ks")
        vn = _slice_feat(gb, qkv, 2 * D, 3 * D, f"{pfx}_vs")
        k_upd = _kv_update(gb, f"k_cache_{l}", kn, "onehot", f"{pfx}_kupd")
        v_upd = _kv_update(gb, f"v_cache_{l}", vn, "onehot", f"{pfx}_vupd")
        ctx = _attention(gb, cfg, qf, k_upd, v_upd, "mask", pfx)
        o = fc_layer(gb, ctx, p["o"], f"{pfx}_o")
        x1 = _residual(gb, x, o, f"{pfx}_res1")
        x = _residual(gb, x1, _mlp(gb, x1, p, pfx), f"{pfx}_res2")
        updates.append((k_upd, v_upd))
    logits = _lm_head(gb, cfg, params, x)
    gb.add_output(logits, "float32", ("N", 1, V))
    for l, (k_upd, v_upd) in enumerate(updates):
        gb.add_output(k_upd, "int8", ("N", "S", D))
        gb.add_output(v_upd, "int8", ("N", "S", D))
        gb.add_state(f"kv{l}_k", input=f"k_cache_{l}", output=k_upd)
        gb.add_state(f"kv{l}_v", input=f"v_cache_{l}", output=v_upd)
    return gb.build(opset=17)


# ---------------------------------------------------------------------------
# jnp mirrors — the opaque-JAX twin the compiled artifacts are pinned against
# ---------------------------------------------------------------------------

def _fc_jax(x_q, p: QuantizedLinearParams, *, relu: bool = False):
    r = p.rescale
    return _ref.qmatmul_ref(
        jnp.asarray(x_q), jnp.asarray(p.weight_q),
        None if p.bias_q is None else jnp.asarray(p.bias_q),
        jnp.float32(r.quant_scale), jnp.float32(r.quant_shift),
        relu=relu, two_mul=True,
    )


def _residual_jax(a, b):
    s = a.astype(jnp.float32) + b.astype(jnp.float32)
    return jnp.clip(jnp.rint(s), -128, 127).astype(jnp.int8)


def _attention_jax(cfg: TokenPathConfig, q, k, v, mask, lut):
    dh = cfg.d_head
    heads = []
    for h in range(cfg.n_heads):
        sl = slice(h * dh, (h + 1) * dh)
        heads.append(
            _ref.qattention_ref(
                q[..., sl], k[..., sl], v[..., sl], mask,
                jnp.float32(cfg.qk_scale), jnp.float32(ATTN_BIG),
                jnp.float32(ATTN_LUT_SCALE), jnp.asarray(lut),
                jnp.float32(ATTN_P_SCALE), jnp.float32(cfg.att_rescale),
                out_dtype=jnp.int8,
            )
        )
    return jnp.concatenate(heads, axis=-1)


def _block_jax(cfg, p, x, k_full, v_full, q_full, mask, lut):
    ctx = _attention_jax(cfg, q_full, k_full, v_full, mask, lut)
    o = _fc_jax(ctx, p["o"])
    x1 = _residual_jax(x, o)
    up = _fc_jax(x1, p["up"], relu=True)
    down = _fc_jax(up, p["down"])
    return _residual_jax(x1, down)


def _logits_jax(params: TokenPathParams, x):
    acc = jnp.matmul(x.astype(jnp.int32), jnp.asarray(params.lm_head).astype(jnp.int32))
    return acc.astype(jnp.float32) * jnp.float32(params.lm_scale)


def prefill_jax(cfg: TokenPathConfig, params: TokenPathParams, tokens, mask, lut=None):
    """jnp mirror of the prefill artifact: op-for-op the same integer/f32
    chain, so the result is bit-identical.  Returns (logits, [(k, v)] per
    layer)."""
    lut = build_exp_lut() if lut is None else lut
    D = cfg.d_model
    x = jnp.take(jnp.asarray(params.embedding), jnp.asarray(tokens, jnp.int32), axis=0)
    caches = []
    for p in params.layers:
        qkv = _fc_jax(x, p["qkv"])
        qf, kf, vf = qkv[..., :D], qkv[..., D : 2 * D], qkv[..., 2 * D :]
        caches.append((kf, vf))
        x = _block_jax(cfg, p, x, kf, vf, qf, mask, lut)
    return _logits_jax(params, x), caches


def decode_jax(cfg: TokenPathConfig, params: TokenPathParams, tokens, onehot, mask, states, lut=None):
    """jnp mirror of the decode artifact.  ``states`` is [(k, v)] per layer;
    returns (logits, new_states) with the codified int8 scatter update."""
    lut = build_exp_lut() if lut is None else lut
    D = cfg.d_model
    oh = jnp.asarray(onehot, jnp.int8)
    keep = (jnp.int8(1) - oh).astype(jnp.int8)
    x = jnp.take(jnp.asarray(params.embedding), jnp.asarray(tokens, jnp.int32), axis=0)
    new_states = []
    for p, (k_st, v_st) in zip(params.layers, states):
        qkv = _fc_jax(x, p["qkv"])
        qf, kn, vn = qkv[..., :D], qkv[..., D : 2 * D], qkv[..., 2 * D :]
        k_upd = (jnp.asarray(k_st) * keep + kn * oh).astype(jnp.int8)
        v_upd = (jnp.asarray(v_st) * keep + vn * oh).astype(jnp.int8)
        new_states.append((k_upd, v_upd))
        x = _block_jax(cfg, p, x, k_upd, v_upd, qf, mask, lut)
    return _logits_jax(params, x), new_states


# ---------------------------------------------------------------------------
# compiled pair + engine adapter
# ---------------------------------------------------------------------------

class CompiledTokenPath:
    """The prefill/decode artifact pair compiled onto one shared PlanCache.

    Keys in the shared cache are graph-qualified, so the pair holds exactly
    one specialization per visited (graph, batch-bucket, seq-bucket) cell —
    ``cache_stats()`` makes that observable."""

    def __init__(
        self,
        cfg: Optional[TokenPathConfig] = None,
        params: Optional[TokenPathParams] = None,
        *,
        backend: str = "ref",
        seed: int = 0,
        s_granularity: int = 32,
        plan_cache_capacity: int = 32,
        autotune=None,
    ) -> None:
        self.cfg = cfg if cfg is not None else TokenPathConfig()
        self.params = params if params is not None else make_token_params(self.cfg, seed)
        self.plan_cache = PlanCache(plan_cache_capacity, scope="plan")
        self.prefill_model = build_prefill_model(self.cfg, self.params)
        self.decode_model = build_decode_model(self.cfg, self.params)
        kw = dict(
            backend=backend,
            batch="dynamic",
            dynamic_axes={"N": None, "S": s_granularity},
            plan_cache=self.plan_cache,
            autotune=autotune,
        )
        self.prefill_cm: CompiledModel = compile_model(self.prefill_model, **kw)
        self.decode_cm: CompiledModel = compile_model(self.decode_model, **kw)
        self._logits_prefill = self.prefill_model.graph.outputs[0].name
        self._logits_decode = self.decode_model.graph.outputs[0].name
        self.state_specs = list(self.decode_model.graph.states)
        # prefill outputs [1:] are the per-layer (k, v) rows in state order
        pre_kv = [t.name for t in self.prefill_model.graph.outputs[1:]]
        self._prefill_kv = {s.input: n for s, n in zip(self.state_specs, pre_kv)}
        # jitted one-dispatch decode steps, keyed by exact (N, S) cell
        self._step_fns: Dict[Tuple[int, int], object] = {}

    # -- direct run API -------------------------------------------------------
    def prefill(self, tokens: np.ndarray, mask: np.ndarray):
        """Returns (logits (N,S,V) f32, {state-input name: (N,S,D) int8})."""
        outs = self.prefill_cm.run({"tokens": np.asarray(tokens, np.int32), "mask": mask})
        cache = {inp: np.asarray(outs[name]) for inp, name in self._prefill_kv.items()}
        return np.asarray(outs[self._logits_prefill]), cache

    def decode(self, tokens, onehot, mask, cache: Dict[str, np.ndarray]):
        """One decode step.  Returns (logits (N,1,V), next cache dict)."""
        feeds = {
            "tokens": np.asarray(tokens, np.int32),
            "onehot": np.asarray(onehot, np.int8),
            "mask": mask,
        }
        feeds.update(cache)
        outs = self.decode_cm.run(feeds)
        nxt = {s.input: np.asarray(outs[s.output]) for s in self.state_specs}
        return np.asarray(outs[self._logits_decode]), nxt

    def decode_step(self, tokens, pos, cache):
        """The decode hot loop: one step at *exact* bucket extents, keeping
        the KV state as device arrays across steps.

        ``decode()`` round-trips every feed and output through host numpy —
        correct, and what the differential tests pin — but on the serving
        steady state those conversions dominate: the jitted executor itself
        is an order of magnitude cheaper than the per-feed device puts and
        per-output host syncs.  Here the position onehot and causal mask
        are built *inside* one jitted step function (host→device traffic
        per token = the sampled tokens and positions, nothing else), the
        state dict flows back in untouched as device arrays, and only the
        logits are materialized on host.  The specialized entry is still
        fetched from the shared PlanCache on every call, so cell accounting
        is identical to the slow path: one miss per first-visited cell,
        hits thereafter.  Falls back to :meth:`decode` when the extents are
        not bucket-aligned (then padding/slicing is required and the slow
        path is the correct one).  Returns (logits (N, V) ndarray, next
        cache of device arrays)."""
        n = int(np.shape(tokens)[0])
        s = int(np.shape(next(iter(cache.values())))[1])
        cm = self.decode_cm
        if cm.bucket_for("N", n) != n or cm.bucket_for("S", s) != s:
            pos = np.asarray(pos, np.int64)
            onehot = np.zeros((n, s, 1), np.int8)
            onehot[np.arange(n), np.clip(pos, 0, s - 1), 0] = 1
            mask = (np.arange(s)[None, None, :] <= pos[:, None, None]).astype(np.float32)
            logits, nxt = self.decode(tokens, onehot, mask, cache)
            return logits[:, 0, :], nxt
        plan, _ = cm.specialized({"N": n, "S": s})  # per-step cell accounting
        fn = self._step_fns.get((n, s))
        if fn is None:
            logits_name, specs = self._logits_decode, self.state_specs

            def step(toks, pos, cache):
                onehot = (jnp.arange(s)[None, :, None] == pos[:, None, None]).astype(jnp.int8)
                mask = (jnp.arange(s)[None, None, :] <= pos[:, None, None]).astype(jnp.float32)
                feeds = {"tokens": toks, "onehot": onehot, "mask": mask}
                feeds.update(cache)
                outs = plan.execute(feeds)
                return outs[logits_name][:, 0, :], {sp.input: outs[sp.output] for sp in specs}

            fn = self._step_fns[(n, s)] = jax.jit(step)
        logits, nxt = fn(
            jnp.asarray(tokens, jnp.int32), jnp.asarray(np.asarray(pos), jnp.int32), cache
        )
        return np.asarray(logits), nxt

    def init_cache(self, n: int, s: int) -> Dict[str, np.ndarray]:
        D = self.cfg.d_model
        return {spec.input: np.zeros((n, s, D), np.int8) for spec in self.state_specs}

    def cache_stats(self) -> Dict[str, float]:
        return self.plan_cache.stats


class CompiledTokenAdapter:
    """ServeEngine adapter for the compiled token path.

    ``init_cache``/``prefill``/``decode``/``scatter`` mirror
    :class:`repro.serving.engine.OpaqueModelAdapter`'s seam, but every call
    executes a pre-specialized ExecutionPlan out of the shared PlanCache —
    after the first step per cell there is zero lowering work per token."""

    def __init__(self, tp: CompiledTokenPath) -> None:
        self.tp = tp
        self.cfg = tp.cfg
        self.max_len = 0
        # no per-bucket jitted-fn cache here — plan specialization IS the
        # per-bucket discipline, surfaced via tp.cache_stats()
        self.prefill_cache = None

    def init_cache(self, slots: int, max_len: int):
        self.max_len = max_len
        return self.tp.init_cache(slots, max_len)

    @staticmethod
    def _causal_mask(n: int, s: int) -> np.ndarray:
        return np.broadcast_to(
            np.tril(np.ones((s, s), np.float32)), (n, s, s)
        ).copy()

    def prefill(self, padded: np.ndarray, plen: int, max_len: int):
        bucket = padded.shape[1]
        logits, cache = self.tp.prefill(padded, self._causal_mask(1, bucket))
        return logits[0, plen - 1], cache

    def scatter(self, cache, slot: int, pcache):
        # cache values may be device arrays (the decode fast path keeps them
        # there between steps); np.array materializes either kind
        out = {}
        for name, buf in cache.items():
            rows = np.asarray(pcache[name])
            dst = np.array(buf, copy=True)
            n = min(rows.shape[1], dst.shape[1])
            dst[slot, :n] = rows[0, :n]
            # rows ≥ prompt bucket keep their zeros: masked until the decode
            # onehot overwrites them position by position
            out[name] = dst
        return out

    def decode(self, toks: np.ndarray, pos: np.ndarray, cache):
        return self.tp.decode_step(toks, pos, cache)
