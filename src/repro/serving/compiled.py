"""Micro-batching server for batch-polymorphic compiled PQ-IR artifacts.

The token engine (:mod:`repro.serving.engine`) serves the transformer stack;
this module serves the *compiled models the paper is actually about*: one
``compile_model(batch="dynamic")`` artifact, heavy request traffic, no
per-shape recompiles.  The structure mirrors the token engine's
request-lifecycle and metrics discipline (submit → step → drain; timestamped
requests; a flat ``metrics`` dict), specialized to single-shot inference:

* **Coalescing** — each :meth:`~CompiledModelServer.step` takes up to
  ``max_batch`` queued requests and runs them as one batch.  The compiled
  model pads that batch to the next power-of-two *bucket* and serves it from
  its bounded :class:`~repro.backend.plan.PlanCache`, so steady-state traffic
  of any size mix touches a handful of plan specializations — the vLLM-style
  shape-bucketing answer to "serve millions of users from one artifact".
* **Padding/slicing** — zero-row padding is exact for the artifact vocabulary
  (ops are elementwise along the leading dim); each request gets back exactly
  its own rows, bit-identical to a solo run.
* **Metrics** — per-bucket batch counts, padded-row overhead, plan-cache
  hit/miss/size, and request latency/throughput summaries.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from ..backend.plan import batch_bucket
from ..core.compile import CompiledModel


@dataclasses.dataclass
class CompiledRequest:
    """One inference request: a single example (no batch dim)."""

    uid: int
    x: np.ndarray
    # filled by the server:
    outputs: Optional[Dict[str, np.ndarray]] = None
    done: bool = False
    t_submit: float = 0.0
    t_done: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class CompiledServerConfig:
    max_batch: int = 32  # largest coalesced batch (its bucket bounds jit traces)
    latency_window: int = 4096  # latency samples kept for summary() aggregates

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.latency_window < 1:
            raise ValueError(f"latency_window must be >= 1, got {self.latency_window}")


class CompiledModelServer:
    """Queue + micro-batching loop over a batch-polymorphic CompiledModel."""

    def __init__(self, cm: CompiledModel, cfg: Optional[CompiledServerConfig] = None) -> None:
        if not cm.is_dynamic:
            raise ValueError(
                "CompiledModelServer needs a batch-polymorphic artifact — "
                'compile with compile_model(..., batch="dynamic")'
            )
        if len(cm.batch_input_names) != 1 or len(cm.input_names) != 1:
            raise ValueError(
                f"the micro-batching server coalesces over exactly one input, "
                f"which must carry the batch dim — model has inputs "
                f"{cm.input_names} (batch-carrying: {cm.batch_input_names})"
            )
        self.cm = cm
        self.cfg = cfg if cfg is not None else CompiledServerConfig()
        self.input_name = cm.batch_input_names[0]
        in_t = next(t for t in cm.model.graph.inputs if t.name == self.input_name)
        self._example_shape = tuple(in_t.shape[1:])  # dims may be None (unknown)
        self._example_dtype = np.dtype(in_t.dtype)
        self.queue: Deque[CompiledRequest] = deque()
        self._uid = 0
        # bounded: a long-lived server keeps a sliding latency window, not
        # one float per request forever
        self._latencies: Deque[float] = deque(maxlen=self.cfg.latency_window)
        self.metrics: Dict[str, Any] = {
            "requests": 0,
            "batches": 0,
            "completed": 0,
            "padded_rows": 0,  # bucket rows minus real rows, summed
            "bucket_batches": {},  # bucket -> number of coalesced batches
        }

    # -- request lifecycle ----------------------------------------------------
    def submit(self, x: np.ndarray) -> CompiledRequest:
        """Enqueue one example (shape = model input shape without the batch
        dim); returns the request handle whose ``outputs`` fill on completion.

        Shape/dtype are validated here, at admission — a bad example must be
        rejected up front, not blow up a coalesced batch mid-``step`` and
        take its co-batched requests down with it."""
        x = np.asarray(x)
        ok = len(x.shape) == len(self._example_shape) and all(
            want is None or got == want for got, want in zip(x.shape, self._example_shape)
        )
        if not ok or x.dtype != self._example_dtype:
            raise ValueError(
                f"request example must have shape {self._example_shape} and "
                f"dtype {self._example_dtype}, got {x.shape} {x.dtype}"
            )
        req = CompiledRequest(uid=self._uid, x=x, t_submit=time.monotonic())
        self._uid += 1
        self.queue.append(req)
        self.metrics["requests"] += 1
        return req

    # -- main loop ------------------------------------------------------------
    def step(self) -> List[CompiledRequest]:
        """One server cycle: coalesce up to ``max_batch`` queued requests into
        a single bucketed model execution.  Returns the completed requests."""
        if not self.queue:
            return []
        n = min(len(self.queue), self.cfg.max_batch)
        reqs = [self.queue.popleft() for _ in range(n)]
        batch = np.stack([r.x for r in reqs])
        # the compiled model pads n → bucket and serves the bucket's plan
        # from its PlanCache; we only account for the coalescing here
        try:
            outs = self.cm.run({self.input_name: batch})
        except Exception:
            # backend/jit failure must not lose the coalesced requests: put
            # them back at the head of the queue (original order) and let
            # the caller decide whether to retry
            self.queue.extendleft(reversed(reqs))
            raise
        bucket = batch_bucket(n)
        self.metrics["batches"] += 1
        self.metrics["padded_rows"] += bucket - n
        hist = self.metrics["bucket_batches"]
        hist[bucket] = hist.get(bucket, 0) + 1
        now = time.monotonic()
        batch_outs = self.cm.batch_output_names
        for i, req in enumerate(reqs):
            # only batch-carrying outputs scatter per request; anything
            # batch-independent (e.g. a constant auxiliary output) is shared
            req.outputs = {k: (v[i] if k in batch_outs else v) for k, v in outs.items()}
            req.done = True
            req.t_done = now
            self._latencies.append(now - req.t_submit)
        self.metrics["completed"] += n
        return reqs

    def run_until_drained(self, max_cycles: int = 10_000) -> List[CompiledRequest]:
        """Step until the queue is empty; returns everything completed."""
        done: List[CompiledRequest] = []
        for _ in range(max_cycles):
            if not self.queue:
                return done
            done.extend(self.step())
        raise RuntimeError("compiled-model serve loop did not drain")

    # -- reporting ------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Serving metrics + plan-cache behavior + latency aggregates."""
        lat = np.asarray(self._latencies, np.float64)
        cache = self.cm.cache_stats
        served = cache["hits"] + cache["misses"]
        out = dict(self.metrics)
        out["bucket_batches"] = dict(self.metrics["bucket_batches"])  # snapshot, not alias
        out.update(
            plan_cache=cache,
            plan_cache_hit_rate=(cache["hits"] / served) if served else 0.0,
            latency_avg_ms=float(lat.mean() * 1e3) if lat.size else None,
            latency_p95_ms=float(np.percentile(lat, 95) * 1e3) if lat.size else None,
            latency_max_ms=float(lat.max() * 1e3) if lat.size else None,
        )
        return out
