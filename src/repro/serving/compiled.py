"""Micro-batching server for scenario-polymorphic compiled PQ-IR artifacts.

The token engine (:mod:`repro.serving.engine`) serves the transformer stack;
this module serves the *compiled models the paper is actually about*: one
``compile_model(dynamic_axes=...)`` artifact, heavy request traffic, no
per-shape recompiles.  The structure mirrors the token engine's
request-lifecycle and metrics discipline (submit → step → drain; timestamped
requests; a flat ``metrics`` dict), specialized to single-shot inference:

* **Coalescing** — each :meth:`~CompiledModelServer.step` takes up to
  ``max_batch`` queued requests and runs them as one batch.  Coalescing is
  *axis-aware and multi-input*: a request carries one example per model
  input (a bare ndarray is single-input sugar), every input is stacked
  along the shared leading batch axis, and per-request named-axis extents
  are validated consistent across the request's inputs at submit.  With a
  variable-length sequence axis the requests are right-padded to the longest
  sequence in the group first, so the whole group lands on one cell of the
  (batch-bucket × seq-bucket) grid; the compiled model pads batch and
  sequence to their per-axis buckets and serves the cell from its bounded
  :class:`~repro.backend.plan.PlanCache` — the vLLM-style shape-bucketing
  answer to "serve millions of users from one artifact", now over a 2-D
  scenario grid instead of a single free axis.
* **Deadline-aware admission** — with ``max_wait_ms`` set, a step holds off
  on a partial batch until either ``max_batch`` requests are queued or the
  *oldest* queued request has aged past the window; ageing out launches the
  partial batch immediately (a *window hit*, surfaced in :meth:`summary`).
  The default (``max_wait_ms=None``) keeps the PR 4 greedy drain.
* **Padding/slicing** — zero padding is exact for every dynamic axis (the
  compiler proved each one elementwise); each request gets back exactly its
  own rows/steps, bit-identical to a solo run.
* **Metrics** — per-bucket and per-grid-cell batch counts, padded-row and
  padded-token overhead, window hits, plan-cache behavior (uniform
  ``hit_rate`` from :class:`repro.core.cache.LruCache`), and request
  latency/queue-wait distributions.  Every number routes through the
  server's :class:`~repro.obs.metrics.MetricsRegistry` under canonical
  ``serve.*`` / ``cache.plan.*`` keys; the flat ``metrics`` dict and
  :meth:`~CompiledModelServer.summary` keys are kept as aliases.  Latency
  is held in a log-bucketed :class:`~repro.obs.metrics.Histogram` — bounded
  memory no matter how long the server lives, with p50/p95/p99 and an
  exact avg/max in :meth:`~CompiledModelServer.summary`.
* **Tracing** — with a tracer installed (:func:`repro.obs.trace.install`),
  each request is an async span (``serve.request``, linked by uid) from
  submit to completion, and each :meth:`~CompiledModelServer.step` emits a
  ``serve.step`` span with ``serve.coalesce`` (stack + seq right-pad) and
  ``serve.compute`` (the bucketed model execution) children plus
  per-request queue-wait accounting.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..backend.autotune import TuneJob
from ..backend.lowering import specialize_plan
from ..backend.plan import bindings_key
from ..core.compile import BATCH_AXIS, CompiledModel
from ..obs import trace as _trace
from ..obs.metrics import MetricsRegistry


@dataclasses.dataclass
class CompiledRequest:
    """One inference request: a single example per model input (no batch
    dim).  With a sequence axis the extent along it may vary per request —
    but every input of *one* request that carries the axis must agree on it
    (validated at submit)."""

    uid: int
    feeds: Dict[str, np.ndarray]
    # the request's extent along the server's variable-length axis, if any
    seq_len: Optional[int] = None
    # filled by the server:
    outputs: Optional[Dict[str, np.ndarray]] = None
    done: bool = False
    t_submit: float = 0.0
    t_done: Optional[float] = None

    @property
    def x(self) -> np.ndarray:
        """Single-input sugar: the example of a one-input request."""
        if len(self.feeds) != 1:
            raise AttributeError(
                f"request has {len(self.feeds)} input examples "
                f"({sorted(self.feeds)}); read .feeds instead of .x"
            )
        return next(iter(self.feeds.values()))

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class CompiledServerConfig:
    max_batch: int = 32  # largest coalesced batch (its bucket bounds jit traces)
    # retained for compatibility: latency now lives in a log-bucketed
    # histogram whose memory is bounded by occupied buckets, not samples —
    # every request counts toward the quantiles, none are dropped
    latency_window: int = 4096
    # admission window: hold a partial batch until the oldest queued request
    # is this old (ms), then launch it (None = greedy drain, the PR 4 mode)
    max_wait_ms: Optional[float] = None
    # background autotuning: at most this many tile candidates measured per
    # step() after its batch is served — the bound that keeps the search from
    # ever stretching a serving cycle unboundedly
    tune_candidates_per_step: int = 2

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.latency_window < 1:
            raise ValueError(f"latency_window must be >= 1, got {self.latency_window}")
        if self.max_wait_ms is not None and self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {self.max_wait_ms}")
        if self.tune_candidates_per_step < 1:
            raise ValueError(
                f"tune_candidates_per_step must be >= 1, got {self.tune_candidates_per_step}"
            )


class CompiledModelServer:
    """Queue + micro-batching loop over a scenario-polymorphic CompiledModel."""

    def __init__(
        self,
        cm: CompiledModel,
        cfg: Optional[CompiledServerConfig] = None,
        *,
        registry: Optional[MetricsRegistry] = None,
        autotuner=None,
        name: str = "",
        uid_start: int = 0,
    ) -> None:
        if not cm.is_dynamic:
            raise ValueError(
                "CompiledModelServer needs a scenario-polymorphic artifact — "
                'compile with compile_model(..., batch="dynamic") or '
                "dynamic_axes={...}"
            )
        batch_inputs = cm.axis_input_pos.get(BATCH_AXIS, {})
        missing = [n for n in cm.input_names if n not in batch_inputs]
        if not batch_inputs or missing:
            raise ValueError(
                f"the micro-batching server coalesces every model input along "
                f"the batch axis — inputs {missing or cm.input_names} do not "
                f"carry it (batch-carrying: {sorted(batch_inputs)})"
            )
        bad = [n for n, pos in batch_inputs.items() if pos != 0]
        if bad:
            raise ValueError(
                f"the batch axis must be the leading dim of every input, but "
                f"it is not on {sorted(bad)}"
            )
        #: single-input sugar target; None on a multi-input artifact
        self.input_name = (
            cm.input_names[0] if len(cm.input_names) == 1 else None
        )
        extra = [a for a in cm.dynamic_axes if a != BATCH_AXIS]
        if len(extra) > 1:
            raise ValueError(
                f"the server coalesces over the batch plus at most one "
                f"variable-length axis, got dynamic axes {sorted(cm.dynamic_axes)}"
            )
        self.cm = cm
        self.cfg = cfg if cfg is not None else CompiledServerConfig()
        #: the variable-length (sequence) axis, if the artifact has one
        self.seq_axis: Optional[str] = extra[0] if extra else None
        #: per-input example shape/dtype (batch dim stripped; dims may be
        #: named symbolic or None)
        self._example_shapes: Dict[str, Tuple] = {}
        self._example_dtypes: Dict[str, np.dtype] = {}
        for in_t in cm.model.graph.inputs:
            self._example_shapes[in_t.name] = tuple(in_t.shape[1:])
            self._example_dtypes[in_t.name] = np.dtype(in_t.dtype)
            stray = [
                d for d in in_t.shape[1:]
                if isinstance(d, str) and d not in cm.dynamic_axes
            ]
            if stray:
                raise ValueError(
                    f"input {in_t.name!r} has named symbolic dims {stray} the "
                    "compile left static — the server cannot validate or bucket "
                    "them; compile them as dynamic_axes or pin them to ints"
                )
        #: example-local sequence-dim position per seq-carrying input
        self._seq_pos: Dict[str, int] = {}
        if self.seq_axis is not None:
            for in_name, pos in cm.axis_input_pos[self.seq_axis].items():
                if pos == 0:
                    raise ValueError(
                        f"sequence axis {self.seq_axis!r} must sit on a "
                        f"non-leading dim of input {in_name!r}"
                    )
                self._seq_pos[in_name] = pos - 1  # batch dim stripped
            if not self._seq_pos:
                raise ValueError(
                    f"sequence axis {self.seq_axis!r} is bound by no input"
                )
        #: replica name when fronted by a router — stamps every span with a
        #: ``replica=`` attribute so fleet traces separate by owner
        self.name = name
        self.queue: Deque[CompiledRequest] = deque()
        # a router shares the uid space across replicas by offsetting each
        # replica's counter — uids stay fleet-unique for trace/fleet accounting
        self._uid = uid_start
        # per-instance registry unless the caller injects a shared one; the
        # plan cache publishes its canonical cache.plan.* gauges into it
        self.registry = registry if registry is not None else MetricsRegistry()
        cm.attach_metrics(self.registry)
        # bounded: a long-lived server keeps a log-bucketed histogram (a few
        # hundred ints), not one float per request forever
        self._latency = self.registry.histogram("serve.latency_ms")
        self._queue_wait = self.registry.histogram("serve.queue_wait_ms")
        self.metrics: Dict[str, Any] = {
            "requests": 0,
            "batches": 0,
            "completed": 0,
            "padded_rows": 0,  # bucket rows minus real rows, summed
            "padded_tokens": 0,  # seq-bucket slots minus real seq steps, summed
            "window_hits": 0,  # partial batches launched by the admission window
            "tuned_swaps": 0,  # cells whose tuned executor swapped in
            "bucket_batches": {},  # batch bucket -> number of coalesced batches
            "grid_batches": {},  # (batch bucket, seq bucket) -> batches (2-D grids)
        }
        # non-blocking autotuning: every served cell enqueues one TuneJob;
        # step() spends a bounded candidate budget on the front job after its
        # batch is out the door, and swaps the tuned executor into the
        # PlanCache atomically when the job finishes — requests are always
        # served on whatever the cache currently holds, never waiting on the
        # search
        self.autotuner = autotuner if autotuner is not None else getattr(cm, "autotuner", None)
        if self.autotuner is not None:
            # the server owns the search: detach the tuner from the model so
            # a first-touch specialization inside step() can never block on a
            # measured search — cells go live on heuristic tiles immediately
            cm.autotuner = None
        self._tune_jobs: Deque[TuneJob] = deque()
        self._tuned_cells: set = set()

    def _count(self, key: str, n: int = 1) -> None:
        """One accounting site: the flat alias dict and the canonical
        ``serve.<key>`` registry counter move together."""
        self.metrics[key] += n
        self.registry.counter(f"serve.{key}").inc(n)

    # -- request lifecycle ----------------------------------------------------
    def submit(self, x) -> CompiledRequest:
        """Enqueue one request: a dict mapping every model input to its
        example (shapes = input shapes without the batch dim; the sequence
        dim, if any, may vary per request), or — single-input sugar — a bare
        ndarray.  Returns the request handle whose ``outputs`` fill on
        completion.

        Shape/dtype *and axis-binding consistency* are validated here, at
        admission: every input of one request that carries the same named
        dynamic axis must agree on its extent.  A bad example must be
        rejected up front, not blow up a coalesced batch mid-``step`` and
        take its co-batched requests down with it."""
        if isinstance(x, dict):
            feeds = {str(k): np.asarray(v) for k, v in x.items()}
            if set(feeds) != set(self.cm.input_names):
                raise ValueError(
                    f"request must feed exactly the model inputs "
                    f"{sorted(self.cm.input_names)}, got {sorted(feeds)}"
                )
        else:
            if self.input_name is None:
                raise ValueError(
                    f"multi-input artifact: submit a dict of examples for "
                    f"inputs {sorted(self.cm.input_names)}"
                )
            feeds = {self.input_name: np.asarray(x)}
        bound: Dict[str, int] = {}  # named axis -> extent this request binds
        for name, arr in feeds.items():
            want = self._example_shapes[name]
            ok = len(arr.shape) == len(want) and all(
                not isinstance(w, int) or got == w
                for got, w in zip(arr.shape, want)
            )
            if not ok or arr.dtype != self._example_dtypes[name]:
                raise ValueError(
                    f"example for input {name!r} must have shape {want} and "
                    f"dtype {self._example_dtypes[name]}, got {arr.shape} {arr.dtype}"
                )
            for got, w in zip(arr.shape, want):
                if not isinstance(w, str):
                    continue
                if got < 1:
                    raise ValueError(
                        f"example for input {name!r} has empty extent along "
                        f"axis {w!r}"
                    )
                prev = bound.setdefault(w, got)
                if prev != got:
                    raise ValueError(
                        f"inconsistent axis bindings within one request: "
                        f"axis {w!r} is {prev} on one input but {got} on "
                        f"{name!r} — all inputs of a request must agree"
                    )
        req = CompiledRequest(
            uid=self._uid,
            feeds=feeds,
            seq_len=bound.get(self.seq_axis) if self.seq_axis else None,
            t_submit=time.monotonic(),
        )
        self._uid += 1
        self.queue.append(req)
        self._count("requests")
        if _trace.enabled:
            _trace.async_begin(
                "serve.request",
                req.uid,
                shape="|".join(str(feeds[n].shape) for n in sorted(feeds)),
            )
        return req

    # -- main loop ------------------------------------------------------------
    def step(self) -> List[CompiledRequest]:
        """One server cycle: coalesce up to ``max_batch`` queued requests into
        a single bucketed model execution.  Returns the completed requests —
        possibly none, when the admission window is still holding a partial
        batch open for more arrivals.

        Idle cycles (empty queue, or a partial batch held by the admission
        window) still spend the bounded background-tuning budget — an idle
        server converges on tuned tiles fastest."""
        if not self.queue:
            self._advance_tuning()
            return []
        if (
            self.cfg.max_wait_ms is not None
            and len(self.queue) < self.cfg.max_batch
        ):
            age_ms = (time.monotonic() - self.queue[0].t_submit) * 1e3
            if age_ms < self.cfg.max_wait_ms:
                self._advance_tuning()
                return []  # hold the partial batch open for more arrivals
            self._count("window_hits")
        n = min(len(self.queue), self.cfg.max_batch)
        reqs = [self.queue.popleft() for _ in range(n)]
        with _trace.span("serve.step", n=n) as step_span:
            if _trace.enabled and self.name:
                step_span.set(replica=self.name)
            # queue wait ends at dequeue, but is only *observed* after the
            # batch succeeds — a failed batch re-queues its requests, and
            # observing here would count each retried request once per attempt
            t_deq = time.monotonic()
            # batch assembly AND execution both re-queue on failure: a failure
            # anywhere here (a shape mismatch np.stack rejects, a backend/jit
            # error) must not lose the coalesced requests — they go back to
            # the head of the queue in original order for the caller to
            # retry/triage
            try:
                with _trace.span("serve.coalesce"):
                    if self.seq_axis is None:
                        seq_lens: Optional[List[int]] = None
                    else:
                        seq_lens = [int(r.seq_len) for r in reqs]
                    batch_feeds: Dict[str, np.ndarray] = {}
                    for name in self.cm.input_names:
                        seq_pos = self._seq_pos.get(name)
                        if seq_pos is None:
                            batch_feeds[name] = np.stack([r.feeds[name] for r in reqs])
                            continue
                        # right-pad every example of every seq-carrying input
                        # to the longest sequence in the group, so the whole
                        # group lands on one (batch-bucket × seq-bucket) cell
                        s_max = max(seq_lens)
                        rows = []
                        for r in reqs:
                            ex = r.feeds[name]
                            pad = s_max - ex.shape[seq_pos]
                            if pad:
                                widths = [(0, 0)] * ex.ndim
                                widths[seq_pos] = (0, pad)
                                ex = np.pad(ex, widths)
                            rows.append(ex)
                        batch_feeds[name] = np.stack(rows)
                # the compiled model pads each axis to its bucket and serves
                # the cell from its PlanCache; we only account for the
                # coalescing here
                with _trace.span("serve.compute"):
                    outs = self.cm.run(batch_feeds)
            except Exception:
                # back to the head of the queue in original order; their
                # serve.request async spans stay open — each closes exactly
                # once, when the request is finally served
                self.queue.extendleft(reversed(reqs))
                raise
            # dequeue is now final: observe each request's queue wait exactly
            # once (measured at dequeue, not at completion)
            for r in reqs:
                self._queue_wait.observe((t_deq - r.t_submit) * 1e3)
            bucket = self.cm.bucket_for(BATCH_AXIS, n)
            cell_bindings = {BATCH_AXIS: bucket}
            self._count("batches")
            self._count("padded_rows", bucket - n)
            hist = self.metrics["bucket_batches"]
            hist[bucket] = hist.get(bucket, 0) + 1
            self.registry.counter(f"serve.batches.bucket.{bucket}").inc()
            if seq_lens is not None:
                s_bucket = self.cm.bucket_for(self.seq_axis, max(seq_lens))
                cell_bindings[self.seq_axis] = s_bucket
                self._count("padded_tokens", sum(s_bucket - s for s in seq_lens))
                grid = self.metrics["grid_batches"]
                cell = (bucket, s_bucket)
                grid[cell] = grid.get(cell, 0) + 1
                self.registry.counter(f"serve.batches.cell.{bucket}x{s_bucket}").inc()
                if _trace.enabled:
                    step_span.set(seq_bucket=s_bucket)
            if _trace.enabled:
                step_span.set(bucket=bucket, requests=",".join(str(r.uid) for r in reqs))
            now = time.monotonic()
            out_axes = self.cm.output_axis_pos
            for i, req in enumerate(reqs):
                # only batch-carrying outputs scatter per request (anything
                # batch-independent is shared whole); sequence-carrying
                # outputs additionally slice back to the request's own true
                # length
                req.outputs = {
                    k: self._request_view(v, out_axes.get(k, {}), i, seq_lens[i] if seq_lens else None)
                    for k, v in outs.items()
                }
                req.done = True
                req.t_done = now
                self._latency.observe((now - req.t_submit) * 1e3)
                if _trace.enabled:
                    _trace.async_end("serve.request", req.uid)
            self._count("completed", n)
        # the batch is out the door: spend the bounded tuning budget only now
        self._note_cell(cell_bindings)
        self._advance_tuning()
        return reqs

    # -- background autotuning ------------------------------------------------
    def _note_cell(self, bindings: Dict[str, int]) -> None:
        """First sighting of a scenario cell enqueues its measured search."""
        if self.autotuner is None:
            return
        key = bindings_key(bindings)
        if key in self._tuned_cells:
            return
        self._tuned_cells.add(key)
        self._tune_jobs.append(TuneJob(self.autotuner, self.cm.plan, bindings))

    def _advance_tuning(self) -> None:
        """Measure at most ``tune_candidates_per_step`` candidates of the
        front job; when a job finishes, swap its tuned executor into the
        PlanCache.  The swap is a single ``put`` — in-flight callers keep the
        heuristic entry they already hold, the next ``step()`` on the cell
        picks up the tuned one."""
        if self.autotuner is None or not self._tune_jobs:
            return
        job = self._tune_jobs[0]
        if job.advance(self.cfg.tune_candidates_per_step):
            self._tune_jobs.popleft()
            # every step of the cell is now resolved in the tuner's session,
            # so this specialization measures nothing — it just stamps the
            # tuned tiles (and their provenance source tags) into a new plan
            plan = specialize_plan(self.cm.plan, job.bindings, tuner=self.autotuner)
            # cache_key: graph-qualified when the cache is fleet-shared, the
            # plain bindings key otherwise — must match what step() looks up
            self.cm.plan_cache.put(
                self.cm.cache_key(job.bindings), (plan, jax.jit(plan.execute))
            )
            self._count("tuned_swaps")
            self.registry.counter("autotune.swaps").inc()

    @property
    def tuning_pending(self) -> int:
        """Candidates still to measure across all queued tune jobs."""
        return sum(j.remaining for j in self._tune_jobs)

    def _request_view(
        self, v: np.ndarray, axes: Dict[str, int], i: int, seq_len: Optional[int]
    ) -> np.ndarray:
        batch_pos = axes.get(BATCH_AXIS)
        seq_pos = axes.get(self.seq_axis) if self.seq_axis is not None else None
        if batch_pos is not None:
            v = v[(slice(None),) * batch_pos + (i,)]  # view, not a copy
            if seq_pos is not None and seq_pos > batch_pos:
                seq_pos -= 1
        if seq_pos is not None and seq_len is not None:
            slicer = [slice(None)] * v.ndim
            slicer[seq_pos] = slice(0, seq_len)
            v = v[tuple(slicer)]
        return v

    def run_until_drained(self, max_cycles: int = 10_000) -> List[CompiledRequest]:
        """Step until the queue is empty; returns everything completed.  An
        admission window cannot stall the drain: once the caller is draining,
        a deferred step only waits for the window to expire."""
        done: List[CompiledRequest] = []
        for _ in range(max_cycles):
            if not self.queue:
                return done
            completed = self.step()
            if not completed and self.cfg.max_wait_ms is not None:
                # deferred by the admission window — wait out the remainder
                age_s = time.monotonic() - self.queue[0].t_submit
                time.sleep(max(0.0, self.cfg.max_wait_ms / 1e3 - age_s))
            done.extend(completed)
        raise RuntimeError("compiled-model serve loop did not drain")

    # -- reporting ------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Serving metrics + plan-cache behavior + latency aggregates.

        Latency aggregates come from the bounded ``serve.latency_ms``
        histogram: avg/max are exact, p50/p95/p99 are bucket estimates
        (within the histogram growth factor)."""
        lat = self._latency.stats()
        cache = self.cm.cache_stats
        out = dict(self.metrics)
        # snapshots, not aliases
        out["bucket_batches"] = dict(self.metrics["bucket_batches"])
        out["grid_batches"] = dict(self.metrics["grid_batches"])
        out.update(
            plan_cache=cache,
            plan_cache_hit_rate=cache["hit_rate"],
            tuning_pending=self.tuning_pending,
            latency_avg_ms=lat["avg"],
            latency_p50_ms=lat["p50"],
            latency_p95_ms=lat["p95"],
            latency_p99_ms=lat["p99"],
            latency_max_ms=lat["max"],
        )
        return out
