from .compiled import (  # noqa: F401
    CompiledModelServer,
    CompiledRequest,
    CompiledServerConfig,
)
from .engine import EngineConfig, Request, ServeEngine, sample_token  # noqa: F401
from .router import RoutedRequest, RouterConfig, ShardedRouter  # noqa: F401
