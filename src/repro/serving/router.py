"""Sharded replica router: N compiled-model servers behind one front door.

One process, one :class:`~repro.serving.compiled.CompiledModelServer`, one
plan cache — that was PR 4/7.  At fleet scale the same AOT artifact
(:mod:`repro.backend.artifact`) warm-starts *N* replicas, and the routing
decision becomes part of the co-design story:

* **Cell affinity** — the scenario-cell grid (batch bucket × seq bucket)
  that bounds specializations in one server also shards traffic across
  servers.  A request's per-request-knowable half of its cell (the sequence
  bucket; batch buckets only emerge at coalescing time) maps *stickily* to
  one replica, so each replica sees a narrow slice of the grid and its
  :class:`~repro.backend.plan.PlanCache` and background autotuner stay hot
  — per-replica hit rates match or beat the single-server baseline instead
  of dividing by N.  New cells go to the replica owning the fewest cells
  (ties to the lowest index); unhealthy replicas are skipped.
* **Health + failure containment** — per-replica consecutive-failure
  counters (a replica is unhealthy at ``failure_threshold``) plus the
  distributed layer's :class:`~repro.distributed.fault_tolerance.
  StragglerMonitor` for step-time anomaly detection (an EWMA-slow replica
  is surfaced in :meth:`ShardedRouter.health`, feeding the same eviction
  decision a fleet scheduler would make).
* **In-order re-queue** — a replica whose ``step()`` raises keeps its batch
  (its server re-queues at the head, original order); the router then
  migrates that replica's entire queue, order preserved, onto a healthy
  replica and re-points the failed replica's cells.  Requests keep their
  fleet-unique uids and their open ``serve.request`` spans — nothing is
  lost, nothing served twice (:meth:`ShardedRouter.summary` carries the uid
  accounting to prove it).
* **One obs plane** — all replicas publish into one shared
  :class:`~repro.obs.metrics.MetricsRegistry` (counters and latency
  histograms aggregate fleet-wide; per-replica state is read live from each
  server), and every replica's spans carry a ``replica=`` attribute.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..distributed.fault_tolerance import StragglerMonitor
from ..obs import trace as _trace
from ..obs.metrics import MetricsRegistry
from .compiled import CompiledModelServer, CompiledRequest, CompiledServerConfig

__all__ = ["RouterConfig", "RoutedRequest", "ShardedRouter"]

#: uid stride between replicas: replica i issues uids in
#: [i*stride, (i+1)*stride) — fleet-unique without a shared counter.
UID_STRIDE = 1_000_000_000


@dataclasses.dataclass
class RouterConfig:
    #: consecutive step failures after which a replica is marked unhealthy
    #: and its cells re-pointed (a success resets the count)
    failure_threshold: int = 3
    #: StragglerMonitor threshold: a step slower than this multiple of the
    #: replica's EWMA step time is recorded as a straggler step
    straggler_threshold: float = 2.0

    def __post_init__(self) -> None:
        if self.failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got {self.failure_threshold}"
            )


@dataclasses.dataclass
class RoutedRequest:
    """A request as the router sees it: the replica-owned
    :class:`CompiledRequest` plus fleet-level routing state."""

    uid: int  # fleet-unique (replica uid spaces are strided)
    cell: Tuple  # the affinity key it was routed on
    replica: str  # current owner (updated if the batch migrates)
    inner: CompiledRequest
    rerouted: int = 0  # times this request migrated off a failed replica

    @property
    def done(self) -> bool:
        return self.inner.done

    @property
    def outputs(self):
        return self.inner.outputs

    @property
    def latency_s(self) -> Optional[float]:
        return self.inner.latency_s


@dataclasses.dataclass
class _Replica:
    name: str
    server: CompiledModelServer
    monitor: StragglerMonitor
    failures: int = 0  # consecutive step failures
    healthy: bool = True
    steps: int = 0


class ShardedRouter:
    """Cell-affinity front door over N warm-started server replicas."""

    def __init__(
        self,
        servers: List[CompiledModelServer],
        *,
        registry: Optional[MetricsRegistry] = None,
        cfg: Optional[RouterConfig] = None,
    ) -> None:
        if not servers:
            raise ValueError("a router needs at least one replica server")
        self.cfg = cfg if cfg is not None else RouterConfig()
        self.registry = registry if registry is not None else MetricsRegistry()
        self.replicas: List[_Replica] = []
        for i, srv in enumerate(servers):
            name = srv.name or f"r{i}"
            srv.name = name
            self.replicas.append(
                _Replica(
                    name=name,
                    server=srv,
                    monitor=StragglerMonitor(threshold=self.cfg.straggler_threshold),
                )
            )
        if len({r.name for r in self.replicas}) != len(self.replicas):
            raise ValueError("replica names must be unique")
        seq_axes = {r.server.seq_axis for r in self.replicas}
        if len(seq_axes) != 1:
            raise ValueError(
                "all replicas must serve the same artifact shape "
                f"(got mixed sequence axes {sorted(map(str, seq_axes))})"
            )
        self._seq_axis = seq_axes.pop()
        #: sticky cell → replica-index map (the affinity table)
        self._cell_owner: Dict[Tuple, int] = {}
        self._inflight: Dict[int, RoutedRequest] = {}
        self._done_uids: set = set()
        self.metrics = {
            "requests": 0,
            "completed": 0,
            "duplicates": 0,  # uid seen completed more than once (must stay 0)
            "rerouted": 0,  # requests migrated off a failed replica
            "failovers": 0,  # replica step failures handled
        }

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_artifact(
        cls,
        path: str,
        replicas: int = 3,
        *,
        server_cfg: Optional[CompiledServerConfig] = None,
        registry: Optional[MetricsRegistry] = None,
        cfg: Optional[RouterConfig] = None,
        warm: bool = True,
        autotuner_factory: Optional[Callable[[], Any]] = None,
    ) -> "ShardedRouter":
        """N replicas warm-started from one AOT artifact: each gets its own
        :func:`~repro.backend.artifact.load_artifact` (own plan cache,
        pre-seeded with the recorded hot cells; ``warm=True`` also primes
        the jit traces), all sharing one metrics registry.
        ``autotuner_factory`` builds one background tuner per replica (a
        tuner holds per-cell session state, so replicas must not share
        one)."""
        from ..backend.artifact import load_artifact

        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        registry = registry if registry is not None else MetricsRegistry()
        servers = []
        for i in range(replicas):
            cm = load_artifact(
                path,
                warm=warm,
                autotuner=autotuner_factory() if autotuner_factory else None,
            )
            servers.append(
                CompiledModelServer(
                    cm,
                    server_cfg,
                    registry=registry,
                    name=f"r{i}",
                    uid_start=i * UID_STRIDE,
                )
            )
        return cls(servers, registry=registry, cfg=cfg)

    # -- routing --------------------------------------------------------------
    def _cell_of(self, x) -> Tuple:
        """The per-request-knowable half of the scenario cell: the sequence
        bucket for two-axis artifacts, or the empty cell (batch-only — the
        batch bucket only exists once a batch is coalesced).  ``x`` is one
        request's example — a dict of per-input examples or the bare-ndarray
        single-input sugar — and any seq-carrying input yields the extent
        (the server validates cross-input consistency at submit)."""
        srv = self.replicas[0].server
        if self._seq_axis is None:
            return ()
        in_name, pos = next(iter(srv._seq_pos.items()))
        ex = x[in_name] if isinstance(x, dict) else x
        extent = int(np.asarray(ex).shape[pos])
        return (self._seq_axis, srv.cm.bucket_for(self._seq_axis, extent))

    def _healthy(self) -> List[_Replica]:
        live = [r for r in self.replicas if r.healthy]
        if not live:
            raise RuntimeError(
                "no healthy replica left "
                f"(all {len(self.replicas)} exceeded the failure threshold)"
            )
        return live

    def _owner_of(self, cell: Tuple) -> _Replica:
        idx = self._cell_owner.get(cell)
        if idx is not None and self.replicas[idx].healthy:
            return self.replicas[idx]
        live = self._healthy()
        if len(live) == 1:
            chosen = live[0]
        else:
            # least-loaded by owned-cell count, ties to the lowest index —
            # deterministic, and it spreads distinct cells across replicas
            owned = {i: 0 for i, r in enumerate(self.replicas) if r.healthy}
            for o in self._cell_owner.values():
                if o in owned:
                    owned[o] += 1
            chosen_i = min(owned, key=lambda i: (owned[i], i))
            chosen = self.replicas[chosen_i]
        self._cell_owner[cell] = self.replicas.index(chosen)
        return chosen

    def submit(self, x) -> RoutedRequest:
        """Route one request (dict of per-input examples, or the bare-ndarray
        single-input sugar) to its cell's replica; returns the fleet-level
        request handle (``outputs`` fill on completion, like the server's)."""
        cell = self._cell_of(x)
        rep = self._owner_of(cell)
        inner = rep.server.submit(x)
        rr = RoutedRequest(uid=inner.uid, cell=cell, replica=rep.name, inner=inner)
        self._inflight[rr.uid] = rr
        self._count("requests")
        return rr

    def _count(self, key: str, n: int = 1) -> None:
        self.metrics[key] += n
        self.registry.counter(f"fleet.{key}").inc(n)

    # -- stepping + failover --------------------------------------------------
    def step(self) -> List[RoutedRequest]:
        """One fleet cycle: step every healthy replica that has queued work.
        A replica failure is contained here — its batch (already re-queued
        in order by the server) and the rest of its queue migrate to a
        healthy replica, and the request handles keep working."""
        completed: List[RoutedRequest] = []
        for rep in self.replicas:
            if not rep.healthy or not rep.server.queue:
                continue
            rep.monitor.start_step()
            try:
                done = rep.server.step()
            except Exception:
                self._on_failure(rep)
                continue
            rep.monitor.end_step(rep.steps)
            rep.steps += 1
            rep.failures = 0
            completed.extend(self._finish(done))
        return completed

    def _finish(self, done: List[CompiledRequest]) -> List[RoutedRequest]:
        out = []
        for req in done:
            rr = self._inflight.pop(req.uid, None)
            if rr is None:
                if req.uid in self._done_uids:
                    # a routed request served twice would resurface here with
                    # no inflight entry — surfaced, never silently dropped
                    self._count("duplicates")
                continue  # else: submitted directly to the server, not via us
            self._done_uids.add(rr.uid)
            self._count("completed")
            out.append(rr)
        return out

    def _on_failure(self, rep: _Replica) -> None:
        rep.failures += 1
        self._count("failovers")
        self.registry.counter(f"fleet.failures.{rep.name}").inc()
        if rep.failures >= self.cfg.failure_threshold:
            rep.healthy = False
        if _trace.enabled:
            _trace.event(
                "fleet.failover", replica=rep.name,
                failures=rep.failures, healthy=rep.healthy,
            )
        # the failed batch is back at the head of rep's queue in original
        # order; migrate the whole queue onto one healthy replica, preserving
        # order, and re-point the failed replica's cells
        targets = [r for r in self.replicas if r.healthy and r is not rep]
        if not targets:
            if not rep.healthy:
                raise RuntimeError(
                    f"replica {rep.name} failed with no healthy replica to "
                    "take its queue"
                )
            return  # still healthy below the threshold: it keeps its queue
        target = targets[0]
        moved = list(rep.server.queue)
        rep.server.queue.clear()
        target.server.queue.extend(moved)  # order preserved, appended in turn
        for req in moved:
            rr = self._inflight.get(req.uid)
            if rr is not None:
                rr.replica = target.name
                rr.rerouted += 1
                self._count("rerouted")
        if not rep.healthy:
            rep_i = self.replicas.index(rep)
            target_i = self.replicas.index(target)
            for cell, owner in list(self._cell_owner.items()):
                if owner == rep_i:
                    self._cell_owner[cell] = target_i

    def run_until_drained(self, max_cycles: int = 10_000) -> List[RoutedRequest]:
        done: List[RoutedRequest] = []
        for _ in range(max_cycles):
            if not any(r.server.queue for r in self.replicas):
                return done
            done.extend(self.step())
        raise RuntimeError("fleet serve loop did not drain")

    # -- reporting ------------------------------------------------------------
    def health(self) -> Dict[str, Dict[str, Any]]:
        """Live per-replica health: failure counters, straggler detection,
        queue depth."""
        return {
            r.name: {
                "healthy": r.healthy,
                "failures": r.failures,
                "steps": r.steps,
                "queue": len(r.server.queue),
                "straggler_steps": list(r.monitor.slow_steps),
                "step_time_ewma_s": r.monitor.ewma,
            }
            for r in self.replicas
        }

    def summary(self) -> Dict[str, Any]:
        """Fleet-wide aggregation: uid accounting (every submitted request is
        completed, pending, or still queued — never lost, never duplicated),
        per-replica summaries, the affinity table, and the shared registry's
        snapshot."""
        pending = len(self._inflight)
        per_replica = {r.name: r.server.summary() for r in self.replicas}
        hit_rates = {
            name: s["plan_cache_hit_rate"] for name, s in per_replica.items()
        }
        cells = {
            (f"{cell[0]}={cell[1]}" if cell else "*"): self.replicas[i].name
            for cell, i in sorted(self._cell_owner.items())
        }
        return {
            "replicas": per_replica,
            "health": self.health(),
            "requests": self.metrics["requests"],
            "completed": self.metrics["completed"],
            "pending": pending,
            "lost": self.metrics["requests"] - self.metrics["completed"] - pending,
            "duplicates": self.metrics["duplicates"],
            "rerouted": self.metrics["rerouted"],
            "failovers": self.metrics["failovers"],
            "plan_cache_hit_rates": hit_rates,
            "cell_owners": cells,
            "registry": self.registry.snapshot(),
        }
