"""AdamW with decoupled weight decay — pure-pytree implementation (f32 moments
sharded like the params ⇒ FSDP over the ``data`` axis shards optimizer state
exactly as ZeRO does)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0


def init(params) -> dict:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(grads, state: dict, params, lr: jax.Array, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
