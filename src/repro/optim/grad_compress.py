"""int8 gradient compression with error feedback for the cross-pod (DCN)
all-reduce — the paper's symmetric integer codification applied to the
distributed-training wire format.

Scheme (per leaf):
  1. g_eff = g_local + residual          (error feedback)
  2. shared scale s = pmax(|g_eff|max over 'pod') / 127
  3. q = saturate(round_half_even(g_eff / s))   int8 — the wire format
  4. wire all-reduce: psum(int32(q)) over 'pod' (int32 accumulation is exact,
     like the paper's MatMulInteger accumulator)
  5. g_avg = s * psum_q / n_pods
  6. residual' = g_eff − s·q               (kept locally)

4× less DCN traffic than f32 (2× vs bf16) at equal step-count quality in
practice thanks to error feedback.  Implemented with shard_map over the
``pod`` axis only; the in-pod ``data``/``model`` axes stay under GSPMD auto
sharding.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _compress_leaf(g: jax.Array, res: jax.Array, axis: str) -> Tuple[jax.Array, jax.Array]:
    g_eff = g.astype(jnp.float32) + res
    local_max = jnp.abs(g_eff).max()
    s = jax.lax.pmax(local_max, axis) / 127.0 + 1e-20
    q = jnp.clip(jnp.rint(g_eff / s), -128, 127)  # int8 wire values
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)  # exact int32 accumulation
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis).astype(jnp.float32)
    g_avg = (s * q_sum.astype(jnp.float32)) / n
    new_res = g_eff - s * q
    return g_avg.astype(g.dtype), new_res


def compressed_cross_pod_mean(grads, residuals, *, axis: str = "pod"):
    """All-reduce-mean ``grads`` across ``axis`` in int8 with error feedback.
    Must be called inside shard_map (or any context where ``axis`` is bound).
    Returns (averaged grads, new residuals)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [_compress_leaf(g, r, axis) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def uncompressed_cross_pod_mean(grads, *, axis: str = "pod"):
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
