"""LR schedules: linear-warmup + cosine, and WSD (warmup-stable-decay — the
MiniCPM schedule, arXiv:2404.06395 §4: stable high LR for most of training,
then a short exponential/linear decay phase; enables continual pretraining
from the stable phase)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd(step, *, peak_lr: float, warmup_steps: int, stable_steps: int, decay_steps: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay.  decay phase: exponential from peak to final_frac."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    decay_start = warmup_steps + stable_steps
    t = jnp.clip((step - decay_start) / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    decay = peak_lr * jnp.power(final_frac, t)
    return jnp.where(step < warmup_steps, warm, jnp.where(step < decay_start, peak_lr, decay))


SCHEDULES = {"warmup_cosine": warmup_cosine, "wsd": wsd}
