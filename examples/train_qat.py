"""End-to-end co-design driver: QAT training → calibrate → export → serve int8.

Trains a reduced qwen3-family decoder with quantization-aware training (the
forward sees int8-faithful fake-quant numerics), runs a few hundred steps with
checkpointing, then:
  * converts the trained params to pre-quantized W8A8 (the paper's scheme,
    per-channel scales codified as integer scale + shift), and
  * verifies the quantized model's loss/greedy decode track the float model.

Run:  PYTHONPATH=src python examples/train_qat.py [--steps 200]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.convert import convert_params_w8a8, export_arch_quant_manifest
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.train import train
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        params, opt, hist = train(
            "qwen3_1_7b",
            steps=args.steps,
            batch=args.batch,
            seq=args.seq,
            qat=True,
            schedule="wsd",
            ckpt_dir=ckpt,
            ckpt_interval=50,
            log_every=20,
        )
    print(f"[qat] loss {hist[0]:.3f} -> {hist[-1]:.3f} over {len(hist)} steps")

    cfg = get_config("qwen3_1_7b", reduced=True)
    pipe = Pipeline(cfg, DataConfig(seed=123))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(10_000, args.batch, args.seq).items()}

    loss_f32, _ = M.loss_fn(params, batch, cfg, compute_dtype=jnp.float32, q_chunk=32, kv_chunk=32)

    # -- export to pre-quantized W8A8 (paper §3 applied to the whole model) ---
    pq = convert_params_w8a8(params)
    manifest = export_arch_quant_manifest(pq)
    print(f"[export] {len(manifest['tensors'])} tensors pre-quantized, e.g.:")
    for t in manifest["tensors"][:3]:
        print("   ", t)
    loss_int8, _ = M.loss_fn(pq, batch, cfg, compute_dtype=jnp.float32, q_chunk=32, kv_chunk=32)
    print(f"[eval] loss f32={float(loss_f32):.4f}  W8A8={float(loss_int8):.4f}  "
          f"Δ={abs(float(loss_int8) - float(loss_f32)):.4f}")

    # greedy decode agreement
    cache_a = M.init_cache(cfg, args.batch, args.seq + 4)
    cache_b = M.init_cache(cfg, args.batch, args.seq + 4)
    la, _ = M.prefill(params, {"tokens": batch["tokens"]}, cfg, cache_a, compute_dtype=jnp.float32, q_chunk=32, kv_chunk=32)
    lb, _ = M.prefill(pq, {"tokens": batch["tokens"]}, cfg, cache_b, compute_dtype=jnp.float32, q_chunk=32, kv_chunk=32)
    agree = float((jnp.argmax(la, -1) == jnp.argmax(lb, -1)).mean())
    print(f"[serve] greedy next-token agreement f32 vs W8A8: {agree:.2%}")
    assert abs(float(loss_int8) - float(loss_f32)) < 0.15, "QAT export drifted"
    print("co-design loop closed: train (QAT) -> export pre-quantized -> serve ✓")


if __name__ == "__main__":
    main()
