"""Quickstart: the paper's §4 MLP, end to end.

1. Build a float MLP and calibration data (the "researcher" side).
2. Quantize + codify it as a pre-quantized ONNX-dialect artifact
   (Figs 1/2 patterns; §3.1 integer scale + right-shift rescaling).
3. Execute the artifact with the standard-tool reference runtime.
4. Compile the SAME artifact with the hardware-specific TPU backend
   (pattern-fused kernels) — outputs must match BIT-EXACTLY.
5. Save/reload the artifact (goal 1: everything is embedded).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import os
import tempfile

import numpy as np

from repro.core import quant
from repro.core.compile import compile_model
from repro.core.export import export_quant_report
from repro.core.pqir import Model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import MLPSpec, quantize_mlp


def main():
    rng = np.random.default_rng(0)

    # -- 1. the float model (quantizer side knows nothing about hardware) ----
    spec = MLPSpec(
        weights=[
            rng.normal(size=(64, 128)).astype(np.float32) * 0.2,
            rng.normal(size=(128, 128)).astype(np.float32) * 0.15,
            rng.normal(size=(128, 10)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(10,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", "Relu", None],
    )
    calib = rng.normal(size=(512, 64)).astype(np.float32)

    # -- 2. quantize + codify ------------------------------------------------
    model = quantize_mlp(spec, calib, observer="percentile", name="quickstart_mlp")
    model.validate(standard_ops_only=True)  # paper goal 3
    print(f"artifact: {len(model.graph.nodes)} standard ONNX ops, "
          f"{len(model.graph.initializers)} embedded initializers")
    for layer in export_quant_report(model)["layers"]:
        print("  ", layer)

    # -- 3. run with the 'standard tool' (reference runtime) ------------------
    s_in = eval(model.metadata["input_scale"])
    s_out = eval(model.metadata["output_scale"])
    x = rng.normal(size=(16, 64)).astype(np.float32)
    xq = quant.quantize(x, s_in, "int8")
    ref_out = ReferenceRuntime(model).run({"input_q": xq})
    (yq_ref,) = ref_out.values()

    # -- 4. compile for TPU (fused int8 kernels) and compare ------------------
    # The compiler first runs the repro.passes pipeline (with its reference-
    # runtime conformance hook on), then pattern-fuses the optimized graph.
    cm = compile_model(model, backend="interpret", verify_passes=True)
    print(f"optimization pipeline: {cm.pass_report.summary()}")
    print(f"compiler fusion report: {cm.stats}")
    # the typed ExecutionPlan — what a hardware designer reads: buffer slots,
    # kernel ids, compile-time tile choices, pre-padded parameter shapes
    print(cm.plan)
    assert cm.pass_report.total("eliminated") >= 1, "canonicalization eliminated nothing"
    (yq_tpu,) = cm.run({"input_q": xq}).values()
    assert np.array_equal(yq_ref, yq_tpu), "conformance violation!"
    print("reference runtime ≡ compiled backend: BIT-EXACT ✓")

    # accuracy vs float
    h = np.maximum(x @ spec.weights[0] + spec.biases[0], 0)
    h = np.maximum(h @ spec.weights[1] + spec.biases[1], 0)
    y_f32 = h @ spec.weights[2] + spec.biases[2]
    y_int8 = yq_ref.astype(np.float32) * s_out
    rel = np.abs(y_int8 - y_f32).max() / np.abs(y_f32).max()
    print(f"int8 vs fp32 relative error: {rel:.4f}")
    agree = (y_int8.argmax(-1) == y_f32.argmax(-1)).mean()
    print(f"argmax agreement: {agree:.2%}")

    # -- 5. serialization round trip ------------------------------------------
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "model.pqir.json")
        model.save(path)
        model2 = Model.load(path)
        (yq2,) = ReferenceRuntime(model2).run({"input_q": xq}).values()
        assert np.array_equal(yq_ref, yq2)
        print(f"artifact round-trip via {os.path.basename(path)}: BIT-EXACT ✓ "
              f"({os.path.getsize(path)} bytes, fully self-contained)")


if __name__ == "__main__":
    main()
