"""Fleet serving: one AOT plan artifact, N warm-started replicas, one router.

examples/serve_compiled.py ends with one process serving one artifact.
This picks up at deployment scale: the compiled plan becomes a *file*
(``repro.backend.artifact``, schema ``repro-plan-v1``) and a sharded
router (``repro.serving.router``) stands up three replicas from it.

1. Compile a two-axis ``("N", "S", …)`` artifact, serve a recording run so
   the PlanCache visits the hot scenario cells, and ``save_artifact`` —
   one JSON (structure + hot cells + provenance) plus an npz sidecar
   (baked constants, sha256-pinned in the JSON).
2. ``ShardedRouter.from_artifact(replicas=3)``: every replica warm-starts
   from disk — no passes, no fusion, no lowering, plan cache pre-seeded —
   and traffic shards by sequence-bucket cell affinity, so each replica's
   cache stays as hot as the single server's was.
3. Throw mixed-length traffic at the front door and check every response
   bit-exact vs a solo reference-runtime run.
4. Kill a replica mid-traffic: its queue migrates in order to a healthy
   replica, its cells re-point, and the uid accounting proves nothing was
   lost and nothing served twice.

Run:  PYTHONPATH=src python examples/fleet_serve.py
"""
import os
import tempfile

import numpy as np

from repro.backend.artifact import load_artifact, save_artifact, sidecar_path
from repro.core import patterns, pqir, quant
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.serving import CompiledModelServer, CompiledServerConfig, RouterConfig, ShardedRouter


def main():
    rng = np.random.default_rng(7)

    # -- 1. compile, record the hot cells, save the artifact ------------------
    p = quant.quantize_linear_layer(
        rng.normal(size=(32, 16)).astype(np.float32) * 0.2,
        rng.normal(size=(16,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    gb = pqir.GraphBuilder("fleet_mlp")
    x = gb.add_input("x", "int8", ("N", "S", 32))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", ("N", "S", 16))
    model = gb.build()

    cm = compile_model(model, backend="interpret", dynamic_axes={"N": None, "S": 8})
    cfg = CompiledServerConfig(max_batch=4)
    recorder = CompiledModelServer(cm, cfg)
    for s in (4, 12, 20):  # the traffic mix: three sequence-bucket cells
        for _ in range(4):
            recorder.submit(rng.integers(-128, 128, (s, 32)).astype(np.int8))
        recorder.run_until_drained()

    path = os.path.join(tempfile.mkdtemp(prefix="repro-fleet-"), "plan.json")
    save_artifact(cm, path)
    print(f"saved {path} (+ {os.path.basename(sidecar_path(path))}): "
          f"{len(cm.plan.steps)} steps, "
          f"{recorder.summary()['plan_cache']['size']} hot cells recorded")

    # -- 2. the fleet: 3 replicas warm-started from the one file --------------
    router = ShardedRouter.from_artifact(
        path, replicas=3, server_cfg=cfg, cfg=RouterConfig(failure_threshold=1)
    )
    print("3 replicas up — zero re-lowering, plan caches pre-seeded\n")

    # -- 3. mixed traffic through the front door ------------------------------
    rt = ReferenceRuntime(model)
    reqs = []
    for _ in range(3):
        for s in (4, 12, 20):
            for _ in range(4):
                reqs.append(router.submit(rng.integers(-128, 128, (s, 32)).astype(np.int8)))
        router.run_until_drained()

    for req in reqs:
        solo = rt.run({"x": req.inner.x[None, :, :]})[y][0]
        assert np.array_equal(req.outputs[y], solo), f"request {req.uid} diverged"
    print(f"{len(reqs)} requests served bit-exactly across the fleet ✓")

    s = router.summary()
    print(f"cell → replica affinity: {s['cell_owners']}")
    print(f"per-replica plan-cache hit rates: "
          f"{ {k: round(v, 2) for k, v in s['plan_cache_hit_rates'].items()} } "
          "(pre-seeded caches: no replica ever missed)")

    # -- 4. failover: kill a replica mid-traffic ------------------------------
    victim = router.replicas[0]
    print(f"\ninjecting a failure into {victim.name} …")
    original_run = victim.server.cm.run
    victim.server.cm.run = lambda feeds: (_ for _ in ()).throw(RuntimeError("down"))
    wave = []
    for s_len in (4, 12, 20):
        for _ in range(4):
            wave.append(router.submit(rng.integers(-128, 128, (s_len, 32)).astype(np.int8)))
    done = router.run_until_drained()
    victim.server.cm.run = original_run

    for req in wave:
        solo = rt.run({"x": req.inner.x[None, :, :]})[y][0]
        assert np.array_equal(req.outputs[y], solo), f"request {req.uid} diverged"
    s = router.summary()
    assert len(done) == len(wave) and s["lost"] == 0 and s["duplicates"] == 0
    print(f"wave of {len(wave)} served anyway: {s['rerouted']} requests migrated "
          f"in order, {s['failovers']} failover handled, lost={s['lost']}, "
          f"duplicates={s['duplicates']}")
    print(f"affinity after failover: {s['cell_owners']}")
    print(f"health: { {k: ('up' if v['healthy'] else 'DOWN') for k, v in s['health'].items()} }")

    # the artifact loads anywhere — a fourth replica, a diff tool, a designer
    cm_again = load_artifact(path, warm=True)
    print(f"\nre-loaded the artifact once more: {len(cm_again.plan.steps)} steps, "
          "ready to serve — `python scripts/plan_diff.py old.json new.json` "
          "diffs two of these structurally")


if __name__ == "__main__":
    main()
