"""The paper's §5 CNN example: ConvInteger patterns + int8 tanh head (Fig 3/4).

Trains a tiny fp32 CNN on a synthetic 8×8 shape-classification task (pure
numpy SGD — the quantizer side needs no accelerator), quantizes it into a
pre-quantized artifact, and compares fp32 vs int8 accuracy under both the
reference runtime and the compiled backend.

Run:  PYTHONPATH=src python examples/cnn_prequant.py
"""
import numpy as np

from repro.core import quant
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime, _conv2d_f32
from repro.core.toolchain import CNNSpec, ConvLayerSpec, MLPSpec, quantize_cnn


def make_data(rng, n):
    """Three classes: horizontal bar, vertical bar, blob."""
    x = rng.normal(size=(n, 1, 8, 8)).astype(np.float32) * 0.3
    y = rng.integers(0, 3, n)
    for i, cls in enumerate(y):
        if cls == 0:
            x[i, 0, 3:5, :] += 2.0
        elif cls == 1:
            x[i, 0, :, 3:5] += 2.0
        else:
            x[i, 0, 2:6, 2:6] += 1.5
    return x, y


def forward_f32(x, convw, convb, fcw, fcb):
    h = _conv2d_f32(x, convw, {"strides": (2, 2), "pads": (1, 1, 1, 1)}) + convb.reshape(1, -1, 1, 1)
    h = np.maximum(h, 0)
    h = h.reshape(h.shape[0], -1)
    return h @ fcw + fcb


def main():
    rng = np.random.default_rng(0)
    xtr, ytr = make_data(rng, 2048)
    xte, yte = make_data(rng, 512)

    # -- tiny fp32 training (numpy SGD on the FC head + fixed conv filters) ---
    convw = rng.normal(size=(8, 1, 3, 3)).astype(np.float32) * 0.5
    convb = np.zeros(8, np.float32)
    feat = lambda x: np.maximum(
        _conv2d_f32(x, convw, {"strides": (2, 2), "pads": (1, 1, 1, 1)}) + convb.reshape(1, -1, 1, 1), 0
    ).reshape(x.shape[0], -1)
    fdim = feat(xtr[:1]).shape[1]
    fcw = rng.normal(size=(fdim, 3)).astype(np.float32) * 0.05
    fcb = np.zeros(3, np.float32)
    lr = 0.05
    for epoch in range(30):
        f = feat(xtr)
        logits = f @ fcw + fcb
        p = np.exp(logits - logits.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        g = p.copy()
        g[np.arange(len(ytr)), ytr] -= 1
        g /= len(ytr)
        fcw -= lr * f.T @ g
        fcb -= lr * g.sum(0)
    acc_f32 = (forward_f32(xte, convw, convb, fcw, fcb).argmax(-1) == yte).mean()
    print(f"fp32 test accuracy: {acc_f32:.3f}")

    # -- quantize into the §5 artifact ----------------------------------------
    spec = CNNSpec(
        convs=[ConvLayerSpec(convw, convb, strides=(2, 2), pads=(1, 1, 1, 1), activation="Relu")],
        head=MLPSpec(weights=[fcw], biases=[fcb], activations=[None]),
    )
    model = quantize_cnn(spec, xtr[:256], observer="percentile", name="cnn_prequant")
    model.validate(standard_ops_only=True)
    ops = [n.op_type for n in model.graph.toposorted()]
    print(f"artifact ops: {ops}")

    s_in = eval(model.metadata["input_scale"])
    xq = quant.quantize(xte, s_in, "int8")
    (yq_ref,) = ReferenceRuntime(model).run({"input_q": xq}).values()
    acc_ref = (yq_ref.astype(np.float32).argmax(-1) == yte).mean()

    cm = compile_model(model)
    print(f"compiler fusion report: {cm.stats}")
    (yq_tpu,) = cm.run({"input_q": xq}).values()
    assert np.array_equal(yq_ref, yq_tpu)
    print("reference runtime ≡ compiled backend: BIT-EXACT ✓")
    print(f"int8 test accuracy: {acc_ref:.3f} (fp32 {acc_f32:.3f}, "
          f"Δ {abs(acc_f32 - acc_ref):.3f})")

    # -- per-channel variant: one weight scale + §3.1 rescale per filter ------
    model_pc = quantize_cnn(spec, xtr[:256], observer="percentile",
                            per_channel=True, name="cnn_prequant_pc")
    model_pc.validate(standard_ops_only=True)
    xq_pc = quant.quantize(xte, eval(model_pc.metadata["input_scale"]), "int8")
    (yq_pc_ref,) = ReferenceRuntime(model_pc).run({"input_q": xq_pc}).values()
    cm_pc = compile_model(model_pc)
    assert cm_pc.stats["fused_qconv"] == 1 and cm_pc.stats["fused_qlinear"] == 1
    (yq_pc,) = cm_pc.run({"input_q": xq_pc}).values()
    assert np.array_equal(yq_pc_ref, yq_pc)
    acc_pc = (yq_pc.astype(np.float32).argmax(-1) == yte).mean()
    print(f"per-channel artifact: fused + BIT-EXACT ✓ "
          f"(int8 per-channel accuracy: {acc_pc:.3f})")


if __name__ == "__main__":
    main()
