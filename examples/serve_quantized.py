"""End-to-end serving driver: batched requests through the continuous-batching
engine, comparing three quantization postures of the SAME model:

    bf16 weights + bf16 KV cache   (baseline)
    bf16 weights + int8 KV cache   (paper scheme on the cache)
    W8A8 weights + int8 KV cache   (fully pre-quantized serving)

Run:  PYTHONPATH=src python examples/serve_quantized.py [--arch minicpm_2b]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.convert import convert_params_w8a8
from repro.models import model as M
from repro.serving.engine import EngineConfig, Request, ServeEngine


def run_engine(params, cfg, prompts, new_tokens, slots):
    ecfg = EngineConfig(slots=slots, max_len=int(max(len(p) for p in prompts)) + new_tokens + 8)
    eng = ServeEngine(params, cfg, ecfg)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=new_tokens) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    t0 = time.monotonic()
    eng.run_until_drained()
    dt = time.monotonic() - t0
    toks = sum(len(r.generated) for r in reqs)
    return reqs, toks / dt, eng.metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm_2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32) for _ in range(args.requests)]

    results = {}
    r_base, tput, m = run_engine(params, cfg, prompts, args.new_tokens, args.slots)
    results["bf16/bf16-kv"] = (r_base, tput, m)

    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    r_kv8, tput, m = run_engine(params, cfg8, prompts, args.new_tokens, args.slots)
    results["bf16/int8-kv"] = (r_kv8, tput, m)

    pq = convert_params_w8a8(params)
    r_w8, tput, m = run_engine(pq, cfg8, prompts, args.new_tokens, args.slots)
    results["w8a8/int8-kv"] = (r_w8, tput, m)

    base = results["bf16/bf16-kv"][0]
    print(f"\n{args.arch} — {args.requests} requests × {args.new_tokens} new tokens, {args.slots} slots")
    print(f"{'config':16s} {'tok/s':>8s} {'vs-baseline token agreement':>30s}")
    for name, (reqs, tput, m) in results.items():
        match = np.mean([
            np.mean([a == b for a, b in zip(x.generated, y.generated)]) for x, y in zip(reqs, base)
        ])
        print(f"{name:16s} {tput:8.1f} {match:29.1%}")
    print("\n(int8 KV and W8A8 cut cache and weight HBM traffic 2× each — on CPU "
        "wall-clock is emulation-bound; the roofline table in EXPERIMENTS.md "
        "§Perf quantifies the TPU effect.)")


if __name__ == "__main__":
    main()
