"""Serving a compiled artifact: one batch-polymorphic compile, any traffic.

The quickstart (examples/quickstart.py) ends at "compile the artifact and
run it bit-exactly".  This picks up where it stops and answers the
production question: how does the SAME artifact serve real request traffic
— many independent clients, ragged arrival sizes — without a recompile per
request shape?

1. Quantize + codify the §4 MLP (identical to the quickstart).
2. Compile ONCE with ``batch="dynamic"``: the plan is a shape-generic
   *template* (fusion, buffer liveness, dtype inference, parameter padding
   all done); the batch-dependent tile choice is bound lazily per
   power-of-two bucket through a bounded PlanCache.
3. Stand up the micro-batching server (repro.serving.compiled): queued
   requests coalesce into buckets, pad, execute, slice.
4. Throw ragged traffic at it and check every response is bit-exact vs a
   solo reference-runtime run — then read the serving metrics: a handful of
   plan specializations served the whole mix.
5. Go multi-axis: a second artifact declares *named* symbolic axes
   ``("N", "S", …)`` and is compiled with ``dynamic_axes={"N": None, "S":
   16}`` — variable-length sequence requests then coalesce onto a 2-D
   (batch-bucket × seq-bucket) grid, with a ``max_wait_ms`` admission
   window trading batch occupancy against tail latency.

Run:  PYTHONPATH=src python examples/serve_compiled.py [--trace out.json]

``--trace`` installs a repro.obs tracer for the whole run and dumps the
Chrome-trace JSON (open it at chrome://tracing or ui.perfetto.dev): the
compile pass pipeline, one specialization span per visited bucket, and
every serving step with its per-request async spans.  It also prints the
plan's provenance section (``pretty(verbose=True)``) — the audit trail
from graph ops to fused kernels to scenario cells.
"""
import argparse

import numpy as np

from repro.core import patterns, pqir, quant
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import MLPSpec, quantize_mlp
from repro.serving import CompiledModelServer, CompiledServerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--trace", metavar="PATH",
        help="dump a Chrome-trace JSON of the whole run (compile, "
        "specializations, serving steps, per-request spans)",
    )
    args = ap.parse_args(argv)
    tracer = None
    if args.trace:
        from repro.obs import trace as obs_trace

        tracer = obs_trace.install()

    rng = np.random.default_rng(0)

    # -- 1. the artifact (same recipe as the quickstart) ----------------------
    spec = MLPSpec(
        weights=[
            rng.normal(size=(64, 128)).astype(np.float32) * 0.2,
            rng.normal(size=(128, 128)).astype(np.float32) * 0.15,
            rng.normal(size=(128, 10)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(10,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", "Relu", None],
    )
    calib = rng.normal(size=(512, 64)).astype(np.float32)
    model = quantize_mlp(spec, calib, observer="percentile", name="served_mlp")
    s_in = eval(model.metadata["input_scale"])

    # -- 2. one batch-polymorphic compile -------------------------------------
    cm = compile_model(model, backend="interpret", batch="dynamic")
    print("template plan (batch-open shape records — no m/bm yet):")
    print(cm.plan)
    print()

    # -- 3. the micro-batching server -----------------------------------------
    srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=32))

    # -- 4. ragged traffic: 64 requests arriving in uneven waves --------------
    rt = ReferenceRuntime(model)
    out_name = cm.output_names[0]
    all_reqs = []
    for wave in (3, 1, 17, 9, 32, 2):
        for _ in range(wave):
            x = quant.quantize(rng.normal(size=(64,)).astype(np.float32), s_in, "int8")
            all_reqs.append(srv.submit(x))
        srv.run_until_drained()

    for req in all_reqs:
        solo = rt.run({"input_q": req.x[None, :]})[out_name][0]
        assert np.array_equal(req.outputs[out_name], solo), f"request {req.uid} diverged"
    print(f"{len(all_reqs)} requests served, every response bit-exact vs the "
          "reference runtime ✓")

    s = srv.summary()
    print(f"batches: {s['batches']}  bucket histogram: {s['bucket_batches']}  "
          f"padded rows: {s['padded_rows']}")
    print(f"plan cache: {s['plan_cache']}  hit rate: {s['plan_cache_hit_rate']:.2f}")
    print(f"latency: avg {s['latency_avg_ms']:.2f} ms  p95 {s['latency_p95_ms']:.2f} ms")
    specialized, _ = cm.specialized(8)
    print("\nthe bucket-8 specialization a hardware designer reads "
          "(m/bm bound, everything else shared with the template):")
    print(specialized)

    # -- 5. named multi-axis serving: variable-length sequences ---------------
    print("\n— multi-axis: one artifact over a (batch × sequence) grid —\n")
    rng2 = np.random.default_rng(1)
    p = quant.quantize_linear_layer(
        rng2.normal(size=(32, 16)).astype(np.float32) * 0.2,
        rng2.normal(size=(16,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    gb = pqir.GraphBuilder("served_seq_mlp")
    x = gb.add_input("x", "int8", ("N", "S", 32))  # named symbolic axes
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", ("N", "S", 16))
    seq_model = gb.build()

    # batch buckets power-of-two; sequence buckets in multiples of 16 (the
    # token engine's prefill-bucket discipline, reused as an axis policy)
    cm2 = compile_model(seq_model, backend="interpret",
                        dynamic_axes={"N": None, "S": 16})
    print("two-axis template (named lead dims, open over N and S):")
    print(cm2.plan)
    print()

    # a small admission window: hold partial batches up to 5 ms for more
    # arrivals instead of draining greedily (window hits in summary())
    srv2 = CompiledModelServer(
        cm2, CompiledServerConfig(max_batch=8, max_wait_ms=5.0)
    )
    rt2 = ReferenceRuntime(seq_model)
    seq_reqs = []
    for wave in (5, 8, 3, 11):
        for _ in range(wave):
            s = int(rng2.integers(1, 40))  # ragged sequence lengths
            seq_reqs.append(
                srv2.submit(rng2.integers(-128, 128, (s, 32)).astype(np.int8))
            )
        srv2.run_until_drained()

    for req in seq_reqs:
        solo = rt2.run({"x": req.x[None, :, :]})[y][0]
        assert np.array_equal(req.outputs[y], solo), f"request {req.uid} diverged"
    print(f"{len(seq_reqs)} variable-length requests served bit-exactly ✓")

    s2 = srv2.summary()
    print(f"grid histogram (batch bucket, seq bucket): {s2['grid_batches']}")
    print(f"padded rows: {s2['padded_rows']}  padded tokens: {s2['padded_tokens']}  "
          f"window hits: {s2['window_hits']}")
    print(f"plan cache: {s2['plan_cache']}")

    if tracer is not None:
        from repro.obs import trace as obs_trace

        obs_trace.uninstall()
        tracer.dump(args.trace)
        print(f"\nwrote {len(tracer.records)} trace events to {args.trace} "
              f"(trace_id={tracer.trace_id}) — load at chrome://tracing")
        print("\nplan provenance (how the first artifact came to be):")
        print(cm.plan.pretty(verbose=True))


if __name__ == "__main__":
    main()
