"""Roofline analysis (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip (394 int8), 819 GB/s HBM,
~50 GB/s/link ICI; single pod = 256 chips.

Methodology — why probes: every stack here is lowered with ``lax.scan`` over
layers/microbatches/attention chunks, and XLA's ``cost_analysis()`` counts a
while-loop body ONCE regardless of trip count.  The full-config dry-run
therefore proves compile/fit (memory_analysis is correct: buffers are reused
across iterations), but FLOP/byte totals must be reconstructed.  We lower
UNROLLED probes of the same config at L=1 and L=2 layers (single microbatch,
single attention chunk) on the production mesh and take differences:

    per_layer   = cost(L=2) − cost(L=1)        (incl. its collectives)
    fixed       = cost(L=1) − per_layer        (embed, logits, loss)
    total       = fixed + per_layer · L_full · microbatches [+ optimizer]

The optimizer is added analytically (elementwise AdamW: ~12 flop, ~24 B HBM
per param, no collectives — grads are already reduced inside the probe's
backward).  Collective bytes come from parsing the probe's partitioned HLO,
so they are per-participant values.

Terms per (arch × shape), single-pod mesh:
    T_comp = FLOPs_per_device / peak
    T_mem  = HBM_bytes_per_device / HBM_bw
    T_coll = collective_bytes_per_device / link_bw

The hardware constants and the term model live in :mod:`repro.backend.cost`
(shared with the backend's measured tile autotuner, which seeds its search
from the same numbers); this module re-exports the flat names it has always
had so downstream readers keep working.
"""
from __future__ import annotations

import dataclasses
import json
import sys
from typing import Dict, Optional

from repro.backend.cost import (  # noqa: F401  (re-exported)
    CHIPS,
    HBM_BW,
    ICI_BW,
    PEAK_BF16,
    PEAK_INT8,
    TPU_V5E,
    roofline_fraction,
    roofline_terms,
)


def model_flops(cfg, sc, n_params_active: int, n_params_total: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active non-embed
    params, D = tokens processed by the step."""
    if sc.kind == "train":
        tokens = sc.global_batch * sc.seq_len
        return 6.0 * n_params_active * tokens
    if sc.kind == "prefill":
        tokens = sc.global_batch * sc.seq_len
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * sc.global_batch  # decode: one token/seq


def count_params(cfg) -> Dict[str, int]:
    """Exact param counts from the abstract param tree."""
    import jax

    from repro.models import model as M

    specs = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    total = 0
    embed = 0
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, leaf in flat:
        names = [str(getattr(k, "key", "")) for k in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if names[0] in ("embed",) or names[-1] == "lm_head":
            embed += n
        if "moe" in names and names[-1] in ("w_gate", "w_up", "w_down"):
            expert += n
    nonembed = total - embed
    active = nonembed
    if cfg.moe is not None:
        active = nonembed - expert + expert * cfg.moe.top_k // cfg.moe.n_experts
    return {"total": total, "non_embed": nonembed, "active_non_embed": active, "expert": expert}


def analytic_memory_bytes(cfg, sc, counts, *, w8a8: bool = False, chips: int = CHIPS, model_axis: int = 16) -> Dict[str, float]:
    """Analytic minimum per-device HBM traffic (bytes) for one step.

    The CPU-HLO ``bytes accessed`` is an unfused upper bound (every elementwise
    op round-trips HBM); on TPU, XLA fuses those chains, so the *floor* is:

      weights/pass : bf16 (or int8 when w8a8) × the device's model-axis shard
                     (N/16) — FSDP gathers over `data` land in HBM once/pass
      activations  : (8·d + 4·d_ff_active)·2B per token per layer, batch-sharded
      KV cache     : full local slice read per decode step; written at prefill
      train extras : ×M microbatches ×3 passes (fwd/bwd/remat), f32 grad
                     accumulate r/w, AdamW 24 B/param — all /chips (FSDP+TP)
    """
    import jax

    from repro.configs.base import ModelConfig
    from repro.launch import specs as S
    from repro.models import model as M

    n_total = counts["total"]
    wb = 1 if w8a8 else 2
    w_pass = wb * n_total / model_axis  # per-device weight bytes per pass
    d = cfg.d_model
    d_ff_active = cfg.d_ff
    if cfg.moe is not None:
        d_ff_active = cfg.moe.top_k * cfg.moe.d_ff_expert + (cfg.moe.d_ff_shared or 0)
    act_unit = (8 * d + 4 * d_ff_active) * 2  # bytes per token per layer
    L = layer_multiplier(cfg)

    if sc.kind == "train":
        m = sc.microbatches
        tokens_local = sc.global_batch * sc.seq_len / 16  # data-sharded
        act = L * tokens_local * act_unit  # per device, summed over microbatches
        w = 3 * m * 2 * n_total / model_axis  # bf16 fwd+bwd+remat passes
        grads = m * 2 * 4 * n_total / chips  # f32 accumulate r/w
        opt = 24 * n_total / chips
        logits = 2 * tokens_local * 4 * M.padded_vocab(cfg) / sc.seq_len * 0  # folded into act
        return {"mem_min_bytes": w + act + grads + opt}
    cache = S.cache_specs(cfg, sc.global_batch, sc.seq_len, src_len=min(sc.seq_len, 4096) if cfg.family == "encdec" else 0)
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)) / chips
    if sc.kind == "prefill":
        tokens_local = sc.global_batch * sc.seq_len / 16
        act = L * tokens_local * act_unit
        return {"mem_min_bytes": 2 * w_pass + act + cache_bytes}
    # decode: every weight + the full local cache slice per token step
    return {"mem_min_bytes": w_pass + cache_bytes + L * sc.global_batch / 16 * act_unit}


def probe_config(cfg, n_layers: int):
    """Unrolled, probe-sized variant of a full config (dims unchanged)."""
    import dataclasses as dc

    kw = dict(scan_layers=False, n_layers=n_layers, remat_policy="none")
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = n_layers
    if cfg.hybrid is not None:
        hy = dc.replace(cfg.hybrid, n_groups=n_layers, tail_ssm_layers=0)
        kw["hybrid"] = hy
        kw["n_layers"] = n_layers * (cfg.hybrid.ssm_per_group + 1)
    return dc.replace(cfg, **kw)


def layer_multiplier(cfg) -> float:
    """How many probe-'layers' the full config has."""
    if cfg.hybrid is not None:
        hy = cfg.hybrid
        return hy.n_groups + hy.tail_ssm_layers / (hy.ssm_per_group + 1)
    return float(cfg.n_layers)


def probe_cell(arch: str, shape_name: str, *, multi_pod: bool = False, w8a8: bool = False) -> Dict:
    """Lower L=1 and L=2 unrolled probes; return per-layer + fixed costs."""
    import dataclasses as dc

    from repro.configs import get_config
    from repro.configs.base import SHAPE_BY_NAME
    from repro.launch import dryrun as DR

    cfg = get_config(arch)
    sc = SHAPE_BY_NAME[shape_name]
    if sc.kind == "train":
        # one microbatch per probe; scale by microbatches afterwards
        sc_probe = dc.replace(sc, global_batch=sc.global_batch // sc.microbatches, microbatches=1)
    else:
        sc_probe = sc
    chunk = min(sc_probe.seq_len, 32768 if sc.kind != "train" else sc_probe.seq_len)

    out = {}
    for L in (1, 2):
        pcfg = probe_config(cfg, L)
        res = _lower_with_cfg(pcfg, arch, sc_probe, multi_pod=multi_pod, q_chunk=chunk, kv_chunk=chunk, w8a8=w8a8)
        hlo = res["compiled"].as_text()
        cost = res["compiled"].cost_analysis()
        coll = DR.collective_bytes_from_hlo(hlo)
        out[L] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(sum(v for k, v in coll.items() if k != "count")),
            "coll_count": int(coll["count"]),
        }
    per_layer = {k: out[2][k] - out[1][k] for k in out[1]}
    fixed = {k: out[1][k] - per_layer[k] for k in out[1]}
    return {"per_layer": per_layer, "fixed": fixed, "probe": out}


def _lower_with_cfg(pcfg, arch, sc, *, multi_pod, q_chunk, kv_chunk, w8a8=False):
    """dryrun.lower_cell but with an explicit (probe) config."""
    from repro.distributed.sharding import use_mesh
    from repro.launch import specs as S
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh

    import jax

    mesh = make_production_mesh(multi_pod=multi_pod)
    with use_mesh(mesh):
        p_specs = S.params_specs(pcfg)
        if w8a8 and sc.kind != "train":
            from repro.core.convert import convert_params_w8a8

            p_specs = jax.eval_shape(convert_params_w8a8, p_specs)
        p_sh = S.params_shardings(p_specs, mesh)
        if sc.kind == "train":
            fn = steps.make_grad_step(pcfg, sc, q_chunk=q_chunk, kv_chunk=kv_chunk)
            b_specs = S.train_batch_specs(pcfg, sc)
            b_sh = S.batch_shardings(b_specs, mesh)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_specs, b_specs)
        elif sc.kind == "prefill":
            b_specs, c_specs = S.prefill_input_specs(pcfg, sc)
            b_sh = S.batch_shardings(b_specs, mesh)
            c_sh = S.cache_shardings(c_specs, mesh)
            fn = steps.make_prefill_step(pcfg, q_chunk=q_chunk, kv_chunk=kv_chunk)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh), donate_argnums=(2,))
            lowered = jitted.lower(p_specs, b_specs, c_specs)
        else:
            toks, pos, c_specs = S.decode_input_specs(pcfg, sc)
            c_sh = S.cache_shardings(c_specs, mesh)
            t_sh = S.batch_shardings({"tokens": toks, "pos": pos}, mesh)
            fn = steps.make_decode_step(pcfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, t_sh["tokens"], t_sh["pos"], c_sh), donate_argnums=(3,))
            lowered = jitted.lower(p_specs, toks, pos, c_specs)
        return {"compiled": lowered.compile(), "lowered": lowered}


def roofline_cell(arch: str, shape_name: str, *, multi_pod: bool = False, w8a8: bool = False) -> Dict:
    """Full roofline record for one cell."""
    from repro.configs import get_config
    from repro.configs.base import SHAPE_BY_NAME
    from repro.launch.specs import skip_reason

    cfg = get_config(arch)
    sc = SHAPE_BY_NAME[shape_name]
    skip = skip_reason(cfg, sc)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skip", "reason": skip}

    counts = count_params(cfg)
    probes = probe_cell(arch, shape_name, multi_pod=multi_pod, w8a8=w8a8)
    lm = layer_multiplier(cfg)
    mm = sc.microbatches if sc.kind == "train" else 1

    per_dev = {}
    for key in ("flops", "bytes", "coll_bytes"):
        per_dev[key] = max(0.0, probes["fixed"][key] * mm + probes["per_layer"][key] * lm * mm)
    if sc.kind == "train":
        # AdamW analytic add-on: ~12 flop + ~24 HBM bytes per param (per-device
        # share: params are FSDP+TP sharded across all chips)
        n_dev = counts["total"] / CHIPS
        per_dev["flops"] += 12 * n_dev
        per_dev["bytes"] += 24 * n_dev
    per_dev.update(analytic_memory_bytes(cfg, sc, counts, w8a8=w8a8))

    # unfused upper bound (CPU HLO) — same T_mem arithmetic as the floor below
    t_mem_hlo = roofline_terms(0.0, per_dev["bytes"])["t_mem_s"]
    # fused analytic floor for T_mem; T_comp/T_coll straight from the probes
    terms = roofline_terms(
        per_dev["flops"], per_dev["mem_min_bytes"], per_dev["coll_bytes"]
    )
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())

    mf = model_flops(cfg, sc, counts["active_non_embed"], counts["total"])
    hlo_total_flops = per_dev["flops"] * CHIPS
    useful = mf / hlo_total_flops if hlo_total_flops else 0.0
    # roofline fraction: model-useful FLOPs per second vs fleet peak,
    # at the bound implied by the dominant term
    mfu_bound = roofline_fraction(mf, step_time)

    return {
        "arch": arch, "shape": shape_name, "status": "ok", "multi_pod": multi_pod, "w8a8": w8a8,
        "params": counts,
        "per_device": per_dev,
        "terms": {**{k: round(v, 6) for k, v in terms.items()}, "t_mem_hlo_upper_s": round(t_mem_hlo, 6)},
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_flops_ratio": round(useful, 4),
        "roofline_fraction": round(mfu_bound, 4),
        "probes": probes,
    }


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--w8a8", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import ARCH_IDS
    from repro.configs.base import SHAPES

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    results = []
    for a in archs:
        for s in shapes:
            try:
                r = roofline_cell(a, s, w8a8=args.w8a8)
            except Exception as e:
                import traceback

                r = {"arch": a, "shape": s, "status": "fail", "error": f"{type(e).__name__}: {e}",
                     "trace": traceback.format_exc()[-1500:]}
            results.append(r)
            if r["status"] == "ok":
                t = r["terms"]
                print(
                    f"{a:24s} {s:12s} comp={t['t_comp_s']:.4f}s mem={t['t_mem_s']:.4f}s "
                    f"coll={t['t_coll_s']:.4f}s bound={r['bottleneck'][2:-2]:4s} "
                    f"useful={r['useful_flops_ratio']:.2f} roofline={r['roofline_fraction']:.3f}",
                    flush=True,
                )
            else:
                print(f"{a:24s} {s:12s} {r['status'].upper()} {r.get('error', r.get('reason', ''))[:140]}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)
    return 0


if __name__ == "__main__":
    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    sys.exit(main())
