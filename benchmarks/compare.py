"""Benchmark diff guard: compare a fresh ``benchmarks/run.py --json`` dump
against the latest committed ``BENCH_*.json`` baseline.

The committed ``BENCH_<n>.json`` files are the repo's perf trajectory; this
tool makes the trajectory actionable by diffing per-row ``us_per_call``
within a configurable tolerance:

  PYTHONPATH=src python -m benchmarks.compare current.json
  PYTHONPATH=src python -m benchmarks.compare current.json --baseline BENCH_5.json
  PYTHONPATH=src python -m benchmarks.compare current.json --tolerance 0.5 --strict

* **Baseline discovery** — ``--baseline`` names one explicitly; otherwise
  the highest-numbered ``BENCH_<n>.json`` next to this file is used.
* **Tolerance** — a row regresses when ``current > baseline * (1 + tol)``
  (default ``--tolerance 0.35``: micro-benchmarks on shared CI runners are
  noisy; the guard is for step changes, not percent drift).  Improvements
  beyond the same factor are reported too (they move the trajectory and
  deserve a fresh committed baseline).
* **Warn-only by default** — exit code is 0 unless ``--strict`` is passed;
  CI runs warn-only so a noisy runner cannot block a merge, while local
  perf work can use ``--strict`` as a gate.
* Rows present on only one side (new/retired benchmarks) are listed but are
  never failures: the benchmark set is expected to grow PR over PR.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Dict, Optional, Tuple

_BENCH_RE = re.compile(r"^BENCH_(\d+)\.json$")


def find_baseline(search_dir: Path) -> Optional[Path]:
    """The highest-numbered committed BENCH_<n>.json, or None."""
    best: Optional[Tuple[int, Path]] = None
    for p in search_dir.glob("BENCH_*.json"):
        m = _BENCH_RE.match(p.name)
        if m is not None:
            key = (int(m.group(1)), p)
            if best is None or key[0] > best[0]:
                best = key
    return best[1] if best is not None else None


def load_rows(path: Path) -> Dict[str, dict]:
    payload = json.loads(path.read_text())
    if payload.get("schema") != "repro-bench-v1":
        raise SystemExit(f"{path}: not a repro-bench-v1 payload")
    rows: Dict[str, dict] = {}
    for i, r in enumerate(payload.get("rows", [])):
        name = r.get("name")
        if not name or not isinstance(r.get("us_per_call"), (int, float)):
            raise SystemExit(
                f"{path}: row {i} malformed — every row needs a 'name' and a "
                f"numeric 'us_per_call' (got {sorted(r)})"
            )
        rows[name] = r
    return rows


def compare(
    current: Dict[str, dict], baseline: Dict[str, dict], tolerance: float
) -> Tuple[list, list, list, list]:
    """(regressions, improvements, added, removed) row-name lists; a
    regression/improvement entry is (name, base_us, cur_us, ratio)."""
    regressions, improvements = [], []
    for name in sorted(set(current) & set(baseline)):
        base, cur = baseline[name]["us_per_call"], current[name]["us_per_call"]
        if base <= 0:
            continue
        ratio = cur / base
        if ratio > 1.0 + tolerance:
            regressions.append((name, base, cur, ratio))
        elif ratio < 1.0 / (1.0 + tolerance):
            improvements.append((name, base, cur, ratio))
    added = sorted(set(current) - set(baseline))
    removed = sorted(set(baseline) - set(current))
    return regressions, improvements, added, removed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", type=Path, help="fresh benchmarks/run.py --json dump")
    ap.add_argument(
        "--baseline", type=Path, default=None,
        help="baseline payload (default: highest-numbered BENCH_<n>.json "
        "next to this script)",
    )
    ap.add_argument(
        "--tolerance", type=float, default=0.35,
        help="allowed relative slowdown before a row is a regression "
        "(0.35 = 35%%; micro-bench noise on shared runners is real)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 1 on regressions (default: warn-only, always exit 0)",
    )
    args = ap.parse_args(argv)

    baseline_path = args.baseline or find_baseline(Path(__file__).resolve().parent)
    if baseline_path is None:
        print("bench-compare: no committed BENCH_*.json baseline found; nothing to diff")
        return 0
    current = load_rows(args.current)
    baseline = load_rows(baseline_path)
    if not set(current) & set(baseline):
        # Disjoint row sets mean the baseline predates (or postdates) every
        # current benchmark — a diff would be vacuous, not a regression.
        print(
            f"bench-compare: no shared rows between {args.current} "
            f"({len(current)} rows) and baseline {baseline_path} "
            f"({len(baseline)} rows); nothing to compare — commit a fresh "
            f"BENCH_<n>.json baseline for the new row set"
        )
        return 0
    regressions, improvements, added, removed = compare(current, baseline, args.tolerance)

    print(f"bench-compare: {args.current} vs {baseline_path} (tolerance {args.tolerance:.0%})")
    for name, base, cur, ratio in regressions:
        print(f"  REGRESSION {name}: {base:.1f}us -> {cur:.1f}us ({ratio:.2f}x)")
    for name, base, cur, ratio in improvements:
        print(f"  improvement {name}: {base:.1f}us -> {cur:.1f}us ({ratio:.2f}x)")
    for name in added:
        print(f"  new row {name} (no baseline)")
    for name in removed:
        print(f"  missing row {name} (present in baseline; smoke subset?)")
    if not (regressions or improvements):
        print(f"  all {len(set(current) & set(baseline))} shared rows within tolerance")

    if regressions and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
