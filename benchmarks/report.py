"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep
JSONs (dryrun_pod1.json / dryrun_pod2.json / dryrun_pod1_w8a8.json /
roofline_pod1.json / roofline_pod1_w8a8.json).

Run:  PYTHONPATH=src python -m benchmarks.report > experiments_tables.md
"""
from __future__ import annotations

import json
import os

GB = 2**30


def load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def dryrun_table(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | status | HLO flops/dev | temp GiB/dev | peak GiB/dev | colls | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — |")
            continue
        if r["status"] == "fail":
            out.append(f"| {r['arch']} | {r['shape']} | **FAIL** | {r.get('error','')[:60]} | | | | |")
            continue
        m = r["memory"]
        temp = (m["temp_bytes"] or 0) / GB
        peak = (m["peak_bytes"] or 0) / GB
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['cost']['flops']:.2e} | "
            f"{temp:.2f} | {peak:.2f} | {r['collectives']['count']} | {r['t_compile_s']} |"
        )
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skip")
    fail = sum(1 for r in rows if r["status"] == "fail")
    out += ["", f"**{ok} ok / {skip} skip / {fail} fail.**",
            "(`temp` is the authoritative per-device residency proof from the "
            "partitioned module; CPU-XLA's `peak` field is erratic on this "
            "backend and reported for completeness only.)", ""]
    return "\n".join(out)


def roofline_table(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | T_comp s | T_mem s | T_coll s | T_mem(HLO-UB) s | bound | useful | roofline |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status'].upper()} | | | | | | |")
            continue
        t = r["terms"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['t_comp_s']:.4f} | {t['t_mem_s']:.4f} | "
            f"{t['t_coll_s']:.4f} | {t.get('t_mem_hlo_upper_s', 0):.3f} | {r['bottleneck'][2:-2]} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    out.append("")
    return "\n".join(out)


def comparison_table(base_rows, opt_rows, title, label):
    idx = {(r["arch"], r["shape"]): r for r in opt_rows if r["status"] == "ok"}
    out = [f"### {title}", "",
           f"| arch | shape | bound | T_dom base s | T_dom {label} s | Δ | roofline base → {label} |",
           "|---|---|---|---|---|---|---|"]
    for r in base_rows:
        if r["status"] != "ok":
            continue
        o = idx.get((r["arch"], r["shape"]))
        if o is None:
            continue
        tb = r["terms"][r["bottleneck"]]
        to = o["terms"][r["bottleneck"]]
        delta = (tb - to) / tb * 100 if tb else 0.0
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['bottleneck'][2:-2]} | {tb:.4f} | {to:.4f} | "
            f"{delta:+.0f}% | {r['roofline_fraction']:.3f} → {o['roofline_fraction']:.3f} |"
        )
    out.append("")
    return "\n".join(out)


def main():
    p1 = load("dryrun_pod1.json")
    p2 = load("dryrun_pod2.json")
    w8 = load("dryrun_pod1_w8a8.json")
    rl = load("roofline_pod1.json")
    rl8 = load("roofline_pod1_w8a8.json")
    print(dryrun_table(p1, "Single-pod mesh (16×16 = 256 chips)"))
    print(dryrun_table(p2, "Multi-pod mesh (2×16×16 = 512 chips)"))
    if w8:
        print(dryrun_table([r for r in w8 if r["shape"] != "train_4k"], "Single-pod, W8A8 pre-quantized serving"))
    print(roofline_table(rl, "Roofline terms — baseline (bf16 weights, bf16 KV)"))
    if rl8:
        print(roofline_table([r for r in rl8 if r["shape"] != "train_4k"], "Roofline terms — W8A8 serving"))
        print(comparison_table(
            [r for r in rl if r["shape"] in ("decode_32k", "long_500k", "prefill_32k")],
            rl8, "W8A8 effect on the dominant term (serving shapes)", "w8a8"))


if __name__ == "__main__":
    main()
