"""Benchmark harness — one entry per paper figure/table + system-level extras.

Prints ``name,us_per_call,derived`` CSV rows:

  fig1_fc_two_mul        — Fig 1 pattern: reference runtime vs fused compile
  fig2_fc_relu_one_mul   — Fig 2
  fig3_conv              — Fig 3
  fig4_int8_tanh         — Fig 4 (derived: max int8 ULP error vs fp32 tanh)
  fig5_fp16_tanh         — Fig 5
  fig6_fp16_sigmoid      — Fig 6
  tbl_rescale_decompose  — §3.1 decomposition (derived: worst rel. error)
  sys_pass_pipeline      — repro.passes optimized vs raw compile of a 3-layer
                           MLP (derived: folded/eliminated pipeline stats)
  sys_plan_overhead      — slot-indexed ExecutionPlan execution vs the old
                           name-keyed dict-env interpretation of the same
                           kernels (derived: slot/tensor counts)
  sys_per_channel_overhead — per-channel vs scalar fused requant on the same
                           FC layer (derived: ratio; pinned at near-parity)
  sys_serving_compiled   — micro-batched serving of one batch-polymorphic
                           compiled artifact: requests/s at batch buckets
                           1/8/32 + plan-cache hit rate (≥2 buckets must be
                           served from cache after warmup)
  sys_seq_buckets        — one two-axis (named N × S) compiled artifact over
                           a (batch ∈ {1,8}) × (seq ∈ {32,128}) scenario
                           grid: requests/s per cell + specialization
                           counts (asserts at most one per grid cell)
  sys_autotune           — measured per-cell tile autotuning: tuned vs
                           heuristic executor per batch cell (tuned must not
                           lose beyond noise), plus the persisted-tile-cache
                           round trip (a warm-started second session must
                           measure nothing)
  sys_fleet              — fleet serving from one AOT plan artifact: a
                           3-replica ShardedRouter (warm-started, cell
                           affinity) vs a single warm server on the same
                           mixed-cell traffic (derived: rps both ways,
                           per-replica plan-cache hit rates pinned ≥ the
                           single-server baseline, warm vs cold first-wave
                           latency, lost/dup request counters)
  sys_int4_decode        — sub-8-bit weight lane on the decode path: one
                           MLP quantized at weight_bits 8 vs 4 on the tiled
                           interpret backend, decode-shaped cells M ∈ {1,8}
                           (derived: tokens/s both ways per cell, cost-model
                           HBM-byte ratio; asserts packed-int4 bit-exact vs
                           the unpacked reference and w4 weight bytes ≤
                           0.55× w8)
  sys_attn_decode        — the compiled token path (docs/token_path.md):
                           transformer decode through the specialized
                           ("N",1,…) ExecutionPlan with int8 KV state slots
                           and fused attention, vs the opaque-JAX engine at
                           the same geometry, decode cells M ∈ {1,8}
                           (derived: tokens/s both ways per cell; asserts
                           compiled decode bit-exact vs the jnp mirror and
                           exactly one specialization per visited cell)
  sys_w8a8_decode        — reduced-arch decode step: bf16 vs W8A8+int8-KV
  sys_grad_compress      — int8 cross-pod gradient all-reduce (derived: wire-
                           bytes ratio vs f32)

Run:  PYTHONPATH=src python -m benchmarks.run [--smoke] [--json PATH]

``--smoke`` runs the fast subset (fig1, pass pipeline, plan overhead,
per-channel overhead, serving-compiled, seq buckets, autotune, fleet,
int4 decode, attn decode) for CI.  ``--json BENCH_<n>.json``
additionally persists the rows as JSON so the perf trajectory survives
across PRs (CI uploads the file as a build artifact).
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

#: Rows accumulated by ``row()`` for the optional --json dump.
_ROWS: list = []


def _timeit(fn, *args, repeat: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args)
    return (time.perf_counter() - t0) / repeat * 1e6  # us


def row(name: str, us: float, derived: str) -> None:
    _ROWS.append({"name": name, "us_per_call": round(us, 1), "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def _fc_pattern(activation, two_mul, act_builder=None):
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(0)
    scale_w = 0.02 if act_builder is not None else 0.1  # keep preacts in the
    w = rng.normal(size=(256, 256)).astype(np.float32) * scale_w  # act range
    b = rng.normal(size=(256,)).astype(np.float32) * 0.1
    scale_y = (patterns.TANH_INPUT_ABSMAX / 127.0) if act_builder else 0.1
    p = quant.quantize_linear_layer(w, b, 0.05, scale_y)
    gb = pqir.GraphBuilder("bench")
    xi = gb.add_input("x", "int8", (None, 256))
    if act_builder is not None:
        y = act_builder(gb, xi, p, "fc0")
        out_dtype = "uint8" if act_builder is patterns.fc_fp16_sigmoid else "int8"
    else:
        y = patterns.fc_layer(gb, xi, p, "fc0", two_mul=two_mul, activation=activation)
        out_dtype = "int8"
    gb.add_output(y, out_dtype, (None, 256))
    model = gb.build()
    xq = rng.integers(-128, 128, (64, 256)).astype(np.int8)
    return model, xq, y, w, b


def bench_pattern(name, activation=None, two_mul=True, act_builder=None, derived_fn=None):
    from repro.core.compile import compile_model
    from repro.core.runtime import ReferenceRuntime

    model, xq, yname, w, b = _fc_pattern(activation, two_mul, act_builder)
    rt = ReferenceRuntime(model)
    # optimize=False: the fig rows measure the paper's codified chains as-is
    # (mul_fold would collapse fig1's two-Mul rescale into fig2's one-Mul
    # kernel config); sys_pass_pipeline below covers the optimized path.
    cm = compile_model(model, optimize=False)
    ref_out = rt.run({"x": xq})[yname]
    fused_out = cm.run({"x": xq})[yname]
    exact = np.array_equal(ref_out, fused_out)
    us_ref = _timeit(lambda: rt.run({"x": xq}))
    us_fused = _timeit(lambda: cm.run({"x": xq}))
    derived = f"fused_us={us_fused:.1f};speedup={us_ref / us_fused:.2f}x;bitexact={exact};{_stats_derived(cm)}"
    if derived_fn is not None:
        derived += ";" + derived_fn(model, xq, ref_out, w, b)
    row(name, us_ref, derived)


def _stats_derived(cm) -> str:
    """Compile/pass stats for the report: fused-vs-fallback step counts plus
    what the repro.passes pipeline folded/eliminated before codegen."""
    s = cm.stats
    fused = s["fused_qlinear"] + s["fused_qconv"] + s["fused_lut"]
    return f"fused={fused};fallback={s['generic']};folded={s['folded']};eliminated={s['eliminated']}"


def _tanh_err(model, xq, out, w, b):
    ref = np.tanh(xq.astype(np.float32) * 0.05 @ w + b)
    err = np.abs(out.astype(np.float32) / 127.0 - ref).max()
    return f"max_err_vs_fp32={err:.4f}"


def _sigmoid_err(model, xq, out, w, b):
    ref = 1.0 / (1.0 + np.exp(-(xq.astype(np.float32) * 0.05 @ w + b)))
    err = np.abs(out.astype(np.float32) / 255.0 - ref).max()
    return f"max_err_vs_fp32={err:.4f}"


def bench_fig3_conv():
    from repro.core import patterns, pqir, quant
    from repro.core.compile import compile_model
    from repro.core.runtime import ReferenceRuntime

    rng = np.random.default_rng(1)
    w = rng.integers(-128, 128, (16, 8, 3, 3)).astype(np.int8)
    b = rng.integers(-100, 100, (16,)).astype(np.int32)
    r = quant.decompose_multiplier(0.002)
    gb = pqir.GraphBuilder("bench_conv")
    xi = gb.add_input("x", "int8", (None, 8, 16, 16))
    y = patterns.conv_layer(gb, xi, w, b, r, "c0", pads=(1, 1, 1, 1))
    gb.add_output(y, "int8", (None, 16, 16, 16))
    model = gb.build()
    xq = rng.integers(-128, 128, (8, 8, 16, 16)).astype(np.int8)
    rt = ReferenceRuntime(model)
    cm = compile_model(model)
    exact = np.array_equal(rt.run({"x": xq})[y], cm.run({"x": xq})[y])
    us_ref = _timeit(lambda: rt.run({"x": xq}), repeat=5)
    us_fused = _timeit(lambda: cm.run({"x": xq}))
    row("fig3_conv", us_ref, f"fused_us={us_fused:.1f};speedup={us_ref / us_fused:.2f}x;bitexact={exact};{_stats_derived(cm)}")


def bench_rescale_table():
    from repro.core import quant

    rng = np.random.default_rng(2)
    worst = 0.0
    for m in np.concatenate([[0.25, 1 / 3, 1.0, 2**-20], rng.uniform(1e-5, 50.0, 5000)]):
        r = quant.decompose_multiplier(float(m))
        worst = max(worst, abs(r.realized - m) / m)
    us = _timeit(lambda: quant.decompose_multiplier(0.123456), repeat=200)
    anchors = quant.decompose_multiplier(1 / 3)
    row(
        "tbl_rescale_decompose",
        us,
        f"worst_rel_err={worst:.2e};anchor_1/3=({anchors.quant_scale},{anchors.shift});max_exact_int={quant.MAX_EXACT_FLOAT_INT}",
    )


def bench_w8a8_decode():
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.convert import convert_params_w8a8
    from repro.models import model as M

    cfg = get_config("qwen3_1_7b", reduced=True)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pq = convert_params_w8a8(params)
    B, S = 4, 64
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)).astype(np.int32))
    pos = jnp.full((B,), S // 2, jnp.int32)

    d16 = jax.jit(lambda p, t, ps, c: M.decode_step(p, t, ps, c, cfg, compute_dtype=jnp.float32))
    d8 = jax.jit(lambda p, t, ps, c: M.decode_step(p, t, ps, c, cfg8, compute_dtype=jnp.float32))
    c16 = M.init_cache(cfg, B, S)
    c8 = M.init_cache(cfg8, B, S)
    l16, _ = d16(params, toks, pos, c16)
    l8, _ = d8(pq, toks, pos, c8)
    agree = float((jnp.argmax(l16, -1) == jnp.argmax(l8, -1)).mean())
    us16 = _timeit(lambda: jax.block_until_ready(d16(params, toks, pos, c16)), repeat=10)
    us8 = _timeit(lambda: jax.block_until_ready(d8(pq, toks, pos, c8)), repeat=10)
    # derived: HBM bytes that matter on TPU — weight + cache footprint ratio
    import jax.tree_util as jtu

    bytes_of = lambda t: sum(x.size * x.dtype.itemsize for x in jtu.tree_leaves(t))
    ratio = bytes_of(params) / bytes_of(pq)
    row("sys_w8a8_decode", us16, f"w8a8_us={us8:.1f};argmax_agree={agree:.2f};weight_bytes_ratio={ratio:.2f}x")


def bench_pass_pipeline():
    """repro.passes pipeline on a 3-layer MLP artifact: optimized vs raw
    compile, with the pipeline's folded/eliminated stats in the derived
    column (the two-Mul rescales fold, dead initializers get pruned)."""
    from repro.core.compile import compile_model

    model, xq = _mlp_artifact()
    cm_raw = compile_model(model, optimize=False)
    cm_opt = compile_model(model)
    exact = all(
        np.array_equal(a, b)
        for a, b in zip(cm_raw.run({"input_q": xq}).values(), cm_opt.run({"input_q": xq}).values())
    )
    us_raw = _timeit(lambda: cm_raw.run({"input_q": xq}))
    us_opt = _timeit(lambda: cm_opt.run({"input_q": xq}))
    row(
        "sys_pass_pipeline",
        us_raw,
        f"optimized_us={us_opt:.1f};speedup={us_raw / us_opt:.2f}x;bitexact={exact};{_stats_derived(cm_opt)}",
    )


def _mlp_artifact(layers: int = 3, width: int = 256):
    from repro.core import quant
    from repro.core.toolchain import MLPSpec, quantize_mlp

    rng = np.random.default_rng(4)
    spec = MLPSpec(
        weights=[rng.normal(size=(width, width)).astype(np.float32) * 0.05 for _ in range(layers)],
        biases=[rng.normal(size=(width,)).astype(np.float32) * 0.1 for _ in range(layers)],
        activations=["Relu"] * (layers - 1) + [None],
    )
    calib = rng.normal(size=(width, width)).astype(np.float32)
    model = quantize_mlp(spec, calib)
    xq = quant.quantize(
        rng.normal(size=(64, width)).astype(np.float32), eval(model.metadata["input_scale"]), "int8"
    )
    return model, xq


def bench_plan_overhead():
    """Typed slot-indexed ExecutionPlan vs the old name-keyed dict-env
    interpretation — same registry kernels, only the storage discipline
    differs, so this row isolates the plan layer's overhead (it should be
    ~1.0x: under jit both lower to the same XLA program)."""
    import jax
    import jax.numpy as jnp

    from repro.core.compile import compile_model

    model, xq = _mlp_artifact()
    cm = compile_model(model)
    plan = cm.plan
    feeds = {"input_q": jnp.asarray(xq)}
    run_slots = jax.jit(plan.execute)
    run_dict = jax.jit(plan.execute_dict_env)
    a, b = run_slots(feeds), run_dict(feeds)
    exact = all(np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)
    us_dict = _timeit(lambda: jax.block_until_ready(run_dict(feeds)))
    us_plan = _timeit(lambda: jax.block_until_ready(run_slots(feeds)))
    n_tensors = len({t for s in plan.steps for t in s.outputs}) + len(plan.inputs)
    row(
        "sys_plan_overhead",
        us_dict,
        f"plan_us={us_plan:.1f};ratio={us_plan / us_dict:.2f}x;bitexact={exact};"
        f"slots={plan.num_slots};tensors={n_tensors};steps={len(plan.steps)}",
    )


def bench_per_channel_overhead():
    """Per-channel fused requant vs scalar: the epilogue multiplies by a
    pre-padded (1, np) vector either way (scalars are broadcast at plan
    time), so per-channel quantization should ride the fused kernels at
    (near-)parity — this row pins that."""
    from repro.core import patterns, pqir, quant
    from repro.core.compile import compile_model

    rng = np.random.default_rng(6)
    w = rng.normal(size=(256, 256)).astype(np.float32) * 0.05
    b = rng.normal(size=(256,)).astype(np.float32) * 0.1
    xq = rng.integers(-128, 128, (64, 256)).astype(np.int8)

    def build(per_channel):
        p = quant.quantize_linear_layer(w, b, 0.05, 0.1, per_channel=per_channel)
        gb = pqir.GraphBuilder("bench_pc")
        xi = gb.add_input("x", "int8", (None, 256))
        y = patterns.fc_layer(gb, xi, p, "fc0", two_mul=True, activation="Relu")
        gb.add_output(y, "int8", (None, 256))
        return compile_model(gb.build())

    cm_scalar, cm_pc = build(False), build(True)
    assert cm_scalar.stats["fused_qlinear"] == 1 and cm_pc.stats["fused_qlinear"] == 1
    us_scalar = _timeit(lambda: cm_scalar.run({"x": xq}))
    us_pc = _timeit(lambda: cm_pc.run({"x": xq}))
    row(
        "sys_per_channel_overhead",
        us_scalar,
        f"per_channel_us={us_pc:.1f};ratio={us_pc / us_scalar:.2f}x;"
        f"fused_scalar={cm_scalar.stats['fused_qlinear']};fused_pc={cm_pc.stats['fused_qlinear']}",
    )


def bench_serving_compiled():
    """One batch-polymorphic compiled artifact served through the
    micro-batching layer at three batch buckets.  After a warmup wave per
    bucket, every timed wave must be served from the plan cache (no
    re-specialization) — the derived column carries requests/s per bucket
    and the cache hit rate, and asserts ≥2 buckets came from cache."""
    from repro.core.compile import compile_model
    from repro.serving import CompiledModelServer, CompiledServerConfig

    from repro.obs.metrics import default_registry

    model, _ = _mlp_artifact(layers=2, width=128)
    cm = compile_model(model, backend="interpret", batch="dynamic")
    # publish serve.* / cache.plan.* into the process registry so a
    # --metrics run snapshots real serving traffic
    srv = CompiledModelServer(
        cm, CompiledServerConfig(max_batch=32), registry=default_registry()
    )
    rng = np.random.default_rng(9)
    xs = rng.integers(-128, 128, (32, 128)).astype(np.int8)

    def serve_wave(n):
        for i in range(n):
            srv.submit(xs[i])
        srv.run_until_drained()

    buckets = (1, 8, 32)
    rps = {}
    buckets_from_cache = 0
    for n in buckets:
        serve_wave(n)  # warmup: specialize + jit this bucket once
        misses_before = cm.cache_stats["misses"]
        repeat = 10
        t0 = time.perf_counter()
        for _ in range(repeat):
            serve_wave(n)
        dt = time.perf_counter() - t0
        rps[n] = n * repeat / dt
        # cache-served: the timed waves for THIS bucket triggered no new
        # specialization (a re-specialization after eviction would show here)
        if cm.cache_stats["misses"] == misses_before:
            buckets_from_cache += 1
    s = srv.summary()
    cache = s["plan_cache"]
    assert buckets_from_cache >= 2, (cache, srv.metrics)
    assert cache["misses"] == len(buckets), cache  # one specialization per bucket
    us = 1e6 / rps[8]  # per-request cost at the mid bucket
    row(
        "sys_serving_compiled",
        us,
        f"rps_b1={rps[1]:.0f};rps_b8={rps[8]:.0f};rps_b32={rps[32]:.0f};"
        f"cache_hit_rate={s['plan_cache_hit_rate']:.2f};"
        f"specializations={cache['misses']};cache_size={cache['size']};"
        f"buckets_from_cache={buckets_from_cache};"
        f"lat_avg_ms={s['latency_avg_ms']:.2f}",
    )


def bench_seq_buckets():
    """One two-axis compiled artifact (named batch N + sequence S) across a
    (batch ∈ {1,8}) × (seq ∈ {32,128}) scenario grid.  Each cell is warmed
    once (specialize + jit), then timed; at most one plan specialization per
    visited grid cell is asserted — the multi-axis generalization of the
    one-specialization-per-bucket serving contract."""
    from repro.core import patterns, pqir, quant
    from repro.core.compile import compile_model

    rng = np.random.default_rng(10)
    p = quant.quantize_linear_layer(
        rng.normal(size=(64, 64)).astype(np.float32) * 0.05,
        rng.normal(size=(64,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    gb = pqir.GraphBuilder("bench_seq")
    x = gb.add_input("x", "int8", ("N", "S", 64))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", ("N", "S", 64))
    cm = compile_model(gb.build(), backend="interpret", dynamic_axes={"N": None, "S": 32})

    grid = [(b, s) for b in (1, 8) for s in (32, 128)]
    feeds = {
        (b, s): {"x": rng.integers(-128, 128, (b, s, 64)).astype(np.int8)}
        for b, s in grid
    }
    rps = {}
    for b, s in grid:
        cm.run(feeds[(b, s)])  # warmup: specialize + jit this cell once
        misses_before = cm.cache_stats["misses"]
        repeat = 10
        t0 = time.perf_counter()
        for _ in range(repeat):
            cm.run(feeds[(b, s)])
        dt = time.perf_counter() - t0
        rps[(b, s)] = b * repeat / dt
        assert cm.cache_stats["misses"] == misses_before, (
            f"grid cell ({b},{s}) re-specialized during the timed waves"
        )
    cache = cm.cache_stats
    assert cache["misses"] == len(grid), cache  # ≤1 specialization per cell
    us = 1e6 / rps[(8, 32)]
    cells = ";".join(f"rps_b{b}_s{s}={rps[(b, s)]:.0f}" for b, s in grid)
    row(
        "sys_seq_buckets",
        us,
        f"{cells};specializations={cache['misses']};grid_cells={len(grid)};"
        f"cache_hit_rate={cache['hit_rate']:.2f}",
    )


def bench_autotune():
    """Measured per-cell tile autotuning closing the co-design loop: one
    batch-polymorphic 2-layer MLP on the interpret backend, two batch cells.
    Each cell is specialized twice — heuristic tiles vs the budgeted measured
    search — and both jitted executors are timed with the shared median-of-k
    helper.  Tuned must never lose to heuristic beyond CI noise on any
    measured cell (the heuristic is always candidate #0 of the search, so a
    regression means the measurement itself is broken), and a second tuner
    session warm-started from the persisted tile cache must resolve every
    cell with zero new measurements."""
    import os
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.backend.autotune import Autotuner, measure_median
    from repro.backend.lowering import specialize_plan
    from repro.core.compile import compile_model

    model, xq = _mlp_artifact(layers=2, width=256)
    cache_path = os.path.join(tempfile.mkdtemp(prefix="repro-autotune-"), "tiles.json")
    tuner = Autotuner(budget=4, repeat=3, warmup=1, cache=cache_path)
    cm = compile_model(model, backend="interpret", batch="dynamic", autotune=tuner)

    cells = (8, 64)
    us_h, us_t, ratios = {}, {}, {}
    for cell in cells:
        feeds = {"input_q": jnp.asarray(xq[:cell])}
        plan_h = specialize_plan(cm.plan, cell)  # static heuristic tiles
        plan_t, run_t = cm.specialized(cell)  # the measured search runs here
        run_h = jax.jit(plan_h.execute)
        a, b = run_h(feeds), run_t(feeds)
        assert all(np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)
        us_h[cell] = measure_median(
            lambda run=run_h, f=feeds: jax.block_until_ready(run(f))
        ) * 1e6
        us_t[cell] = measure_median(
            lambda run=run_t, f=feeds: jax.block_until_ready(run(f))
        ) * 1e6
        ratios[cell] = us_t[cell] / us_h[cell]
        assert ratios[cell] <= 1.35, (
            f"tuned tiles lost to the heuristic at cell N={cell}: "
            f"{us_t[cell]:.1f}us vs {us_h[cell]:.1f}us"
        )
    measured = tuner.measurements

    # warm-start round trip: a brand-new session on the same artifact file
    # specializes every known cell without timing a single candidate
    warm = Autotuner(budget=4, cache=cache_path)
    cm2 = compile_model(model, backend="interpret", batch="dynamic", autotune=warm)
    for cell in cells:
        cm2.specialized(cell)
    assert warm.measurements == 0, (
        f"warm-started session re-measured {warm.measurements} candidate(s)"
    )
    cells_s = ";".join(f"tuned_vs_heur_b{c}={ratios[c]:.2f}x" for c in cells)
    row(
        "sys_autotune",
        us_t[cells[0]],
        f"{cells_s};measurements={measured};warm_measurements={warm.measurements};"
        f"cache_entries={len(warm.cache)}",
    )


def bench_fleet():
    """Fleet-scale serving from one AOT plan artifact: a 3-replica
    ShardedRouter (each replica warm-started by ``load_artifact`` — plan
    cache pre-seeded with the recorded hot cells, jit traces primed) vs a
    single warm-started server on the same mixed-cell traffic.  Cell
    affinity must keep every replica's plan-cache hit rate at least the
    single-server baseline (sharding must not divide cache locality by N),
    and the warm start must serve its first wave faster than a cold
    compile-specialize-jit does.  Zero lost, zero duplicated requests."""
    import os
    import tempfile

    from repro.backend.artifact import load_artifact, save_artifact
    from repro.core import patterns, pqir, quant
    from repro.core.compile import compile_model
    from repro.serving import CompiledModelServer, CompiledServerConfig, ShardedRouter

    rng = np.random.default_rng(11)
    p = quant.quantize_linear_layer(
        rng.normal(size=(64, 64)).astype(np.float32) * 0.05,
        rng.normal(size=(64,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )

    def build_model():
        gb = pqir.GraphBuilder("bench_fleet")
        x = gb.add_input("x", "int8", ("N", "S", 64))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
        gb.add_output(y, "int8", ("N", "S", 64))
        return gb.build()

    # the traffic mix: three seq-bucket cells (S ∈ {8, 16, 24}), waves of 4
    seq_lens, wave = (4, 12, 20), 4
    cfg = CompiledServerConfig(max_batch=wave)

    def serve_waves(submit, drain, n_waves):
        for _ in range(n_waves):
            for s in seq_lens:
                for _ in range(wave):
                    submit(rng.integers(-128, 128, (s, 64)).astype(np.int8))
            drain()

    # record the hot cells once and save the artifact the whole fleet shares
    cm_rec = compile_model(build_model(), backend="interpret", dynamic_axes={"N": None, "S": 8})
    srv_rec = CompiledModelServer(cm_rec, cfg)
    serve_waves(srv_rec.submit, srv_rec.run_until_drained, 1)
    path = os.path.join(tempfile.mkdtemp(prefix="repro-fleet-"), "fleet.json")
    save_artifact(cm_rec, path)

    # warm-start value: first wave on a pre-seeded + jit-primed replica vs a
    # cold compile (specialize + jit on first touch, per cell)
    cm_cold = compile_model(build_model(), backend="interpret", dynamic_axes={"N": None, "S": 8})
    srv_cold = CompiledModelServer(cm_cold, cfg)
    t0 = time.perf_counter()
    serve_waves(srv_cold.submit, srv_cold.run_until_drained, 1)
    cold_ms = (time.perf_counter() - t0) * 1e3

    srv_single = CompiledModelServer(load_artifact(path, warm=True), cfg)
    t0 = time.perf_counter()
    serve_waves(srv_single.submit, srv_single.run_until_drained, 1)
    warm_ms = (time.perf_counter() - t0) * 1e3

    # single warm-started server baseline throughput + hit rate
    n_waves = 10
    t0 = time.perf_counter()
    serve_waves(srv_single.submit, srv_single.run_until_drained, n_waves)
    single_rps = len(seq_lens) * wave * n_waves / (time.perf_counter() - t0)
    single_summary = srv_single.summary()
    single_rate = single_summary["plan_cache_hit_rate"]

    # the fleet: 3 replicas, one front door, cell-affinity sharding
    router = ShardedRouter.from_artifact(path, replicas=3, server_cfg=cfg)
    serve_waves(router.submit, router.run_until_drained, 1)  # route the cells
    t0 = time.perf_counter()
    serve_waves(router.submit, router.run_until_drained, n_waves)
    fleet_rps = len(seq_lens) * wave * n_waves / (time.perf_counter() - t0)
    s = router.summary()
    assert s["lost"] == 0 and s["duplicates"] == 0, s
    assert len(set(s["cell_owners"].values())) == 3, s["cell_owners"]
    rates = s["plan_cache_hit_rates"]
    for name, rate in rates.items():
        assert rate >= single_rate - 1e-9, (
            f"replica {name} hit rate {rate:.3f} fell below the single-server "
            f"baseline {single_rate:.3f}: sharding broke cache locality"
        )
    us = 1e6 / single_rps
    row(
        "sys_fleet",
        us,
        f"fleet_rps={fleet_rps:.0f};single_rps={single_rps:.0f};replicas=3;"
        f"cells={len(seq_lens)};hit_rate_single={single_rate:.2f};"
        f"hit_rate_replicas_min={min(rates.values()):.2f};"
        f"warm_first_wave_ms={warm_ms:.0f};cold_first_wave_ms={cold_ms:.0f};"
        f"warm_speedup={cold_ms / warm_ms:.1f}x;"
        f"lost={s['lost']};dup={s['duplicates']}",
    )


def bench_int4_decode():
    """Sub-8-bit weight lane on the decode path: one 1-layer MLP quantized
    twice from identical float weights (``weight_bits`` 8 vs 4), compiled on
    the tiled interpret backend, timed at decode-shaped cells M ∈ {1, 8}
    (one token per sequence → tokens/s = M / step time).  The packed-int4
    output must be bit-exact against the *unpacked* int4 reference runtime
    (the lane's oracle — see docs/quantization.md) at every cell, and the
    shared cost model must price the w4 weight stream at ≤ 0.55× the w8
    bytes (it is exactly 0.5×: two nibbles per byte)."""
    from repro.backend import cost
    from repro.core.compile import compile_model
    from repro.core.runtime import ReferenceRuntime
    from repro.core.toolchain import MLPSpec, quantize_mlp
    from repro.kernels.qmatmul import choose_tiles

    d = 1024

    def build(bits):
        rng = np.random.default_rng(7)  # identical float weights both ways
        spec = MLPSpec(
            weights=[rng.normal(size=(d, d)).astype(np.float32) * 0.05],
            biases=[rng.normal(size=(d,)).astype(np.float32) * 0.1],
            activations=[None],
        )
        calib = rng.normal(size=(64, d)).astype(np.float32)
        return quantize_mlp(spec, calib, weight_bits=bits, name=f"decode_w{bits}")

    m8, m4 = build(8), build(4)
    cm8 = compile_model(m8, backend="interpret", batch="dynamic")
    cm4 = compile_model(m4, backend="interpret", batch="dynamic")
    rt4 = ReferenceRuntime(m4)

    rng = np.random.default_rng(8)
    cells = (1, 8)
    parts, best_speedup = [], 0.0
    for M in cells:
        feeds = {"input_q": rng.integers(-128, 128, (M, d)).astype(np.int8)}
        out4, ref4 = cm4.run(feeds), rt4.run(feeds)
        exact = all(np.array_equal(out4[k], ref4[k]) for k in out4)
        assert exact, f"packed int4 diverged from the unpacked reference at M={M}"
        us8 = _timeit(lambda: cm8.run(feeds))
        us4 = _timeit(lambda: cm4.run(feeds))
        best_speedup = max(best_speedup, us8 / us4)
        parts.append(
            f"tok_s_b{M}_w8={M / (us8 * 1e-6):.0f};tok_s_b{M}_w4={M / (us4 * 1e-6):.0f};"
            f"speedup_b{M}={us8 / us4:.2f}x"
        )
    # analytic HBM accounting from the single source of truth (backend.cost),
    # at the tiles the decode cell actually specializes to
    bm, bk, bn = choose_tiles(cells[0], d, d)
    hbm8 = cost.qmatmul_hbm_bytes(cells[0], d, d, bm, bk, bn, weight_bits=8)
    hbm4 = cost.qmatmul_hbm_bytes(cells[0], d, d, bm, bk, bn, weight_bits=4)
    w4_w = hbm8 - hbm4  # the packed stream: exactly the halved weight term
    weight_ratio = w4_w / (2.0 * w4_w)
    assert weight_ratio <= 0.55, f"w4 weight bytes {weight_ratio:.2f}x of w8"
    us_ref4 = _timeit(lambda: rt4.run({"input_q": rng.integers(-128, 128, (1, d)).astype(np.int8)}), repeat=5)
    row(
        "sys_int4_decode",
        us_ref4,
        ";".join(parts)
        + f";weight_bytes_ratio={weight_ratio:.2f}x;hbm_est_ratio={hbm4 / hbm8:.2f}x;"
        f"best_speedup={best_speedup:.2f}x;bitexact=True;width={d}",
    )


def bench_attn_decode():
    """The compiled token path on the decode hot loop: the transformer block
    (QKV/O + int8-KV update + fused attention + MLP) executing through the
    specialized ``("N",1,…)`` ExecutionPlan — int8 KV cache in state slots,
    mixed w4/w8 projections — vs the opaque jitted-JAX engine path at the
    same geometry, at decode cells M ∈ {1, 8}.  Before timing: the compiled
    step must be bit-exact against the jnp mirror (``decode_jax``) at every
    cell, and the shared PlanCache must hold exactly one specialization per
    visited cell (zero per-step re-lowering).  See docs/token_path.md."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.models import model as M
    from repro.serving.engine import OpaqueModelAdapter
    from repro.serving.token_path import (
        CompiledTokenAdapter,
        CompiledTokenPath,
        TokenPathConfig,
        decode_jax,
        make_token_params,
    )

    cfg = TokenPathConfig()
    params = make_token_params(cfg, seed=3)
    tp = CompiledTokenPath(cfg, params, backend="ref", s_granularity=8)
    ad = CompiledTokenAdapter(tp)

    # opaque baseline: a decoder of the same geometry on the bf16 JAX path
    ocfg = ModelConfig(
        name="tiny-opaque", family="decoder", n_layers=cfg.n_layers,
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
        d_ff=cfg.d_ff, vocab_size=cfg.vocab, mlp_type="gelu",
    )
    oparams = M.init_params(jax.random.PRNGKey(0), ocfg)
    oad = OpaqueModelAdapter(oparams, ocfg, compute_dtype=jnp.bfloat16)

    rng = np.random.default_rng(11)
    s_max, pos0 = 32, 10
    cells = (1, 8)
    parts = []
    for m in cells:
        toks = rng.integers(1, cfg.vocab, (m, 1)).astype(np.int32)
        pos = np.full((m,), pos0, np.int64)
        cache = ad.init_cache(m, s_max)
        for k in cache:  # a warm, non-trivial KV state
            cache[k] = rng.integers(-128, 128, cache[k].shape).astype(np.int8)

        # bit-exactness gate: compiled decode == jnp mirror, logits + states
        onehot = np.zeros((m, s_max, 1), np.int8)
        onehot[:, pos0, 0] = 1
        mask = np.broadcast_to(
            np.arange(s_max)[None, None, :] <= pos0, (m, 1, s_max)
        ).astype(np.float32)
        logits_c, nxt = tp.decode(toks, onehot, mask, cache)
        states = [
            (cache[tp.state_specs[2 * l].input], cache[tp.state_specs[2 * l + 1].input])
            for l in range(cfg.n_layers)
        ]
        logits_j, jstates = decode_jax(cfg, params, toks, onehot, mask, states)
        assert np.array_equal(logits_c, np.asarray(logits_j)), (
            f"compiled decode diverged from the jnp mirror at M={m}"
        )
        for l, (kj, vj) in enumerate(jstates):
            assert np.array_equal(nxt[tp.state_specs[2 * l].input], np.asarray(kj))
            assert np.array_equal(nxt[tp.state_specs[2 * l + 1].input], np.asarray(vj))

        us_c = _timeit(lambda: ad.decode(toks, pos, cache))
        ocache = oad.init_cache(m, s_max)
        us_o = _timeit(lambda: jax.block_until_ready(oad.decode(toks, pos, ocache)[0]))
        parts.append(
            f"tok_s_b{m}_compiled={m / (us_c * 1e-6):.0f};"
            f"tok_s_b{m}_opaque={m / (us_o * 1e-6):.0f};"
            f"speedup_b{m}={us_o / us_c:.2f}x"
        )

    # exactly one specialization per visited decode cell, all hits after
    stats = tp.cache_stats()
    assert stats["misses"] == len(cells), stats
    us_c1 = _timeit(
        lambda: ad.decode(
            np.ones((1, 1), np.int32), np.full((1,), pos0, np.int64), ad.init_cache(1, s_max)
        )
    )
    row(
        "sys_attn_decode",
        us_c1,
        ";".join(parts)
        + f";plan_misses={stats['misses']};cells={len(cells)};bitexact=True;"
        f"d={cfg.d_model};layers={cfg.n_layers};heads={cfg.n_heads}",
    )


def bench_grad_compress():
    import jax
    import jax.numpy as jnp

    from repro.optim.grad_compress import _compress_leaf

    # single-device emulation of the wire format economics
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(1024, 1024)).astype(np.float32))
    res = jnp.zeros_like(g)

    def one(g, res):
        g_eff = g + res
        s = jnp.abs(g_eff).max() / 127.0 + 1e-20
        q = jnp.clip(jnp.rint(g_eff / s), -128, 127)
        return (s * q), g_eff - s * q

    fn = jax.jit(one)
    fn(g, res)
    us = _timeit(lambda: jax.block_until_ready(fn(g, res)), repeat=10)
    deq, _ = fn(g, res)
    err = float(jnp.abs(deq - g).max() / jnp.abs(g).max())
    row("sys_grad_compress", us, f"wire_bytes_ratio=4.00x_vs_f32;one_round_rel_err={err:.4f}")


def main(argv=None) -> None:
    from repro.core import patterns

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI subset")
    ap.add_argument(
        "--json", metavar="PATH",
        help="also write the rows as JSON (e.g. BENCH_42.json) so the perf "
        "trajectory persists across PRs; CI uploads it as an artifact",
    )
    ap.add_argument(
        "--trace", metavar="PATH",
        help="install a repro.obs tracer for the whole run and dump the "
        "Chrome-trace JSON (load it at chrome://tracing or ui.perfetto.dev): "
        "compile/pass spans, per-cell specializations, serving steps",
    )
    ap.add_argument(
        "--metrics", metavar="PATH",
        help="dump the process MetricsRegistry snapshot (serve.*, engine.*, "
        "cache.*) as JSON after the run",
    )
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        from repro.obs import trace as _trace

        tracer = _trace.install()

    print("name,us_per_call,derived")
    bench_pattern("fig1_fc_two_mul", activation=None, two_mul=True)
    if not args.smoke:
        bench_pattern("fig2_fc_relu_one_mul", activation="Relu", two_mul=False)
        bench_fig3_conv()
        bench_pattern("fig4_int8_tanh", act_builder=patterns.fc_int8_tanh, derived_fn=_tanh_err)
        bench_pattern("fig5_fp16_tanh", act_builder=patterns.fc_fp16_tanh, derived_fn=_tanh_err)
        bench_pattern("fig6_fp16_sigmoid", act_builder=patterns.fc_fp16_sigmoid, derived_fn=_sigmoid_err)
        bench_rescale_table()
    bench_pass_pipeline()
    bench_plan_overhead()
    bench_per_channel_overhead()
    bench_serving_compiled()
    bench_seq_buckets()
    bench_autotune()
    bench_fleet()
    bench_int4_decode()
    bench_attn_decode()
    if not args.smoke:
        bench_w8a8_decode()
        bench_grad_compress()

    if tracer is not None:
        from repro.obs import trace as _trace

        _trace.uninstall()
        tracer.dump(args.trace)
        print(f"# wrote {len(tracer.records)} trace events to {args.trace} (trace_id={tracer.trace_id})")
    if args.metrics:
        from repro.obs.metrics import default_registry

        with open(args.metrics, "w") as f:
            json.dump(default_registry().snapshot(), f, indent=2)
            f.write("\n")
        print(f"# wrote metrics snapshot to {args.metrics}")

    if args.json:
        payload = {
            "schema": "repro-bench-v1",
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "smoke": bool(args.smoke),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "rows": _ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(_ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
