"""It.7 measurement: qwen3_1_7b × decode_32k roofline under the three serving
postures — bf16 baseline (paper-faithful float serving), W8A8 weights, and
W8A8 + int8 KV cache.  Writes hillclimb_decode.json and prints the table.

Run:  PYTHONPATH=src python -m benchmarks.hillclimb_decode
"""
from __future__ import annotations

import json


def main():
    import dataclasses as dc

    import jax

    from benchmarks import roofline as RL
    from repro.configs import get_config
    from repro.configs.base import SHAPE_BY_NAME

    results = {}
    for name, w8a8, kv in (
        ("bf16 + bf16 KV (baseline)", False, "bf16"),
        ("W8A8 + bf16 KV", True, "bf16"),
        ("W8A8 + int8 KV", True, "int8"),
    ):
        # patch the registry config's cache dtype for this run
        import repro.configs.qwen3_1_7b as qmod

        orig = qmod.CONFIG
        qmod.CONFIG = dc.replace(orig, kv_cache_dtype=kv)
        try:
            r = RL.roofline_cell("qwen3_1_7b", "decode_32k", w8a8=w8a8)
        finally:
            qmod.CONFIG = orig
        results[name] = r
        t = r["terms"]
        print(
            f"{name:28s} comp={t['t_comp_s']*1e3:8.3f}ms mem={t['t_mem_s']*1e3:8.3f}ms "
            f"coll={t['t_coll_s']*1e3:8.3f}ms bound={r['bottleneck'][2:-2]} roofline={r['roofline_fraction']:.4f}",
            flush=True,
        )
    base = results["bf16 + bf16 KV (baseline)"]["terms"]["t_mem_s"]
    best = results["W8A8 + int8 KV"]["terms"]["t_mem_s"]
    print(f"\ndominant (memory) term: {base*1e3:.3f}ms -> {best*1e3:.3f}ms  ({base/best:.2f}x)")
    with open("hillclimb_decode.json", "w") as f:
        json.dump({k: {kk: vv for kk, vv in v.items() if kk != "probes"} for k, v in results.items()}, f, indent=1, default=float)


if __name__ == "__main__":
    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    main()
