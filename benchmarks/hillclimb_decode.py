"""It.7 measurement: qwen3_1_7b × decode_32k roofline under the three serving
postures — bf16 baseline (paper-faithful float serving), W8A8 weights, and
W8A8 + int8 KV cache.  Writes hillclimb_decode.json and prints the table.

Run:  PYTHONPATH=src python -m benchmarks.hillclimb_decode

``--measure-tiles`` swaps the analytic study for a *measured* one: a
decode-shaped fused qmatmul cell is tuned through the backend's budgeted
tile search and the per-candidate evidence table is printed.  All timing
goes through the shared seeded warmup + median-of-k helper
(:func:`repro.backend.autotune.measure_median`), so tuned-vs-heuristic
deltas are reproducible run to run — ``--seed/--repeat/--warmup`` pin the
measurement discipline explicitly, and ``--bits 4`` probes the packed
sub-8-bit weight lane.  Every analytic number (tile prices, HBM bytes,
roofline terms) comes from :mod:`repro.backend.cost` — the single source
of truth the autotuner and ``benchmarks/roofline.py`` also read — so the
int4 byte accounting can never fork.
"""
from __future__ import annotations

import argparse
import json


def measure_tiles(args) -> int:
    """Measured tuned-vs-heuristic tile comparison on a decode-shaped cell.

    Decode serving flattens to a small-M fused qmatmul (one token per
    sequence), which is exactly where the static tile heuristic over-blocks;
    this drives the real measured search on the interpret backend and prints
    the full candidate evidence from the tuner's co-design artifact."""
    import os
    import tempfile

    import numpy as np

    from repro.backend import cost
    from repro.backend.autotune import Autotuner
    from repro.core.compile import compile_model
    from repro.core.toolchain import MLPSpec, quantize_mlp

    rng = np.random.default_rng(args.seed)
    d = args.width
    spec = MLPSpec(
        weights=[rng.normal(0, 0.4, (d, d)).astype(np.float32)],
        biases=[rng.normal(0, 0.2, (d,)).astype(np.float32)],
        activations=[None],
    )
    calib = rng.normal(0, 1.0, (64, d)).astype(np.float32)
    model = quantize_mlp(spec, calib, weight_bits=args.bits, name="decode_tile_probe")

    cache = os.path.join(tempfile.mkdtemp(prefix="hillclimb-tiles-"), "tiles.json")
    tuner = Autotuner(
        budget=args.budget, repeat=args.repeat, warmup=args.warmup,
        seed=args.seed, cache=cache,
    )
    cm = compile_model(model, backend="interpret", batch="dynamic", autotune=tuner)
    plan, _ = cm.specialized(args.cell)

    print(
        f"decode-shaped tile search: d={d} cell N={args.cell} budget={args.budget} "
        f"repeat={args.repeat} warmup={args.warmup} seed={args.seed} bits={args.bits}"
    )
    for key, entry in sorted(tuner.cache.store.entries.items()):
        print(f"  {key}")
        heur_us = entry["heuristic_us"]
        for tiles, us in sorted(entry["candidates_us"].items(), key=lambda kv: kv[1]):
            bm, bk, bn = tiles.split(",")
            mark = " <- tuned" if us == entry["best_us"] else ""
            # analytic price from the shared cost model (the same numbers
            # that seeded the search) — including the bits-aware HBM bytes,
            # so the measured-vs-model gap is readable per candidate
            est_us = cost.qmatmul_tile_cost(
                args.cell, d, d, int(bm), int(bk), int(bn), weight_bits=args.bits
            ) * 1e6
            hbm_kib = cost.qmatmul_hbm_bytes(
                args.cell, d, d, int(bm), int(bk), int(bn), weight_bits=args.bits
            ) / 1024.0
            print(
                f"    bm={bm:>4s} bk={bk:>4s} bn={bn:>4s}  {us:9.1f}us "
                f"({us / heur_us:.2f}x vs heuristic)  "
                f"model={est_us:.3f}us hbm={hbm_kib:.0f}KiB{mark}"
            )
        print(
            f"    tuned {entry['best_us']:.1f}us vs heuristic {heur_us:.1f}us "
            f"({heur_us / entry['best_us']:.2f}x) over {entry['measured']} measured"
        )
    ev = plan.provenance.specializations[-1]
    for name, rec in ev.tiles:
        print(f"  provenance {name}: {rec}")
    return 0


def analytic(args) -> int:
    import dataclasses as dc

    import jax

    from benchmarks import roofline as RL
    from repro.configs import get_config
    from repro.configs.base import SHAPE_BY_NAME

    results = {}
    for name, w8a8, kv in (
        ("bf16 + bf16 KV (baseline)", False, "bf16"),
        ("W8A8 + bf16 KV", True, "bf16"),
        ("W8A8 + int8 KV", True, "int8"),
    ):
        # patch the registry config's cache dtype for this run
        import repro.configs.qwen3_1_7b as qmod

        orig = qmod.CONFIG
        qmod.CONFIG = dc.replace(orig, kv_cache_dtype=kv)
        try:
            r = RL.roofline_cell("qwen3_1_7b", "decode_32k", w8a8=w8a8)
        finally:
            qmod.CONFIG = orig
        results[name] = r
        t = r["terms"]
        print(
            f"{name:28s} comp={t['t_comp_s']*1e3:8.3f}ms mem={t['t_mem_s']*1e3:8.3f}ms "
            f"coll={t['t_coll_s']*1e3:8.3f}ms bound={r['bottleneck'][2:-2]} roofline={r['roofline_fraction']:.4f}",
            flush=True,
        )
    base = results["bf16 + bf16 KV (baseline)"]["terms"]["t_mem_s"]
    best = results["W8A8 + int8 KV"]["terms"]["t_mem_s"]
    print(f"\ndominant (memory) term: {base*1e3:.3f}ms -> {best*1e3:.3f}ms  ({base/best:.2f}x)")
    with open("hillclimb_decode.json", "w") as f:
        json.dump({k: {kk: vv for kk, vv in v.items() if kk != "probes"} for k, v in results.items()}, f, indent=1, default=float)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--measure-tiles", action="store_true",
        help="run the measured decode-shaped tile search instead of the "
        "analytic roofline study",
    )
    ap.add_argument("--width", type=int, default=512, help="probe layer width")
    ap.add_argument("--cell", type=int, default=8, help="decode batch bucket (flat M)")
    ap.add_argument("--budget", type=int, default=6, help="candidates measured per step")
    ap.add_argument("--repeat", type=int, default=5, help="median-of-k repeat count")
    ap.add_argument("--warmup", type=int, default=2, help="discarded warmup calls")
    ap.add_argument("--seed", type=int, default=0, help="rng seed for probe data")
    ap.add_argument(
        "--bits", type=int, default=8, choices=(4, 8),
        help="weight bitwidth of the measured probe (4 = packed sub-8-bit "
        "lane; the cost-model columns use the same bits-aware accounting)",
    )
    args = ap.parse_args(argv)
    if args.measure_tiles:
        return measure_tiles(args)
    return analytic(args)


if __name__ == "__main__":
    import os
    import sys

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    sys.exit(main())
