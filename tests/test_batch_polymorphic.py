"""Differential conformance for scenario-polymorphic compilation.

One dynamic artifact must serve every shape scenario bit-exactly — against
the reference runtime AND against a per-shape *static* compile of the same
model — with at most one specialization (one PlanCache miss, no re-lowering)
per visited bucket combination.  Covers the legacy single-axis contract
(``batch="dynamic"``: MLP, CNN, uint8 per-channel across batches {1, 3, 8,
17}) and the named multi-axis contract (``dynamic_axes={"N": ..., "S":
...}``: a (batch × sequence) grid) on the ref and interpret backends, plus
the plan-cache LRU-bounding behavior, the per-axis bucketing policies and
the analysis-layer named-axis helpers.
"""
import numpy as np
import pytest

from repro.backend.plan import (
    PlanCache,
    batch_bucket,
    bindings_key,
    bucket_multiple,
    resolve_bucketing,
)
from repro.backend.lowering import specialize_plan
from repro.core.cache import LruCache
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import CNNSpec, ConvLayerSpec, MLPSpec, quantize_cnn, quantize_mlp
from repro.passes import analysis

BATCH_SIZES = (1, 3, 8, 17)
BACKENDS = ("ref", "interpret")


def _mlp_model():
    rng = np.random.default_rng(11)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(32, 48)).astype(np.float32) * 0.15,
            rng.normal(size=(48, 10)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(48,)).astype(np.float32) * 0.1,
            rng.normal(size=(10,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(128, 32)).astype(np.float32)
    model = quantize_mlp(spec, calib, name="dyn_mlp")

    def feed(m):
        return {"input_q": rng.integers(-128, 128, (m, 32)).astype(np.int8)}

    return model, feed


def _cnn_model():
    rng = np.random.default_rng(12)
    spec = CNNSpec(
        convs=[
            ConvLayerSpec(
                rng.normal(size=(4, 1, 3, 3)).astype(np.float32) * 0.3,
                rng.normal(size=(4,)).astype(np.float32) * 0.1,
                strides=(1, 1),
                pads=(1, 1, 1, 1),
                activation="Relu",
            )
        ],
        head=MLPSpec(
            weights=[rng.normal(size=(4 * 8 * 8, 10)).astype(np.float32) * 0.1],
            biases=[rng.normal(size=(10,)).astype(np.float32) * 0.1],
            activations=[None],
        ),
    )
    calib = rng.normal(size=(64, 1, 8, 8)).astype(np.float32)
    model = quantize_cnn(spec, calib, per_channel=True, name="dyn_cnn")

    def feed(m):
        return {"input_q": rng.integers(-128, 128, (m, 1, 8, 8)).astype(np.int8)}

    return model, feed


def _uint8_pc_model():
    """uint8 activations (plan-time signed fold) + per-channel rescale +
    two-Mul epilogue — the template path must carry the folded bias and the
    vector params exactly like the static path does."""
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(13)
    w = rng.normal(size=(32, 24)).astype(np.float32) * 0.2
    w[:, 5] *= 25.0
    b = rng.normal(size=(24,)).astype(np.float32) * 0.1
    p = quant.quantize_linear_layer(w, b, 0.05, 0.1, per_channel=True)
    gb = pqir.GraphBuilder("dyn_u8")
    x = gb.add_input("x", "uint8", (None, 32))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", (None, 24))
    model = gb.build()

    def feed(m):
        return {"x": rng.integers(0, 256, (m, 32)).astype(np.uint8)}

    return model, feed


MODELS = {"mlp": _mlp_model, "cnn": _cnn_model, "uint8_pc": _uint8_pc_model}


def _two_axis_model():
    """A two-layer FC stack over a ('N', 'S', 32) input: both the batch and
    the sequence length are named symbolic axes, so one artifact serves the
    whole (batch × sequence) scenario grid."""
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(15)
    p0 = quant.quantize_linear_layer(
        rng.normal(size=(32, 48)).astype(np.float32) * 0.15,
        rng.normal(size=(48,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    p1 = quant.quantize_linear_layer(
        rng.normal(size=(48, 24)).astype(np.float32) * 0.2,
        rng.normal(size=(24,)).astype(np.float32) * 0.1, 0.1, 0.12,
    )
    gb = pqir.GraphBuilder("two_axis_mlp")
    x = gb.add_input("x", "int8", ("N", "S", 32))
    h = patterns.fc_layer(gb, x, p0, "fc0", two_mul=True, activation="Relu")
    y = patterns.fc_layer(gb, h, p1, "fc1", two_mul=True)
    gb.add_output(y, "int8", ("N", "S", 24))
    model = gb.build()

    def feed(m, s):
        return {"x": rng.integers(-128, 128, (m, s, 32)).astype(np.int8)}

    return model, feed


def _static_for(model, bindings, backend: str):
    """A per-shape static compile: the same artifact with every symbolic
    axis pinned to its concrete extent in the input/output signatures."""
    pinned = analysis.clone_model(model)
    for t in list(pinned.graph.inputs) + list(pinned.graph.outputs):
        t.shape = analysis.bind(tuple(t.shape), bindings)
    return compile_model(pinned, backend=backend)


def _static_for_batch(model, m: int, backend: str):
    return _static_for(model, {analysis.BATCH_AXIS: m}, backend)


class TestDynamicConformance:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_dynamic_matches_reference_and_static(self, name, backend):
        model, feed = MODELS[name]()
        rt = ReferenceRuntime(model)
        cm = compile_model(model, backend=backend, batch="dynamic")
        assert cm.is_dynamic and cm.plan.batch == "dynamic"
        for m in BATCH_SIZES:
            feeds = feed(m)
            ref = rt.run(feeds)
            got = cm.run(feeds)
            static = _static_for_batch(model, m, backend).run(feeds)
            for k, want in ref.items():
                assert got[k].shape == want.shape, (name, backend, m)
                np.testing.assert_array_equal(got[k], want, err_msg=f"{name}/{backend}/m={m} vs ref")
                np.testing.assert_array_equal(
                    static[k], want, err_msg=f"{name}/{backend}/m={m} static vs ref"
                )

    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_one_specialization_per_bucket(self, name):
        model, feed = MODELS[name]()
        cm = compile_model(model, backend="ref", batch="dynamic")
        for m in BATCH_SIZES:  # buckets {1, 4, 8, 32}
            cm.run(feed(m))
        buckets = {batch_bucket(m) for m in BATCH_SIZES}
        assert cm.cache_stats["misses"] == len(buckets)
        assert cm.cache_stats["size"] == len(buckets)
        for m in BATCH_SIZES:  # same buckets again: pure cache hits
            cm.run(feed(m))
        assert cm.cache_stats["misses"] == len(buckets)
        assert cm.cache_stats["hits"] >= len(BATCH_SIZES)

    def test_sizes_sharing_a_bucket_specialize_once(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="ref", batch="dynamic")
        for m in (5, 6, 7, 8):  # all land in bucket 8
            cm.run(feed(m))
        assert cm.cache_stats == {
            "size": 1, "capacity": PlanCache.DEFAULT_CAPACITY,
            "hits": 3, "misses": 1, "evictions": 0, "hit_rate": 0.75,
        }

    def test_plan_cache_is_bounded(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="ref", batch="dynamic", plan_cache_capacity=2)
        for m in (1, 2, 4):
            cm.run(feed(m))
        stats = cm.cache_stats
        assert stats["size"] == 2 and stats["evictions"] == 1
        cm.run(feed(1))  # bucket 1 was LRU-evicted → re-specializes
        assert cm.cache_stats["misses"] == 4


class TestTemplatePlan:
    def test_template_is_not_directly_executable_on_tiled_backends(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="interpret", batch="dynamic")
        with pytest.raises(RuntimeError, match="specialize"):
            cm.plan.execute({"input_q": feed(4)["input_q"]})

    def test_specialize_binds_m_and_bm_without_copying_params(self):
        model, _ = MODELS["mlp"]()
        cm = compile_model(model, backend="interpret", batch="dynamic")
        spec = specialize_plan(cm.plan, 8)
        assert spec.batch == 8
        for tmpl_step, spec_step in zip(cm.plan.steps, spec.steps):
            if tmpl_step.kind != "fused_qlinear":
                continue
            shape = spec_step.params["shape"]
            assert shape["m"] == 8 and shape["bm"] == 32  # sublane-min tile, not 128
            assert "lead" not in shape and "dynamic_batch" not in spec_step.params
            # padded parameter arrays are shared with the template, not copied
            for a, b in zip(tmpl_step.consts, spec_step.consts):
                assert a is b
            # symbolic leading dims bound in the value typing
            for info in spec_step.out_info:
                assert info.shape[0] == 8

    def test_specialize_rejects_non_templates(self):
        model, _ = MODELS["mlp"]()
        cm = compile_model(model, backend="interpret")
        with pytest.raises(ValueError, match="dynamic"):
            specialize_plan(cm.plan, 8)

    def test_dynamic_compile_requires_symbolic_batch_input(self):
        model, _ = MODELS["mlp"]()
        pinned = analysis.clone_model(model)
        for t in pinned.graph.inputs:
            t.shape = (4,) + tuple(t.shape[1:])
        with pytest.raises(ValueError, match="symbolic"):
            compile_model(pinned, batch="dynamic")

    def test_misdeclared_output_batch_dim_still_sliced(self):
        """An output declared with a concrete leading dim is still recognized
        as batch-carrying via the plan's inferred value shapes — the result
        comes back sliced to the true batch, not bucket-padded."""
        from repro.core import patterns, pqir, quant

        rng = np.random.default_rng(14)
        p = quant.quantize_linear_layer(
            rng.normal(size=(16, 8)).astype(np.float32) * 0.2,
            rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1,
        )
        gb = pqir.GraphBuilder("misdeclared")
        x = gb.add_input("x", "int8", (None, 16))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True)
        gb.add_output(y, "int8", (4, 8))  # wrong: leading dim is really dynamic
        model = gb.build()
        cm = compile_model(model, backend="ref", batch="dynamic")
        assert cm.batch_output_names == {y}
        got = cm.run({"x": rng.integers(-128, 128, (3, 16)).astype(np.int8)})
        assert got[y].shape == (3, 8)

    def test_batch_independent_output_returned_whole(self):
        """A constant (batch-independent) auxiliary output is not sliced."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("aux")
        x = gb.add_input("x", "float32", (None, 4))
        c1 = gb.add_initializer("c1", np.arange(5, dtype=np.float32))
        c2 = gb.add_initializer("c2", np.ones(5, np.float32))
        y = gb.op("Relu", [x])
        z = gb.op("Add", [c1, c2])
        gb.add_output(y, "float32", (None, 4))
        gb.add_output(z, "float32", (5,))
        model = gb.build()
        # optimize=False keeps the const-only Add as a live step
        cm = compile_model(model, backend="ref", batch="dynamic", optimize=False, fuse=False)
        assert cm.batch_output_names == {y}
        got = cm.run({"x": np.ones((3, 4), np.float32)})
        assert got[y].shape == (3, 4)
        np.testing.assert_array_equal(got[z], np.arange(5, dtype=np.float32) + 1.0)

    def test_zero_batch_rejected(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="ref", batch="dynamic")
        with pytest.raises(ValueError, match="batch must be >= 1"):
            cm.run({"input_q": np.zeros((0, 32), np.int8)})


class TestTwoAxisConformance:
    """The named-axis generalization: one ``dynamic_axes={"N", "S"}``
    artifact serves a whole (batch × sequence) grid bit-exactly vs the
    reference runtime AND vs per-shape static compiles, with exactly one
    specialization per visited bucket pair."""

    GRID = tuple((m, s) for m in (1, 3, 8, 17) for s in (16, 32, 100))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_two_axis_matches_reference_and_static(self, backend):
        model, feed = _two_axis_model()
        rt = ReferenceRuntime(model)
        cm = compile_model(model, backend=backend, dynamic_axes={"N": None, "S": 32})
        assert cm.is_dynamic and cm.plan.axes == ("N", "S")
        for m, s in self.GRID:
            feeds = feed(m, s)
            ref = rt.run(feeds)
            got = cm.run(feeds)
            static = _static_for(model, {"N": m, "S": s}, backend).run(feeds)
            for k, want in ref.items():
                assert got[k].shape == want.shape == (m, s, 24), (backend, m, s)
                np.testing.assert_array_equal(
                    got[k], want, err_msg=f"{backend}/m={m}/s={s} vs ref"
                )
                np.testing.assert_array_equal(
                    static[k], want, err_msg=f"{backend}/m={m}/s={s} static vs ref"
                )

    def test_one_specialization_per_bucket_pair(self):
        model, feed = _two_axis_model()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 32})
        for m, s in self.GRID:
            cm.run(feed(m, s))
        cells = {(batch_bucket(m), bucket_multiple(s, 32)) for m, s in self.GRID}
        assert cm.cache_stats["misses"] == len(cells)
        assert cm.cache_stats["size"] == len(cells)
        for m, s in self.GRID:  # revisit the grid: pure cache hits
            cm.run(feed(m, s))
        assert cm.cache_stats["misses"] == len(cells)
        assert cm.cache_stats["hits"] >= len(self.GRID)

    def test_per_axis_bucketing_policies(self):
        """The batch axis buckets power-of-two, the sequence axis rounds to
        the configured granularity — per-axis, not one global policy."""
        model, feed = _two_axis_model()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 32})
        assert cm.bucket_for("N", 3) == 4 and cm.bucket_for("N", 8) == 8
        assert cm.bucket_for("S", 3) == 32 and cm.bucket_for("S", 33) == 64
        cm.run(feed(3, 40))
        assert cm.plan_cache.keys() == [(("N", 4), ("S", 64))]

    def test_binding_order_independence(self):
        """{'N':…, 'S':…} and {'S':…, 'N':…} are the same specialization —
        one cache entry, identical plan rendering."""
        model, _ = _two_axis_model()
        cm = compile_model(model, backend="interpret", dynamic_axes={"N": None, "S": 32})
        plan_a, fn_a = cm.specialized({"N": 4, "S": 32})
        plan_b, fn_b = cm.specialized({"S": 32, "N": 4})
        assert fn_a is fn_b  # second lookup is a cache hit, not a new entry
        assert cm.cache_stats["misses"] == 1 and cm.cache_stats["hits"] == 1
        assert plan_a.pretty() == plan_b.pretty()
        direct_a = specialize_plan(cm.plan, {"N": 4, "S": 32})
        direct_b = specialize_plan(cm.plan, {"S": 32, "N": 4})
        assert direct_a.pretty() == direct_b.pretty()
        assert "batch=(N=4,S=32)" in direct_a.pretty().splitlines()[0]

    def test_unknown_axis_name_rejected(self):
        model, _ = _two_axis_model()
        with pytest.raises(ValueError, match="not symbolic"):
            compile_model(model, dynamic_axes={"N": None, "T": None})
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": None})
        with pytest.raises(ValueError, match="unknown dynamic axes"):
            cm.specialized({"N": 4, "T": 8})
        with pytest.raises(ValueError, match="unknown dynamic axes"):
            specialize_plan(cm.plan, {"T": 8})

    def test_partially_bound_template_refuses_to_execute(self):
        """Binding a subset of the axes keeps the plan a template over the
        rest: it renders with the remaining open axes and refuses direct
        execution until fully bound."""
        model, feed = _two_axis_model()
        cm = compile_model(model, backend="interpret", dynamic_axes={"N": None, "S": 32})
        partial = specialize_plan(cm.plan, {"S": 32})
        assert partial.batch == "dynamic" and partial.axes == ("N",)
        with pytest.raises(RuntimeError, match="specialize"):
            partial.execute({"x": feed(4, 32)["x"]})
        full = specialize_plan(partial, {"N": 4})
        assert full.batch == (("N", 4),) or full.batch == 4
        for step in full.steps:
            if step.kind == "fused_qlinear":
                assert step.params["shape"]["m"] == 4 * 32
                assert "dynamic_batch" not in step.params

    def test_specialize_empty_bindings_on_static_plan_is_noop(self):
        model, _ = _two_axis_model()
        static = _static_for(model, {"N": 2, "S": 32}, "ref")
        assert specialize_plan(static.plan, {}) is static.plan
        with pytest.raises(ValueError, match="dynamic"):
            specialize_plan(static.plan, {"N": 4})

    def test_dynamic_single_named_axis_only(self):
        """Leaving one named axis static: requesting only S keeps N as a
        compile-time-unknown dim (default tiles) but buckets S."""
        model, feed = _two_axis_model()
        rt = ReferenceRuntime(model)
        cm = compile_model(model, backend="ref", dynamic_axes={"S": 32})
        assert cm.plan.axes == ("S",)
        feeds = feed(2, 40)
        got = cm.run(feeds)
        want = rt.run(feeds)
        for k in want:
            # N is not dynamic: the feed's own batch extent must be used
            # as-is (no padding), while S pads 40 → 64 and slices back
            np.testing.assert_array_equal(got[k], want[k])

    def test_seq_axis_mixing_rejected(self):
        """An op that mixes information across the sequence axis (softmax
        over it) must reject a dynamic-S compile but still allow dynamic-N."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("seq_mix")
        x = gb.add_input("x", "float32", ("N", "S", 8))
        y = gb.op("Softmax", [x], axis=1)  # normalizes over S
        gb.add_output(y, "float32", ("N", "S", 8))
        model = gb.build()
        with pytest.raises(ValueError, match="'S'"):
            compile_model(model, dynamic_axes={"S": None}, fuse=False, optimize=False)
        cm = compile_model(model, dynamic_axes={"N": None}, fuse=False, optimize=False)
        rt = ReferenceRuntime(model)
        feeds = {"x": np.random.default_rng(3).normal(size=(3, 5, 8)).astype(np.float32)}
        np.testing.assert_allclose(
            cm.run(feeds)[y], rt.run(feeds)[y], rtol=1e-6, atol=1e-6
        )

    def test_named_transpose_tracks_the_axis(self):
        """With named axes a permutation is fine — the axis is tracked by
        name to its new position, padded there, and sliced back there."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("named_transpose")
        x = gb.add_input("x", "float32", ("N", 4, 8))
        t = gb.op("Transpose", [x], perm=[1, 0, 2])  # N moves to position 1
        y = gb.op("Relu", [t])
        gb.add_output(y, "float32", (4, "N", 8))
        model = gb.build()
        cm = compile_model(model, dynamic_axes={"N": None}, fuse=False, optimize=False)
        rt = ReferenceRuntime(model)
        for m in (1, 3, 5):
            feeds = {"x": np.random.default_rng(m).normal(size=(m, 4, 8)).astype(np.float32)}
            got, want = cm.run(feeds)[y], rt.run(feeds)[y]
            assert got.shape == (4, m, 8)
            np.testing.assert_array_equal(got, want)


class TestBatchMixingRejection:
    """compile_model(batch="dynamic") must refuse graphs whose ops mix rows
    across the batch axis — zero-row padding would silently corrupt them."""

    def _graph(self, build):
        from repro.core import pqir

        gb = pqir.GraphBuilder("mix")
        x = gb.add_input("x", "float32", (None, 4, 4))
        y = build(gb, x)
        gb.add_output(y, "float32", (None,))
        return gb.build()

    @pytest.mark.parametrize(
        "case, build",
        [
            ("reduce_all", lambda gb, x: gb.op("ReduceMean", [x])),
            ("softmax_axis0", lambda gb, x: gb.op("Softmax", [x], axis=0)),
            ("transpose_batch", lambda gb, x: gb.op("Transpose", [x], perm=[1, 0, 2])),
            ("flatten_axis0", lambda gb, x: gb.op("Flatten", [x], axis=0)),
            (
                "reshape_folds_batch",
                lambda gb, x: gb.op(
                    "Reshape", [x, gb.add_initializer("t", np.asarray([-1, 8], np.int64))]
                ),
            ),
            ("concat_axis0", lambda gb, x: gb.op("Concat", [x, x], axis=0)),
        ],
    )
    def test_batch_mixing_op_rejected(self, case, build):
        model = self._graph(build)
        with pytest.raises(ValueError, match="batch-elementwise"):
            compile_model(model, batch="dynamic", fuse=False, optimize=False)
        compile_model(model, fuse=False, optimize=False)  # static stays fine

    def test_batch_safe_shape_ops_accepted(self):
        """Row-preserving uses of the same ops compile dynamically."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("safe")
        x = gb.add_input("x", "float32", (None, 4, 4))
        t = gb.add_initializer("t", np.asarray([-1, 16], np.int64))
        r = gb.op("Reshape", [x, t])  # (-1, 16): batch maps 1:1
        s = gb.op("Softmax", [r], axis=-1)
        f = gb.op("Flatten", [s], axis=1)
        gb.add_output(f, "float32", (None, 16))
        model = gb.build()
        cm = compile_model(model, batch="dynamic", fuse=False, optimize=False)
        ref = ReferenceRuntime(model)
        for m in (1, 3, 5):
            feeds = {"x": np.random.default_rng(m).normal(size=(m, 4, 4)).astype(np.float32)}
            want, got = ref.run(feeds)[f], cm.run(feeds)[f]
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


class TestSymbolicAxisAnalysis:
    def test_infer_shapes_binds_batch_through_the_graph(self):
        """Leading-dim-symbolic inference: binding the implicit batch axis
        propagates through Conv → Flatten → MatMulInteger to every value."""
        model, _ = MODELS["cnn"]()
        sym = analysis.infer_shapes(model.graph)
        bound = analysis.infer_shapes(model.graph, bindings={"N": 8})
        saw_symbolic = 0
        for name, shape in sym.items():
            if name in model.graph.initializers:
                continue
            if shape is not None and len(shape) >= 1 and shape[0] is None:
                saw_symbolic += 1
                assert bound[name] == (8,) + tuple(shape[1:]), name
        assert saw_symbolic >= 3  # input, conv out, flatten out, head out…

    def test_infer_shapes_propagates_named_axes(self):
        """Named axes flow by name through the fused-FC op chain, so every
        intermediate knows which dynamic axes it carries and where."""
        model = _two_axis_model()[0]
        shapes = analysis.infer_shapes(model.graph)
        out = model.graph.outputs[0].name
        assert shapes[out] == ("N", "S", 24)
        bound = analysis.infer_shapes(model.graph, bindings={"S": 64, "N": 4})
        assert bound[out] == (4, 64, 24)

    def test_bind_helpers(self):
        # named substitution, partial binding, legacy leading-None batch
        assert analysis.bind(("N", "S", 4), {"N": 8, "S": 16}) == (8, 16, 4)
        assert analysis.bind(("N", "S", 4), {"S": 16}) == ("N", 16, 4)
        assert analysis.bind((None, 4), {"N": 8}) == (8, 4)  # legacy batch
        assert analysis.bind((None, 4), {"S": 8}) == (None, 4)
        assert analysis.bind((2, 4), {"N": 8}) == (2, 4)
        assert analysis.bind(None, {"N": 8}) is None
        assert analysis.bind(("N", 4), None) == ("N", 4)
        assert analysis.symbolic_axes(("N", "S", 4)) == ("N", "S")
        assert analysis.symbolic_axes((None, 4)) == ()
        assert analysis.symbolic_axes(None) == ()

    def test_graph_axes_and_axis_inputs(self):
        model = _two_axis_model()[0]
        assert analysis.graph_axes(model.graph) == ("N", "S")
        assert analysis.axis_inputs(model.graph, "N") == ["x"]
        assert analysis.axis_inputs(model.graph, "S") == ["x"]
        legacy, _ = MODELS["mlp"]()
        assert analysis.graph_axes(legacy.graph) == ("N",)  # implicit batch
        assert analysis.axis_inputs(legacy.graph, "N") == ["input_q"]
        assert analysis.implicit_batch_graph(legacy.graph)
        assert not analysis.implicit_batch_graph(model.graph)

    def test_axis_positions(self):
        assert analysis.axis_positions(("N", "S", 4), "S") == (1,)
        assert analysis.axis_positions(("N", "S", 4), "K") == ()
        assert analysis.axis_positions(None, "N") is None
        assert analysis.axis_positions((None, 4), "N", implicit=True) == (0,)
        assert analysis.axis_positions((2, 4), "N", implicit=True) == ()

    def test_bind_qmatmul_axes_lead_handling(self):
        from repro.kernels.ops import bind_qmatmul_axes, bind_qmatmul_batch

        base = {"k": 64, "n": 32, "kp": 128, "np": 128, "bk": 128, "bn": 128}
        b = bind_qmatmul_batch({**base, "lead": (None,)}, 8)
        assert b["m"] == 8 and b["bm"] == 32 and "lead" not in b
        b = bind_qmatmul_batch({**base, "lead": (None, 4)}, 8)
        assert b["m"] == 32  # flat M = batch × static leading dims
        # wholly-unknown activation shape: M stays unknown, default bm stands
        b = bind_qmatmul_batch({**base, "lead": None}, 8)
        assert b["m"] is None and b["bm"] == 128
        # non-leading unknown dim: cannot know flat M either
        b = bind_qmatmul_batch({**base, "lead": (None, None)}, 8)
        assert b["m"] is None
        # named lead dims: flat M is the product of the bindings
        b = bind_qmatmul_axes({**base, "lead": ("N", "S")}, {"N": 4, "S": 16})
        assert b["m"] == 64 and "lead" not in b
        # partial binding keeps the record open (no m/bm) for the rest
        b = bind_qmatmul_axes({**base, "lead": ("N", "S")}, {"S": 16}, partial=True)
        assert b["lead"] == ("N", 16) and "m" not in b and "bm" not in b
        # unbound named axis: M unknowable, default bm stands
        b = bind_qmatmul_axes({**base, "lead": ("N", "S")}, {"N": 4})
        assert b["m"] is None and b["bm"] == 128

    def test_batch_bucket(self):
        assert [batch_bucket(m) for m in (1, 2, 3, 4, 5, 8, 17, 32)] == [1, 2, 4, 4, 8, 8, 32, 32]
        with pytest.raises(ValueError):
            batch_bucket(0)

    def test_bucket_rounding_at_exact_powers_of_two(self):
        """An extent already on a bucket boundary must map to itself — no
        off-by-one ballooning to the next bucket."""
        for m in (1, 2, 4, 8, 32, 128, 1024):
            assert batch_bucket(m) == m
        for n in (32, 64, 96, 128):
            assert bucket_multiple(n, 32) == n
        assert bucket_multiple(33, 32) == 64
        assert bucket_multiple(1, 32) == 32

    def test_resolve_bucketing_specs(self):
        assert resolve_bucketing(None)(5) == 8  # power-of-two default
        assert resolve_bucketing(32)(40) == 64  # int granularity
        assert resolve_bucketing(lambda n: n + 1)(5) == 6  # custom policy
        with pytest.raises(ValueError):
            resolve_bucketing(0)
        with pytest.raises(TypeError):
            resolve_bucketing("pow2")

    def test_bindings_key_is_order_independent(self):
        assert bindings_key({"S": 32, "N": 8}) == bindings_key({"N": 8, "S": 32})
        assert bindings_key({"N": 8, "S": 32}) == (("N", 8), ("S", 32))


class TestLruCache:
    def test_hit_miss_eviction_accounting(self):
        c = LruCache(2)
        assert c.get("a") is None
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1  # refreshes "a" → "b" is now LRU
        c.put("c", 3)  # evicts "b"
        assert "b" not in c and "a" in c and "c" in c
        assert c.get("b") is None
        assert c.stats == {
            "size": 2, "capacity": 2, "hits": 1, "misses": 2, "evictions": 1,
            "hit_rate": 1 / 3,
        }

    def test_put_refreshes_existing_key(self):
        c = LruCache(2)
        c.put("a", 1)
        c.put("b", 2)
        c.put("a", 10)  # refresh, not insert — "b" stays resident
        c.put("c", 3)  # evicts "b" (LRU), not "a"
        assert c.get("a") == 10 and "b" not in c

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            LruCache(0)
