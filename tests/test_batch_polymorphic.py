"""Differential conformance for batch-polymorphic compilation.

One ``compile_model(batch="dynamic")`` artifact must serve every batch size
bit-exactly — against the reference runtime AND against a per-shape *static*
compile of the same model — with at most one specialization (one PlanCache
miss, no re-lowering) per power-of-two bucket.  Covers the MLP (fused
qlinear chain) and the CNN (conv + Flatten + head) across batch sizes
{1, 3, 8, 17} on the ref and interpret backends, plus the plan-cache
LRU-bounding behavior and the analysis-layer symbolic-batch helpers.
"""
import numpy as np
import pytest

from repro.backend.plan import PlanCache, batch_bucket
from repro.backend.lowering import specialize_plan
from repro.core.cache import LruCache
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import CNNSpec, ConvLayerSpec, MLPSpec, quantize_cnn, quantize_mlp
from repro.passes import analysis

BATCH_SIZES = (1, 3, 8, 17)
BACKENDS = ("ref", "interpret")


def _mlp_model():
    rng = np.random.default_rng(11)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(32, 48)).astype(np.float32) * 0.15,
            rng.normal(size=(48, 10)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(48,)).astype(np.float32) * 0.1,
            rng.normal(size=(10,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(128, 32)).astype(np.float32)
    model = quantize_mlp(spec, calib, name="dyn_mlp")

    def feed(m):
        return {"input_q": rng.integers(-128, 128, (m, 32)).astype(np.int8)}

    return model, feed


def _cnn_model():
    rng = np.random.default_rng(12)
    spec = CNNSpec(
        convs=[
            ConvLayerSpec(
                rng.normal(size=(4, 1, 3, 3)).astype(np.float32) * 0.3,
                rng.normal(size=(4,)).astype(np.float32) * 0.1,
                strides=(1, 1),
                pads=(1, 1, 1, 1),
                activation="Relu",
            )
        ],
        head=MLPSpec(
            weights=[rng.normal(size=(4 * 8 * 8, 10)).astype(np.float32) * 0.1],
            biases=[rng.normal(size=(10,)).astype(np.float32) * 0.1],
            activations=[None],
        ),
    )
    calib = rng.normal(size=(64, 1, 8, 8)).astype(np.float32)
    model = quantize_cnn(spec, calib, per_channel=True, name="dyn_cnn")

    def feed(m):
        return {"input_q": rng.integers(-128, 128, (m, 1, 8, 8)).astype(np.int8)}

    return model, feed


def _uint8_pc_model():
    """uint8 activations (plan-time signed fold) + per-channel rescale +
    two-Mul epilogue — the template path must carry the folded bias and the
    vector params exactly like the static path does."""
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(13)
    w = rng.normal(size=(32, 24)).astype(np.float32) * 0.2
    w[:, 5] *= 25.0
    b = rng.normal(size=(24,)).astype(np.float32) * 0.1
    p = quant.quantize_linear_layer(w, b, 0.05, 0.1, per_channel=True)
    gb = pqir.GraphBuilder("dyn_u8")
    x = gb.add_input("x", "uint8", (None, 32))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", (None, 24))
    model = gb.build()

    def feed(m):
        return {"x": rng.integers(0, 256, (m, 32)).astype(np.uint8)}

    return model, feed


MODELS = {"mlp": _mlp_model, "cnn": _cnn_model, "uint8_pc": _uint8_pc_model}


def _static_for_batch(model, m: int, backend: str):
    """A per-shape static compile: the same artifact with the symbolic batch
    pinned to ``m`` in its input/output signature."""
    pinned = analysis.clone_model(model)
    for t in list(pinned.graph.inputs) + list(pinned.graph.outputs):
        if analysis.has_symbolic_batch(tuple(t.shape)):
            t.shape = (m,) + tuple(t.shape[1:])
    return compile_model(pinned, backend=backend)


class TestDynamicConformance:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_dynamic_matches_reference_and_static(self, name, backend):
        model, feed = MODELS[name]()
        rt = ReferenceRuntime(model)
        cm = compile_model(model, backend=backend, batch="dynamic")
        assert cm.is_dynamic and cm.plan.batch == "dynamic"
        for m in BATCH_SIZES:
            feeds = feed(m)
            ref = rt.run(feeds)
            got = cm.run(feeds)
            static = _static_for_batch(model, m, backend).run(feeds)
            for k, want in ref.items():
                assert got[k].shape == want.shape, (name, backend, m)
                np.testing.assert_array_equal(got[k], want, err_msg=f"{name}/{backend}/m={m} vs ref")
                np.testing.assert_array_equal(
                    static[k], want, err_msg=f"{name}/{backend}/m={m} static vs ref"
                )

    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_one_specialization_per_bucket(self, name):
        model, feed = MODELS[name]()
        cm = compile_model(model, backend="ref", batch="dynamic")
        for m in BATCH_SIZES:  # buckets {1, 4, 8, 32}
            cm.run(feed(m))
        buckets = {batch_bucket(m) for m in BATCH_SIZES}
        assert cm.cache_stats["misses"] == len(buckets)
        assert cm.cache_stats["size"] == len(buckets)
        for m in BATCH_SIZES:  # same buckets again: pure cache hits
            cm.run(feed(m))
        assert cm.cache_stats["misses"] == len(buckets)
        assert cm.cache_stats["hits"] >= len(BATCH_SIZES)

    def test_sizes_sharing_a_bucket_specialize_once(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="ref", batch="dynamic")
        for m in (5, 6, 7, 8):  # all land in bucket 8
            cm.run(feed(m))
        assert cm.cache_stats == {
            "size": 1, "capacity": PlanCache.DEFAULT_CAPACITY,
            "hits": 3, "misses": 1, "evictions": 0,
        }

    def test_plan_cache_is_bounded(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="ref", batch="dynamic", plan_cache_capacity=2)
        for m in (1, 2, 4):
            cm.run(feed(m))
        stats = cm.cache_stats
        assert stats["size"] == 2 and stats["evictions"] == 1
        cm.run(feed(1))  # bucket 1 was LRU-evicted → re-specializes
        assert cm.cache_stats["misses"] == 4


class TestTemplatePlan:
    def test_template_is_not_directly_executable_on_tiled_backends(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="interpret", batch="dynamic")
        with pytest.raises(RuntimeError, match="specialize"):
            cm.plan.execute({"input_q": feed(4)["input_q"]})

    def test_specialize_binds_m_and_bm_without_copying_params(self):
        model, _ = MODELS["mlp"]()
        cm = compile_model(model, backend="interpret", batch="dynamic")
        spec = specialize_plan(cm.plan, 8)
        assert spec.batch == 8
        for tmpl_step, spec_step in zip(cm.plan.steps, spec.steps):
            if tmpl_step.kind != "fused_qlinear":
                continue
            shape = spec_step.params["shape"]
            assert shape["m"] == 8 and shape["bm"] == 32  # sublane-min tile, not 128
            assert "lead" not in shape and "dynamic_batch" not in spec_step.params
            # padded parameter arrays are shared with the template, not copied
            for a, b in zip(tmpl_step.consts, spec_step.consts):
                assert a is b
            # symbolic leading dims bound in the value typing
            for info in spec_step.out_info:
                assert info.shape[0] == 8

    def test_specialize_rejects_non_templates(self):
        model, _ = MODELS["mlp"]()
        cm = compile_model(model, backend="interpret")
        with pytest.raises(ValueError, match="dynamic"):
            specialize_plan(cm.plan, 8)

    def test_dynamic_compile_requires_symbolic_batch_input(self):
        model, _ = MODELS["mlp"]()
        pinned = analysis.clone_model(model)
        for t in pinned.graph.inputs:
            t.shape = (4,) + tuple(t.shape[1:])
        with pytest.raises(ValueError, match="symbolic"):
            compile_model(pinned, batch="dynamic")

    def test_misdeclared_output_batch_dim_still_sliced(self):
        """An output declared with a concrete leading dim is still recognized
        as batch-carrying via the plan's inferred value shapes — the result
        comes back sliced to the true batch, not bucket-padded."""
        from repro.core import patterns, pqir, quant

        rng = np.random.default_rng(14)
        p = quant.quantize_linear_layer(
            rng.normal(size=(16, 8)).astype(np.float32) * 0.2,
            rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1,
        )
        gb = pqir.GraphBuilder("misdeclared")
        x = gb.add_input("x", "int8", (None, 16))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True)
        gb.add_output(y, "int8", (4, 8))  # wrong: leading dim is really dynamic
        model = gb.build()
        cm = compile_model(model, backend="ref", batch="dynamic")
        assert cm.batch_output_names == {y}
        got = cm.run({"x": rng.integers(-128, 128, (3, 16)).astype(np.int8)})
        assert got[y].shape == (3, 8)

    def test_batch_independent_output_returned_whole(self):
        """A constant (batch-independent) auxiliary output is not sliced."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("aux")
        x = gb.add_input("x", "float32", (None, 4))
        c1 = gb.add_initializer("c1", np.arange(5, dtype=np.float32))
        c2 = gb.add_initializer("c2", np.ones(5, np.float32))
        y = gb.op("Relu", [x])
        z = gb.op("Add", [c1, c2])
        gb.add_output(y, "float32", (None, 4))
        gb.add_output(z, "float32", (5,))
        model = gb.build()
        # optimize=False keeps the const-only Add as a live step
        cm = compile_model(model, backend="ref", batch="dynamic", optimize=False, fuse=False)
        assert cm.batch_output_names == {y}
        got = cm.run({"x": np.ones((3, 4), np.float32)})
        assert got[y].shape == (3, 4)
        np.testing.assert_array_equal(got[z], np.arange(5, dtype=np.float32) + 1.0)

    def test_zero_batch_rejected(self):
        model, feed = MODELS["mlp"]()
        cm = compile_model(model, backend="ref", batch="dynamic")
        with pytest.raises(ValueError, match="batch must be >= 1"):
            cm.run({"input_q": np.zeros((0, 32), np.int8)})


class TestBatchMixingRejection:
    """compile_model(batch="dynamic") must refuse graphs whose ops mix rows
    across the batch axis — zero-row padding would silently corrupt them."""

    def _graph(self, build):
        from repro.core import pqir

        gb = pqir.GraphBuilder("mix")
        x = gb.add_input("x", "float32", (None, 4, 4))
        y = build(gb, x)
        gb.add_output(y, "float32", (None,))
        return gb.build()

    @pytest.mark.parametrize(
        "case, build",
        [
            ("reduce_all", lambda gb, x: gb.op("ReduceMean", [x])),
            ("softmax_axis0", lambda gb, x: gb.op("Softmax", [x], axis=0)),
            ("transpose_batch", lambda gb, x: gb.op("Transpose", [x], perm=[1, 0, 2])),
            ("flatten_axis0", lambda gb, x: gb.op("Flatten", [x], axis=0)),
            (
                "reshape_folds_batch",
                lambda gb, x: gb.op(
                    "Reshape", [x, gb.add_initializer("t", np.asarray([-1, 8], np.int64))]
                ),
            ),
            ("concat_axis0", lambda gb, x: gb.op("Concat", [x, x], axis=0)),
        ],
    )
    def test_batch_mixing_op_rejected(self, case, build):
        model = self._graph(build)
        with pytest.raises(ValueError, match="batch-elementwise"):
            compile_model(model, batch="dynamic", fuse=False, optimize=False)
        compile_model(model, fuse=False, optimize=False)  # static stays fine

    def test_batch_safe_shape_ops_accepted(self):
        """Row-preserving uses of the same ops compile dynamically."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("safe")
        x = gb.add_input("x", "float32", (None, 4, 4))
        t = gb.add_initializer("t", np.asarray([-1, 16], np.int64))
        r = gb.op("Reshape", [x, t])  # (-1, 16): batch maps 1:1
        s = gb.op("Softmax", [r], axis=-1)
        f = gb.op("Flatten", [s], axis=1)
        gb.add_output(f, "float32", (None, 16))
        model = gb.build()
        cm = compile_model(model, batch="dynamic", fuse=False, optimize=False)
        ref = ReferenceRuntime(model)
        for m in (1, 3, 5):
            feeds = {"x": np.random.default_rng(m).normal(size=(m, 4, 4)).astype(np.float32)}
            want, got = ref.run(feeds)[f], cm.run(feeds)[f]
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


class TestSymbolicBatchAnalysis:
    def test_infer_shapes_binds_batch_through_the_graph(self):
        """Leading-dim-symbolic inference: binding the input batch propagates
        through Conv → Flatten → MatMulInteger to every value."""
        model, _ = MODELS["cnn"]()
        sym = analysis.infer_shapes(model.graph)
        bound = analysis.infer_shapes(model.graph, batch=8)
        saw_symbolic = 0
        for name, shape in sym.items():
            if name in model.graph.initializers:
                continue
            if analysis.has_symbolic_batch(shape):
                saw_symbolic += 1
                assert bound[name] == (8,) + tuple(shape[1:]), name
        assert saw_symbolic >= 3  # input, conv out, flatten out, head out…

    def test_bind_batch_helpers(self):
        assert analysis.bind_batch((None, 4), 8) == (8, 4)
        assert analysis.bind_batch((None, 4), None) == (None, 4)
        assert analysis.bind_batch((2, 4), 8) == (2, 4)
        assert analysis.bind_batch(None, 8) is None
        assert analysis.has_symbolic_batch((None, 3))
        assert not analysis.has_symbolic_batch((2, 3))
        assert not analysis.has_symbolic_batch(None)

    def test_bind_qmatmul_batch_lead_handling(self):
        from repro.kernels.ops import bind_qmatmul_batch

        base = {"k": 64, "n": 32, "kp": 128, "np": 128, "bk": 128, "bn": 128}
        b = bind_qmatmul_batch({**base, "lead": (None,)}, 8)
        assert b["m"] == 8 and b["bm"] == 32 and "lead" not in b
        b = bind_qmatmul_batch({**base, "lead": (None, 4)}, 8)
        assert b["m"] == 32  # flat M = batch × static leading dims
        # wholly-unknown activation shape: M stays unknown, default bm stands
        b = bind_qmatmul_batch({**base, "lead": None}, 8)
        assert b["m"] is None and b["bm"] == 128
        # non-leading unknown dim: cannot know flat M either
        b = bind_qmatmul_batch({**base, "lead": (None, None)}, 8)
        assert b["m"] is None

    def test_batch_bucket(self):
        assert [batch_bucket(m) for m in (1, 2, 3, 4, 5, 8, 17, 32)] == [1, 2, 4, 4, 8, 8, 32, 32]
        with pytest.raises(ValueError):
            batch_bucket(0)


class TestLruCache:
    def test_hit_miss_eviction_accounting(self):
        c = LruCache(2)
        assert c.get("a") is None
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1  # refreshes "a" → "b" is now LRU
        c.put("c", 3)  # evicts "b"
        assert "b" not in c and "a" in c and "c" in c
        assert c.get("b") is None
        assert c.stats == {"size": 2, "capacity": 2, "hits": 1, "misses": 2, "evictions": 1}

    def test_put_refreshes_existing_key(self):
        c = LruCache(2)
        c.put("a", 1)
        c.put("b", 2)
        c.put("a", 10)  # refresh, not insert — "b" stays resident
        c.put("c", 3)  # evicts "b" (LRU), not "a"
        assert c.get("a") == 10 and "b" not in c

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            LruCache(0)
