"""E4: Pallas qmatmul kernel ≡ ref.py oracle ≡ reference runtime, bit-exact,
over a shape/dtype/feature sweep (interpret mode on CPU)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import ops, ref
from repro.kernels.qmatmul import qmatmul


def _mk(rng, m, k, n, in_dtype="int8"):
    lo, hi = (-128, 128) if in_dtype == "int8" else (0, 256)
    x = rng.integers(lo, hi, (m, k)).astype(in_dtype)
    w = rng.integers(-128, 128, (k, n)).astype(np.int8)
    b = rng.integers(-(2**18), 2**18, (n,)).astype(np.int32)
    r = quant.decompose_multiplier(rng.uniform(1e-4, 0.01))
    return x, w, b, r


SHAPES = [(128, 256, 128), (256, 256, 256), (128, 512, 384), (384, 256, 128)]


class TestKernelTilePure:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    def test_matches_ref_bitexact(self, m, k, n):
        rng = np.random.default_rng(m + k + n)
        x, w, b, r = _mk(rng, m, k, n)
        qs = jnp.full((1, n), np.float32(r.quant_scale))
        qsh = jnp.full((1, n), np.float32(r.quant_shift))
        out = qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b).reshape(1, n), qs, qsh, interpret=True)
        expect = ref.qmatmul_ref(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
            jnp.float32(r.quant_scale), jnp.float32(r.quant_shift),
        )
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    def test_relu_and_uint8_out(self):
        rng = np.random.default_rng(0)
        x, w, b, r = _mk(rng, 128, 256, 128)
        qs = jnp.full((1, 128), np.float32(r.quant_scale))
        qsh = jnp.full((1, 128), np.float32(r.quant_shift))
        out = qmatmul(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b).reshape(1, -1), qs, qsh,
            relu=True, out_dtype=jnp.uint8, interpret=True,
        )
        expect = ref.qmatmul_ref(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
            jnp.float32(r.quant_scale), jnp.float32(r.quant_shift),
            relu=True, out_dtype=jnp.uint8,
        )
        assert np.asarray(out).dtype == np.uint8
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    def test_one_mul_mode(self):
        rng = np.random.default_rng(1)
        x, w, b, r = _mk(rng, 128, 256, 128)
        qs = jnp.full((1, 128), np.float32(r.multiplier))
        qsh = jnp.ones((1, 128), jnp.float32)
        out = qmatmul(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b).reshape(1, -1), qs, qsh,
            two_mul=False, interpret=True,
        )
        expect = ref.qmatmul_ref(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
            jnp.float32(r.multiplier), jnp.float32(1.0), two_mul=False,
        )
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


class TestOpsWrapper:
    @pytest.mark.parametrize(
        "shape_x,k,n",
        [((7, 33), 33, 17), ((3, 5, 40), 40, 50), ((1, 1), 1, 1), ((130, 260), 260, 129)],
    )
    def test_ragged_shapes_padded(self, shape_x, k, n):
        """Wrapper pads ragged shapes; result equals oracle exactly."""
        rng = np.random.default_rng(42)
        x = rng.integers(-128, 128, shape_x).astype(np.int8)
        w = rng.integers(-128, 128, (k, n)).astype(np.int8)
        b = rng.integers(-1000, 1000, (n,)).astype(np.int32)
        r = quant.decompose_multiplier(0.003)
        got = ops.quantized_matmul(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
            float(r.quant_scale), r.quant_shift, backend="interpret", bm=128, bk=128, bn=128,
        )
        expect = ref.qmatmul_ref(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
            jnp.float32(r.quant_scale), jnp.float32(r.quant_shift),
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))

    def test_uint8_fold_matches_reference_runtime_semantics(self):
        """uint8 activations folded to int8 (+128 offset into bias) must equal
        the artifact's MatMulInteger on uint8 exactly."""
        rng = np.random.default_rng(7)
        x = rng.integers(0, 256, (32, 64)).astype(np.uint8)
        w = rng.integers(-128, 128, (64, 48)).astype(np.int8)
        b = rng.integers(-500, 500, (48,)).astype(np.int32)
        r = quant.decompose_multiplier(0.004)
        for backend in ("ref", "interpret"):
            got = ops.quantized_matmul(
                jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                float(r.quant_scale), r.quant_shift, backend=backend, bm=32, bk=64, bn=48,
            )
            # semantic reference: true uint8 matmul
            acc = x.astype(np.int32) @ w.astype(np.int32) + b
            f = acc.astype(np.float32) * np.float32(r.quant_scale) * np.float32(r.quant_shift)
            expect = np.clip(np.rint(f), -128, 127).astype(np.int8)
            np.testing.assert_array_equal(np.asarray(got), expect)

    def test_per_channel_rescale(self):
        rng = np.random.default_rng(8)
        x = rng.integers(-128, 128, (16, 32)).astype(np.int8)
        w = rng.integers(-128, 128, (32, 24)).astype(np.int8)
        qs = rng.integers(1, 2**20, (24,)).astype(np.float32)
        qsh = np.full((24,), 2.0**-28, np.float32)
        got = ops.quantized_matmul(
            jnp.asarray(x), jnp.asarray(w), None, jnp.asarray(qs), jnp.asarray(qsh),
            backend="interpret", bm=16, bk=32, bn=24,
        )
        acc = x.astype(np.int32) @ w.astype(np.int32)
        expect = np.clip(np.rint(acc.astype(np.float32) * qs * qsh), -128, 127).astype(np.int8)
        np.testing.assert_array_equal(np.asarray(got), expect)


class TestQuantizedConv:
    def test_conv_matches_runtime(self):
        from repro.core import pqir, patterns
        from repro.core.runtime import ReferenceRuntime

        rng = np.random.default_rng(9)
        x = rng.integers(-128, 128, (2, 3, 10, 10)).astype(np.int8)
        w = rng.integers(-128, 128, (8, 3, 3, 3)).astype(np.int8)
        b = rng.integers(-100, 100, (8,)).astype(np.int32)
        r = quant.decompose_multiplier(0.002)
        got = ops.quantized_conv2d(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
            float(r.quant_scale), r.quant_shift, pads=(1, 1, 1, 1), two_mul=True,
        )
        gb = pqir.GraphBuilder("c")
        xi = gb.add_input("x", "int8", (None, 3, 10, 10))
        y = patterns.conv_layer(gb, xi, w, b, r, "c0", pads=(1, 1, 1, 1), two_mul=True)
        gb.add_output(y, "int8", (None, 8, 10, 10))
        ref_out = ReferenceRuntime(gb.build()).run({"x": x})[y]
        np.testing.assert_array_equal(np.asarray(got), ref_out)
