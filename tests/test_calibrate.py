"""Calibration observers (§3's scale-selection approaches) + runtime op edges."""
import numpy as np
import pytest

from repro.core import quant
from repro.core.calibrate import AbsMaxObserver, MSEObserver, PercentileObserver, make_observer


class TestObservers:
    def test_absmax_tracks_max(self):
        o = AbsMaxObserver()
        o.observe(np.array([1.0, -3.0]))
        o.observe(np.array([2.0]))
        assert o.absmax == 3.0
        assert np.isclose(o.scale("int8"), 3.0 / 127.0)

    def test_percentile_rejects_outliers(self):
        rng = np.random.default_rng(0)
        o = PercentileObserver(percentile=99.0)
        x = rng.normal(size=20_000).astype(np.float32)
        x[0] = 1000.0  # single huge outlier
        o.observe(x)
        # saturated range ignores the outlier: close to the 99th pct of |N(0,1)|
        assert o.absmax() < 5.0
        a = AbsMaxObserver()
        a.observe(x)
        assert a.absmax == 1000.0  # absmax would be wrecked

    def test_percentile_rebinning_grows_range(self):
        o = PercentileObserver(percentile=100.0, bins=64)
        o.observe(np.ones(10, np.float32))
        o.observe(np.full(10, 50.0, np.float32))  # forces histogram growth
        assert o.absmax() >= 50.0

    def test_mse_beats_absmax_on_heavy_tails(self):
        rng = np.random.default_rng(1)
        x = rng.standard_t(df=2, size=30_000).astype(np.float32)  # heavy tails
        mse_o, abs_o = MSEObserver(), AbsMaxObserver()
        mse_o.observe(x)
        abs_o.observe(x)
        def err(scale):
            q = quant.quantize(x, scale, "int8")
            return float(np.mean((quant.dequantize(q, scale) - x) ** 2))
        assert err(mse_o.scale()) <= err(abs_o.scale())

    def test_factory(self):
        assert isinstance(make_observer("absmax"), AbsMaxObserver)
        with pytest.raises(ValueError):
            make_observer("nope")


class TestRuntimeOpEdges:
    def test_matmul_integer_zero_points(self):
        """MatMulInteger honors optional zero-point inputs (asymmetric mode —
        we emit symmetric artifacts but the runtime follows the ONNX spec)."""
        from repro.core import pqir
        from repro.core.runtime import ReferenceRuntime

        gb = pqir.GraphBuilder("zp")
        x = gb.add_input("x", "uint8", (None, 4))
        w = gb.add_initializer("w", np.arange(8, dtype=np.int8).reshape(4, 2))
        xzp = gb.add_initializer("xzp", np.asarray(128, np.uint8))
        wzp = gb.add_initializer("wzp", np.asarray(0, np.int8))
        y = gb.op("MatMulInteger", [x, w, xzp, wzp], out_hint="y")
        gb.add_output(y, "int32", (None, 2))
        model = gb.build()
        xv = np.array([[128, 129, 130, 131]], np.uint8)
        out = ReferenceRuntime(model).run({"x": xv})[y]
        expect = (xv.astype(np.int32) - 128) @ np.arange(8, dtype=np.int32).reshape(4, 2)
        np.testing.assert_array_equal(out, expect)

    def test_conv_integer_groups_strides_dilations(self):
        from repro.core.runtime import _conv2d_int32

        rng = np.random.default_rng(2)
        x = rng.integers(-5, 5, (1, 4, 9, 9)).astype(np.int32)
        w = rng.integers(-3, 3, (4, 2, 3, 3)).astype(np.int32)
        out = _conv2d_int32(x, w, {"strides": (2, 2), "pads": (1, 1, 1, 1), "dilations": (2, 2), "group": 2})
        assert out.shape[1] == 4
        # spot check one output element against a hand loop
        g, m = 0, 0
        oh = ow = 1
        acc = 0
        xp = np.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
        for c in range(2):
            for kh in range(3):
                for kw in range(3):
                    acc += xp[0, c, oh * 2 + kh * 2, ow * 2 + kw * 2] * w[m, c, kh, kw]
        assert out[0, m, oh, ow] == acc

    def test_fp16_sections_stay_fp16(self):
        from repro.core import pqir
        from repro.core.runtime import ReferenceRuntime

        gb = pqir.GraphBuilder("f16")
        x = gb.add_input("x", "float32", (None, 4))
        h = gb.op("Cast", [x], to="float16")
        t = gb.op("Tanh", [h])
        gb.add_output(t, "float16", (None, 4))
        out = ReferenceRuntime(gb.build()).run({"x": np.ones((1, 4), np.float32)})[t]
        assert out.dtype == np.float16  # paper Fig 5: tanh executes in fp16

    def test_unknown_op_rejected_by_validator_and_runtime(self):
        from repro.core import pqir
        from repro.core.runtime import ReferenceRuntime

        gb = pqir.GraphBuilder("bad")
        x = gb.add_input("x", "float32", (None, 2))
        y = gb.op("Erf", [x])
        gb.add_output(y, "float32", (None, 2))
        model = gb.build()  # Erf IS standard
        out = ReferenceRuntime(model).run({"x": np.zeros((1, 2), np.float32)})[y]
        np.testing.assert_allclose(out, 0.0)
