"""E11: the paper's technique as a first-class big-model feature — W8A8
conversion across all 10 architectures + the quantization manifest."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.convert import W8A8_NAMES, convert_params_w8a8, export_arch_quant_manifest
from repro.models import model as M

# heavyweight model/serving tier — excluded from the fast CI tier (scripts/check.sh)
pytestmark = pytest.mark.slow

B, S = 2, 16


def _batch(cfg, rng):
    tok = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok)}
    if cfg.frontend == "vision":
        batch["tokens"] = jnp.asarray(tok[:, : S - cfg.frontend_tokens])
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        )
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_w8a8_prefill_tracks_f32(arch):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pq = convert_params_w8a8(params)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    l16, _ = M.prefill(params, batch, cfg, M.init_cache(cfg, B, S + 4, src_len=S), compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
    lq, _ = M.prefill(pq, batch, cfg, M.init_cache(cfg, B, S + 4, src_len=S), compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
    rel = float(jnp.abs(lq - l16).max() / (jnp.abs(l16).max() + 1e-9))
    assert rel < 0.08, rel
    agree = float((jnp.argmax(lq, -1) == jnp.argmax(l16, -1)).mean())
    assert agree >= 0.5, agree  # greedy next token usually unchanged


def test_conversion_halves_weight_bytes():
    cfg = get_config("qwen3_1_7b", reduced=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pq = convert_params_w8a8(params)
    bytes_of = lambda t: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    # f32 masters -> int8 + small scales: ≥3x smaller on the converted subset;
    # vs bf16 serving weights that is still ≥1.9x
    assert bytes_of(params) / bytes_of(pq) > 2.5


def test_manifest_codifies_scales():
    cfg = get_config("mixtral_8x22b", reduced=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pq = convert_params_w8a8(params)
    mani = export_arch_quant_manifest(pq)
    assert mani["format"] == "pq-w8a8/v1"
    assert len(mani["tensors"]) >= 5
    for t in mani["tensors"]:
        assert 1 <= t["quant_scale_median"] < 2**24  # §3.1 exactness bound
        assert t["scale_min"] > 0


def test_routers_and_norms_not_quantized():
    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pq = convert_params_w8a8(params)
    flat = jax.tree_util.tree_flatten_with_path(pq)[0]
    for path, leaf in flat:
        names = [str(getattr(k, "key", "")) for k in path]
        if "router" in names or "ln1" in names or names[-1] == "table":
            assert leaf.dtype != jnp.int8, names


def test_quantized_decode_runs_all_archs_with_int8_kv():
    for arch in ("gemma2_2b", "mixtral_8x22b", "zamba2_7b"):
        cfg = dataclasses.replace(get_config(arch, reduced=True), kv_cache_dtype="int8")
        params = convert_params_w8a8(M.init_params(jax.random.PRNGKey(0), cfg))
        rng = np.random.default_rng(2)
        batch = _batch(cfg, rng)
        cache = M.init_cache(cfg, B, S + 4, src_len=S)
        logits, cache = M.prefill(params, batch, cfg, cache, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits2, _ = M.decode_step(params, nxt, jnp.full((B,), S, jnp.int32), cache, cfg, compute_dtype=jnp.float32)
        assert np.isfinite(np.asarray(logits2)).all()
