"""E5: the typed ExecutionPlan backend layer.

Covers: liveness-planned slot reuse, plan-time shape specialization
(pre-padded fused-qmatmul parameters, static tile choice), the backend
kernel registry, plan printing, the dict-env baseline executor, and
bit-exact conformance of the slot-indexed interpreter.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.backend import UnknownKernelError, backends_for, kernel_ids, lookup
from repro.core import patterns, pqir, quant
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import MLPSpec, quantize_mlp


def _fc_model(rng, n_in=100, n_out=60, batch=None, activation="Relu"):
    x = rng.normal(size=(8, n_in)).astype(np.float32)
    w = rng.normal(size=(n_in, n_out)).astype(np.float32) * 0.1
    b = rng.normal(size=(n_out,)).astype(np.float32) * 0.2
    scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
    p = quant.quantize_linear_layer(w, b, scale_x, 0.1)
    xq = quant.quantize(x, scale_x, "int8")
    gb = pqir.GraphBuilder("m")
    xi = gb.add_input("input_q", "int8", (batch, n_in))
    y = patterns.fc_layer(gb, xi, p, "fc0", two_mul=True, activation=activation)
    gb.add_output(y, "int8", (batch, n_out))
    return gb.build(), xq, y


def _mlp(rng):
    spec = MLPSpec(
        weights=[rng.normal(size=(32, 64)).astype(np.float32) * 0.2,
                 rng.normal(size=(64, 64)).astype(np.float32) * 0.2,
                 rng.normal(size=(64, 10)).astype(np.float32) * 0.2],
        biases=[rng.normal(size=(64,)).astype(np.float32) * 0.1,
                rng.normal(size=(64,)).astype(np.float32) * 0.1,
                rng.normal(size=(10,)).astype(np.float32) * 0.1],
        activations=["Relu", "Relu", None],
    )
    calib = rng.normal(size=(128, 32)).astype(np.float32)
    model = quantize_mlp(spec, calib)
    xq = quant.quantize(rng.normal(size=(8, 32)).astype(np.float32),
                        eval(model.metadata["input_scale"]), "int8")
    return model, xq


class TestShapeSpecialization:
    def test_qmatmul_params_prepadded_at_plan_time(self):
        """The acceptance criterion: no per-call padding of the fused qmatmul
        parameters — weight/bias/scales arrive at the kernel already padded
        to the tile multiples chosen for the static shape."""
        rng = np.random.default_rng(0)
        model, xq, y = _fc_model(rng, n_in=100, n_out=60)
        cm = compile_model(model, backend="interpret")
        (step,) = [s for s in cm.plan.steps if s.kernel == "qlinear_matmul"]
        shape = step.params["shape"]
        assert shape["k"] == 100 and shape["n"] == 60
        assert shape["kp"] % shape["bk"] == 0 and shape["np"] % shape["bn"] == 0
        assert shape["kp"] > shape["k"] and shape["np"] > shape["n"]  # ragged ⇒ padded
        w2, b2, qs2, qsh2 = step.consts
        assert w2.shape == (shape["kp"], shape["np"])
        assert b2.shape == qs2.shape == qsh2.shape == (1, shape["np"])
        # padded lanes of the epilogue scales are 1.0 (finite epilogue)
        assert float(np.asarray(qs2)[0, -1]) == 1.0
        # and the specialized plan is still bit-exact
        ref = ReferenceRuntime(model).run({"input_q": xq})[y]
        np.testing.assert_array_equal(cm.run({"input_q": xq})[y], ref)

    def test_static_batch_shrinks_tiles(self):
        rng = np.random.default_rng(1)
        model, xq, y = _fc_model(rng, n_in=256, n_out=128, batch=8)
        cm = compile_model(model, backend="interpret")
        (step,) = [s for s in cm.plan.steps if s.kernel == "qlinear_matmul"]
        shape = step.params["shape"]
        assert shape["m"] == 8
        assert shape["bm"] == 32  # hardware minimum sublane tile, not BM=128
        ref = ReferenceRuntime(model).run({"input_q": xq})[y]
        np.testing.assert_array_equal(cm.run({"input_q": xq})[y], ref)

    def test_uint8_activation_folded_at_plan_time(self):
        """uint8 activations fold to signed int8 (+128 into the bias) when
        the plan is built, not per call — and stay bit-exact."""
        rng = np.random.default_rng(2)
        x = rng.normal(size=(8, 32)).astype(np.float32)
        w1 = rng.normal(size=(32, 16)).astype(np.float32) * 0.3
        b1 = rng.normal(size=(16,)).astype(np.float32) * 0.1
        w2 = rng.normal(size=(16, 8)).astype(np.float32) * 0.3
        b2 = rng.normal(size=(8,)).astype(np.float32) * 0.1
        scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
        p1 = quant.quantize_linear_layer(w1, b1, scale_x, patterns.SIGMOID_INPUT_ABSMAX / 127.0)
        p2 = quant.quantize_linear_layer(w2, b2, 1.0 / 255.0, 0.1)
        gb = pqir.GraphBuilder("m")
        xi = gb.add_input("input_q", "int8", (None, 32))
        h = patterns.fc_fp16_sigmoid(gb, xi, p1, "fc0")  # uint8 output
        y = patterns.fc_layer(gb, h, p2, "fc1", two_mul=True)
        gb.add_output(y, "int8", (None, 8))
        model = gb.build()
        xq = quant.quantize(x, scale_x, "int8")
        cm = compile_model(model, backend="interpret")
        steps = [s for s in cm.plan.steps if s.kernel == "qlinear_matmul"]
        uint8_steps = [s for s in steps if s.params.get("x_uint8")]
        assert len(uint8_steps) == 1  # the second layer consumes uint8
        ref = ReferenceRuntime(model).run({"input_q": xq})[y]
        np.testing.assert_array_equal(cm.run({"input_q": xq})[y], ref)


class TestSlotPlanning:
    def test_elementwise_chain_runs_in_one_slot(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 16))
        t = x
        for _ in range(6):
            t = gb.op("Relu", [t], out_hint="r")
        gb.add_output(t, "float32", (None, 16))
        model = gb.build()
        cm = compile_model(model, fuse=False, optimize=False)
        assert cm.plan.num_slots == 1  # every step aliases its input's slot
        xv = np.random.default_rng(0).normal(size=(4, 16)).astype(np.float32)
        np.testing.assert_array_equal(
            cm.run({"x": xv})[t], ReferenceRuntime(model).run({"x": xv})[t]
        )

    def test_multi_consumer_tensor_not_freed_early(self):
        """Diamond: r feeds two later steps — its slot must survive until the
        second read."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 8))
        c = gb.add_initializer("c", np.float32(2.0))
        r = gb.op("Relu", [x], out_hint="r")
        m = gb.op("Mul", [r, c], out_hint="m")
        a = gb.op("Add", [r, m], out_hint="a")
        gb.add_output(a, "float32", (None, 8))
        model = gb.build()
        cm = compile_model(model, fuse=False, optimize=False)
        assert cm.plan.num_slots >= 2
        xv = np.random.default_rng(1).normal(size=(4, 8)).astype(np.float32)
        np.testing.assert_array_equal(
            cm.run({"x": xv})[a], ReferenceRuntime(model).run({"x": xv})[a]
        )

    def test_mlp_uses_fewer_slots_than_tensors(self):
        rng = np.random.default_rng(3)
        model, xq = _mlp(rng)
        cm = compile_model(model)
        plan = cm.plan
        n_tensors = len({t for s in plan.steps for t in s.outputs}) + len(plan.inputs)
        assert plan.num_slots < n_tensors
        assert cm.stats["plan_slots"] == plan.num_slots

    def test_graph_output_slot_is_pinned(self):
        """A tensor that is both consumed downstream and a graph output keeps
        its slot to the end."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 8))
        r = gb.op("Relu", [x], out_hint="r")
        s = gb.op("Sqrt", [r], out_hint="s")
        gb.add_output(r, "float32", (None, 8))
        gb.add_output(s, "float32", (None, 8))
        model = gb.build()
        cm = compile_model(model, fuse=False, optimize=False)
        xv = np.abs(np.random.default_rng(2).normal(size=(4, 8))).astype(np.float32)
        got = cm.run({"x": xv})
        ref = ReferenceRuntime(model).run({"x": xv})
        for k in ref:
            np.testing.assert_allclose(got[k], ref[k], rtol=1e-6)


class TestExecutors:
    def test_slot_plan_matches_dict_env_baseline(self):
        rng = np.random.default_rng(4)
        model, xq = _mlp(rng)
        cm = compile_model(model)
        feeds = {"input_q": jnp.asarray(xq)}
        a = jax.jit(cm.plan.execute)(feeds)
        b = jax.jit(cm.plan.execute_dict_env)(feeds)
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


class TestRegistry:
    def test_shared_fallback_resolution(self):
        impl_ref = lookup("ref", "op.Relu")
        impl_pallas = lookup("pallas", "op.Relu")
        assert impl_ref is impl_pallas  # both hit the "*" registration

    def test_backend_specific_beats_fallback(self):
        assert lookup("ref", "qlinear_matmul") is not lookup("interpret", "qlinear_matmul")

    def test_unknown_kernel_raises(self):
        with pytest.raises(UnknownKernelError, match="nope"):
            lookup("ref", "nope")

    def test_fused_kernels_cover_all_backends(self):
        assert backends_for("qlinear_matmul") == ["interpret", "pallas", "ref"]
        assert backends_for("qact_lut") == ["interpret", "pallas", "ref"]
        assert "op.MatMulInteger" in kernel_ids() and "op.Slice" in kernel_ids()


class TestPlanInspection:
    def test_pretty_print_is_the_codesign_artifact(self):
        rng = np.random.default_rng(5)
        model, xq = _mlp(rng)
        cm = compile_model(model, backend="interpret")
        text = str(cm.plan)
        assert "ExecutionPlan(backend=interpret" in text
        assert "qlinear_matmul" in text
        assert "%0" in text and "int8" in text
        assert "inputs:" in text and "outputs:" in text
        assert repr(cm.plan).startswith("ExecutionPlan(")

    def test_step_typing_from_analysis(self):
        rng = np.random.default_rng(6)
        model, xq, y = _fc_model(rng, n_in=64, n_out=32, batch=8)
        cm = compile_model(model)
        (step,) = [s for s in cm.plan.steps if s.kernel == "qlinear_matmul"]
        (info,) = step.out_info
        assert info.dtype == "int8"
        assert info.shape == (8, 32)
