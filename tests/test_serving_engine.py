"""Token-engine request lifecycle: admission validation and the generation
budget.

The ``max_new_tokens`` contract: a request gets back *exactly* that many
tokens — the prefill emits the first one, so a budget of 1 completes at
admission without ever occupying a decode slot, and a budget of n decodes
exactly n-1 more.  Admission rejects what the per-slot KV cache cannot hold
instead of silently clipping the cache write mid-decode.
"""
from collections import deque

import numpy as np
import pytest

from repro.serving.engine import EngineConfig, Request, ServeEngine


def _engine_shell(**cfg_kwargs):
    """An engine with only the admission surface wired up — ``submit`` needs
    just the config and the queue (same idiom as test_serving_sampling)."""
    eng = object.__new__(ServeEngine)
    eng.ecfg = EngineConfig(**cfg_kwargs)
    eng.queue = deque()
    return eng


class TestSubmitValidation:
    def test_overlong_prompt_rejected(self):
        eng = _engine_shell(max_len=64, prefill_bucket=32)
        with pytest.raises(ValueError, match="KV cache"):
            eng.submit(Request(uid=0, prompt=np.arange(65, dtype=np.int32)))
        # bucket overflow, not just raw length: 50 tokens pad to bucket 64,
        # which fits — but decoding past max_len would clip, so only a
        # single-token budget is admissible at plen >= max_len
        eng.submit(Request(uid=1, prompt=np.arange(50, dtype=np.int32), max_new_tokens=8))
        assert len(eng.queue) == 1

    def test_prompt_at_max_len_admits_only_single_token_budget(self):
        eng = _engine_shell(max_len=64, prefill_bucket=32)
        eng.submit(Request(uid=0, prompt=np.arange(64, dtype=np.int32), max_new_tokens=1))
        with pytest.raises(ValueError, match="KV cache"):
            eng.submit(Request(uid=1, prompt=np.arange(64, dtype=np.int32), max_new_tokens=2))
        assert len(eng.queue) == 1

    def test_empty_prompt_rejected(self):
        eng = _engine_shell()
        with pytest.raises(ValueError, match="at least one token"):
            eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32)))

    def test_nonpositive_budget_rejected(self):
        eng = _engine_shell()
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32), max_new_tokens=0))
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32), max_new_tokens=-3))
        assert not eng.queue

    def test_rejected_requests_leave_no_state(self):
        eng = _engine_shell(max_len=32, prefill_bucket=32)
        bad = Request(uid=0, prompt=np.arange(40, dtype=np.int32))
        with pytest.raises(ValueError):
            eng.submit(bad)
        assert not eng.queue and bad.generated is None


@pytest.mark.slow
class TestGenerationBudget:
    @pytest.mark.parametrize("budget", [1, 2, 16])
    def test_exactly_max_new_tokens_generated(self, budget):
        """The off-by-one regression: the prefill token counts against the
        budget, so len(generated) == max_new_tokens exactly — including the
        budget-1 case, which must complete at admit without a decode."""
        from repro.launch.serve import serve_demo

        reqs, eng = serve_demo(
            "qwen3_1_7b", requests=5, prompt_len=12, new_tokens=budget, slots=2
        )
        assert all(r.done for r in reqs)
        assert [len(r.generated) for r in reqs] == [budget] * 5
        assert eng.metrics["completed"] == 5
        if budget == 1:
            # all five completed at admit: the decode loop never ran a slot
            # for them, so no decode step was needed at all
            assert eng.metrics["decode_steps"] == 0
        assert all(r.t_done is not None and r.t_done >= r.t_first for r in reqs)
