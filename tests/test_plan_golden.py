"""Golden-plan snapshot tests.

``print(cm.plan)`` is the co-design artifact a hardware designer reads —
buffer slots, kernel ids, plan-time specialization params (tile choices,
pre-padded parameter shapes, uint8 folds).  Pinning the rendering for the
quickstart MLP and a per-channel CNN catches plan-level regressions (slot
counts, kernel ids, specialization params) in review, where a numeric
conformance test would stay green.

To update after an *intentional* lowering change:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_plan_golden.py

then review the golden diff like any other code change.
"""
import os

import numpy as np
import pytest

from repro.core.compile import compile_model
from repro.core.toolchain import CNNSpec, ConvLayerSpec, MLPSpec, quantize_cnn, quantize_mlp

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _check_golden(name: str, text: str) -> None:
    path = os.path.join(GOLDEN_DIR, name)
    if os.environ.get("REGEN_GOLDEN"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            f.write(text)
        pytest.skip(f"regenerated {name}")
    assert os.path.exists(path), f"missing golden file {path} — run with REGEN_GOLDEN=1"
    with open(path) as f:
        want = f.read()
    assert text == want, (
        f"ExecutionPlan rendering for {name} changed.  If intentional, regenerate "
        f"with REGEN_GOLDEN=1 and review the diff.\n--- golden ---\n{want}\n--- got ---\n{text}"
    )


def quickstart_mlp():
    """The examples/quickstart.py model, byte-for-byte (same seed/spec)."""
    rng = np.random.default_rng(0)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(64, 128)).astype(np.float32) * 0.2,
            rng.normal(size=(128, 128)).astype(np.float32) * 0.15,
            rng.normal(size=(128, 10)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(10,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", "Relu", None],
    )
    calib = rng.normal(size=(512, 64)).astype(np.float32)
    return quantize_mlp(spec, calib, observer="percentile", name="quickstart_mlp")


def per_channel_cnn():
    rng = np.random.default_rng(5)
    spec = CNNSpec(
        convs=[
            ConvLayerSpec(
                rng.normal(size=(4, 1, 3, 3)).astype(np.float32) * 0.3,
                rng.normal(size=(4,)).astype(np.float32) * 0.1,
                strides=(1, 1),
                pads=(1, 1, 1, 1),
                activation="Relu",
            )
        ],
        head=MLPSpec(
            weights=[rng.normal(size=(4 * 8 * 8, 10)).astype(np.float32) * 0.1],
            biases=[rng.normal(size=(10,)).astype(np.float32) * 0.1],
            activations=[None],
        ),
    )
    calib = rng.normal(size=(64, 1, 8, 8)).astype(np.float32)
    return quantize_cnn(spec, calib, per_channel=True, two_mul=True, name="per_channel_cnn")


def test_quickstart_mlp_plan_golden():
    cm = compile_model(quickstart_mlp(), backend="interpret")
    assert cm.stats["fused_qlinear"] == 3 and cm.stats["generic"] == 0
    _check_golden("quickstart_mlp.plan.txt", cm.plan.pretty() + "\n")


def test_per_channel_cnn_plan_golden():
    cm = compile_model(per_channel_cnn(), backend="interpret")
    # per-channel chains fuse — no scalar-only fallback to the generic mirror
    assert cm.stats["fused_qconv"] == 1 and cm.stats["fused_qlinear"] == 1
    assert cm.stats["generic"] == 1  # the Flatten between conv stack and head
    _check_golden("per_channel_cnn.plan.txt", cm.plan.pretty() + "\n")


def test_quickstart_mlp_template_plan_golden():
    """The batch-polymorphic *template* rendering: batch-open shape records
    (lead marks the symbolic dim; no m/bm) on every fused step."""
    cm = compile_model(quickstart_mlp(), backend="interpret", batch="dynamic")
    assert cm.stats["fused_qlinear"] == 3 and cm.stats["generic"] == 0
    _check_golden("quickstart_mlp.template.plan.txt", cm.plan.pretty() + "\n")


def test_specialized_plan_binds_bucket_in_rendering():
    """A bucket specialization of the template renders fully concrete —
    same slots/kernels, m/bm bound, batch stamped in the header."""
    cm = compile_model(quickstart_mlp(), backend="interpret", batch="dynamic")
    plan8, _ = cm.specialized(8)
    text = plan8.pretty()
    assert "batch=8" in text.splitlines()[0]
    assert "m=8" in text and "bm=32" in text
    assert "lead=" not in text and "dynamic_batch" not in text


def two_axis_mlp():
    """The tests/test_batch_polymorphic.py two-axis model, byte-for-byte."""
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(15)
    p0 = quant.quantize_linear_layer(
        rng.normal(size=(32, 48)).astype(np.float32) * 0.15,
        rng.normal(size=(48,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    p1 = quant.quantize_linear_layer(
        rng.normal(size=(48, 24)).astype(np.float32) * 0.2,
        rng.normal(size=(24,)).astype(np.float32) * 0.1, 0.1, 0.12,
    )
    gb = pqir.GraphBuilder("two_axis_mlp")
    x = gb.add_input("x", "int8", ("N", "S", 32))
    h = patterns.fc_layer(gb, x, p0, "fc0", two_mul=True, activation="Relu")
    y = patterns.fc_layer(gb, h, p1, "fc1", two_mul=True)
    gb.add_output(y, "int8", ("N", "S", 24))
    return gb.build()


def test_two_axis_template_plan_golden():
    """The multi-axis template rendering: named axes in the header, named
    lead dims in the axis-open shape records, names in the value typing."""
    cm = compile_model(two_axis_mlp(), backend="interpret", dynamic_axes={"N": None, "S": 32})
    assert cm.stats["fused_qlinear"] == 2 and cm.stats["generic"] == 0
    _check_golden("two_axis_mlp.template.plan.txt", cm.plan.pretty() + "\n")


def test_quickstart_mlp_provenance_golden():
    """``pretty(verbose=True)`` pins the provenance section: which passes
    fired (with counters), which fusion patterns matched which nodes, and
    every scenario-cell specialization with its bindings and chosen tiles.
    Deterministic by construction — provenance carries no wall times, and
    the trace id only appears when a tracer is installed (none here)."""
    cm = compile_model(quickstart_mlp(), backend="interpret", batch="dynamic")
    cm.specialized(1)
    cm.specialized(8)
    text = cm.plan.pretty(verbose=True)
    assert "provenance:" in text and "specializations: 2" in text
    assert "provenance:" not in cm.plan.pretty()  # default rendering unchanged
    _check_golden("quickstart_mlp.provenance.txt", text + "\n")


def test_quickstart_mlp_tuned_provenance_golden(tmp_path):
    """The tuned artifact trail, golden-pinned: a cell whose lattice collapses
    renders untagged (heuristic), a measured cell renders ``[tuned]``, and a
    second session warm-started from the persisted tile cache renders
    ``[cache]`` — all bit-reproducible because the timing oracle is the
    analytic cost model, not a wall clock."""
    from repro.backend import cost
    from repro.backend.autotune import Autotuner

    def cost_measure(step, shape, backend):
        return cost.qmatmul_tile_cost(
            shape["m"], shape["k"], shape["n"], shape["bm"], shape["bk"], shape["bn"]
        )

    cache = str(tmp_path / "tiles.json")
    t1 = Autotuner(budget=4, measure_fn=cost_measure, cache=cache)
    cm = compile_model(quickstart_mlp(), backend="interpret", batch="dynamic", autotune=t1)
    cm.specialized(1)  # mp=32 collapses the lattice: stays heuristic, untagged
    cm.specialized(64)  # bm ∈ {32, 64} per step: measured -> [tuned]
    assert t1.measurements == 6  # 3 fused steps x 2 candidates

    t2 = Autotuner(budget=4, measure_fn=cost_measure, cache=cache)
    cm2 = compile_model(quickstart_mlp(), backend="interpret", batch="dynamic", autotune=t2)
    cm2.specialized(64)  # warm start from the artifact -> [cache]
    assert t2.measurements == 0

    default = cm.plan.pretty()  # default rendering carries no source tags
    assert "[tuned]" not in default and "[cache]" not in default
    text = (
        cm.plan.pretty(verbose=True)
        + "\n--- second session, warm-started from the tile cache ---\n"
        + cm2.plan.pretty(verbose=True)
    )
    _check_golden("quickstart_mlp.tuned.provenance.txt", text + "\n")


def test_two_axis_specialization_renders_bindings():
    cm = compile_model(two_axis_mlp(), backend="interpret", dynamic_axes={"N": None, "S": 32})
    plan, _ = cm.specialized({"N": 4, "S": 32})
    head = plan.pretty().splitlines()[0]
    assert "batch=(N=4,S=32)" in head
    assert "m=128" in plan.pretty()  # flat M = 4 × 32


def quickstart_mlp_int4():
    """The quickstart model re-quantized onto the sub-8-bit weight lane:
    same seeds/spec, ``weight_bits=4`` (weights on [-8, 7], packed two
    nibbles per byte at plan time)."""
    rng = np.random.default_rng(0)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(64, 128)).astype(np.float32) * 0.2,
            rng.normal(size=(128, 128)).astype(np.float32) * 0.15,
            rng.normal(size=(128, 10)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(128,)).astype(np.float32) * 0.1,
            rng.normal(size=(10,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", "Relu", None],
    )
    calib = rng.normal(size=(512, 64)).astype(np.float32)
    return quantize_mlp(
        spec, calib, observer="percentile", name="quickstart_mlp_int4",
        weight_bits=4,
    )


def test_quickstart_mlp_int4_plan_golden():
    """The w4 plan rendering: every fused step carries ``bits=4`` and its
    packed uint8 weight template (kp/2 rows)."""
    cm = compile_model(quickstart_mlp_int4(), backend="interpret")
    assert cm.stats["fused_qlinear"] == 3 and cm.stats["generic"] == 0
    text = cm.plan.pretty()
    assert text.count("bits=4") == 3
    _check_golden("quickstart_mlp_int4.plan.txt", text + "\n")


def test_quickstart_mlp_int4_provenance_golden():
    """The w4 provenance rendering: every specialized cell's tile record
    carries the ``w4/a8`` precision tag."""
    cm = compile_model(quickstart_mlp_int4(), backend="interpret", batch="dynamic")
    cm.specialized(1)
    cm.specialized(8)
    text = cm.plan.pretty(verbose=True)
    assert "w4/a8" in text and "specializations: 2" in text
    _check_golden("quickstart_mlp_int4.provenance.txt", text + "\n")
