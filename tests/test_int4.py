"""Sub-8-bit weight lane: packed-int4 vs unpacked-int4 vs reference.

The lane's correctness story is a chain of bit-exact equalities, pinned here
end to end:

* ``pack_int4``/``unpack_int4`` round-trip (and reject malformed inputs);
* the *unpacked* int4 reference path (int8 storage, values in [-8, 7]) is
  the oracle — the packed Pallas kernel and the plan-time packed templates
  must reproduce it exactly, across scalar and per-channel rescales, both
  rescale decompositions, ragged shapes, and every backend;
* the ``weight_bits`` attr survives the optimization passes (the gates
  rewrite Mul/Add/DQL→QL chains, never the core integer matmul);
* a w4 model round-trips through the AOT artifact (packed uint8 sidecar,
  zero re-lowering, pre-seeded plan cache) and renders its precision
  (``bits=4`` in the plan, ``w4/a8`` in the provenance cell records).
"""
import numpy as np
import pytest

from repro.core import pqir
from repro.core.compile import compile_model
from repro.core.patterns import fc_layer, fc_layer_gemm
from repro.core.quant import quantize_linear_layer
from repro.core.runtime import ReferenceRuntime
from repro.kernels import ops as kops
from repro.kernels.pack import pack_int4, unpack_int4


def _int4_fc_model(rng, k=48, n=24, *, per_channel=False, gemm=False,
                   activation="Relu", two_mul=True, name="int4_fc"):
    w = rng.normal(size=(k, n)).astype(np.float32) * 0.2
    b = rng.normal(size=(n,)).astype(np.float32) * 0.1
    p = quantize_linear_layer(w, b, 0.05, 0.08, bits=4, per_channel=per_channel)
    gb = pqir.GraphBuilder(name)
    x = gb.add_input("x", "int8", (None, k))
    if gemm:
        y = fc_layer_gemm(gb, x, p, "fc0", activation=activation)
    else:
        y = fc_layer(gb, x, p, "fc0", two_mul=two_mul, activation=activation)
    gb.add_output(y, "int8", (None, n))
    return gb.build(), p


class TestPackInt4:
    def test_round_trip_all_values(self):
        rng = np.random.default_rng(0)
        w = rng.integers(-8, 8, (64, 12)).astype(np.int8)
        packed = pack_int4(w)
        assert packed.dtype == np.uint8 and packed.shape == (32, 12)
        np.testing.assert_array_equal(unpack_int4(packed), w)

    def test_trim_to_odd_k(self):
        rng = np.random.default_rng(1)
        w = rng.integers(-8, 8, (10, 4)).astype(np.int8)
        np.testing.assert_array_equal(unpack_int4(pack_int4(w), k=7), w[:7])

    def test_every_nibble_pair(self):
        """Exhaustive over the 16x16 value pairs: sign extension is exact."""
        lo, hi = np.meshgrid(np.arange(-8, 8), np.arange(-8, 8))
        w = np.stack([lo.ravel(), hi.ravel()]).astype(np.int8)  # (2, 256)
        np.testing.assert_array_equal(unpack_int4(pack_int4(w)), w)

    def test_rejects_malformed(self):
        w = np.zeros((4, 4), np.int8)
        with pytest.raises(ValueError, match="even"):
            pack_int4(w[:3])
        with pytest.raises(ValueError, match="int8"):
            pack_int4(w.astype(np.int16))
        with pytest.raises(ValueError, match="2-D"):
            pack_int4(w[0])
        with pytest.raises(ValueError, match=r"\[-8, 7\]"):
            pack_int4(np.full((2, 2), 8, np.int8))
        with pytest.raises(ValueError, match="uint8"):
            unpack_int4(w)
        with pytest.raises(ValueError, match="k="):
            unpack_int4(pack_int4(w), k=9)


class TestQuantizeInt4:
    def test_weights_land_on_int4_range(self):
        rng = np.random.default_rng(2)
        w = rng.normal(size=(32, 16)).astype(np.float32)
        for per_channel in (False, True):
            p = quantize_linear_layer(w, None, 0.05, 0.1, bits=4,
                                      per_channel=per_channel)
            assert p.bits == 4
            assert p.weight_q.dtype == np.int8  # int4 is int8-stored
            assert p.weight_q.min() >= -8 and p.weight_q.max() <= 7
            # the scale is chosen against qmax=7, so the range is used
            assert p.weight_q.max() == 7 or p.weight_q.min() == -8

    def test_rejects_unsupported_bits(self):
        w = np.zeros((4, 4), np.float32)
        with pytest.raises(ValueError, match="bitwidth"):
            quantize_linear_layer(w, None, 0.05, 0.1, bits=3)


class TestDifferentialSweep:
    """Packed plan == unpacked reference, across the whole config lattice."""

    @pytest.mark.parametrize("per_channel", [False, True])
    @pytest.mark.parametrize("two_mul", [False, True])
    @pytest.mark.parametrize("backend", ["ref", "interpret"])
    def test_packed_matches_reference(self, per_channel, two_mul, backend):
        rng = np.random.default_rng(7)
        model, _ = _int4_fc_model(
            rng, per_channel=per_channel, two_mul=two_mul,
            name=f"int4_{backend}_{per_channel}_{two_mul}",
        )
        xq = rng.integers(-128, 128, (16, 48)).astype(np.int8)
        want = ReferenceRuntime(model).run({"x": xq})
        for batch in ("static", "dynamic"):
            cm = compile_model(model, backend=backend, batch=batch)
            assert cm.stats["generic"] == 0
            got = cm.run({"x": xq})
            for key in want:
                np.testing.assert_array_equal(
                    np.asarray(got[key]), want[key],
                    err_msg=f"{backend}/{batch}",
                )

    def test_kernel_level_packed_vs_unpacked(self):
        """qmatmul_packed == qmatmul on the same int4-valued operands, in
        Pallas interpret mode, over ragged tile-boundary shapes."""
        from repro.kernels import qmatmul as qmm

        rng = np.random.default_rng(11)
        for m, k, n in [(8, 128, 128), (32, 256, 128), (16, 384, 256)]:
            x = rng.integers(-128, 128, (m, k)).astype(np.int8)
            w = rng.integers(-8, 8, (k, n)).astype(np.int8)
            b = rng.integers(-2000, 2000, (1, n)).astype(np.int32)
            qs = np.full((1, n), 2.0 ** -9, np.float32)
            qsh = np.full((1, n), 2.0 ** -2, np.float32)
            base = qmm.qmatmul(x, w, b, qs, qsh, relu=True,
                               bm=8, bk=128, bn=128, interpret=True)
            packed = qmm.qmatmul_packed(x, pack_int4(w), b, qs, qsh, relu=True,
                                        bm=8, bk=128, bn=128, interpret=True)
            np.testing.assert_array_equal(np.asarray(packed), np.asarray(base))


class TestPassGates:
    def test_weight_bits_attr_survives_optimization(self):
        """qdq_cancel / mul_fold / add_fold rewrite the rescale chains around
        the core op; the codified bitwidth must ride through untouched."""
        from repro.passes import optimize

        rng = np.random.default_rng(13)
        model, _ = _int4_fc_model(rng, name="int4_passes")
        opt, report = optimize(model)
        cores = [nd for nd in opt.graph.nodes if nd.op_type == "MatMulInteger"]
        assert len(cores) == 1
        assert int(cores[0].attrs.get("weight_bits", 8)) == 4
        # and the passes did actually fire on the surrounding chain
        assert report.nodes_after < report.nodes_before

    def test_mixed_int4_int8_layers_coexist(self):
        """A 2-layer stack with one w4 and one w8 layer: each core op keeps
        its own precision and the whole model stays bit-exact."""
        rng = np.random.default_rng(17)
        w1 = rng.normal(size=(32, 24)).astype(np.float32) * 0.2
        b1 = rng.normal(size=(24,)).astype(np.float32) * 0.1
        w2 = rng.normal(size=(24, 8)).astype(np.float32) * 0.2
        b2 = rng.normal(size=(8,)).astype(np.float32) * 0.1
        p1 = quantize_linear_layer(w1, b1, 0.05, 0.08, bits=4)
        p2 = quantize_linear_layer(w2, b2, 0.08, 0.1, bits=8)
        gb = pqir.GraphBuilder("mixed_bits")
        x = gb.add_input("x", "int8", (None, 32))
        h = fc_layer(gb, x, p1, "fc0", activation="Relu")
        y = fc_layer(gb, h, p2, "fc1")
        gb.add_output(y, "int8", (None, 8))
        model = gb.build()
        xq = rng.integers(-128, 128, (8, 32)).astype(np.int8)
        want = ReferenceRuntime(model).run({"x": xq})
        for backend in ("ref", "interpret"):
            cm = compile_model(model, backend=backend, batch="dynamic")
            if backend == "interpret":
                # tiled templates carry the precision on the shape record
                shapes = [s.params["shape"] for s in cm.plan.steps
                          if isinstance(s.params.get("shape"), dict)]
                assert [sh.get("bits", 8) for sh in shapes] == [4, 8]
            got = cm.run({"x": xq})
            for key in want:
                np.testing.assert_array_equal(np.asarray(got[key]), want[key])


class TestPlanAndArtifact:
    def test_packed_template_halves_weight_bytes(self):
        rng = np.random.default_rng(19)
        model, p = _int4_fc_model(rng, k=64, n=32, name="int4_tmpl")
        cm = compile_model(model, backend="interpret", batch="dynamic")
        step = next(s for s in cm.plan.steps
                    if isinstance(s.params.get("shape"), dict))
        sh = step.params["shape"]
        assert sh["bits"] == 4
        wq = np.asarray(step.consts[0])
        assert wq.dtype == np.uint8 and wq.shape[0] * 2 == sh["kp"]
        assert "bits=4" in cm.plan.pretty()

    def test_w4_artifact_round_trip_zero_relowering(self, tmp_path):
        from repro.backend.artifact import load_artifact, save_artifact
        from repro.obs import trace as _trace

        rng = np.random.default_rng(23)
        model, _ = _int4_fc_model(rng, k=64, n=32, name="int4_art")
        cm = compile_model(model, backend="interpret", batch="dynamic")
        xq = rng.integers(-128, 128, (8, 64)).astype(np.int8)
        want = cm.run({"x": xq})
        path = str(tmp_path / "w4.json")
        save_artifact(cm, path)

        tracer = _trace.install()
        try:
            cm2 = load_artifact(path, warm=True)
            got = cm2.run({"x": xq})
        finally:
            _trace.uninstall()
        relower = len(tracer.spans("compile.fuse")) + len(tracer.spans("compile.lower"))
        assert relower == 0
        stats = cm2.plan_cache.stats
        assert stats["misses"] == 0 and stats["hits"] == 1
        for key in want:
            np.testing.assert_array_equal(np.asarray(got[key]), np.asarray(want[key]))
        # the packed uint8 weights round-tripped through the npz sidecar
        step = next(s for s in cm2.plan.steps
                    if isinstance(s.params.get("shape"), dict))
        assert np.asarray(step.consts[0]).dtype == np.uint8
        # hot-cell records carry the precision for plan_diff
        import json
        cells = json.load(open(path))["cells"]
        assert cells and all(
            rec.get("bits") == 4 for c in cells for rec in c["tiles"].values()
        )

    def test_provenance_cells_render_w4_a8(self):
        rng = np.random.default_rng(29)
        model, _ = _int4_fc_model(rng, name="int4_prov")
        cm = compile_model(model, backend="interpret", batch="dynamic")
        cm.run({"x": rng.integers(-128, 128, (4, 48)).astype(np.int8)})
        recs = [r for ev in cm.plan.provenance.specializations for _, r in ev.tiles]
        assert recs and all("w4/a8" in r for r in recs)

    def test_plan_diff_surfaces_bitwidth(self, tmp_path):
        """A w4 artifact and its w8 twin must never diff as identical."""
        import importlib.util
        import os

        from repro.backend.artifact import save_artifact

        spec = importlib.util.spec_from_file_location(
            "plan_diff",
            os.path.join(os.path.dirname(__file__), "..", "scripts", "plan_diff.py"),
        )
        plan_diff = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(plan_diff)

        rng = np.random.default_rng(31)
        paths = {}
        for bits, name in ((8, "w8"), (4, "w4")):
            rng_b = np.random.default_rng(31)
            w = rng_b.normal(size=(48, 24)).astype(np.float32) * 0.2
            b = rng_b.normal(size=(24,)).astype(np.float32) * 0.1
            p = quantize_linear_layer(w, b, 0.05, 0.08, bits=bits)
            gb = pqir.GraphBuilder("bits_twin")
            x = gb.add_input("x", "int8", (None, 48))
            y = fc_layer(gb, x, p, "fc0", activation="Relu")
            gb.add_output(y, "int8", (None, 24))
            cm = compile_model(gb.build(), backend="ref", batch="dynamic")
            cm.run({"x": rng.integers(-128, 128, (4, 48)).astype(np.int8)})
            paths[name] = str(tmp_path / f"{name}.json")
            save_artifact(cm, paths[name])
        # self-diff stays clean; w4-vs-w8 is structurally different
        assert plan_diff.main([paths["w4"], paths["w4"]]) == 0
        assert plan_diff.main([paths["w8"], paths["w4"]]) == 1
