"""Micro-batching server over a batch-polymorphic compiled artifact.

The server coalesces queued single-example requests into power-of-two batch
buckets served through the CompiledModel's PlanCache; every request must get
back exactly the rows a solo reference-runtime run would produce.
"""
import numpy as np
import pytest

from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import MLPSpec, quantize_mlp
from repro.serving import CompiledModelServer, CompiledServerConfig


def _artifact():
    rng = np.random.default_rng(21)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(16, 32)).astype(np.float32) * 0.2,
            rng.normal(size=(32, 8)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(32,)).astype(np.float32) * 0.1,
            rng.normal(size=(8,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(64, 16)).astype(np.float32)
    return quantize_mlp(spec, calib, name="served_mlp"), rng


def _examples(rng, n):
    return [rng.integers(-128, 128, (16,)).astype(np.int8) for _ in range(n)]


class TestCompiledModelServer:
    def test_coalesced_results_match_reference_per_request(self):
        model, rng = _artifact()
        rt = ReferenceRuntime(model)
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        reqs = [srv.submit(x) for x in _examples(rng, 11)]
        done = srv.run_until_drained()
        assert len(done) == 11 and all(r.done for r in reqs)
        out_name = cm.output_names[0]
        for r in reqs:
            solo = rt.run({"input_q": r.x[None, :]})[out_name][0]
            np.testing.assert_array_equal(r.outputs[out_name], solo, err_msg=f"req {r.uid}")
            assert r.t_done is not None and r.latency_s >= 0.0

    def test_bucketing_and_metrics(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        for x in _examples(rng, 11):
            srv.submit(x)
        srv.step()  # 8 requests → bucket 8
        srv.step()  # 3 requests → bucket 4 (one padded row)
        m = srv.metrics
        assert m["requests"] == 11 and m["completed"] == 11 and m["batches"] == 2
        assert m["bucket_batches"] == {8: 1, 4: 1}
        assert m["padded_rows"] == 1
        assert not srv.queue

    def test_steady_traffic_served_from_plan_cache(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        for _ in range(5):  # five full waves, one bucket → one specialization
            for x in _examples(rng, 8):
                srv.submit(x)
            srv.run_until_drained()
        summary = srv.summary()
        assert summary["plan_cache"]["misses"] == 1
        assert summary["plan_cache"]["hits"] == 4
        assert summary["plan_cache_hit_rate"] == pytest.approx(0.8)
        assert summary["latency_avg_ms"] is not None
        assert summary["latency_p95_ms"] >= 0.0

    def test_bad_examples_rejected_at_submit_not_mid_batch(self):
        """A malformed request must fail at admission — popping it into a
        coalesced batch would take its co-batched requests down with it."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm)
        with pytest.raises(ValueError, match="shape"):
            srv.submit(rng.integers(-128, 128, (32,)).astype(np.int8))  # wrong width
        with pytest.raises(ValueError, match="dtype"):
            srv.submit(rng.integers(-128, 128, (16,)).astype(np.int32))  # wrong dtype
        assert not srv.queue and srv.metrics["requests"] == 0
        good = srv.submit(rng.integers(-128, 128, (16,)).astype(np.int8))
        srv.run_until_drained()
        assert good.done

    def test_execution_failure_requeues_the_batch(self):
        """A backend/jit failure mid-step must not lose the coalesced
        requests — they go back to the head of the queue in order."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
        reqs = [srv.submit(x) for x in _examples(rng, 3)]
        boom = RuntimeError("device OOM")
        real_run = cm.run
        cm.run = lambda feeds: (_ for _ in ()).throw(boom)
        with pytest.raises(RuntimeError, match="device OOM"):
            srv.step()
        assert [r.uid for r in srv.queue] == [r.uid for r in reqs]  # order kept
        assert all(not r.done for r in reqs)
        cm.run = real_run
        srv.run_until_drained()
        assert all(r.done for r in reqs)
        assert srv.metrics["completed"] == srv.metrics["requests"] == 3

    def test_step_on_empty_queue_is_noop(self):
        model, _ = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm)
        assert srv.step() == []
        assert srv.run_until_drained() == []
        assert srv.metrics["batches"] == 0

    def test_rejects_static_artifacts(self):
        model, _ = _artifact()
        cm = compile_model(model, backend="ref")
        with pytest.raises(ValueError, match="dynamic"):
            CompiledModelServer(cm)

    def test_rejects_non_batch_carrying_inputs_at_construction(self):
        """Multi-input artifacts coalesce fine, but every input must carry
        the leading batch dim — a static side input can't be stacked per
        request; fail at construction, not with a KeyError mid-serving."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("two_in")
        a = gb.add_input("a", "float32", (None, 4))
        b = gb.add_input("b", "float32", (4, 4))
        y = gb.op("MatMul", [a, b])
        gb.add_output(y, "float32", (None, 4))
        cm = compile_model(gb.build(), backend="ref", batch="dynamic", fuse=False)
        with pytest.raises(ValueError, match="do not carry"):
            CompiledModelServer(cm)

    def test_summary_snapshots_do_not_alias_live_state(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        for x in _examples(rng, 2):
            srv.submit(x)
        srv.run_until_drained()
        s1 = srv.summary()
        for x in _examples(rng, 8):
            srv.submit(x)
        srv.run_until_drained()
        assert s1["bucket_batches"] == {2: 1}  # unchanged by later steps

    def test_rejects_bad_max_batch(self):
        with pytest.raises(ValueError, match="max_batch"):
            CompiledServerConfig(max_batch=0)
        with pytest.raises(ValueError, match="latency_window"):
            CompiledServerConfig(latency_window=0)
        with pytest.raises(ValueError, match="max_wait_ms"):
            CompiledServerConfig(max_wait_ms=-1.0)

    def test_latency_memory_is_bounded(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4, latency_window=6))
        for x in _examples(rng, 10):
            srv.submit(x)
        srv.run_until_drained()
        # log-bucketed histogram: every request is counted, memory is bounded
        # by occupied buckets rather than one float per request forever
        assert srv._latency.count == 10
        assert len(srv._latency.buckets) <= 10
        s = srv.summary()
        assert s["latency_avg_ms"] is not None
        assert s["latency_p50_ms"] <= s["latency_p99_ms"] <= s["latency_max_ms"]
        reg = srv.registry.snapshot()
        assert reg["serve.latency_ms"]["count"] == 10
        assert reg["serve.completed"] == 10

    def test_batch_independent_output_shared_across_requests(self):
        """Auxiliary outputs without a batch dim are handed to every request
        whole, not indexed per request."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("aux_served")
        x = gb.add_input("x", "float32", (None, 4))
        c1 = gb.add_initializer("c1", np.arange(5, dtype=np.float32))
        c2 = gb.add_initializer("c2", np.ones(5, np.float32))
        y = gb.op("Relu", [x])
        z = gb.op("Add", [c1, c2])
        gb.add_output(y, "float32", (None, 4))
        gb.add_output(z, "float32", (5,))
        cm = compile_model(gb.build(), backend="ref", batch="dynamic", optimize=False, fuse=False)
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        rng = np.random.default_rng(0)
        reqs = [srv.submit(rng.normal(size=(4,)).astype(np.float32)) for _ in range(7)]
        srv.run_until_drained()
        for r in reqs:
            assert r.outputs[y].shape == (4,)
            np.testing.assert_array_equal(r.outputs[z], np.arange(5, dtype=np.float32) + 1.0)


def _seq_artifact():
    """A ('N', 'S', 16) two-axis artifact: requests are variable-length
    sequences the server coalesces onto a (batch × seq) bucket grid."""
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(31)
    p = quant.quantize_linear_layer(
        rng.normal(size=(16, 8)).astype(np.float32) * 0.2,
        rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    gb = pqir.GraphBuilder("served_seq")
    x = gb.add_input("x", "int8", ("N", "S", 16))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", ("N", "S", 8))
    return gb.build(), rng


class TestSequenceGridServer:
    def test_variable_length_requests_bit_exact_per_request(self):
        """Ragged sequence lengths coalesce onto one (batch-bucket ×
        seq-bucket) cell per step; every request gets back exactly its own
        rows and true sequence length, bit-identical to a solo run."""
        model, rng = _seq_artifact()
        rt = ReferenceRuntime(model)
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 8})
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        assert srv.seq_axis == "S"
        lens = [3, 8, 1, 13, 5, 8, 21, 2, 9, 4, 7]
        reqs = [
            srv.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8)) for s in lens
        ]
        srv.run_until_drained()
        out_name = cm.output_names[0]
        for r, s in zip(reqs, lens):
            assert r.done and r.outputs[out_name].shape == (s, 8)
            solo = rt.run({"x": r.x[None, :, :]})[out_name][0]
            np.testing.assert_array_equal(r.outputs[out_name], solo, err_msg=f"req {r.uid}")

    def test_grid_metrics_and_one_specialization_per_cell(self):
        model, rng = _seq_artifact()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 8})
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
        for s in (3, 5, 7, 8):  # one step: batch 4 → bucket 4, max seq 8 → bucket 8
            srv.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
        srv.step()
        for s in (10, 12):  # second step: batch 2 → bucket 2, seq bucket 16
            srv.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
        srv.step()
        m = srv.metrics
        assert m["grid_batches"] == {(4, 8): 1, (2, 16): 1}
        assert m["bucket_batches"] == {4: 1, 2: 1}
        # first step: seq pads (8-3)+(8-5)+(8-7)+(8-8); second: (16-10)+(16-12)
        assert m["padded_tokens"] == (5 + 3 + 1 + 0) + (6 + 4)
        assert cm.cache_stats["misses"] == 2  # one specialization per grid cell
        # revisiting both cells adds no specialization
        for s in (3, 5, 7, 8):
            srv.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
        srv.step()
        assert cm.cache_stats["misses"] == 2

    def test_named_but_static_axis_rejected_at_construction(self):
        """A named symbolic dim the compile left static can be neither
        validated nor bucketed by the server — ragged extents along it would
        blow up a coalesced batch — so construction must refuse it."""
        model, _ = _seq_artifact()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None})  # S static
        with pytest.raises(ValueError, match="static"):
            CompiledModelServer(cm)

    def test_batch_assembly_failure_requeues(self):
        """Even if mismatched examples reach a step (e.g. via an unknown
        None dim), assembly failure re-queues instead of losing requests."""
        model, rng = _seq_artifact()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 8})
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
        a = srv.submit(rng.integers(-128, 128, (3, 16)).astype(np.int8))
        b = srv.submit(rng.integers(-128, 128, (5, 16)).astype(np.int8))
        srv._seq_pos = {}  # simulate a server that can't right-pad
        with pytest.raises(ValueError):
            srv.step()  # np.stack of ragged examples
        assert [r.uid for r in srv.queue] == [a.uid, b.uid]  # nothing lost

    def test_variable_seq_validated_at_submit(self):
        model, rng = _seq_artifact()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 8})
        srv = CompiledModelServer(cm)
        with pytest.raises(ValueError, match="shape"):
            srv.submit(rng.integers(-128, 128, (5, 32)).astype(np.int8))  # wrong width
        with pytest.raises(ValueError, match="empty extent"):
            srv.submit(rng.integers(-128, 128, (0, 16)).astype(np.int8))  # empty seq
        srv.submit(rng.integers(-128, 128, (5, 16)).astype(np.int8))  # seq len is free
        assert srv.metrics["requests"] == 1


class TestDeadlineAwareCoalescing:
    def test_partial_batch_held_until_window_expires(self):
        """With max_wait_ms set, a partial batch is deferred while young and
        launched (a window hit) once the oldest request ages out."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(
            cm, CompiledServerConfig(max_batch=8, max_wait_ms=30.0)
        )
        srv.submit(_examples(rng, 1)[0])
        assert srv.step() == []  # young partial batch: held open
        assert srv.metrics["batches"] == 0 and len(srv.queue) == 1
        import time as _time

        _time.sleep(0.04)  # let the oldest request age past the window
        done = srv.step()
        assert len(done) == 1 and srv.metrics["window_hits"] == 1
        assert srv.summary()["window_hits"] == 1

    def test_full_batch_launches_without_window_hit(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(
            cm, CompiledServerConfig(max_batch=4, max_wait_ms=10_000.0)
        )
        for x in _examples(rng, 4):
            srv.submit(x)
        done = srv.step()  # max_batch reached: no reason to wait
        assert len(done) == 4
        assert srv.metrics["window_hits"] == 0

    def test_run_until_drained_waits_out_the_window(self):
        """Draining with an admission window must terminate: the drain loop
        sleeps out the remainder instead of spinning forever."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(
            cm, CompiledServerConfig(max_batch=8, max_wait_ms=20.0)
        )
        reqs = [srv.submit(x) for x in _examples(rng, 3)]
        done = srv.run_until_drained()
        assert len(done) == 3 and all(r.done for r in reqs)
        assert srv.metrics["window_hits"] == 1

    def test_greedy_default_unchanged(self):
        """max_wait_ms=None keeps the PR 4 behavior: any queued requests
        launch immediately, and window hits stay at zero."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        srv.submit(_examples(rng, 1)[0])
        assert len(srv.step()) == 1
        assert srv.metrics["window_hits"] == 0


def _tunable_artifact():
    """A width-256 MLP on the interpret backend: kp = np = 256 admit a
    {128, 256} bk/bn lattice, so every cell has a real (4-candidate) search."""
    rng = np.random.default_rng(17)
    spec = MLPSpec(
        weights=[rng.normal(0, 0.4, (256, 256)).astype(np.float32) for _ in range(2)],
        biases=[rng.normal(0, 0.2, (256,)).astype(np.float32) for _ in range(2)],
        activations=["Relu", None],
    )
    calib = rng.normal(0, 1.0, (64, 256)).astype(np.float32)
    return quantize_mlp(spec, calib, name="tuned_served_mlp"), rng


def _cost_measure(step, shape, backend):
    """Deterministic timing oracle for background-tuning tests."""
    from repro.backend import cost

    return cost.qmatmul_tile_cost(
        shape["m"], shape["k"], shape["n"], shape["bm"], shape["bk"], shape["bn"]
    )


class TestBackgroundTuning:
    """Non-blocking autotuning: serve on heuristic tiles immediately, measure
    a bounded number of candidates between batches, swap the tuned executor
    into the PlanCache atomically when the cell's search completes."""

    def _server(self, per_step=2):
        from repro.backend.autotune import Autotuner

        model, rng = _tunable_artifact()
        tuner = Autotuner(budget=4, measure_fn=_cost_measure)
        cm = compile_model(model, backend="interpret", batch="dynamic", autotune=tuner)
        srv = CompiledModelServer(
            cm,
            CompiledServerConfig(max_batch=8, tune_candidates_per_step=per_step),
        )
        return model, rng, tuner, cm, srv

    def test_step_serves_before_tuning_completes(self):
        """The first step on a fresh cell must go out on heuristic tiles with
        at most tune_candidates_per_step measurements spent — never the full
        blocking search."""
        model, rng, tuner, cm, srv = self._server(per_step=2)
        # the server owns the search: a first-touch specialization inside
        # step() must not route through the tuner (that would block)
        assert cm.autotuner is None
        reqs = [srv.submit(rng.integers(-128, 128, (256,)).astype(np.int8)) for _ in range(8)]
        done = srv.step()
        assert len(done) == 8 and all(r.done for r in reqs)
        assert tuner.measurements == 2  # bounded budget, spent AFTER serving
        assert srv.tuning_pending == 6  # 2 steps x 4 candidates - 2 measured
        assert srv.metrics["tuned_swaps"] == 0
        # the plan serving the cell right now carries untagged heuristic tiles
        from repro.backend.plan import bindings_key

        plan, _ = cm.plan_cache.get(bindings_key({"N": 8}))
        shape = next(s.params["shape"] for s in plan.steps if "shape" in s.params)
        assert (shape["bm"], shape["bk"], shape["bn"]) == (32, 256, 128)

    def test_idle_steps_advance_and_swap_atomically(self):
        model, rng, tuner, cm, srv = self._server(per_step=2)
        rt = ReferenceRuntime(model)
        out_name = cm.output_names[0]
        xs = [rng.integers(-128, 128, (256,)).astype(np.int8) for _ in range(8)]
        for x in xs:
            srv.submit(x)
        before = srv.step()  # serve wave 1 on heuristic tiles (+2 candidates)
        for expected in (4, 6, 8):  # idle cycles keep spending the budget
            srv.step()
            assert tuner.measurements == expected
        assert srv.tuning_pending == 0
        assert srv.metrics["tuned_swaps"] == 1
        assert srv.registry.snapshot()["autotune.swaps"] == 1
        # the swapped-in plan is the tuned one, provenance-tagged
        from repro.backend.plan import bindings_key

        plan, _ = cm.plan_cache.get(bindings_key({"N": 8}))
        ev = plan.provenance.specializations[-1]
        assert ev.tiles and all("[tuned]" in rec for _, rec in ev.tiles)
        # and the swap changed tiles without changing a single output bit
        for x in xs:
            srv.submit(x)
        after = srv.run_until_drained()
        for rb, ra in zip(before, after):
            solo = rt.run({"input_q": ra.x[None, :]})[out_name][0]
            np.testing.assert_array_equal(ra.outputs[out_name], solo)
            np.testing.assert_array_equal(rb.outputs[out_name], ra.outputs[out_name])
        # the swap itself counted no extra specialization-by-miss
        assert srv.summary()["tuning_pending"] == 0

    def test_cell_enqueues_exactly_one_job(self):
        model, rng, tuner, cm, srv = self._server(per_step=1)
        for _ in range(3):  # three waves on the same bucket
            for _ in range(8):
                srv.submit(rng.integers(-128, 128, (256,)).astype(np.int8))
            srv.step()
        assert len(srv._tuned_cells) == 1
        assert len(srv._tune_jobs) == 1  # still the one (slowly draining) job
        assert tuner.measurements == 3  # one candidate per step, three steps

    def test_no_tuner_means_no_tuning_state(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm)
        srv.submit(_examples(rng, 1)[0])
        srv.step()
        assert srv.tuning_pending == 0 and srv.metrics["tuned_swaps"] == 0
        assert srv.summary()["tuning_pending"] == 0

    def test_rejects_bad_tune_budget(self):
        with pytest.raises(ValueError, match="tune_candidates_per_step"):
            CompiledServerConfig(tune_candidates_per_step=0)


class TestRetryAccounting:
    """A failed batch re-queues and retries — the retry must not double-count
    queue waits or leak request spans."""

    def _failing_once(self, cm):
        """cm.run that raises on the first call, then serves normally."""
        real_run = cm.run
        state = {"failed": False}

        def run(feeds):
            if not state["failed"]:
                state["failed"] = True
                raise RuntimeError("transient backend failure")
            return real_run(feeds)

        cm.run = run

    def test_queue_wait_observed_once_per_request(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
        for x in _examples(rng, 3):
            srv.submit(x)
        self._failing_once(cm)
        with pytest.raises(RuntimeError, match="transient"):
            srv.step()
        srv.run_until_drained()
        snap = srv.registry.snapshot()
        # 3 requests, each dequeued twice (failure + retry) but each counted
        # exactly once — at the dequeue that actually served it
        assert snap["serve.queue_wait_ms"]["count"] == 3
        assert snap["serve.latency_ms"]["count"] == 3
        assert srv.metrics["completed"] == 3

    def test_request_spans_balanced_after_retry(self):
        from repro.obs import trace as _trace

        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
        tracer = _trace.install()
        try:
            reqs = [srv.submit(x) for x in _examples(rng, 3)]
            self._failing_once(cm)
            with pytest.raises(RuntimeError, match="transient"):
                srv.step()
            srv.run_until_drained()
        finally:
            _trace.uninstall()
        # each request's async serve.request span opens once and closes once
        # — a failed attempt neither closes nor re-opens it
        begins = [r for r in tracer.records if r.kind == "async_b" and r.name == "serve.request"]
        ends = [r for r in tracer.records if r.kind == "async_e" and r.name == "serve.request"]
        assert sorted(r.aid for r in begins) == [r.uid for r in reqs]
        assert sorted(r.aid for r in ends) == [r.uid for r in reqs]


class TestUniformCacheMetrics:
    def test_plan_cache_hit_rate_is_the_lru_rate(self):
        """summary()['plan_cache_hit_rate'] is LruCache's own hit_rate — one
        accounting site for every cache in the system."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        for _ in range(4):
            for x in _examples(rng, 8):
                srv.submit(x)
            srv.run_until_drained()
        s = srv.summary()
        assert s["plan_cache"]["hit_rate"] == pytest.approx(0.75)
        assert s["plan_cache_hit_rate"] == s["plan_cache"]["hit_rate"]
        assert cm.cache_stats["hit_rate"] == pytest.approx(0.75)


def _two_input_model():
    """Two batch-carrying inputs, both also carrying the 'S' sequence axis:
    the server must stack both and right-pad both to the group's longest."""
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(41)
    pa = quant.quantize_linear_layer(
        rng.normal(size=(16, 8)).astype(np.float32) * 0.2,
        rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    pb = quant.quantize_linear_layer(
        rng.normal(size=(12, 8)).astype(np.float32) * 0.2,
        rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    gb = pqir.GraphBuilder("served_two_in")
    xa = gb.add_input("xa", "int8", ("N", "S", 16))
    xb = gb.add_input("xb", "int8", ("N", "S", 12))
    ya = patterns.fc_layer(gb, xa, pa, "fca", two_mul=True, activation="Relu")
    yb = patterns.fc_layer(gb, xb, pb, "fcb", two_mul=True, activation="Relu")
    gb.add_output(ya, "int8", ("N", "S", 8))
    gb.add_output(yb, "int8", ("N", "S", 8))
    return gb.build(), rng


class TestMultiInputCoalescing:
    def _server(self, max_batch=4):
        model, rng = _two_input_model()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 8})
        return model, rng, CompiledModelServer(cm, CompiledServerConfig(max_batch=max_batch))

    def _example(self, rng, s):
        return {
            "xa": rng.integers(-128, 128, (s, 16)).astype(np.int8),
            "xb": rng.integers(-128, 128, (s, 12)).astype(np.int8),
        }

    def test_multi_input_requests_bit_exact_per_request(self):
        model, rng, srv = self._server()
        rt = ReferenceRuntime(model)
        lens = [3, 7, 5, 7, 2, 9]
        reqs = [srv.submit(self._example(rng, s)) for s in lens]
        srv.run_until_drained()
        for r, s in zip(reqs, lens):
            assert r.done and r.seq_len == s
            solo = rt.run({k: v[None] for k, v in r.feeds.items()})
            for name, want in solo.items():
                np.testing.assert_array_equal(r.outputs[name], want[0], err_msg=name)

    def test_bare_ndarray_rejected_on_multi_input_artifact(self):
        _, rng, srv = self._server()
        with pytest.raises(ValueError, match="multi-input"):
            srv.submit(rng.integers(-128, 128, (4, 16)).astype(np.int8))

    def test_missing_or_unknown_inputs_rejected(self):
        _, rng, srv = self._server()
        ex = self._example(rng, 4)
        with pytest.raises(ValueError, match="exactly the model inputs"):
            srv.submit({"xa": ex["xa"]})
        with pytest.raises(ValueError, match="exactly the model inputs"):
            srv.submit({**ex, "stray": ex["xa"]})

    def test_inconsistent_axis_bindings_rejected_at_submit(self):
        """One request binding S=4 on one input and S=6 on the other must be
        rejected at admission, not mis-coalesced."""
        _, rng, srv = self._server()
        with pytest.raises(ValueError, match="inconsistent axis bindings"):
            srv.submit(
                {
                    "xa": rng.integers(-128, 128, (4, 16)).astype(np.int8),
                    "xb": rng.integers(-128, 128, (6, 12)).astype(np.int8),
                }
            )
        assert srv.metrics["requests"] == 0

    def test_single_input_sugar_still_works(self):
        model, rng = _seq_artifact()
        cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 8})
        srv = CompiledModelServer(cm)
        req = srv.submit(rng.integers(-128, 128, (5, 16)).astype(np.int8))
        srv.run_until_drained()
        assert req.done and req.x.shape == (5, 16)  # .x sugar on 1-input reqs
