"""Micro-batching server over a batch-polymorphic compiled artifact.

The server coalesces queued single-example requests into power-of-two batch
buckets served through the CompiledModel's PlanCache; every request must get
back exactly the rows a solo reference-runtime run would produce.
"""
import numpy as np
import pytest

from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import MLPSpec, quantize_mlp
from repro.serving import CompiledModelServer, CompiledServerConfig


def _artifact():
    rng = np.random.default_rng(21)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(16, 32)).astype(np.float32) * 0.2,
            rng.normal(size=(32, 8)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(32,)).astype(np.float32) * 0.1,
            rng.normal(size=(8,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(64, 16)).astype(np.float32)
    return quantize_mlp(spec, calib, name="served_mlp"), rng


def _examples(rng, n):
    return [rng.integers(-128, 128, (16,)).astype(np.int8) for _ in range(n)]


class TestCompiledModelServer:
    def test_coalesced_results_match_reference_per_request(self):
        model, rng = _artifact()
        rt = ReferenceRuntime(model)
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        reqs = [srv.submit(x) for x in _examples(rng, 11)]
        done = srv.run_until_drained()
        assert len(done) == 11 and all(r.done for r in reqs)
        out_name = cm.output_names[0]
        for r in reqs:
            solo = rt.run({"input_q": r.x[None, :]})[out_name][0]
            np.testing.assert_array_equal(r.outputs[out_name], solo, err_msg=f"req {r.uid}")
            assert r.t_done is not None and r.latency_s >= 0.0

    def test_bucketing_and_metrics(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        for x in _examples(rng, 11):
            srv.submit(x)
        srv.step()  # 8 requests → bucket 8
        srv.step()  # 3 requests → bucket 4 (one padded row)
        m = srv.metrics
        assert m["requests"] == 11 and m["completed"] == 11 and m["batches"] == 2
        assert m["bucket_batches"] == {8: 1, 4: 1}
        assert m["padded_rows"] == 1
        assert not srv.queue

    def test_steady_traffic_served_from_plan_cache(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        for _ in range(5):  # five full waves, one bucket → one specialization
            for x in _examples(rng, 8):
                srv.submit(x)
            srv.run_until_drained()
        summary = srv.summary()
        assert summary["plan_cache"]["misses"] == 1
        assert summary["plan_cache"]["hits"] == 4
        assert summary["plan_cache_hit_rate"] == pytest.approx(0.8)
        assert summary["latency_avg_ms"] is not None
        assert summary["latency_p95_ms"] >= 0.0

    def test_bad_examples_rejected_at_submit_not_mid_batch(self):
        """A malformed request must fail at admission — popping it into a
        coalesced batch would take its co-batched requests down with it."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm)
        with pytest.raises(ValueError, match="shape"):
            srv.submit(rng.integers(-128, 128, (32,)).astype(np.int8))  # wrong width
        with pytest.raises(ValueError, match="dtype"):
            srv.submit(rng.integers(-128, 128, (16,)).astype(np.int32))  # wrong dtype
        assert not srv.queue and srv.metrics["requests"] == 0
        good = srv.submit(rng.integers(-128, 128, (16,)).astype(np.int8))
        srv.run_until_drained()
        assert good.done

    def test_execution_failure_requeues_the_batch(self):
        """A backend/jit failure mid-step must not lose the coalesced
        requests — they go back to the head of the queue in order."""
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
        reqs = [srv.submit(x) for x in _examples(rng, 3)]
        boom = RuntimeError("device OOM")
        real_run = cm.run
        cm.run = lambda feeds: (_ for _ in ()).throw(boom)
        with pytest.raises(RuntimeError, match="device OOM"):
            srv.step()
        assert [r.uid for r in srv.queue] == [r.uid for r in reqs]  # order kept
        assert all(not r.done for r in reqs)
        cm.run = real_run
        srv.run_until_drained()
        assert all(r.done for r in reqs)
        assert srv.metrics["completed"] == srv.metrics["requests"] == 3

    def test_step_on_empty_queue_is_noop(self):
        model, _ = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm)
        assert srv.step() == []
        assert srv.run_until_drained() == []
        assert srv.metrics["batches"] == 0

    def test_rejects_static_artifacts(self):
        model, _ = _artifact()
        cm = compile_model(model, backend="ref")
        with pytest.raises(ValueError, match="dynamic"):
            CompiledModelServer(cm)

    def test_rejects_multi_input_artifacts_at_construction(self):
        """A second (even static) input can't be fed by the coalescing loop —
        fail at construction, not with a KeyError mid-serving."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("two_in")
        a = gb.add_input("a", "float32", (None, 4))
        b = gb.add_input("b", "float32", (4, 4))
        y = gb.op("MatMul", [a, b])
        gb.add_output(y, "float32", (None, 4))
        cm = compile_model(gb.build(), backend="ref", batch="dynamic", fuse=False)
        with pytest.raises(ValueError, match="exactly one input"):
            CompiledModelServer(cm)

    def test_summary_snapshots_do_not_alias_live_state(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        for x in _examples(rng, 2):
            srv.submit(x)
        srv.run_until_drained()
        s1 = srv.summary()
        for x in _examples(rng, 8):
            srv.submit(x)
        srv.run_until_drained()
        assert s1["bucket_batches"] == {2: 1}  # unchanged by later steps

    def test_rejects_bad_max_batch(self):
        with pytest.raises(ValueError, match="max_batch"):
            CompiledServerConfig(max_batch=0)
        with pytest.raises(ValueError, match="latency_window"):
            CompiledServerConfig(latency_window=0)

    def test_latency_window_is_bounded(self):
        model, rng = _artifact()
        cm = compile_model(model, backend="ref", batch="dynamic")
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4, latency_window=6))
        for x in _examples(rng, 10):
            srv.submit(x)
        srv.run_until_drained()
        assert len(srv._latencies) == 6  # sliding window, not one per request
        assert srv.summary()["latency_avg_ms"] is not None

    def test_batch_independent_output_shared_across_requests(self):
        """Auxiliary outputs without a batch dim are handed to every request
        whole, not indexed per request."""
        from repro.core import pqir

        gb = pqir.GraphBuilder("aux_served")
        x = gb.add_input("x", "float32", (None, 4))
        c1 = gb.add_initializer("c1", np.arange(5, dtype=np.float32))
        c2 = gb.add_initializer("c2", np.ones(5, np.float32))
        y = gb.op("Relu", [x])
        z = gb.op("Add", [c1, c2])
        gb.add_output(y, "float32", (None, 4))
        gb.add_output(z, "float32", (5,))
        cm = compile_model(gb.build(), backend="ref", batch="dynamic", optimize=False, fuse=False)
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=8))
        rng = np.random.default_rng(0)
        reqs = [srv.submit(rng.normal(size=(4,)).astype(np.float32)) for _ in range(7)]
        srv.run_until_drained()
        for r in reqs:
            assert r.outputs[y].shape == (4,)
            np.testing.assert_array_equal(r.outputs[z], np.arange(5, dtype=np.float32) + 1.0)
