"""Numerically stable sigmoid: no overflow at any input magnitude, and the
LUT fusion stays bit-exact against the reference runtime.

The naive ``1/(1+exp(-x))`` overflows ``exp`` for large-magnitude negative
inputs (dequantized int activations reach them easily).  The runtime's
``stable_sigmoid`` only ever exponentiates ``-|x|``, which cannot overflow;
``repro.core.compile._NP_ACT`` bakes the *same* function into the 256-entry
activation LUT, so the fused kernel and the per-element reference agree bit
for bit.
"""
import numpy as np
import pytest

from repro.core import patterns, pqir, quant
from repro.core.compile import _NP_ACT, compile_model
from repro.core.runtime import ReferenceRuntime, stable_sigmoid
from repro.kernels.qact_lut import build_lut


class TestStableSigmoid:
    def test_no_overflow_at_any_magnitude(self):
        x = np.array([-1e4, -500.0, -88.0, -20.0, 0.0, 20.0, 88.0, 500.0, 1e4],
                     np.float32)
        with np.errstate(over="raise"):
            y = stable_sigmoid(x)
        assert np.all((y >= 0.0) & (y <= 1.0))
        assert y[0] == 0.0 and y[-1] == 1.0  # saturates, never NaN/inf
        assert np.isfinite(y).all()

    def test_matches_naive_form_in_the_safe_range(self):
        x = np.linspace(-30, 30, 2001, dtype=np.float32)
        naive = (1.0 / (1.0 + np.exp(-x.astype(np.float64)))).astype(np.float32)
        np.testing.assert_allclose(stable_sigmoid(x), naive, rtol=0, atol=2e-7)

    def test_preserves_dtype(self):
        for dt in (np.float16, np.float32, np.float64):
            y = stable_sigmoid(np.array([-1000.0, 2.0], dt))
            assert y.dtype == dt
            assert np.isfinite(y.astype(np.float64)).all()

    def test_reference_runtime_sigmoid_op_is_stable(self):
        gb = pqir.GraphBuilder("sig")
        x = gb.add_input("x", "float32", (None, 4))
        y = gb.op("Sigmoid", [x])
        gb.add_output(y, "float32", (None, 4))
        rt = ReferenceRuntime(gb.build())
        feeds = {"x": np.array([[-4000.0, -100.0, 100.0, 4000.0]], np.float32)}
        with np.errstate(over="raise"):
            out = rt.run(feeds)[y]
        assert np.isfinite(out).all() and np.all((out >= 0.0) & (out <= 1.0))
        # sigmoid(-100) is a subnormal (~4e-44), not exactly zero
        np.testing.assert_allclose(out, [[0.0, 0.0, 1.0, 1.0]], rtol=0, atol=1e-40)


class TestLutBitExactness:
    def test_lut_table_pins_the_stable_form(self):
        """The compiler's activation table (_NP_ACT) must be stable_sigmoid
        itself — the LUT bakes whatever the reference executes, so the two
        stay bit-exact by construction."""
        assert _NP_ACT["Sigmoid"] is stable_sigmoid
        # and the baked table matches an independently computed stable
        # reference over all 256 codes, including scales that push the
        # dequantized domain far into saturation
        for in_scale in (8.0 / 127.0, 100.0, 1e4):
            lut = build_lut(stable_sigmoid, in_scale, 1.0 / 255.0, "uint8")
            codes = np.arange(-128, 128, dtype=np.int32).astype(np.float32)
            z = (codes * np.float32(in_scale)).astype(np.float64)
            e = np.exp(-np.abs(z))
            ref = np.where(z >= 0, 1.0 / (1.0 + e), e / (1.0 + e)).astype(np.float32)
            q = np.clip(np.rint(ref / np.float32(1.0 / 255.0)), 0, 255).astype(np.uint8)
            np.testing.assert_array_equal(lut, q)

    @pytest.mark.parametrize("backend", ["ref", "interpret"])
    def test_fused_sigmoid_lut_bit_exact_vs_reference(self, backend):
        """Fig-6 artifact (FC + fp16 sigmoid → uint8): the compiled LUT path
        must agree with the per-element reference on every one of the 256
        reachable int8 codes."""
        rng = np.random.default_rng(5)
        w = rng.normal(size=(32, 16)).astype(np.float32) * 0.3
        b = rng.normal(size=(16,)).astype(np.float32) * 0.1
        p = quant.quantize_linear_layer(
            w, b, 4.0 / 127.0, patterns.SIGMOID_INPUT_ABSMAX / 127.0
        )
        gb = pqir.GraphBuilder("figsig")
        xi = gb.add_input("input_q", "int8", (None, 32))
        y = patterns.fc_fp16_sigmoid(gb, xi, p, "fc0")
        gb.add_output(y, "uint8", (None, 16))
        model = gb.build()

        xq = rng.integers(-128, 128, (64, 32)).astype(np.int8)
        with np.errstate(over="raise"):
            want = ReferenceRuntime(model).run({"input_q": xq})[y]
        cm = compile_model(model, backend=backend)
        assert cm.stats["fused_lut"] == 1
        got = cm.run({"input_q": xq})[y]
        assert got.dtype == np.uint8
        np.testing.assert_array_equal(got, want)
