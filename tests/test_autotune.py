"""Measured per-cell tile autotuning (PR 7).

* Correctness floor: EVERY candidate tile configuration the search could
  ever pick must be bit-exact against the ref oracle — the tuner may only
  trade time, never numerics.
* Search-space properties: every candidate satisfies the kernel's alignment
  constraints and the template-padding divisibility contract.
* Sessions and the persisted co-design artifact: session memoization, disk
  warm start with zero measurements, provenance source tags, the
  ``compile_model(autotune=...)`` sugar, and the PersistentJsonStore
  mechanics underneath.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backend import cost
from repro.backend.autotune import (
    CACHE_SCHEMA,
    Autotuner,
    AutotuneCache,
    measure_median,
    seed_candidates,
    tile_candidates,
)
from repro.backend.lowering import specialize_plan
from repro.core.cache import PersistentJsonStore
from repro.core.compile import compile_model
from repro.core.toolchain import MLPSpec, quantize_mlp
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.qmatmul import MIN_LANE, MIN_SUBLANE, tile_aligned


def _mlp(layers=2, width=256, seed=4):
    rng = np.random.default_rng(seed)
    spec = MLPSpec(
        weights=[rng.normal(0, 0.4, (width, width)).astype(np.float32) for _ in range(layers)],
        biases=[rng.normal(0, 0.2, (width,)).astype(np.float32) for _ in range(layers)],
        activations=["Relu"] * (layers - 1) + [None],
    )
    calib = rng.normal(0, 1.0, (64, width)).astype(np.float32)
    return quantize_mlp(spec, calib, name="autotune_test")


def _cost_measure(step, shape, backend):
    """Deterministic timing oracle: the analytic intensity model itself."""
    return cost.qmatmul_tile_cost(
        shape["m"], shape["k"], shape["n"], shape["bm"], shape["bk"], shape["bn"]
    )


# ---------------------------------------------------------------------------
# search space properties
# ---------------------------------------------------------------------------


class TestSearchSpace:
    @pytest.mark.parametrize("m", [1, 7, 8, 32, 64, 200, 256])
    @pytest.mark.parametrize("kp,np_", [(128, 128), (256, 256), (512, 384), (256, 640)])
    def test_candidates_satisfy_all_constraints(self, m, kp, np_):
        cands = tile_candidates(m, kp, np_)
        assert cands, (m, kp, np_)
        mp = max(32, -(-m // 32) * 32)
        assert len(set(cands)) == len(cands)
        for bm, bk, bn in cands:
            assert tile_aligned(bm, bk, bn), (bm, bk, bn)
            assert bm % MIN_SUBLANE == 0 and bk % MIN_LANE == 0 and bn % MIN_LANE == 0
            assert kp % bk == 0, "bk must divide the template's padded kp"
            assert np_ % bn == 0, "bn must divide the template's padded np"
            assert bm <= mp, "a bm beyond the padded M only adds padding"
            assert cost.qmatmul_vmem_bytes(bm, bk, bn) <= cost.TPU_V5E.vmem_bytes

    def test_seeding_puts_heuristic_first_and_respects_budget(self):
        _, shape = kops.template_qmatmul_params(
            np.zeros((256, 256), np.int8), None, np.float32(0.1), np.float32(0.5)
        )
        bound = kops.bind_qmatmul_axes({**shape, "lead": ("N",)}, {"N": 64})
        heuristic = (bound["bm"], bound["bk"], bound["bn"])
        for budget in (1, 2, 3, 100):
            cands = seed_candidates(bound, budget=budget)
            assert cands[0] == heuristic
            assert len(cands) <= max(budget, 1)
            assert len(set(cands)) == len(cands)
        full = seed_candidates(bound, budget=100)
        assert set(full) == set(tile_candidates(64, bound["kp"], bound["np"]))
        # the non-heuristic tail is ranked by the analytic cost model
        costs = [
            cost.qmatmul_tile_cost(bound["m"], bound["k"], bound["n"], *c)
            for c in full[1:]
        ]
        assert costs == sorted(costs)


# ---------------------------------------------------------------------------
# every candidate is bit-exact (the differential sweep)
# ---------------------------------------------------------------------------


class TestEveryCandidateBitExact:
    @pytest.mark.parametrize("m,k,n", [(7, 200, 130), (64, 256, 256)])
    def test_all_candidate_tilings_match_ref(self, m, k, n):
        """The search may pick ANY lattice point; all of them must agree with
        the ref oracle bit-for-bit on ragged real-world shapes."""
        rng = np.random.default_rng(m * 1000 + n)
        x = rng.integers(-128, 128, (m, k)).astype(np.int8)
        w = rng.integers(-128, 128, (k, n)).astype(np.int8)
        b = rng.integers(-(2**18), 2**18, (n,)).astype(np.int32)
        qs, qsh = np.float32(417.0), np.float32(2.0**-21)
        consts, shape = kops.template_qmatmul_params(w, b, qs, qsh)
        bound = kops.bind_qmatmul_axes({**shape, "lead": (m,)}, None)
        expect = np.asarray(
            kref.qmatmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                             jnp.float32(qs), jnp.float32(qsh), relu=True)
        )
        cands = tile_candidates(m, bound["kp"], bound["np"])
        assert len(cands) >= 2, "sweep must cover a non-trivial lattice"
        for bm, bk, bn in cands:
            tiled = kops.with_tiles(bound, bm=bm, bk=bk, bn=bn)
            got = kops.quantized_matmul_planned(
                jnp.asarray(x), *consts, tiled,
                out_dtype=jnp.int8, relu=True, two_mul=True, interpret=True,
            )
            np.testing.assert_array_equal(np.asarray(got), expect, err_msg=str((bm, bk, bn)))


class TestWithTiles:
    def setup_method(self):
        _, shape = kops.template_qmatmul_params(
            np.zeros((256, 256), np.int8), None, np.float32(0.1), np.float32(0.5)
        )
        self.bound = kops.bind_qmatmul_axes({**shape, "lead": (8,)}, None)

    def test_legal_override(self):
        out = kops.with_tiles(self.bound, bm=64, bk=128, bn=128)
        assert (out["bm"], out["bk"], out["bn"]) == (64, 128, 128)
        assert self.bound["bm"] != 64 or True  # original untouched
        assert out is not self.bound

    @pytest.mark.parametrize(
        "kw",
        [{"bm": 48}, {"bm": 0}, {"bm": -32}, {"bk": 192}, {"bk": 64}, {"bn": 96}],
    )
    def test_misaligned_tiles_rejected(self, kw):
        with pytest.raises(ValueError):
            kops.with_tiles(self.bound, **kw)

    def test_non_dividing_tiles_rejected(self):
        # kp = np = 256 here: 512 is aligned but does not divide the padding
        with pytest.raises(ValueError, match="does not divide"):
            kops.with_tiles(self.bound, bk=512)
        with pytest.raises(ValueError, match="does not divide"):
            kops.with_tiles(self.bound, bn=512)


# ---------------------------------------------------------------------------
# stable timing helper
# ---------------------------------------------------------------------------


class TestMeasureMedian:
    def test_call_count_and_median(self, monkeypatch):
        from repro.backend import autotune as at

        # fake clock: (t0, t1) pairs for 3 samples of 10 / 20 / 1 ms
        ticks = iter([0.0, 0.010, 0.010, 0.030, 0.030, 0.031])
        monkeypatch.setattr(at.time, "perf_counter", lambda: next(ticks))
        calls = []
        got = measure_median(lambda: calls.append(1), repeat=3, warmup=2)
        assert len(calls) == 5  # warmup runs happen before the clock is read
        assert got == pytest.approx(0.010)  # median, not mean (noise-robust)

    def test_even_repeat_averages_middle_pair(self, monkeypatch):
        from repro.backend import autotune as at

        ticks = iter([0.0, 0.004, 0.004, 0.012, 0.012, 0.013, 0.013, 0.033])
        monkeypatch.setattr(at.time, "perf_counter", lambda: next(ticks))
        got = measure_median(lambda: None, repeat=4, warmup=0)
        assert got == pytest.approx(0.5 * (0.004 + 0.008))

    def test_repeat_must_be_positive(self):
        with pytest.raises(ValueError, match="repeat"):
            measure_median(lambda: None, repeat=0)


# ---------------------------------------------------------------------------
# the tuner: sessions, provenance tags, persistence
# ---------------------------------------------------------------------------


class TestAutotunerSessions:
    def test_measured_search_tags_provenance_and_memoizes(self):
        tuner = Autotuner(budget=4, measure_fn=_cost_measure)
        cm = compile_model(_mlp(), backend="interpret", batch="dynamic", autotune=tuner)
        plan, _ = cm.specialized(64)
        ev = plan.provenance.specializations[-1]
        assert ev.tiles and all("[tuned]" in rec for _, rec in ev.tiles)
        assert tuner.measurements == 8  # 2 fused steps x budget 4
        # session memoization: re-specializing the same cell measures nothing
        specialize_plan(cm.plan, 64, tuner=tuner)
        assert tuner.measurements == 8
        # a different cell is a different search
        specialize_plan(cm.plan, 8, tuner=tuner)
        assert tuner.measurements > 8

    def test_collapsed_lattice_stays_heuristic(self):
        # width 128: kp = np = 128 admit one bk/bn; N=8 pads to mp=32 -> one bm
        tuner = Autotuner(budget=8, measure_fn=_cost_measure)
        cm = compile_model(
            _mlp(width=128), backend="interpret", batch="dynamic", autotune=tuner
        )
        plan, _ = cm.specialized(8)
        ev = plan.provenance.specializations[-1]
        assert all("[" not in rec for _, rec in ev.tiles)  # untagged = heuristic
        assert tuner.measurements == 0

    def test_budget_one_never_measures(self):
        tuner = Autotuner(budget=1, measure_fn=_cost_measure)
        cm = compile_model(_mlp(), backend="interpret", batch="dynamic", autotune=tuner)
        plan, _ = cm.specialized(64)
        assert tuner.measurements == 0
        ev = plan.provenance.specializations[-1]
        assert all("[" not in rec for _, rec in ev.tiles)

    def test_ref_backend_is_not_tunable(self):
        tuner = Autotuner(budget=8, measure_fn=_cost_measure)
        cm = compile_model(_mlp(), backend="ref", batch="dynamic", autotune=tuner)
        cm.specialized(64)
        assert tuner.measurements == 0

    def test_invalid_budget_rejected(self):
        with pytest.raises(ValueError, match="budget"):
            Autotuner(budget=0)

    def test_tuned_plan_is_bitexact_vs_untuned(self):
        model = _mlp()
        tuner = Autotuner(budget=4, measure_fn=_cost_measure)
        cm_t = compile_model(model, backend="interpret", batch="dynamic", autotune=tuner)
        cm_h = compile_model(model, backend="interpret", batch="dynamic")
        rng = np.random.default_rng(0)
        feeds = {"input_q": rng.integers(-128, 128, (64, 256)).astype(np.int8)}
        got = cm_t.run(feeds)
        expect = cm_h.run(feeds)
        for k in expect:
            np.testing.assert_array_equal(got[k], expect[k])


class TestPersistence:
    def test_disk_cache_warm_start_measures_nothing(self, tmp_path):
        path = str(tmp_path / "tiles.json")
        model = _mlp()
        t1 = Autotuner(budget=4, measure_fn=_cost_measure, cache=path)
        cm1 = compile_model(model, backend="interpret", batch="dynamic", autotune=t1)
        cm1.specialized(64)
        assert t1.measurements == 8
        assert len(t1.cache) == 2  # one entry per fused step

        t2 = Autotuner(budget=4, measure_fn=_cost_measure, cache=path)
        cm2 = compile_model(model, backend="interpret", batch="dynamic", autotune=t2)
        plan, _ = cm2.specialized(64)
        assert t2.measurements == 0
        ev = plan.provenance.specializations[-1]
        assert ev.tiles and all("[cache]" in rec for _, rec in ev.tiles)
        # warm-start winners are the measured winners
        e1 = {k: (v["bm"], v["bk"], v["bn"]) for k, v in t1.cache.store.entries.items()}
        e2 = {k: (v["bm"], v["bk"], v["bn"]) for k, v in t2.cache.store.entries.items()}
        assert e1 == e2

    def test_cache_entry_carries_measurement_evidence(self, tmp_path):
        path = str(tmp_path / "tiles.json")
        tuner = Autotuner(budget=4, measure_fn=_cost_measure, cache=path)
        cm = compile_model(_mlp(layers=1), backend="interpret", batch="dynamic", autotune=tuner)
        cm.specialized(64)
        (key, entry), = tuner.cache.store.entries.items()
        step, backend, cell, shp = key.split("|")
        assert backend == "interpret" and cell == "N=64"
        assert shp == "m=64,k=256,n=256,kp=256,np=256"
        assert entry["measured"] == 4 == len(entry["candidates_us"])
        assert entry["best_us"] <= entry["heuristic_us"]
        assert entry["best_us"] == min(entry["candidates_us"].values())

    def test_compile_model_autotune_path_sugar(self, tmp_path):
        path = str(tmp_path / "tiles.json")
        cm = compile_model(_mlp(), backend="interpret", batch="dynamic", autotune=path)
        assert isinstance(cm.autotuner, Autotuner)
        assert cm.autotuner.cache is not None and cm.autotuner.cache.path == path

    def test_compile_model_autotune_true_sugar(self):
        cm = compile_model(_mlp(), backend="interpret", batch="dynamic", autotune=True)
        assert isinstance(cm.autotuner, Autotuner)
        assert cm.autotuner.cache is None

    def test_compile_model_autotune_duck_typed_instance(self):
        class FakeTuner:
            def tune_step(self, step, shape, *, backend, bindings):
                return shape, "heuristic"

        fake = FakeTuner()
        cm = compile_model(_mlp(), backend="interpret", batch="dynamic", autotune=fake)
        assert cm.autotuner is fake


class TestPersistentJsonStore:
    def test_roundtrip_and_reload(self, tmp_path):
        path = str(tmp_path / "store.json")
        s = PersistentJsonStore(path, schema="test-v1")
        assert len(s) == 0
        s.put("a", {"x": 1})
        assert "a" in s and s.get("a") == {"x": 1}
        data = json.loads(open(path).read())
        assert data["schema"] == "test-v1"
        s2 = PersistentJsonStore(path, schema="test-v1")
        assert s2.get("a") == {"x": 1}

    def test_schema_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "store.json")
        PersistentJsonStore(path, schema="test-v1").put("a", 1)
        with pytest.raises(ValueError, match="schema"):
            PersistentJsonStore(path, schema="test-v2")
        with pytest.raises(ValueError, match="schema"):
            AutotuneCache(path)  # the tile cache checks its own tag too

    def test_missing_file_is_empty_store(self, tmp_path):
        s = PersistentJsonStore(str(tmp_path / "never_written.json"), schema="x")
        assert len(s) == 0 and s.get("a") is None
        assert not os.path.exists(s.path)  # load never creates the file

    def test_save_leaves_no_temp_files(self, tmp_path):
        path = str(tmp_path / "store.json")
        s = PersistentJsonStore(path, schema=CACHE_SCHEMA)
        for i in range(3):
            s.put(f"k{i}", i)
        assert sorted(p.name for p in tmp_path.iterdir()) == ["store.json"]

    def test_deterministic_rendering(self, tmp_path):
        a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
        sa = PersistentJsonStore(a, schema="x")
        sb = PersistentJsonStore(b, schema="x")
        sa.put("k1", 1)
        sa.put("k2", 2)
        sb.put("k2", 2)  # insertion order must not leak into the artifact
        sb.put("k1", 1)
        assert open(a).read() == open(b).read()


# ---------------------------------------------------------------------------
# bench-compare guard (satellite: clean no-overlap behavior)
# ---------------------------------------------------------------------------


class TestBenchCompareGuards:
    def _payload(self, path, names):
        payload = {
            "schema": "repro-bench-v1",
            "rows": [{"name": n, "us_per_call": 10.0, "derived": ""} for n in names],
        }
        path.write_text(json.dumps(payload))
        return path

    def test_disjoint_row_sets_exit_cleanly(self, tmp_path, capsys):
        from benchmarks import compare as bc

        cur = self._payload(tmp_path / "cur.json", ["new_row_a", "new_row_b"])
        base = self._payload(tmp_path / "base.json", ["old_row"])
        rc = bc.main([str(cur), "--baseline", str(base), "--strict"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "no shared rows" in out and "nothing to compare" in out

    def test_malformed_row_is_a_clear_error(self, tmp_path):
        from benchmarks import compare as bc

        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"schema": "repro-bench-v1", "rows": [{"name": "x"}]}))
        ok = self._payload(tmp_path / "ok.json", ["x"])
        with pytest.raises(SystemExit, match="malformed"):
            bc.main([str(bad), "--baseline", str(ok)])

    def test_overlapping_rows_still_compare(self, tmp_path, capsys):
        from benchmarks import compare as bc

        cur = self._payload(tmp_path / "cur.json", ["shared", "only_new"])
        base = self._payload(tmp_path / "base.json", ["shared", "only_old"])
        assert bc.main([str(cur), "--baseline", str(base)]) == 0
        out = capsys.readouterr().out
        assert "1 shared rows within tolerance" in out
