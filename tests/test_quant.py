"""E1: §3/§3.1 quantization math, incl. the paper's exact numeric anchors."""
import numpy as np
import pytest

from repro.core import quant


class TestDecomposeMultiplier:
    def test_paper_anchor_one_third(self):
        """Paper §3.1: M = 1/3 → Quant_scale 11184810, shift 2^-25 (floor)."""
        r = quant.decompose_multiplier(1.0 / 3.0)
        assert (r.quant_scale, r.shift) == (11184810, 25)
        assert r.quant_shift == 2.0**-25

    def test_paper_anchor_quarter_reduced(self):
        """Paper §3.1: M = 0.25 → Quant_scale 1, shift 2^-2 (reduced form)."""
        r = quant.decompose_multiplier(0.25, reduce=True)
        assert (r.quant_scale, r.shift) == (1, 2)
        assert r.realized == 0.25

    def test_paper_anchor_max_exact_float_int(self):
        """Paper §3.1: largest exactly-represented integer in FLOAT is 2^24."""
        assert quant.MAX_EXACT_FLOAT_INT == 16_777_216
        # Every decomposition keeps quant_scale < 2^24 ⇒ exact as FLOAT.
        for m in [1e-6, 0.1, 1 / 3, 0.999, 1.0, 1.5, 17.3, 12345.678]:
            r = quant.decompose_multiplier(m)
            assert 1 <= r.quant_scale < 2**24
            assert np.float32(r.quant_scale) == r.quant_scale  # exact in f32

    def test_unreduced_quarter_same_value(self):
        r = quant.decompose_multiplier(0.25)
        assert r.realized == 0.25  # unreduced (8388608, 25) is the same value

    def test_precision_bound(self):
        """Realized multiplier is within one ULP of quant_scale (2^-shift)."""
        rng = np.random.default_rng(0)
        for m in rng.uniform(1e-5, 100.0, size=200):
            r = quant.decompose_multiplier(float(m))
            assert abs(r.realized - m) <= 2.0 ** (-r.shift) + 1e-12
            assert abs(r.realized - m) / m < 2.0**-23  # <1 part in 2^23

    def test_reduce_is_lossless(self):
        for m in [0.25, 1 / 3, 0.75, 0.5, 2.0, 0.0625]:
            a = quant.decompose_multiplier(m, reduce=False)
            b = quant.decompose_multiplier(m, reduce=True)
            assert a.realized == b.realized

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            quant.decompose_multiplier(0.0)
        with pytest.raises(ValueError):
            quant.decompose_multiplier(-1.0)


class TestQuantizeRoundtrip:
    def test_symmetric_eq1(self):
        """Eq (1): X = scale_X * X_q."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(64, 32)).astype(np.float32)
        s = quant.choose_scale(float(np.abs(x).max()), "int8")
        xq = quant.quantize(x, s, "int8")
        assert xq.dtype == np.int8
        err = np.abs(quant.dequantize(xq, s) - x)
        assert float(err.max()) <= s / 2 + 1e-7  # half-step rounding bound

    def test_round_half_even(self):
        x = np.array([0.5, 1.5, 2.5, -0.5, -1.5], dtype=np.float32)
        np.testing.assert_array_equal(quant.round_half_even(x), [0.0, 2.0, 2.0, -0.0, -2.0])

    def test_saturation(self):
        x = np.array([-1000.0, 1000.0], dtype=np.float32)
        q = quant.quantize(x, 1.0, "int8")
        np.testing.assert_array_equal(q, [-128, 127])
        q = quant.quantize(x, 1.0, "uint8")
        np.testing.assert_array_equal(q, [0, 255])

    def test_uint8_scale_maps_full_range(self):
        s = quant.choose_scale(10.2, "uint8")
        assert np.isclose(s * 255.0, 10.2)


class TestFCReference:
    def test_eq2_through_eq6_roundtrip(self):
        """Quantized FC ≈ float FC within rescale quantization error."""
        rng = np.random.default_rng(2)
        x = rng.normal(size=(16, 64)).astype(np.float32)
        w = rng.normal(size=(64, 32)).astype(np.float32) * 0.1
        b = rng.normal(size=(32,)).astype(np.float32) * 0.5
        y = x @ w + b
        scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
        scale_y = quant.choose_scale(float(np.abs(y).max()), "int8")
        p = quant.quantize_linear_layer(w, b, scale_x, scale_y)
        xq = quant.quantize(x, scale_x, "int8")
        yq = quant.fc_reference(xq, p)
        y_hat = quant.dequantize(yq, scale_y)
        # int8-in/int8-out matmul: expect small relative error on y's scale
        rel = np.abs(y_hat - y).max() / np.abs(y).max()
        assert rel < 0.05, rel

    def test_two_mul_vs_one_mul_close(self):
        """The 2-Mul integer codification matches the 1-Mul float multiplier
        within 1 quantization step (they're different roundings of M)."""
        rng = np.random.default_rng(3)
        x = rng.normal(size=(8, 32)).astype(np.float32)
        w = rng.normal(size=(32, 16)).astype(np.float32) * 0.2
        scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
        p = quant.quantize_linear_layer(w, None, scale_x, 0.05)
        xq = quant.quantize(x, scale_x, "int8")
        y2 = quant.fc_reference(xq, p, two_mul=True).astype(np.int32)
        y1 = quant.fc_reference(xq, p, two_mul=False).astype(np.int32)
        assert np.abs(y2 - y1).max() <= 1

    def test_bias_scale_is_sw_times_sx(self):
        """Eq (6): B_q = B / (scale_W·scale_X)."""
        b = np.array([1.0, -2.0, 0.5], dtype=np.float32)
        bq = quant.quantize_bias(b, 0.1, 0.2)
        np.testing.assert_array_equal(bq, np.rint(b / 0.02).astype(np.int32))

    def test_per_channel_weights(self):
        rng = np.random.default_rng(4)
        w = rng.normal(size=(32, 16)).astype(np.float32)
        w[:, 3] *= 100.0  # one hot channel would wreck per-tensor scaling
        p = quant.quantize_linear_layer(w, None, 0.1, 0.5, per_channel=True)
        assert p.per_channel and p.scale_w.shape == (16,)
        w_hat = p.weight_q.astype(np.float32) * p.scale_w
        assert np.abs(w_hat - w).max() <= float(p.scale_w.max()) / 2 + 1e-6


class TestRescaleReference:
    def test_exact_shift_semantics(self):
        """Integer mul + right shift == multiply by qs*2^-N, exactly, for
        values small enough that f32 is exact."""
        acc = np.arange(-1000, 1000, dtype=np.int32)
        r = quant.decompose_multiplier(1 / 3)
        out = quant.apply_rescale_reference(acc, r, "int8")
        expect = np.clip(np.rint(acc.astype(np.float64) * r.quant_scale * 2.0**-r.shift), -128, 127)
        np.testing.assert_array_equal(out.astype(np.int64), expect.astype(np.int64))
